"""Fault-tolerant checkpointing: async, atomic, reshard-on-restore.

Design (scales to multi-host by construction, exercised single-host here):
  - Arrays are written as *logical* (fully-gathered) npz shards keyed by
    flattened pytree paths, with a JSON manifest (step, shapes, dtypes).  On a
    real cluster each host writes only the shards it owns
    (``jax.experimental.multihost_utils``); the manifest format is identical.
  - Writes go to ``<dir>/step_<n>.tmp`` then ``os.replace`` to
    ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint.
  - ``save_async`` snapshots to host memory synchronously (cheap) and does
    file IO on a worker thread so the train loop is not blocked.
  - ``restore`` accepts a *target sharding tree* — restoring onto a different
    mesh (elastic up/down-scale) just places the logical arrays with the new
    NamedShardings.
  - A retention window bounds disk use.
"""

from __future__ import annotations

import json
import os
import queue
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}/{k}") for k in sorted(template)}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}/#{i}") for i, v in enumerate(template)]
        return type(template)(vals)
    return flat[prefix]


def save_pytree(tree, directory: Path, step: int) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step:08d}.tmp"
    final = directory / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "keys": {}}
    for k, v in flat.items():
        a = np.asarray(v)
        arrays[k.replace("/", "|")] = a
        manifest["keys"][k] = {"shape": list(a.shape), "dtype": str(a.dtype)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_pytree(directory: Path, step: int | None = None, template=None, shardings=None):
    """Restore; if ``shardings`` (a matching pytree of NamedSharding) is given,
    arrays are device_put with those shardings — elastic resharding."""
    directory = Path(directory)
    if step is None:
        steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*") if not p.name.endswith(".tmp"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        step = steps[-1]
    final = directory / f"step_{step:08d}"
    with np.load(final / "arrays.npz") as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    manifest = json.loads((final / "manifest.json").read_text())
    if template is None:
        tree = flat  # flat dict form
    else:
        tree = _unflatten_into(template, flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        flat_tr = _flatten(tree)
        placed = {
            k: jax.device_put(v, flat_sh[k]) if k in flat_sh else jax.numpy.asarray(v)
            for k, v in flat_tr.items()
        }
        tree = _unflatten_into(template if template is not None else tree, placed)
    return tree, manifest["step"]


class Checkpointer:
    """Async checkpointer with retention + failure-injection test hooks."""

    def __init__(self, directory, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._pending = 0
        self._lock = threading.Lock()
        self.saved_steps: list[int] = []

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self.saved_steps.append(step)
                self._gc()
            finally:
                with self._lock:
                    self._pending -= 1

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.directory.glob("step_*")
            if not p.name.endswith(".tmp")
        )
        import shutil
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

    def save_async(self, tree, step: int):
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)  # sync snapshot
        with self._lock:
            self._pending += 1
        self._q.put((host, step))

    def wait(self):
        while True:
            with self._lock:
                if self._pending == 0:
                    return
            import time
            time.sleep(0.01)

    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.directory.glob("step_*")
            if not p.name.endswith(".tmp")
        )
        return steps[-1] if steps else None

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=5)
