from .checkpointer import Checkpointer, save_pytree, restore_pytree  # noqa: F401
