"""End-to-end training driver with fault tolerance.

Features exercised here (single-host simulation of the multi-host design):
  - config-driven model (--arch, full or --smoke reduced config)
  - optional host mesh (--data/--model axes over virtual devices)
  - async checkpointing + resume (bitwise-identical restart)
  - failure injection (--inject-failure N kills the step loop at step N; the
    driver restores from the last checkpoint and continues — the recovery
    path a cluster supervisor would drive)
  - straggler monitor (EWMA step-time outlier flagging)
  - int8-compressed manual-DP gradients (--compress-grads; needs >1 device)
  - Treant telemetry: per-step metric relations are appended and a CJT
    dashboard over them stays calibrated during "think time" between steps
    (the paper's §4.2.1 loop applied to the training run itself).

Example (the ~100M-parameter end-to-end run):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-12b --smoke \
      --preset 100m --steps 300 --batch 4 --seq 512
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

import jax
import numpy as np


PRESETS = {
    # d_model, n_layers, d_ff, vocab  (≈ params with tied-ish heads)
    "tiny": dict(d_model=64, n_layers=2),
    "10m": dict(d_model=256, n_layers=6),
    "100m": dict(d_model=640, n_layers=12),
}


class InjectedFailure(RuntimeError):
    pass


def build_cfg(args):
    from repro.configs import get_config
    from repro.configs.base import smoke_config
    import dataclasses as dc

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.preset:
        p = PRESETS[args.preset]
        d = p["d_model"]
        cfg = dc.replace(
            cfg, d_model=d, n_layers=p["n_layers"], d_ff=4 * d,
            n_heads=8, n_kv_heads=4, d_head=d // 8, vocab=args.vocab,
            loss_chunk=128, attn_q_chunk=128, attn_kv_chunk=128, attn_min_block=128,
        )
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--telemetry-dashboard", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs.base import smoke_config  # noqa: F401
    from repro.data.pipeline import TokenPipeline, StragglerMonitor
    from repro.checkpoint.checkpointer import Checkpointer, restore_pytree
    from repro.models import lm
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.runtime.step import make_train_step

    cfg = build_cfg(args)
    if args.preset:
        cfg = dataclasses.replace(cfg, vocab=args.vocab)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps, m_dtype="float32")
    params = lm.init_params(cfg, seed=0)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq} steps={args.steps}", flush=True)
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = make_train_step(cfg, opt_cfg, rules=None, donate=True)

    ckpt = Checkpointer(Path(args.ckpt_dir) / cfg.name, keep=3)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt_state), start_step = restore_pytree(
            ckpt.directory, template=(params, opt_state)
        )
        print(f"[train] resumed from step {start_step}", flush=True)

    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq,
                         mode=cfg.input_mode, d_model=cfg.d_model,
                         n_vision_tokens=cfg.n_vision_tokens, start_step=start_step)
    monitor = StragglerMonitor()
    telemetry: list[dict] = []

    dash = None
    if args.telemetry_dashboard:
        dash = _make_telemetry_dashboard()

    step = start_step
    injected = False
    losses = []
    try:
        while step < args.steps:
            try:
                t0 = time.perf_counter()
                batch = next(pipe)
                if args.inject_failure is not None and step == args.inject_failure and not injected:
                    injected = True
                    raise InjectedFailure(f"injected node failure at step {step}")
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                slow = monitor.observe(step, dt)
                telemetry.append({"step": step, "loss": loss, "dt": dt, "slow": slow})
                losses.append(loss)
                if step % args.log_every == 0:
                    print(f"[train] step={step} loss={loss:.4f} dt={dt*1e3:.0f}ms"
                          + (" STRAGGLER" if slow else ""), flush=True)
                step += 1
                if step % args.ckpt_every == 0:
                    ckpt.save_async((params, opt_state), step)
                if dash is not None and step % 10 == 0:
                    _update_dashboard(dash, telemetry[-10:])
            except InjectedFailure as e:
                print(f"[train] FAILURE: {e}; restoring from checkpoint", flush=True)
                ckpt.wait()
                latest = ckpt.latest_step()
                if latest is None:
                    print("[train] no checkpoint yet; restarting from scratch", flush=True)
                    params = lm.init_params(cfg, seed=0)
                    opt_state = init_opt_state(params, opt_cfg)
                    step = 0
                else:
                    (params, opt_state), step = restore_pytree(
                        ckpt.directory, template=(params, opt_state)
                    )
                    print(f"[train] restored step {step}", flush=True)
                pipe.close()
                pipe = TokenPipeline(cfg.vocab, args.batch, args.seq,
                                     mode=cfg.input_mode, d_model=cfg.d_model,
                                     n_vision_tokens=cfg.n_vision_tokens, start_step=step)
    finally:
        ckpt.wait()
        ckpt.close()
        pipe.close()

    print(f"[train] done: first-loss={losses[0]:.4f} last-loss={losses[-1]:.4f} "
          f"stragglers={len(monitor.flagged)}", flush=True)
    return losses


# ---------------------------------------------------------------------------
# Treant telemetry dashboard (the paper's system watching the training run)
# ---------------------------------------------------------------------------

def _make_telemetry_dashboard():
    from repro.core import Treant, Query
    from repro.core import semiring as sr
    from repro.relational.relation import Catalog, Relation
    import numpy as np

    steps = Relation(
        name="Steps", attrs=("step_b", "phase"),
        codes={"step_b": np.zeros(1, np.int32), "phase": np.zeros(1, np.int32)},
        domains={"step_b": 64, "phase": 4},
        measures={"loss": np.zeros(1, np.float32), "dt": np.zeros(1, np.float32)},
    )
    phases = Relation(
        name="Phases", attrs=("phase", "phase_kind"),
        codes={"phase": np.arange(4, dtype=np.int32), "phase_kind": np.arange(4, dtype=np.int32) % 2},
        domains={"phase": 4, "phase_kind": 2},
    )
    cat = Catalog([steps, phases])
    t = Treant(cat, ring=sr.SUM)
    q = Query.make(cat, ring="sum", measure=("Steps", "dt"), group_by=("phase_kind",))
    t.register_dashboard("step_time", q)
    return {"treant": t, "cat": cat, "version": 0}


def _update_dashboard(dash, recent):
    import numpy as np
    from repro.core import Query

    t = dash["treant"]
    cat = dash["cat"]
    dash["version"] += 1
    v = f"v{dash['version']}"
    n = len(recent)
    steps = cat.get("Steps").with_version(
        v,
        codes={
            "step_b": np.array([r["step"] % 64 for r in recent], np.int32),
            "phase": np.array([r["step"] // 16 % 4 for r in recent], np.int32),
        },
        measures={
            "loss": np.array([r["loss"] for r in recent], np.float32),
            "dt": np.array([r["dt"] for r in recent], np.float32),
        },
    )
    cat.put(steps)
    q = Query.make(cat, ring="sum", measure=("Steps", "dt"), group_by=("phase_kind",),
                   versions={"Steps": v})
    t.interact("trainer", "step_time", q)
    # think-time calibration between steps
    t.think_time("trainer", "step_time", budget_messages=2)


if __name__ == "__main__":
    main()
