"""Batched serving driver: prefill a prompt batch, then decode N tokens.

Demonstrates the inference path the decode_32k/long_500k dry-run shapes
lower: a prefill step builds the KV/state caches, then a jitted single-token
decode step runs autoregressively with donated caches.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.configs.base import smoke_config
    from repro.models import lm
    from repro.runtime.step import make_decode_step, make_prefill_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    b, s = args.batch, args.prompt_len
    cache_len = args.cache_len or (s + args.gen)
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, 0)

    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
    else:
        batch["tokens"] = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    if cfg.input_mode == "tokens+vision":
        batch["vision"] = rng.standard_normal(
            (b, cfg.n_vision_tokens, cfg.d_model)
        ).astype(np.float32)

    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg, donate=False)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {b}x{s}: {t_prefill*1e3:.1f}ms", flush=True)

    # pad attention caches out to cache_len so decode writes in-place
    def pad_cache(x, name):
        if "k" == name or "v" == name or name.endswith("_k") or name.endswith("_v"):
            pad = cache_len - x.shape[-3]
            if pad > 0:
                cfgpad = [(0, 0)] * x.ndim
                cfgpad[-3] = (0, pad)
                return jnp.pad(x, cfgpad)
        return x

    caches = {k: pad_cache(v, k) for k, v in caches.items()}

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen):
        db = {}
        if cfg.input_mode == "embeddings":
            db["embeds"] = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
        else:
            db["tokens"] = tok
        if cfg.input_mode == "tokens+vision":
            db["vision"] = jnp.asarray(batch["vision"])
        logits, caches = decode(params, db, caches, jnp.int32(s + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_dec = time.perf_counter() - t0
    tps = b * args.gen / t_dec
    print(f"[serve] decode {args.gen} steps: {t_dec*1e3:.1f}ms  ({tps:.1f} tok/s)", flush=True)
    seq = np.concatenate(out_tokens, axis=1)
    print(f"[serve] sample tokens: {seq[0][:16].tolist()}", flush=True)
    return seq


if __name__ == "__main__":
    main()
