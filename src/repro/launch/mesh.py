"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state: the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first jax
init; smoke tests and benchmarks see the single real CPU device.
"""

from __future__ import annotations

import jax

from repro.runtime.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (virtual) devices the host exposes."""
    return make_mesh((data, model), ("data", "model"))
