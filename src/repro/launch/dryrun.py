import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax-importing import: jax locks the device count on init.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, record memory/cost/collective artifacts for the roofline analysis.

Methodology notes (see DESIGN.md §8):
  - The *production* compile (scanned layers, chunked attention) proves the
    sharding is coherent, yields ``memory_analysis()`` and the collective
    schedule. XLA's HloCostAnalysis visits while-loop bodies ONCE, so its
    flops/bytes on scanned programs undercount by the trip count.
  - The *analysis* compiles therefore rebuild the same cell at 1 and 2 layer
    units with every scan unrolled (``cfg.unroll_scans``) and chunk-free
    attention/loss (identical matmul FLOPs, no loops).  Costs are affine in
    the unit count, so ``total = c1 + (c2 - c1)·(units - 1)`` is exact.
  - Collective bytes are parsed from the compiled per-device HLO; we report
    both the raw operand-byte sum (the brief's formula) and a ring-model
    wire-byte estimate per device.

Usage:
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --cell treant    # the paper's own workload
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

from repro.runtime.compat import cost_analysis as compat_cost_analysis

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective byte totals from a compiled SPMD module."""
    per_op: dict[str, dict] = {}
    operand_bytes = 0.0
    wire_bytes = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        result_t, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        r = _shape_bytes(result_t)
        # group size
        tail = hlo_text[m.end(): m.end() + 2000]
        g = None
        mi = _IOTA_GROUPS_RE.search(tail)
        if mi:
            g = int(mi.group(2))
        else:
            ml = _LIST_GROUPS_RE.search(tail)
            if ml:
                g = len([x for x in ml.group(1).split(",") if x.strip()])
        if not g or g < 1:
            g = 2
        if op == "all-gather":
            operand = r / g
            wire = r * (g - 1) / g
        elif op == "reduce-scatter":
            operand = r * g
            wire = r * (g - 1)
        elif op == "all-reduce":
            operand = r
            wire = 2 * r * (g - 1) / g
        elif op == "all-to-all":
            operand = r
            wire = r * (g - 1) / g
        else:  # collective-permute
            operand = r
            wire = r
        operand_bytes += operand
        wire_bytes += wire
        d = per_op.setdefault(op, {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += operand
        d["wire_bytes"] += wire
    return {
        "per_op": per_op,
        "operand_bytes": operand_bytes,
        "wire_bytes": wire_bytes,
    }


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def arch_overrides(name: str, shape_name: str) -> dict:
    """Per-cell production-compile knobs (memory dials; see EXPERIMENTS.md)."""
    out: dict = {}
    if name == "nemotron-4-340b" and shape_name == "train_4k":
        out["scan_groups"] = 12      # √L nested remat
    if name == "llama-3.2-vision-90b" and shape_name == "prefill_32k":
        out["attn_q_chunk"] = 1024
    return out


def train_accum(name: str, shape_name: str) -> int:
    """Microbatch accumulation per arch: the HBM dial that brings every
    train cell under the 16 GiB/chip budget (EXPERIMENTS.md §Dry-run)."""
    if shape_name != "train_4k":
        return 1
    return {
        "nemotron-4-340b": 8,
        "llama-3.2-vision-90b": 8,
        "deepseek-coder-33b": 4,
        "dbrx-132b": 4,
        "nemotron-4-15b": 2,
        "stablelm-12b": 2,
        "rwkv6-7b": 2,
        "zamba2-1.2b": 2,
    }.get(name, 1)


def unit_layers(cfg, k: int) -> int:
    """Layer count for k pattern units (differencing grid)."""
    if cfg.pattern == "vlm":
        return k * cfg.cross_every
    if cfg.pattern == "zamba":
        ng, per, tail = _zamba_layout(cfg)
        return k * per + tail
    return k


def n_units(cfg) -> int:
    if cfg.pattern == "vlm":
        return cfg.n_layers // cfg.cross_every
    if cfg.pattern == "zamba":
        ng, per, tail = _zamba_layout(cfg)
        return ng
    return cfg.n_layers


def _zamba_layout(cfg):
    per = cfg.shared_attn_every
    ng = cfg.n_layers // per
    return ng, per, cfg.n_layers - ng * per


def analysis_cfg(cfg, k_units: int, shape, grid: str = "flops"):
    """Two analysis grids (DESIGN.md §8):

    - ``flops``: every loop unrolled/vectorized, attention chunk-free —
      trip-count-exact FLOPs (identical matmul work to production).
    - ``bytes``: production attention chunking (flash loop bodies counted
      once = the scores-stay-in-VMEM traffic model) with layer/moe/loss
      loops unrolled — realistic bytes + collective schedule, free of the
      chunk-free grid's giant-score-tensor resharding artifacts.
    """
    seq = shape.seq_len if shape.kind != "decode" else 1
    over = dict(
        n_layers=unit_layers(cfg, k_units),
        unroll_scans=True,
        scan_groups=None,
    )
    if grid == "flops":
        over.update(
            attn_q_chunk=max(seq, 16),
            attn_kv_chunk=max(seq, 16),
            loss_chunk=max(seq, 16),
        )
        if cfg.attn_mode != "divide":
            # divide-mode keeps its recursion depth (it determines the FLOPs);
            # its flash sub-blocks are already single-iteration at q_chunk=S
            over["attn_min_block"] = max(seq, 16)
    return dataclasses.replace(cfg, **over)


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "full-attention arch: 500k-context decode requires sub-quadratic "
            "attention (brief: skip for pure full-attention archs)"
        )
    return None


def input_specs(arch: str, shape_name: str = "train_4k", mesh=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell (the brief's
    §MULTI-POD DRY-RUN contract): weak-type-correct, shardable, no allocation.
    For training that's {tokens, labels}; embeddings/vision stubs for the
    [audio]/[vlm] archs; decode shapes add the KV/state cache skeletons."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.runtime.sharding import batch_specs, make_rules
    from repro.runtime.step import abstract_caches

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    rules = make_rules(mesh, shape)
    out = batch_specs(cfg, shape, rules, "bfloat16")
    if shape.kind == "decode":
        out["caches"] = abstract_caches(cfg, shape, rules)
    return out


def lower_cell(cfg, shape, mesh, rules, accum: int):
    """Build SDS inputs and lower the appropriate step. Returns (lowered, meta)."""
    import jax.numpy as jnp
    import jax

    from repro.optim.adamw import AdamWConfig
    from repro.runtime.sharding import batch_specs, tree_abstract
    from repro.runtime.step import (
        abstract_caches, abstract_train_state, make_decode_step,
        make_prefill_step, make_train_step,
    )
    from repro.models.lm import param_specs

    meta = {"accum": accum}
    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        params, opt = abstract_train_state(cfg, opt_cfg, rules)
        batch = batch_specs(cfg, shape, rules, "bfloat16")
        step = make_train_step(cfg, opt_cfg, rules, accum=accum)
        lowered = step.lower(params, opt, batch)
    elif shape.kind == "prefill":
        params = tree_abstract(param_specs(cfg), rules, "bfloat16")
        batch = batch_specs(cfg, shape, rules, "bfloat16")
        step = make_prefill_step(cfg, rules, shape)
        lowered = step.lower(params, batch)
    else:  # decode
        params = tree_abstract(param_specs(cfg), rules, "bfloat16")
        batch = batch_specs(cfg, shape, rules, "bfloat16")
        caches = abstract_caches(cfg, shape, rules)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        step = make_decode_step(cfg, rules)
        lowered = step.lower(params, batch, caches, pos)
    return lowered, meta


def parse_overrides(sets) -> dict:
    """--set key=value perf-variant overrides (nested: moe.group=64)."""
    out: dict = {}
    for kv in sets or []:
        key, val = kv.split("=", 1)
        try:
            val = int(val)
        except ValueError:
            try:
                val = float(val)
            except ValueError:
                pass
        out[key] = val
    return out


def apply_overrides(cfg, overrides: dict):
    moe_over = {k.split(".", 1)[1]: v for k, v in overrides.items() if k.startswith("moe.")}
    flat = {k: v for k, v in overrides.items() if "." not in k}
    if moe_over and cfg.moe is not None:
        flat["moe"] = dataclasses.replace(cfg.moe, **moe_over)
    return dataclasses.replace(cfg, **flat)


def run_cell(arch: str, shape_name: str, mesh_kind: str, analysis: bool = True,
             overrides: dict | None = None) -> dict:
    import jax
    from repro.configs import get_config, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.runtime.sharding import make_rules

    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "timestamp": time.time(),
    }
    reason = skip_reason(cfg0, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rules = make_rules(mesh, shape)
    cfg = dataclasses.replace(cfg0, **arch_overrides(arch, shape_name))
    accum = train_accum(arch, shape_name)
    overrides = dict(overrides or {})
    if overrides:
        accum = int(overrides.pop("accum", accum))
        cfg = apply_overrides(cfg, overrides)
        rec["overrides"] = {**overrides, "accum": accum}

    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh, rules, accum)
    rec["lower_s"] = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0
    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_per_device_bytes": (
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes
        ),
    }
    ca = compat_cost_analysis(compiled)
    rec["cost_raw"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    rec["collectives_schedule"] = parse_collectives(compiled.as_text())
    rec["meta"] = meta
    rec["status"] = "ok"

    if analysis and mesh_kind == "single":
        # the roofline table is single-pod; multi-pod cells only need the
        # production compile (sharding proof + memory + schedule)
        rec["analysis"] = run_analysis(cfg, shape, mesh, rules)
    return rec


def run_analysis(cfg, shape, mesh, rules) -> dict:
    """1/2-unit differencing on both analysis grids; flops from the chunk-free
    grid, bytes + collectives from the production-chunked grid."""
    units = n_units(cfg)
    out: dict = {"units": units}
    costs: dict = {}
    for grid, keys in (("flops", ("flops",)),
                       ("bytes", ("bytes", "operand_bytes", "wire_bytes"))):
        for k in (1, 2):
            acfg = analysis_cfg(cfg, k, shape, grid=grid)
            lowered, _ = lower_cell(acfg, shape, mesh, rules, accum=1)
            compiled = lowered.compile()
            ca = compat_cost_analysis(compiled)
            coll = parse_collectives(compiled.as_text())
            c = costs.setdefault(k, {})
            vals = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "operand_bytes": coll["operand_bytes"],
                "wire_bytes": coll["wire_bytes"],
            }
            for key in keys:
                c[key] = vals[key]
    out["unit_costs"] = costs
    extr = {}
    for key in ("flops", "bytes", "operand_bytes", "wire_bytes"):
        c1, c2 = costs[1][key], costs[2][key]
        per_unit = c2 - c1
        extr[key] = c1 + per_unit * (units - 1)
        extr[f"{key}_per_unit"] = per_unit
    out["extrapolated"] = extr
    return out


# ---------------------------------------------------------------------------
# The paper's own workload: distributed CJT calibration on the mesh
# ---------------------------------------------------------------------------

def run_treant_cell(mesh_kind: str, n_measures: int = 1) -> dict:
    import jax
    from repro.core.distributed import (
        chain_factor_specs, chain_multi_specs, make_chain_calibrate,
        make_chain_calibrate_multi,
    )
    from repro.launch.mesh import make_production_mesh

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    axis = "data"
    r, d = 8, 65536  # 8-relation chain (Appendix D.3 shape), 64k domains
    rec = {"arch": "treant_dashboard", "shape": f"chain_r{r}_d{d}", "mesh": mesh_kind,
           "n_measures": n_measures}
    if n_measures > 1:
        fn = make_chain_calibrate_multi(mesh, axis, r, d, n_measures)
        factors, leaf = chain_multi_specs(mesh, axis, r, d, n_measures)
        specs = (factors, leaf)
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t0
        mem = compiled.memory_analysis()
        rec["memory"] = {"argument_bytes": mem.argument_size_in_bytes,
                         "temp_bytes": mem.temp_size_in_bytes}
        ca = compat_cost_analysis(compiled)
        rec["cost_raw"] = {"flops": float(ca.get("flops", 0.0)),
                           "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        rec["collectives_schedule"] = parse_collectives(compiled.as_text())
        rec["status"] = "ok"
        return rec
    fn = make_chain_calibrate(mesh, axis, r, d)
    specs = chain_factor_specs(mesh, axis, r, d)
    t0 = time.time()
    lowered = jax.jit(fn).lower(specs)
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0
    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
    }
    ca = compat_cost_analysis(compiled)
    rec["cost_raw"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    rec["collectives_schedule"] = parse_collectives(compiled.as_text())
    rec["status"] = "ok"
    return rec


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def cell_path(arch: str, shape: str, mesh: str) -> Path:
    return ARTIFACTS / f"{arch}__{shape}__{mesh}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cell", default=None, help="'treant' for the CJT workload")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--set", action="append", default=[],
                    help="perf-variant override key=value (e.g. attn_mode=divide)")
    ap.add_argument("--tag", default=None,
                    help="write to artifacts/hillclimb/<cell>__<tag>.json")
    args = ap.parse_args()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import ALL_ARCHS
        from repro.configs.base import SHAPES
        cells = [
            (a, s, m)
            for a in ALL_ARCHS
            for s in SHAPES
            for m in ("single", "multi")
        ] + [("treant_dashboard", "chain", m) for m in ("single", "multi")]
        failures = 0
        for a, s, m in cells:
            out = cell_path(a, s, m)
            if out.exists() and not args.force:
                try:
                    prev = json.loads(out.read_text()).get("status")
                except Exception:
                    prev = None
                if prev in ("ok", "skipped"):
                    print(f"[skip-existing] {out.name}")
                    continue
                out.unlink()
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--mesh", m, "--timeout", str(args.timeout),
            ]
            cmd += ["--cell", "treant"] if a == "treant_dashboard" else ["--arch", a, "--shape", s]
            if args.no_analysis:
                cmd.append("--no-analysis")
            print(f"[run] {a} × {s} × {m}", flush=True)
            repo = ARTIFACTS.parents[1]
            env = dict(os.environ, PYTHONPATH=str(repo / "src"))
            env.pop("XLA_FLAGS", None)  # each child sets its own 512-device flag
            try:
                r = subprocess.run(cmd, timeout=args.timeout, cwd=str(repo), env=env)
                if r.returncode != 0:
                    failures += 1
                    _write_fail(out, a, s, m, f"exit={r.returncode}")
            except subprocess.TimeoutExpired:
                failures += 1
                _write_fail(out, a, s, m, f"timeout>{args.timeout}s")
        print(f"done; failures={failures}")
        sys.exit(1 if failures else 0)

    if args.cell == "treant":
        over = parse_overrides(getattr(args, "set"))
        rec = run_treant_cell(args.mesh, n_measures=int(over.get("measures", 1)))
        out = cell_path("treant_dashboard", "chain", args.mesh)
        if args.tag:
            out = ARTIFACTS.parent / "hillclimb" / f"treant_dashboard__chain__{args.mesh}__{args.tag}.json"
            out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=2))
        print(json.dumps(rec, indent=2)[:1500])
        return
    else:
        try:
            rec = run_cell(args.arch, args.shape, args.mesh,
                           analysis=not args.no_analysis,
                           overrides=parse_overrides(getattr(args, "set")))
        except Exception:
            rec = {
                "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                "status": "error", "traceback": traceback.format_exc(),
            }
            out = cell_path(args.arch, args.shape, args.mesh)
            out.write_text(json.dumps(rec, indent=2))
            print(rec["traceback"], file=sys.stderr)
            sys.exit(1)
        out = cell_path(args.arch, args.shape, args.mesh)
    if args.tag:
        out = ARTIFACTS.parent / "hillclimb" / f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"
        out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items() if k not in ("traceback",)}, indent=2)[:2000])


def _write_fail(out: Path, a, s, m, why):
    if not out.exists():
        out.write_text(json.dumps(
            {"arch": a, "shape": s, "mesh": m, "status": "error", "reason": why}
        ))


if __name__ == "__main__":
    main()
