"""State-space blocks: Mamba2 (SSD chunked form) and RWKV6 (chunked WKV).

Both are implemented in their TPU-native *chunked-parallel* forms: within a
chunk the recurrence is expressed as masked matmuls (MXU work), and only the
chunk-to-chunk state carry is a sequential ``lax.scan`` — the standard
hardware adaptation of linear-attention/SSM recurrences (Mamba2's own SSD
algorithm; GLA-style chunking for WKV6).  Sequential single-step references
(`*_reference`) are the oracles for the property tests, and double as the
O(1)-state decode steps.

Numerical note (WKV6): the intra-chunk decay matrix is computed with the
exact pairwise log-difference ``exp(pc_t - cum_s)`` (always ≤ 1 under the
strictly-lower-triangular mask), avoiding the separable-form overflow;
memory is O(Q²·H·K) per chunk, which is why the default chunk is 32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

def ssd_chunked(xh, log_a, B_t, C_t, chunk: int, vectorized: bool = False):
    """Chunked SSD scan.

    xh:    (B, S, H, P)  dt-scaled inputs
    log_a: (B, S, H)     per-step log decay (≤ 0)
    B_t:   (B, S, N)     input projections (shared across heads)
    C_t:   (B, S, N)     output projections
    Returns y (B, S, H, P) and final state (B, H, N, P).
    """
    b, s, h, p = xh.shape
    n = B_t.shape[-1]
    pad = (-s) % chunk
    if pad:  # state-neutral padding: zero input, decay 1
        zp = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        y, S_fin = ssd_chunked(zp(xh), zp(log_a), zp(B_t), zp(C_t), chunk, vectorized)
        return y[:, :s], S_fin
    nc = s // chunk
    xh_c = xh.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    la_c = log_a.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    b_c = B_t.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    c_c = C_t.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(S, inp):
        x_i, la_i, b_i, c_i = inp                       # (B,Q,...)
        cum = jnp.cumsum(la_i, axis=1)                   # (B,Q,H)
        cb = jnp.einsum("btn,bsn->bts", c_i, b_i)        # (B,Q,Q)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,H)
        m = jnp.where(tril[None, :, :, None], cb[..., None] * dec, 0.0)
        y = jnp.einsum("btsh,bshp->bthp", m, x_i)        # intra
        y += jnp.einsum("bth,btn,bhnp->bthp", jnp.exp(cum), c_i, S)  # inter
        w = jnp.exp(cum[:, -1:, :] - cum)                # (B,Q,H)
        S_new = jnp.exp(cum[:, -1])[:, :, None, None] * S + jnp.einsum(
            "bsh,bsn,bshp->bhnp", w, b_i, x_i
        )
        return S_new, y

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    if vectorized:
        # analysis-exact form: all intra-chunk work is batched over chunks so
        # XLA cost analysis counts every block; only the (tiny) chunk-state
        # recurrence remains a while loop.
        cum = jnp.cumsum(la_c, axis=2)                              # (nc,B,Q,H)
        cb = jnp.einsum("cbtn,cbsn->cbts", c_c, b_c)
        dec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
        m = jnp.where(tril[None, None, :, :, None], cb[..., None] * dec, 0.0)
        y_intra = jnp.einsum("cbtsh,cbshp->cbthp", m, xh_c)
        w = jnp.exp(cum[:, :, -1:, :] - cum)
        wx = w[..., None] * xh_c                                     # (nc,B,Q,H,P)
        S_in = jnp.einsum("cbshp,cbsn->cbhnp", wx, b_c)              # per-chunk input state
        gain = jnp.exp(cum[:, :, -1])                                # (nc,B,H)

        def carry_body(S, inp):
            S_i, g_i = inp
            S_new = g_i[:, :, None, None] * S + S_i
            return S_new, S                                          # emit state BEFORE chunk

        S_fin, S_prev = jax.lax.scan(carry_body, S0, (S_in, gain))
        y_int = jnp.einsum("cbtn,cbhnp->cbthp", c_c, S_prev)
        y = y_intra + jnp.exp(cum)[..., None] * y_int
        y = y.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
        return y, S_fin
    S_fin, y = jax.lax.scan(body, S0, (xh_c, la_c, b_c, c_c))
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, S_fin


def ssd_reference(xh, log_a, B_t, C_t):
    """Sequential oracle: S_t = a_t S_{t-1} + B_t ⊗ x_t ; y_t = C_t · S_t."""
    b, s, h, p = xh.shape
    n = B_t.shape[-1]

    def step(S, inp):
        x_t, la_t, b_t, c_t = inp
        S = jnp.exp(la_t)[:, :, None, None] * S + jnp.einsum(
            "bn,bhp->bhnp", b_t, x_t
        )
        y = jnp.einsum("bn,bhnp->bhp", c_t, S)
        return S, y

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (
        xh.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2),
        B_t.transpose(1, 0, 2), C_t.transpose(1, 0, 2),
    )
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3), S_fin


def ssd_decode_step(S, x_t, log_a_t, b_t, c_t):
    """One decode step; S (B,H,N,P), x_t (B,H,P), log_a_t (B,H), b/c_t (B,N)."""
    S = jnp.exp(log_a_t)[:, :, None, None] * S + jnp.einsum("bn,bhp->bhnp", b_t, x_t)
    y = jnp.einsum("bn,bhnp->bhp", c_t, S)
    return S, y


def mamba2_mix(x, p, cfg, state=None, acts=None):
    """Full Mamba2 mixer: in_proj → causal depthwise conv → SSD → gated out.

    state (decode): dict(conv=(B, conv-1, d_in), ssm=(B,H,N,P)) or None.
    Returns (y, new_state).
    """
    from .layers import rms_norm, with_sharding
    ssm = cfg.ssm
    b = x.shape[0]
    s = x.shape[1]
    d_in = ssm.expand * cfg.d_model
    h = d_in // ssm.head_dim
    n, pdim = ssm.state, ssm.head_dim
    acts = acts or {}

    zxbcdt = x @ p["in_proj"]
    z, xr, b_t, c_t, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    # causal depthwise conv (kernel ssm.conv) on xr
    if state is None:
        pad = jnp.zeros((b, ssm.conv - 1, d_in), xr.dtype)
        xp = jnp.concatenate([pad, xr], axis=1)
        new_conv = xp[:, -(ssm.conv - 1):]
    else:
        xp = jnp.concatenate([state["conv"].astype(xr.dtype), xr], axis=1)
        new_conv = xp[:, -(ssm.conv - 1):]
    xc = sum(
        xp[:, i : i + s] * p["conv_w"][i][None, None, :] for i in range(ssm.conv)
    ) + p["conv_b"]
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    log_a = -jnp.exp(p["a_log"])[None, None, :] * dt                 # (B,S,H)
    xh = xc.reshape(b, s, h, pdim).astype(jnp.float32) * dt[..., None]
    b_t = b_t.astype(jnp.float32)
    c_t = c_t.astype(jnp.float32)

    if state is None:
        y, S_fin = ssd_chunked(xh, log_a, b_t, c_t, min(ssm.chunk, s),
                               vectorized=cfg.unroll_scans)
    else:
        S_fin, y1 = ssd_decode_step(
            state["ssm"], xh[:, 0], log_a[:, 0], b_t[:, 0], c_t[:, 0]
        )
        y = y1[:, None]
    y = y + p["d_skip"][None, None, :, None] * xc.reshape(b, s, h, pdim).astype(jnp.float32)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = with_sharding(y, acts.get("ff"))
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": S_fin}


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

def wkv_chunked(r, k, v, log_w, u, chunk: int, vectorized: bool = False):
    """Chunked WKV6: S_t = diag(w_t) S_{t-1} + kᵀv ; o_t = r·(S_{t-1} + diag(u) kᵀv).

    r, k, log_w: (B, S, H, K); v: (B, S, H, V); u: (H, K).
    Returns o (B, S, H, V) and final state (B, H, K, V).
    """
    b, s, h, kk = r.shape
    vv = v.shape[-1]
    pad = (-s) % chunk
    if pad:  # state-neutral padding: r=k=v=0, decay 1 (log_w=0)
        zp = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        o, S_fin = wkv_chunked(zp(r), zp(k), zp(v), zp(log_w), u, chunk, vectorized)
        return o[:, :s], S_fin
    nc = s // chunk
    rs = r.reshape(b, nc, chunk, h, kk).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(b, nc, chunk, h, kk).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nc, chunk, h, vv).transpose(1, 0, 2, 3, 4)
    ws = log_w.reshape(b, nc, chunk, h, kk).transpose(1, 0, 2, 3, 4)
    strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def body(S, inp):
        r_i, k_i, v_i, w_i = inp                    # (B,Q,H,K/V)
        cum = jnp.cumsum(w_i, axis=1)                # (B,Q,H,K)  cum_t = Σ_{j≤t} log w
        pc = cum - w_i                               # cum_{t-1}
        # inter-chunk: o += (r ⊙ exp(pc)) · S
        o = jnp.einsum("bthk,bhkv->bthv", r_i * jnp.exp(pc), S)
        # intra-chunk strictly-lower: A[t,s] = Σ_K r_t k_s exp(pc_t - cum_s)
        dec = jnp.exp(
            jnp.clip(pc[:, :, None] - cum[:, None, :], max=0.0)
        )                                            # (B,t,s,H,K), ≤1 on mask
        a = jnp.einsum("bthk,bshk,btshk->bths", r_i, k_i, dec)
        a = jnp.where(strict[None, :, None, :], a, 0.0)
        o += jnp.einsum("bths,bshv->bthv", a, v_i)
        # diagonal bonus term: (r ⊙ u ⊙ k) per step
        o += (r_i * u[None, None] * k_i).sum(-1)[..., None] * v_i
        # state update: S' = diag(Πw) S + Σ_s exp(cum_Q - cum_s) k_s ⊗ v_s
        wq = cum[:, -1]                               # (B,H,K)
        decay_to_end = jnp.exp(cum[:, -1][:, None] - cum)   # (B,Q,H,K) ≤ 1
        S_new = jnp.exp(wq)[..., None] * S + jnp.einsum(
            "bshk,bshv->bhkv", k_i * decay_to_end, v_i
        )
        return S_new, o

    S0 = jnp.zeros((b, h, kk, vv), jnp.float32)
    if vectorized:
        cum = jnp.cumsum(ws, axis=2)                                 # (nc,B,Q,H,K)
        pc = cum - ws
        dec = jnp.exp(jnp.clip(pc[:, :, :, None] - cum[:, :, None, :], max=0.0))
        rdec = rs[:, :, :, None] * dec                               # (nc,B,t,s,H,K)
        a = jnp.einsum("cbtshk,cbshk->cbths", rdec, ks)
        a = jnp.where(strict[None, None, :, None, :], a, 0.0)
        o = jnp.einsum("cbths,cbshv->cbthv", a, vs)
        o += (rs * u[None, None, None] * ks).sum(-1)[..., None] * vs
        decay_to_end = jnp.exp(cum[:, :, -1][:, :, None] - cum)
        S_in = jnp.einsum("cbshk,cbshv->cbhkv", ks * decay_to_end, vs)
        gain = jnp.exp(cum[:, :, -1])                                 # (nc,B,H,K)

        def carry_body(S, inp):
            S_i, g_i = inp
            return g_i[..., None] * S + S_i, S

        S_fin, S_prev = jax.lax.scan(carry_body, S0, (S_in, gain))
        o += jnp.einsum("cbthk,cbhkv->cbthv", rs * jnp.exp(pc), S_prev)
        o = o.transpose(1, 0, 2, 3, 4).reshape(b, s, h, vv)
        return o, S_fin
    S_fin, o = jax.lax.scan(body, S0, (rs, ks, vs, ws))
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, s, h, vv)
    return o, S_fin


def wkv_reference(r, k, v, log_w, u):
    """Sequential oracle for WKV6."""
    b, s, h, kk = r.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None][..., None] * kv)
        S = jnp.exp(w_t)[..., None] * S + kv
        return S, o

    S0 = jnp.zeros((b, h, kk, v.shape[-1]), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, log_w))
    S_fin, o = jax.lax.scan(step, S0, xs)
    return o.transpose(1, 0, 2, 3), S_fin


def wkv_decode_step(S, r_t, k_t, v_t, log_w_t, u):
    kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
    o = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None][..., None] * kv)
    S = jnp.exp(log_w_t)[..., None] * S + kv
    return S, o


def rwkv_time_mix(x, p, cfg, state=None, acts=None):
    """RWKV6 time-mix with data-dependent decay.

    state (decode): dict(shift=(B, D), wkv=(B,H,K,V)).  Returns (y, new_state).
    """
    from .layers import with_sharding
    rw = cfg.rwkv
    b, s, d = x.shape
    h = d // rw.head_dim
    kk = rw.head_dim
    acts = acts or {}

    if state is None:
        prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
        new_shift = x[:, -1]
    else:
        prev = state["shift"][:, None].astype(x.dtype)
        new_shift = x[:, -1]
    dx = prev - x

    def mix(mu):
        return x + dx * mu[None, None, :]

    r = (mix(p["mu_r"]) @ p["wr"]).reshape(b, s, h, kk)
    kx = (mix(p["mu_k"]) @ p["wk"]).reshape(b, s, h, kk)
    vx = (mix(p["mu_v"]) @ p["wv"]).reshape(b, s, h, kk)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    # data-dependent decay (the Finch contribution): w = exp(-exp(w0 + lora(x)))
    xw = mix(p["mu_w"])
    ddd = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    log_w = -jnp.exp(
        jnp.clip(p["w0"][None, None, :] + ddd.astype(jnp.float32), max=8.0)
    )
    log_w = log_w.reshape(b, s, h, kk)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, kx, vx))
    if state is None:
        o, S_fin = wkv_chunked(r32, k32, v32, log_w, p["u"], min(rw.chunk, s),
                               vectorized=cfg.unroll_scans)
    else:
        S_fin, o1 = wkv_decode_step(
            state["wkv"], r32[:, 0], k32[:, 0], v32[:, 0], log_w[:, 0], p["u"]
        )
        o = o1[:, None]
    # per-head groupnorm
    o = o.reshape(b, s, h, kk)
    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 64e-5)
    o = o.reshape(b, s, d) * p["ln_x"][None, None, :]
    o = (o.astype(x.dtype) * g)
    o = with_sharding(o, acts.get("ff"))
    y = o @ p["wo"]
    return y, {"shift": new_shift, "wkv": S_fin}


def rwkv_channel_mix(x, p, state=None):
    """RWKV6 channel-mix; state (decode): (B, D) shift."""
    if state is None:
        prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
        new_shift = x[:, -1]
    else:
        prev = state[:, None].astype(x.dtype)
        new_shift = x[:, -1]
    dx = prev - x
    xk = x + dx * p["mu_k"][None, None, :]
    xr = x + dx * p["mu_r"][None, None, :]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    y = jax.nn.sigmoid(xr @ p["wr_gate"]) * (k @ p["wv"])
    return y, new_shift
