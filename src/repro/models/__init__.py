"""Assigned-architecture model zoo: pure-JAX, scan-over-layers decoders."""

from .lm import LM, abstract_params, init_params, param_specs  # noqa: F401
