"""Transformer building blocks: norms, RoPE, GQA attention (chunked-causal
flash for train/prefill, cache attention for decode), MLP variants, MoE.

All functions are pure; parameters are dicts of arrays built by
``lm.param_specs``.  Activation sharding constraints are injected by the
runtime via ``cfg.act_rules`` (a mapping logical-axis → PartitionSpec entry),
so the same code lowers for 1 CPU device and for the 512-chip mesh.

Attention compute modes (see EXPERIMENTS.md §Perf):
  - ``full_masked``  — chunked online-softmax attention over all kv chunks
    with a causal mask (baseline; does ~2× the useful FLOPs).
  - ``divide``       — recursive causal decomposition: causal(S) =
    causal(S/2) ⊕ full(S/2×S/2) ⊕ causal(S/2); exact same result, ~half the
    FLOPs, static shapes (the TPU-native replacement for ragged causal
    kernels).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def with_sharding(x, spec):
    """Apply a sharding constraint if a PartitionSpec is provided."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Norms / RoPE
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-5):
    # mean-of-squares with fp32 accumulation, without materializing an fp32
    # copy of the residual stream (a multi-GiB buffer at 18k d_model)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — flash (chunked online-softmax) with a custom VJP so neither the
# score matrices nor per-chunk softmax residuals are ever saved: forward keeps
# only (q, k, v, o, lse); backward re-streams (nq × nk) blocks, accumulating
# dk/dv in-place.  This is the memory-term workhorse of EXPERIMENTS.md §Perf.
# ---------------------------------------------------------------------------

from functools import partial as _partial


def _expand_kv(x, g):
    return jnp.repeat(x, g, axis=2) if g > 1 else x


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_off, k_off):
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / np.sqrt(dh)
    qc_n = max(sq // min(q_chunk, sq), 1)
    kc_n = max(sk // min(kv_chunk, sk), 1)
    qc, kc = sq // qc_n, sk // kc_n
    qs = (q * scale).astype(jnp.float32).reshape(b, qc_n, qc, h, dh).transpose(1, 0, 2, 3, 4)
    ks = k.astype(jnp.float32).reshape(b, kc_n, kc, kh, dh).transpose(1, 0, 2, 3, 4)
    vs = v.astype(jnp.float32).reshape(b, kc_n, kc, kh, dh).transpose(1, 0, 2, 3, 4)

    def q_body(_, qin):
        qb, qi = qin
        qpos = q_off + qi * qc + jnp.arange(qc)

        def kv_body(carry, kin):
            o, m, l = carry
            kb, vb, kj = kin
            kb = _expand_kv(kb, g)
            vb = _expand_kv(vb, g)
            s = jnp.einsum("bqhd,bthd->bhqt", qb, kb)
            if causal:
                kpos = k_off + kj * kc + jnp.arange(kc)
                s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1).transpose(0, 2, 1))
            p = jnp.exp(s - m_new.transpose(0, 2, 1)[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1).transpose(0, 2, 1)
            o_new = o * corr[..., None] + jnp.einsum("bhqt,bthd->bqhd", p, vb)
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, qc, h, dh), jnp.float32)
        m0 = jnp.full((b, qc, h), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qc, h), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_body, (o0, m0, l0), (ks, vs, jnp.arange(kc_n)))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (o / jnp.maximum(l, 1e-30)[..., None], lse)

    _, (o, lse) = jax.lax.scan(q_body, None, (qs, jnp.arange(qc_n)))
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)
    lse = lse.transpose(1, 0, 2, 3).reshape(b, sq, h)
    return o.astype(q.dtype), lse


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, q_chunk=512, kv_chunk=512, q_off=0, k_off=0):
    """Memory-O(S·d) exact attention. Returns (o, lse); lse enables merging
    partial attentions (the causal-divide decomposition)."""
    return _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_off, k_off)


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, q_off, k_off):
    o, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_off, k_off)
    return (o, lse), (q, k, v, o, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, q_off, k_off, res, ct):
    q, k, v, o, lse = res
    do, dlse = ct
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / np.sqrt(dh)
    qc_n = max(sq // min(q_chunk, sq), 1)
    kc_n = max(sk // min(kv_chunk, sk), 1)
    qc, kc = sq // qc_n, sk // kc_n
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # (B,Sq,H)

    def chunked(x, n, c):
        return x.reshape(b, n, c, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qs = chunked(q.astype(jnp.float32), qc_n, qc)
    dos = chunked(do.astype(jnp.float32), qc_n, qc)
    lses = chunked(lse, qc_n, qc)
    deltas = chunked(delta, qc_n, qc)
    dlses = chunked(dlse.astype(jnp.float32), qc_n, qc) if dlse is not None else None
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    def q_body(carry, qin):
        dk_acc, dv_acc = carry
        if dlses is None:
            qb, dob, lseb, delb, qi = qin
            dlb = None
        else:
            qb, dob, lseb, delb, dlb, qi = qin
        qpos = q_off + qi * qc + jnp.arange(qc)

        def kv_body(inner, kj):
            dk_a, dv_a, dq_b = inner
            kb = jax.lax.dynamic_slice_in_dim(k32, kj * kc, kc, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v32, kj * kc, kc, axis=1)
            kbe = _expand_kv(kb, g)
            vbe = _expand_kv(vb, g)
            s = jnp.einsum("bqhd,bthd->bhqt", qb * scale, kbe)
            if causal:
                kpos = k_off + kj * kc + jnp.arange(kc)
                s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None], s, NEG_INF)
            p = jnp.exp(s - lseb.transpose(0, 2, 1)[..., None])       # (B,H,qc,kc)
            dv_blk = jnp.einsum("bhqt,bqhd->bthd", p, dob)            # (B,kc,H,dh)
            dp = jnp.einsum("bqhd,bthd->bhqt", dob, vbe)
            ds = p * (dp - delb.transpose(0, 2, 1)[..., None])
            if dlb is not None:
                ds = ds + p * dlb.transpose(0, 2, 1)[..., None]
            dq_b = dq_b + jnp.einsum("bhqt,bthd->bqhd", ds, kbe) * scale
            dk_blk = jnp.einsum("bhqt,bqhd->bthd", ds, qb) * scale    # (B,kc,H,dh)
            # GQA: fold the head-group dim back onto kv heads
            dk_blk = dk_blk.reshape(b, kc, kh, g, dh).sum(3)
            dv_blk = dv_blk.reshape(b, kc, kh, g, dh).sum(3)
            dk_a = jax.lax.dynamic_update_slice_in_dim(
                dk_a, jax.lax.dynamic_slice_in_dim(dk_a, kj * kc, kc, 1) + dk_blk, kj * kc, 1)
            dv_a = jax.lax.dynamic_update_slice_in_dim(
                dv_a, jax.lax.dynamic_slice_in_dim(dv_a, kj * kc, kc, 1) + dv_blk, kj * kc, 1)
            return (dk_a, dv_a, dq_b), None

        dq0 = jnp.zeros((b, qc, h, dh), jnp.float32)
        (dk_acc, dv_acc, dqb), _ = jax.lax.scan(
            kv_body, (dk_acc, dv_acc, dq0), jnp.arange(kc_n))
        return (dk_acc, dv_acc), dqb

    dk0 = jnp.zeros((b, sk, kh, dh), jnp.float32)
    dv0 = jnp.zeros((b, sk, kh, dh), jnp.float32)
    xs = (qs, dos, lses, deltas, jnp.arange(qc_n)) if dlses is None else (
        qs, dos, lses, deltas, dlses, jnp.arange(qc_n))
    (dk, dv), dqs = jax.lax.scan(q_body, (dk0, dv0), xs)
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def _merge_attn(a, b):
    """Merge two normalized partial attentions via their lse."""
    o_a, lse_a = a
    o_b, lse_b = b
    lse = jnp.logaddexp(lse_a, lse_b)
    wa = jnp.exp(lse_a - lse)[..., None]
    wb = jnp.exp(lse_b - lse)[..., None]
    return o_a * wa + o_b * wb, lse


def _causal_divide(q, k, v, q_off, k_off, min_block, q_chunk, kv_chunk):
    """Exact causal attention in ~half the FLOPs: causal(S) = causal(S/2) ⊕
    full(upper·lower) ⊕ causal(S/2), recursively (static shapes)."""
    s = q.shape[1]
    if s <= min_block:
        return flash_attention(q, k, v, True, q_chunk, kv_chunk, q_off, k_off)
    half = s // 2
    a1 = _causal_divide(q[:, :half], k[:, :half], v[:, :half],
                        q_off, k_off, min_block, q_chunk, kv_chunk)
    a2d = _causal_divide(q[:, half:], k[:, half:], v[:, half:],
                         q_off + half, k_off + half, min_block, q_chunk, kv_chunk)
    a2f = flash_attention(q[:, half:], k[:, :half], v[:, :half],
                          False, q_chunk, kv_chunk, q_off + half, k_off)
    a2 = _merge_attn(a2d, a2f)
    return tuple(jnp.concatenate([x1, x2], axis=1) for x1, x2 in zip(a1, a2))


def causal_attention(q, k, v, *, mode: str = "full_masked", q_chunk: int = 512,
                     kv_chunk: int = 512, min_block: int = 1024, offset: int = 0):
    """Causal self attention. q (B,S,H,dh), k/v (B,S,K,dh) → (B,S,H,dh)."""
    s = q.shape[1]
    if mode == "divide" and s > min_block:
        o, _ = _causal_divide(q, k, v, offset, offset, min_block, q_chunk, kv_chunk)
    else:
        o, _ = flash_attention(q, k, v, True, q_chunk, kv_chunk, offset, offset)
    return o.astype(q.dtype)


def cross_attention(q, k, v, *, kv_chunk: int = 512):
    """Full (non-causal) attention against precomputed kv (VLM image tokens)."""
    o, _ = flash_attention(q, k, v, False, 512, kv_chunk, 0, 0)
    return o.astype(q.dtype)


def decode_attention(q, cache_k, cache_v, valid_upto=None):
    """One-token attention against a full KV cache.

    q: (B, H, dh); cache_k/v: (B, T, K, dh).  Uses a grouped einsum so the
    cache is never head-expanded (it can be tens of GB at 32k–500k context).
    ``valid_upto`` (inclusive position) masks unwritten cache slots.
    """
    b, h, dh = q.shape
    t, kh = cache_k.shape[1], cache_k.shape[2]
    g = h // kh
    scale = 1.0 / np.sqrt(dh)
    qg = (q * scale).reshape(b, kh, g, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, cache_k.astype(jnp.float32))
    if valid_upto is not None:
        mask = jnp.arange(t) <= valid_upto
        s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, cache_v.astype(jnp.float32))
    return o.reshape(b, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + modes)
# ---------------------------------------------------------------------------

def attention_block(x, p, cfg, *, cache=None, pos_offset=0, acts=None):
    """Self-attention with GQA + RoPE.

    train/prefill: cache is None → returns (y, (k, v)) so callers can build a
    prefill cache.  decode: cache = (k, v, cur_index is implicit: full cache).
    """
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    acts = acts or {}
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kh, dh)
    v = (x @ p["wv"]).reshape(b, s, kh, dh)
    pos = pos_offset + jnp.arange(s)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q = with_sharding(q, acts.get("qkv"))
    k = with_sharding(k, acts.get("kv"))
    v = with_sharding(v, acts.get("kv"))
    if cache is not None:
        ck, cv = cache
        o = decode_attention(q[:, 0], ck, cv)[:, None]
    else:
        o = causal_attention(
            q, k, v, mode=cfg.attn_mode, q_chunk=cfg.attn_q_chunk,
            kv_chunk=cfg.attn_kv_chunk, min_block=cfg.attn_min_block,
        )
    o = with_sharding(o, acts.get("qkv"))
    y = o.reshape(b, s, h * dh) @ p["wo"]
    return y, (k, v)


def cross_attention_block(x, p, cfg, kv=None, vision=None, acts=None):
    """Cross-attention against vision tokens (llama-3.2-vision style).

    ``kv`` (cached projected vision K/V) or ``vision`` (embeddings) must be
    given; returns (y, (k, v)).
    """
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    acts = acts or {}
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    if kv is None:
        t = vision.shape[1]
        k = (vision @ p["wk"]).reshape(b, t, kh, dh)
        v = (vision @ p["wv"]).reshape(b, t, kh, dh)
    else:
        k, v = kv
    q = with_sharding(q, acts.get("qkv"))
    if s == 1:
        o = decode_attention(q[:, 0], k, v)[:, None]
    else:
        o = cross_attention(q, k, v, kv_chunk=cfg.attn_kv_chunk)
    y = o.reshape(b, s, h * dh) @ p["wo"]
    return y, (k, v)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(x, p, cfg, acts=None):
    acts = acts or {}
    if cfg.mlp == "swiglu":
        hdn = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    elif cfg.mlp == "squared_relu":        # nemotron-4
        hdn = jnp.square(jax.nn.relu(x @ p["w1"]))
    elif cfg.mlp == "gelu":
        hdn = jax.nn.gelu(x @ p["w1"])
    else:  # pragma: no cover
        raise ValueError(cfg.mlp)
    hdn = with_sharding(hdn, acts.get("ff"))
    return hdn @ p["w2"]


# ---------------------------------------------------------------------------
# Mixture of Experts (dropping, grouped one-hot dispatch — MXU friendly)
# ---------------------------------------------------------------------------

def _moe_groups(xt, p, cfg, acts, g: int, cap: int):
    """Dispatch + expert compute + combine for a slab of token groups.

    xt: (ng, g, d).  Returns (y (ng, g, d), aux scalar).
    """
    moe = cfg.moe
    e, k = moe.n_experts, moe.top_k
    ng = xt.shape[0]
    logits = jnp.einsum("ngd,de->nge", xt, p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                      # (ng, g, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)       # (ng, g, k, e)
    # buffer position per (token, choice): fp32 cumsum (exact for these
    # counts); the big (g,e,cap) tensors are bf16 to halve the working set.
    flat = onehot.transpose(0, 2, 1, 3).reshape(ng, k * xt.shape[1], e)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = pos.reshape(ng, k, xt.shape[1], e).transpose(0, 2, 1, 3)
    in_cap = (pos < cap) & (onehot > 0)
    slot = jnp.where(in_cap, pos, 0).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, cap, dtype=xt.dtype) * in_cap[..., None].astype(xt.dtype)
    dispatch = slot_oh.sum(axis=2)                            # (ng,g,e,cap)
    combine = jnp.einsum("ngkec,ngk->ngec", slot_oh, topv.astype(xt.dtype))

    xin = jnp.einsum("ngec,ngd->necd", dispatch, xt)           # (ng,e,cap,d)
    xin = with_sharding(xin, acts.get("expert_in"))
    if cfg.mlp == "swiglu":
        hdn = jax.nn.silu(jnp.einsum("necd,edf->necf", xin, p["w1"]))
        hdn = hdn * jnp.einsum("necd,edf->necf", xin, p["w3"])
    else:
        hdn = jnp.square(jax.nn.relu(jnp.einsum("necd,edf->necf", xin, p["w1"])))
    hdn = with_sharding(hdn, acts.get("expert_ff"))
    out = jnp.einsum("necf,efd->necd", hdn, p["w2"])
    y = jnp.einsum("ngec,necd->ngd", combine, out)
    aux = _load_balance_loss(gates, onehot)
    return y, aux


def moe_block(x, p, cfg, acts=None):
    """Top-k MoE with capacity-bounded one-hot dispatch.

    Tokens are processed in groups of ``cfg.moe.group`` so the dispatch
    einsum's cost stays a few % of expert FLOPs (tokens×E×C×d with
    C = group·k·cf/E).  Groups are streamed through a *checkpointed scan*
    in slabs so only one slab's (g,e,cap)/(e,cap,d)/(e,cap,f) tensors are
    ever live — without it the dbrx-132b train cell holds >100 GiB of
    dispatch intermediates per device (see EXPERIMENTS.md §Dry-run).
    Expert weights are sharded over the ``expert`` logical axis; GSPMD turns
    the dispatch/combine einsums into the classical EP all-to-all.
    """
    moe = cfg.moe
    acts = acts or {}
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    g = min(moe.group, b * s)
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    assert n % g == 0, (n, g)
    ng = n // g
    cap = min(int(np.ceil(g * k * moe.capacity_factor / e)), g)
    xt = tokens.reshape(ng, g, d)

    steps = min(16, ng)
    while ng % steps:
        steps -= 1
    if steps <= 1:
        y, aux = _moe_groups(xt, p, cfg, acts, g, cap)
        return y.reshape(b, s, d).astype(x.dtype), aux

    xc = xt.reshape(steps, ng // steps, g, d)

    def body(_, slab):
        y, aux = _moe_groups(slab, p, cfg, acts, g, cap)
        return None, (y, aux)

    body = jax.checkpoint(body)
    _, (ys, auxs) = jax.lax.scan(
        body, None, xc, unroll=True if cfg.unroll_scans else 1
    )
    y = ys.reshape(ng, g, d)
    return y.reshape(b, s, d).astype(x.dtype), jnp.mean(auxs)


def _load_balance_loss(gates, onehot):
    # Switch-style auxiliary load-balance loss
    e = gates.shape[-1]
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))   # (e,)
    frac_gates = jnp.mean(gates, axis=(0, 1))
    return e * jnp.sum(frac_tokens * frac_gates) / onehot.shape[2]
