"""LM assembly: param specs with logical sharding axes + train/prefill/decode
forwards for the four block patterns (uniform, vlm, zamba, rwkv).

Layers are stacked and iterated with ``lax.scan`` so the lowered HLO is O(1)
in depth — mandatory for compiling 96-layer × 512-way-SPMD programs in the
dry-run.  Parameters are plain nested dicts; ``param_specs`` describes every
leaf once as (shape, logical axes, init), from which both real initialization
(smoke tests / train driver) and ShapeDtypeStruct skeletons (dry-run) derive.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import layers as L
from . import ssm as S


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]          # logical axis name (or None) per dim
    init: str = "normal"           # normal | zeros | ones | const:<val>
    dtype: Any = None              # override (defaults to build dtype)


def _is_spec(x):
    return isinstance(x, Spec)


# ---------------------------------------------------------------------------
# Param specs per pattern
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig, stack: tuple[int, ...], saxes: tuple) -> dict:
    d, ha, kv = cfg.d_model, cfg.d_attn, cfg.n_kv_heads * cfg.d_head
    return {
        "wq": Spec(stack + (d, ha), saxes + ("embed", "heads_flat")),
        "wk": Spec(stack + (d, kv), saxes + ("embed", "kv_flat")),
        "wv": Spec(stack + (d, kv), saxes + ("embed", "kv_flat")),
        "wo": Spec(stack + (ha, d), saxes + ("heads_flat", "embed")),
    }


def _mlp_specs(cfg: ModelConfig, stack, saxes) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    out = {
        "w1": Spec(stack + (d, f), saxes + ("embed", "mlp")),
        "w2": Spec(stack + (f, d), saxes + ("mlp", "embed")),
    }
    if cfg.mlp == "swiglu":
        out["w3"] = Spec(stack + (d, f), saxes + ("embed", "mlp"))
    return out


def _moe_specs(cfg: ModelConfig, stack, saxes) -> dict:
    d, e, f = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff
    out = {
        "router": Spec(stack + (d, e), saxes + ("embed", None)),
        "w1": Spec(stack + (e, d, f), saxes + ("experts", "embed", "mlp")),
        "w2": Spec(stack + (e, f, d), saxes + ("experts", "mlp", "embed")),
    }
    if cfg.mlp == "swiglu":
        out["w3"] = Spec(stack + (e, d, f), saxes + ("experts", "embed", "mlp"))
    return out


def _uniform_layer_specs(cfg: ModelConfig, stack, saxes) -> dict:
    d = cfg.d_model
    out = {
        "ln1": Spec(stack + (d,), saxes + ("embed",), init="ones"),
        "ln2": Spec(stack + (d,), saxes + ("embed",), init="ones"),
        "attn": _attn_specs(cfg, stack, saxes),
    }
    if cfg.moe is not None:
        out["moe"] = _moe_specs(cfg, stack, saxes)
    else:
        out["mlp"] = _mlp_specs(cfg, stack, saxes)
    return out


def _mamba_layer_specs(cfg: ModelConfig, stack, saxes) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    d_in = ssm.expand * d
    h = d_in // ssm.head_dim
    n = ssm.state
    proj_out = 2 * d_in + 2 * n + h
    return {
        "ln": Spec(stack + (d,), saxes + ("embed",), init="ones"),
        "in_proj": Spec(stack + (d, proj_out), saxes + ("embed", "ssm_inner")),
        "conv_w": Spec(stack + (ssm.conv, d_in), saxes + (None, "ssm_inner"),
                       init="const:0.25"),
        "conv_b": Spec(stack + (d_in,), saxes + ("ssm_inner",), init="zeros"),
        "dt_bias": Spec(stack + (h,), saxes + (None,), init="const:-2.0"),
        "a_log": Spec(stack + (h,), saxes + (None,), init="zeros"),
        "d_skip": Spec(stack + (h,), saxes + (None,), init="ones"),
        "norm": Spec(stack + (d_in,), saxes + ("ssm_inner",), init="ones"),
        "out_proj": Spec(stack + (d_in, d), saxes + ("ssm_inner", "embed")),
    }


def _rwkv_layer_specs(cfg: ModelConfig, stack, saxes) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    rw = cfg.rwkv
    h = d // rw.head_dim
    mu = lambda: Spec(stack + (d,), saxes + ("embed",), init="const:0.5")
    return {
        "ln1": Spec(stack + (d,), saxes + ("embed",), init="ones"),
        "ln2": Spec(stack + (d,), saxes + ("embed",), init="ones"),
        "tm": {
            "mu_r": mu(), "mu_k": mu(), "mu_v": mu(), "mu_g": mu(), "mu_w": mu(),
            "wr": Spec(stack + (d, d), saxes + ("embed", "heads_flat")),
            "wk": Spec(stack + (d, d), saxes + ("embed", "heads_flat")),
            "wv": Spec(stack + (d, d), saxes + ("embed", "heads_flat")),
            "wg": Spec(stack + (d, d), saxes + ("embed", "heads_flat")),
            "wo": Spec(stack + (d, d), saxes + ("heads_flat", "embed")),
            "w_lora_a": Spec(stack + (d, rw.lora_rank), saxes + ("embed", None)),
            "w_lora_b": Spec(stack + (rw.lora_rank, d), saxes + (None, "heads_flat")),
            "w0": Spec(stack + (d,), saxes + ("heads_flat",), init="const:-2.0"),
            "u": Spec(stack + (h, rw.head_dim), saxes + (None, None), init="const:0.1"),
            "ln_x": Spec(stack + (d,), saxes + ("heads_flat",), init="ones"),
        },
        "cm": {
            "mu_k": mu(), "mu_r": mu(),
            "wk": Spec(stack + (d, f), saxes + ("embed", "mlp")),
            "wv": Spec(stack + (f, d), saxes + ("mlp", "embed")),
            "wr_gate": Spec(stack + (d, d), saxes + ("embed", "heads_flat")),
        },
    }


def _cross_layer_specs(cfg: ModelConfig, stack, saxes) -> dict:
    out = _attn_specs(cfg, stack, saxes)
    out["ln"] = Spec(stack + (cfg.d_model,), saxes + ("embed",), init="ones")
    out["gate"] = Spec(stack + (), saxes, init="zeros")
    return out


def vlm_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, self_per_group): cross-attn after every ``cross_every``-1
    self layers; total layers = n_groups * cross_every."""
    assert cfg.n_layers % cfg.cross_every == 0
    return cfg.n_layers // cfg.cross_every, cfg.cross_every - 1


def zamba_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, mamba_per_group, tail): shared attn block before each group."""
    per = cfg.shared_attn_every
    n_groups = cfg.n_layers // per
    tail = cfg.n_layers - n_groups * per
    return n_groups, per, tail


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    out: dict = {}
    if cfg.input_mode != "embeddings":
        out["embed"] = Spec((v, d), ("vocab", "embed"))
    out["final_norm"] = Spec((d,), ("embed",), init="ones")
    out["head"] = Spec((d, v), ("embed", "vocab"))

    if cfg.pattern == "uniform":
        out["layers"] = _uniform_layer_specs(cfg, (cfg.n_layers,), ("layers",))
    elif cfg.pattern == "vlm":
        g, self_per = vlm_layout(cfg)
        out["groups"] = {
            "self": _uniform_layer_specs(cfg, (g, self_per), ("group", "layers")),
            "cross": _cross_layer_specs(cfg, (g,), ("group",)),
            "cross_ln2": Spec((g, d), ("group", "embed"), init="ones"),
            "cross_mlp": _mlp_specs(cfg, (g,), ("group",)),
        }
    elif cfg.pattern == "zamba":
        ng, per, tail = zamba_layout(cfg)
        out["mamba_groups"] = _mamba_layer_specs(cfg, (ng, per), ("group", "layers"))
        if tail:
            out["tail"] = _mamba_layer_specs(cfg, (tail,), ("layers",))
        out["shared"] = {
            "ln1": Spec((d,), ("embed",), init="ones"),
            "ln2": Spec((d,), ("embed",), init="ones"),
            "attn": _attn_specs(cfg, (), ()),
            "mlp": _mlp_specs(cfg, (), ()),
        }
    elif cfg.pattern == "rwkv":
        out["layers"] = _rwkv_layer_specs(cfg, (cfg.n_layers,), ("layers",))
    else:  # pragma: no cover
        raise ValueError(cfg.pattern)
    return out


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def _path_key(path: str, seed: int) -> jax.Array:
    h = int(hashlib.sha1(path.encode()).hexdigest()[:8], 16)
    return jax.random.PRNGKey((seed * 1_000_003 + h) % (2**31))


def init_params(cfg: ModelConfig, seed: int = 0, dtype=jnp.float32) -> dict:
    def build(path: str, spec: Spec):
        dt = spec.dtype or dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init.startswith("const:"):
            return jnp.full(spec.shape, float(spec.init[6:]), dt)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        scale = 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(_path_key(path, seed), spec.shape) * scale).astype(dt)

    return _map_specs(param_specs(cfg), build)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16, sharding_fn=None) -> dict:
    """ShapeDtypeStruct skeleton (+ shardings) — no device allocation."""

    def build(path: str, spec: Spec):
        dt = spec.dtype or dtype
        sh = sharding_fn(spec) if sharding_fn else None
        if sh is not None:
            return jax.ShapeDtypeStruct(spec.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(spec.shape, dt)

    return _map_specs(param_specs(cfg), build)


def _map_specs(tree, fn, path=""):
    if _is_spec(tree):
        return fn(path, tree)
    return {k: _map_specs(v, fn, f"{path}/{k}") for k, v in tree.items()}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _scan(cfg, body, init, xs):
    """lax.scan that fully unrolls in analysis mode (exact cost accounting)."""
    return jax.lax.scan(body, init, xs, unroll=True if cfg.unroll_scans else 1)

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def embed_inputs(params, cfg: ModelConfig, batch, acts=None):
    acts = acts or {}
    if cfg.input_mode == "embeddings":
        h = batch["embeds"]
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    return L.with_sharding(h.astype(compute_dtype(params)), acts.get("resid"))


def compute_dtype(params):
    leaf = jax.tree_util.tree_leaves(params)[0]
    return jnp.bfloat16 if leaf.dtype == jnp.bfloat16 else leaf.dtype


def _uniform_block(h, lp, cfg, acts, cache=None, pos=0):
    a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
    a, kv = L.attention_block(a_in, lp["attn"], cfg, cache=cache, pos_offset=pos, acts=acts)
    h = h + a
    m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        m, aux = L.moe_block(m_in, lp["moe"], cfg, acts=acts)
    else:
        m, aux = L.mlp_block(m_in, lp["mlp"], cfg, acts=acts), 0.0
    h = L.with_sharding(h + m, (acts or {}).get("resid"))
    return h, kv, aux


def _shared_block(h, sp, cfg, acts, cache=None, pos=0):
    a, kv = L.attention_block(
        L.rms_norm(h, sp["ln1"], cfg.norm_eps), sp["attn"], cfg,
        cache=cache, pos_offset=pos, acts=acts,
    )
    h = h + a
    h = h + L.mlp_block(L.rms_norm(h, sp["ln2"], cfg.norm_eps), sp["mlp"], cfg, acts=acts)
    return L.with_sharding(h, (acts or {}).get("resid")), kv


def _rwkv_block(h, lp, cfg, acts, state=None):
    tm_state = None if state is None else {"shift": state["tm_shift"], "wkv": state["wkv"]}
    y, new_tm = S.rwkv_time_mix(L.rms_norm(h, lp["ln1"], cfg.norm_eps), lp["tm"], cfg,
                                state=tm_state, acts=acts)
    h = h + y
    y2, new_cm = S.rwkv_channel_mix(
        L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp["cm"],
        state=None if state is None else state["cm_shift"],
    )
    h = L.with_sharding(h + y2, (acts or {}).get("resid"))
    return h, {"tm_shift": new_tm["shift"], "wkv": new_tm["wkv"], "cm_shift": new_cm}


def _mamba_block(h, mp, cfg, acts, state=None):
    y, new_state = S.mamba2_mix(
        L.rms_norm(h, mp["ln"], cfg.norm_eps), mp, cfg, state=state, acts=acts
    )
    return L.with_sharding(h + y, (acts or {}).get("resid")), new_state


# -- mode: train / prefill ----------------------------------------------------

def backbone(params, cfg: ModelConfig, h, batch, acts=None, collect_cache=False):
    """Run all blocks. Returns (h, caches-or-None, aux_loss)."""
    acts = acts or {}

    if cfg.pattern == "uniform":
        def body(carry, lp):
            hh, aux = carry
            if acts.get("layer_params") is not None:
                lp = jax.tree_util.tree_map(
                    L.with_sharding, lp, acts["layer_params"]
                )
            hh, kv, a = _uniform_block(hh, lp, cfg, acts)
            ys = kv if collect_cache else None
            return (hh, aux + a), ys

        body = _remat(body, cfg)
        layer_params = params["layers"]
        if cfg.scan_groups and not collect_cache:
            # √L nested scan: outer saves only G carries; the inner scan's
            # carries are rematerialized per-group during backward, bounding
            # live activation memory at (G + L/G)·|carry| instead of L·|carry|.
            g = cfg.scan_groups
            lt = cfg.n_layers
            assert lt % g == 0, (lt, g)
            grouped = jax.tree_util.tree_map(
                lambda x: x.reshape((g, lt // g) + x.shape[1:]), layer_params
            )

            def outer(carry, gp):
                out, _ = _scan(cfg, body, carry, gp)
                return out, None

            (h, aux), _ = _scan(cfg, _remat(outer, cfg), (h, 0.0), grouped)
            return h, None, aux
        (h, aux), kvs = _scan(cfg, body, (h, 0.0), layer_params)
        caches = None if not collect_cache else {"k": kvs[0], "v": kvs[1]}
        return h, caches, aux

    if cfg.pattern == "vlm":
        vision = batch["vision"].astype(h.dtype)

        def body(carry, gp):
            hh, aux = carry
            def inner(hh2, lp):
                hh2, kv, a = _uniform_block(hh2, lp, cfg, acts)
                return hh2, (kv if collect_cache else None, a)
            hh, (kvs, aux_s) = _scan(cfg, inner, hh, gp["self"])
            aux = aux + jnp.sum(aux_s)
            cp = gp["cross"]
            x, xkv = L.cross_attention_block(
                L.rms_norm(hh, cp["ln"], cfg.norm_eps), cp, cfg, vision=vision, acts=acts
            )
            hh = hh + jnp.tanh(cp["gate"]) * x
            hh = hh + L.mlp_block(
                L.rms_norm(hh, gp["cross_ln2"], cfg.norm_eps), gp["cross_mlp"], cfg, acts=acts
            )
            hh = L.with_sharding(hh, acts.get("resid"))
            ys = (kvs, xkv) if collect_cache else None
            return (hh, aux), ys

        body = _remat(body, cfg)
        (h, aux), ys = _scan(cfg, body, (h, 0.0), params["groups"])
        caches = None
        if collect_cache:
            kvs, xkv = ys
            caches = {"k": kvs[0], "v": kvs[1], "xk": xkv[0], "xv": xkv[1]}
        return h, caches, aux

    if cfg.pattern == "zamba":
        sp = params["shared"]

        def body(carry, gp):
            hh = carry
            hh, kv = _shared_block(hh, sp, cfg, acts)
            def inner(hh2, mp):
                hh2, st = _mamba_block(hh2, mp, cfg, acts)
                return hh2, (st if collect_cache else None)
            hh, sts = _scan(cfg, inner, hh, gp)
            return hh, ((kv, sts) if collect_cache else None)

        body = _remat(body, cfg)
        h, ys = _scan(cfg, body, h, params["mamba_groups"])
        tail_sts = None
        if "tail" in params:
            def tbody(hh, mp):
                hh, st = _mamba_block(hh, mp, cfg, acts)
                return hh, (st if collect_cache else None)
            h, tail_sts = _scan(cfg, _remat(tbody, cfg), h, params["tail"])
        caches = None
        if collect_cache:
            kv, sts = ys
            caches = {
                "shared_k": kv[0], "shared_v": kv[1],
                "conv": sts["conv"], "ssm": sts["ssm"],
            }
            if tail_sts is not None:
                caches["tail_conv"] = tail_sts["conv"]
                caches["tail_ssm"] = tail_sts["ssm"]
        return h, caches, 0.0

    if cfg.pattern == "rwkv":
        def body(hh, lp):
            hh, st = _rwkv_block(hh, lp, cfg, acts)
            return hh, (st if collect_cache else None)

        body = _remat(body, cfg)
        h, sts = _scan(cfg, body, h, params["layers"])
        caches = sts if collect_cache else None
        return h, caches, 0.0

    raise ValueError(cfg.pattern)  # pragma: no cover


def chunked_xent(h, head_w, labels, chunk: int, acts=None, unroll: bool = False):
    """Sequence-chunked softmax cross-entropy (keeps logits O(B·chunk·V))."""
    acts = acts or {}
    b, s, d = h.shape
    chunk = min(chunk, s)
    n = s // chunk
    assert s % chunk == 0
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(tot, inp):
        hb, lb = inp
        logits = (hb @ head_w).astype(jnp.float32)
        logits = L.with_sharding(logits, acts.get("logits"))
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - ll), None

    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc),
                            unroll=True if unroll else 1)
    return total / (b * s)


def forward_train(params, cfg: ModelConfig, batch, acts=None):
    h = embed_inputs(params, cfg, batch, acts)
    h, _, aux = backbone(params, cfg, h, batch, acts=acts, collect_cache=False)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    loss = chunked_xent(h, params["head"], batch["labels"], cfg.loss_chunk, acts,
                        unroll=cfg.unroll_scans)
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


def forward_prefill(params, cfg: ModelConfig, batch, acts=None):
    h = embed_inputs(params, cfg, batch, acts)
    h, caches, _ = backbone(params, cfg, h, batch, acts=acts, collect_cache=True)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, -1] @ params["head"]).astype(jnp.float32)
    return logits, caches


# -- mode: decode ----------------------------------------------------------------

def forward_decode(params, cfg: ModelConfig, batch, caches, pos, acts=None):
    """One-token decode against full caches; returns (logits, new_caches).

    ``pos`` is the (traced) write position; attention reads the whole cache
    (decode_32k/long_500k lower with a full cache of seq_len per the brief).
    """
    acts = acts or {}
    h = embed_inputs(params, cfg, batch, acts)     # (B, 1, D)

    if cfg.pattern == "uniform":
        def body(hh, xs):
            lp, ck, cv = xs
            k_new_v_new = None
            x_in = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            b, s, d = x_in.shape
            hh2, (ck2, cv2), _ = _decode_attn_update(x_in, hh, lp, cfg, ck, cv, pos, acts)
            m_in = L.rms_norm(hh2, lp["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                m, _ = L.moe_block(m_in, lp["moe"], cfg, acts=acts)
            else:
                m = L.mlp_block(m_in, lp["mlp"], cfg, acts=acts)
            return hh2 + m, (ck2, cv2)

        h, (ck, cv) = _scan(cfg, body, h, (params["layers"], caches["k"], caches["v"]))
        new_caches = {"k": ck, "v": cv}

    elif cfg.pattern == "vlm":
        def body(hh, xs):
            gp, ck, cv, xk, xv = xs
            def inner(hh2, xs2):
                lp, ck1, cv1 = xs2
                x_in = L.rms_norm(hh2, lp["ln1"], cfg.norm_eps)
                hh3, (ck2, cv2), _ = _decode_attn_update(x_in, hh2, lp, cfg, ck1, cv1, pos, acts)
                hh3 = hh3 + L.mlp_block(
                    L.rms_norm(hh3, lp["ln2"], cfg.norm_eps), lp["mlp"], cfg, acts=acts)
                return hh3, (ck2, cv2)
            hh, (ck2, cv2) = _scan(cfg, inner, hh, (gp["self"], ck, cv))
            cp = gp["cross"]
            x, _ = L.cross_attention_block(
                L.rms_norm(hh, cp["ln"], cfg.norm_eps), cp, cfg, kv=(xk, xv), acts=acts)
            hh = hh + jnp.tanh(cp["gate"]) * x
            hh = hh + L.mlp_block(
                L.rms_norm(hh, gp["cross_ln2"], cfg.norm_eps), gp["cross_mlp"], cfg, acts=acts)
            return hh, (ck2, cv2)

        h, (ck, cv) = jax.lax.scan(
            body, h,
            (params["groups"], caches["k"], caches["v"], caches["xk"], caches["xv"]),
        )
        new_caches = dict(caches, k=ck, v=cv)

    elif cfg.pattern == "zamba":
        sp = params["shared"]

        def body(hh, xs):
            gp, sk, sv, conv, ssm_st = xs
            x_in = L.rms_norm(hh, sp["ln1"], cfg.norm_eps)
            hh, (sk2, sv2), _ = _decode_attn_update(
                x_in, hh, {"attn": sp["attn"]}, cfg, sk, sv, pos, acts, wo_parent=sp)
            hh = hh + L.mlp_block(L.rms_norm(hh, sp["ln2"], cfg.norm_eps), sp["mlp"], cfg, acts=acts)
            def inner(hh2, xs2):
                mp, cst, sst = xs2
                hh3, st = _mamba_block(hh2, mp, cfg, acts, state={"conv": cst, "ssm": sst})
                return hh3, (st["conv"], st["ssm"])
            hh, (conv2, ssm2) = _scan(cfg, inner, hh, (gp, conv, ssm_st))
            return hh, (sk2, sv2, conv2, ssm2)

        h, (sk, sv, conv, ssm_st) = jax.lax.scan(
            body, h,
            (params["mamba_groups"], caches["shared_k"], caches["shared_v"],
             caches["conv"], caches["ssm"]),
        )
        new_caches = {"shared_k": sk, "shared_v": sv, "conv": conv, "ssm": ssm_st}
        if "tail" in params:
            def tbody(hh, xs2):
                mp, cst, sst = xs2
                hh3, st = _mamba_block(hh, mp, cfg, acts, state={"conv": cst, "ssm": sst})
                return hh3, (st["conv"], st["ssm"])
            h, (tc, ts) = jax.lax.scan(
                tbody, h, (params["tail"], caches["tail_conv"], caches["tail_ssm"]))
            new_caches["tail_conv"], new_caches["tail_ssm"] = tc, ts

    elif cfg.pattern == "rwkv":
        def body(hh, xs):
            lp, tm, cm, wkv = xs
            hh, st = _rwkv_block(hh, lp, cfg, acts,
                                 state={"tm_shift": tm, "cm_shift": cm, "wkv": wkv})
            return hh, (st["tm_shift"], st["cm_shift"], st["wkv"])

        h, (tm, cm, wkv) = jax.lax.scan(
            body, h,
            (params["layers"], caches["tm_shift"], caches["cm_shift"], caches["wkv"]),
        )
        new_caches = {"tm_shift": tm, "cm_shift": cm, "wkv": wkv}
    else:  # pragma: no cover
        raise ValueError(cfg.pattern)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, -1] @ params["head"]).astype(jnp.float32)
    return logits, new_caches


def _decode_attn_update(x_in, h, lp, cfg, ck, cv, pos, acts, wo_parent=None):
    """Project one token, write kv into the cache at ``pos``, attend, residual."""
    p = lp["attn"]
    b, s, d = x_in.shape
    hN, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x_in @ p["wq"]).reshape(b, s, hN, dh)
    k = (x_in @ p["wk"]).reshape(b, s, kh, dh)
    v = (x_in @ p["wv"]).reshape(b, s, kh, dh)
    positions = pos + jnp.arange(s)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
    o = L.decode_attention(q[:, 0], ck, cv, valid_upto=pos)[:, None]
    y = o.reshape(b, s, hN * dh) @ p["wo"]
    return h + y, (ck, cv), None


# ---------------------------------------------------------------------------
# Cache skeletons (decode dry-run inputs)
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
    """Shape/logical-axes description of the decode cache pytree."""
    kh, dh = cfg.n_kv_heads, cfg.d_head
    kv_axes = (None, "act_batch", "cache_seq", "kv_heads", None)

    def kv(*lead):
        return Spec(lead + (batch, seq, kh, dh), (None,) * (len(lead)) + kv_axes[1:], init="zeros")

    if cfg.pattern == "uniform":
        return {"k": kv(cfg.n_layers), "v": kv(cfg.n_layers)}
    if cfg.pattern == "vlm":
        g, self_per = vlm_layout(cfg)
        out = {
            "k": Spec((g, self_per, batch, seq, kh, dh),
                      (None, None, "act_batch", "cache_seq", "kv_heads", None), init="zeros"),
            "v": Spec((g, self_per, batch, seq, kh, dh),
                      (None, None, "act_batch", "cache_seq", "kv_heads", None), init="zeros"),
            "xk": Spec((g, batch, cfg.n_vision_tokens, kh, dh),
                       (None, "act_batch", None, "kv_heads", None), init="zeros"),
            "xv": Spec((g, batch, cfg.n_vision_tokens, kh, dh),
                       (None, "act_batch", None, "kv_heads", None), init="zeros"),
        }
        return out
    if cfg.pattern == "zamba":
        ng, per, tail = zamba_layout(cfg)
        ssm = cfg.ssm
        d_in = ssm.expand * cfg.d_model
        hS = d_in // ssm.head_dim
        out = {
            "shared_k": kv(ng), "shared_v": kv(ng),
            "conv": Spec((ng, per, batch, ssm.conv - 1, d_in),
                         (None, None, "act_batch", None, "ssm_inner"), init="zeros"),
            "ssm": Spec((ng, per, batch, hS, ssm.state, ssm.head_dim),
                        (None, None, "act_batch", None, None, None), init="zeros"),
        }
        if tail:
            out["tail_conv"] = Spec((tail, batch, ssm.conv - 1, d_in),
                                    (None, "act_batch", None, "ssm_inner"), init="zeros")
            out["tail_ssm"] = Spec((tail, batch, hS, ssm.state, ssm.head_dim),
                                   (None, "act_batch", None, None, None), init="zeros")
        return out
    if cfg.pattern == "rwkv":
        rw = cfg.rwkv
        hR = cfg.d_model // rw.head_dim
        lN, d = cfg.n_layers, cfg.d_model
        return {
            "tm_shift": Spec((lN, batch, d), (None, "act_batch", "act_embed"), init="zeros"),
            "cm_shift": Spec((lN, batch, d), (None, "act_batch", "act_embed"), init="zeros"),
            "wkv": Spec((lN, batch, hR, rw.head_dim, rw.head_dim),
                        (None, "act_batch", None, None, None), init="zeros",
                        dtype=jnp.float32),
        }
    raise ValueError(cfg.pattern)  # pragma: no cover


class LM:
    """Convenience namespace used by examples/tests."""

    param_specs = staticmethod(param_specs)
    init_params = staticmethod(init_params)
    abstract_params = staticmethod(abstract_params)
    forward_train = staticmethod(forward_train)
    forward_prefill = staticmethod(forward_prefill)
    forward_decode = staticmethod(forward_decode)
    cache_specs = staticmethod(cache_specs)
