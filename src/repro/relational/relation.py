"""Sparse annotated relations (dictionary-encoded COO) and the catalog.

The DBMS the paper delegates storage to is replaced by this layer: a relation
is a set of dictionary-encoded attribute code columns plus numeric measure
columns.  ``lift_rows`` turns a relation into per-row semiring fields
(COUNT → 1̄, SUM → measure, MOMENTS → (1,x,x²), tropical → value, …);
``Relation.to_factor`` densifies via segment ⊕-aggregation (the
``segment_aggregate`` Pallas kernel's job on TPU).

Data updates are first-class: ``Relation.append_rows`` / ``delete_rows``
produce a new immutable version *plus* a signed :class:`Delta` whose rows
lift to the exact ⊕-difference between the versions.  Appends carry positive
weights (valid in every semiring — min over a union is min of mins);
deletes carry ⊕-inverse annotations via negated weights, which is only sound
when the ring is a group under ⊕ (``Semiring.has_add_inverse``: SUM/COUNT/
MOMENTS yes, MIN/MAX/BOOL no — those fall back to recomputation).  The CJT
side of the machinery lives in ``core.calibration.CJTEngine.apply_delta``.

Streaming ingestion (``repro.relational.stream.StreamBuffer``) adds two
refinements on top of the one-shot path:

- *Tombstoned* deletes keep the deleted rows physically present at weight 0
  (the exact ⊕-zero under every group ring's lift).  Idempotent rings
  (MIN/MAX/BOOL), whose lifts ignore weights, can then absorb mixed deltas
  without an ⊕-inverse — the delete becomes visible when
  :meth:`Relation.compact` drops the tombstones (``Delta.kind == "compact"``).
- The :class:`Catalog` gains a *watermark* commit protocol: new versions are
  staged (``put(make_latest=False)``) while cached CJTs are maintained, then
  ``commit`` atomically advances every flushed relation's latest pointer, so
  a concurrent reader either sees the whole tick or none of it.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from collections import OrderedDict, deque
from contextlib import contextmanager
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr
from repro.core.factor import Factor


def row_bucket(n: int) -> int:
    """Next power of two ≥ ``n`` (min 64) — the padded row count compiled
    plans trace against.

    Plan signatures that bake in the exact ``num_rows`` retrace on every
    streamed tick (appends grow the base, and each tick's coalesced delta has
    its own row count).  Bucketing the row axis keeps signatures stable until
    a bucket boundary is crossed; the pad rows carry the ring's ⊕-identity
    (⊗-absorbing), aggregated into segment 0, so results are bit-identical.
    """
    return 64 if n <= 64 else 1 << int(n - 1).bit_length()


def _digest_array(a: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


class LRU:
    """Tiny bounded mapping with least-recently-used eviction.

    Shared by the device-resident code caches below, the compiled plan cache
    (core.plans) and CJTEngine's signature memo — anywhere an unbounded
    per-call dict would leak across a long-lived Treant session.
    """

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self._data: OrderedDict = OrderedDict()

    def get(self, key, default=None):
        try:
            self._data.move_to_end(key)
            return self._data[key]
        except KeyError:
            return default

    def put(self, key, value):
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    __setitem__ = put

    def __contains__(self, key):
        return key in self._data

    def __len__(self):
        return len(self._data)

    def clear(self):
        self._data.clear()


@dataclasses.dataclass(frozen=True)
class Predicate:
    """σ annotation: a boolean mask over one attribute's domain (paper §3.3).

    Hashable by content digest so message signatures (Prop. 2) can include it.
    """

    attr: str
    mask: np.ndarray  # bool (domain,)
    label: str = ""

    @functools.cached_property
    def digest(self) -> str:
        # cached: recomputing the mask sha1 per signature lookup dominates
        # warm interaction latency (the mask is treated as immutable)
        return f"{self.attr}:{_digest_array(self.mask)}"

    def __hash__(self):
        return hash(self.digest)

    def __eq__(self, other):
        return isinstance(other, Predicate) and self.digest == other.digest


def mask_in(domain: int, values: Sequence[int], attr: str = "", label: str = "") -> Predicate:
    m = np.zeros((domain,), bool)
    m[np.asarray(list(values), np.int64)] = True
    return Predicate(attr=attr, mask=m, label=label or f"{attr} IN {list(values)[:4]}")


def mask_range(domain: int, lo: int, hi: int, attr: str = "", label: str = "") -> Predicate:
    m = np.zeros((domain,), bool)
    m[lo:hi] = True
    return Predicate(attr=attr, mask=m, label=label or f"{lo}<={attr}<{hi}")


@dataclasses.dataclass(frozen=True)
class Relation:
    """Dictionary-encoded sparse annotated relation."""

    name: str
    attrs: tuple[str, ...]
    codes: Mapping[str, np.ndarray]        # attr -> int32 (N,)
    domains: Mapping[str, int]             # attr -> domain size
    measures: Mapping[str, np.ndarray] = dataclasses.field(default_factory=dict)
    weights: np.ndarray | None = None      # explicit multiplicity annotation
    version: str = "v0"

    @property
    def num_rows(self) -> int:
        return 0 if not self.attrs else int(self.codes[self.attrs[0]].shape[0])

    @property
    def key(self) -> tuple[str, str]:
        return (self.name, self.version)

    @property
    def digest(self) -> str:
        h = hashlib.sha1()
        h.update(self.name.encode())
        h.update(self.version.encode())
        return h.hexdigest()[:16]

    def with_version(self, version: str, **updates) -> "Relation":
        return dataclasses.replace(self, version=version, **updates)

    def filter_rows(self, row_mask: np.ndarray, version: str) -> "Relation":
        codes = {a: c[row_mask] for a, c in self.codes.items()}
        measures = {m: v[row_mask] for m, v in self.measures.items()}
        w = self.weights[row_mask] if self.weights is not None else None
        return dataclasses.replace(
            self, codes=codes, measures=measures, weights=w, version=version
        )

    def perturb_measure(self, measure: str, scale: float, seed: int, version: str) -> "Relation":
        """Random cell-value perturbation (paper §5.1.1 relation-update test)."""
        rng = np.random.default_rng(seed)
        col = self.measures[measure]
        new = col * (1.0 + scale * rng.standard_normal(col.shape)).astype(col.dtype)
        measures = dict(self.measures)
        measures[measure] = new
        return dataclasses.replace(self, measures=measures, version=version)

    # -- data updates (delta calibration) ------------------------------------
    def _materialized_weights(self) -> np.ndarray:
        return (
            np.asarray(self.weights, np.float32)
            if self.weights is not None
            else np.ones((self.num_rows,), np.float32)
        )

    @property
    def tombstone_count(self) -> int:
        """Rows annotated ⊕-zero (weight 0): logically deleted but physically
        present.  Produced by the streaming path for rings without an
        ⊕-inverse; reclaimed by :meth:`compact`."""
        if self.weights is None:
            return 0
        return int(np.count_nonzero(np.asarray(self.weights, np.float32) == 0.0))

    def append_rows(
        self,
        codes: Mapping[str, np.ndarray],
        measures: Mapping[str, np.ndarray] | None = None,
        weights: np.ndarray | None = None,
        version: str | None = None,
    ) -> tuple["Relation", "Delta | None"]:
        """Append rows, returning ``(new_version, delta)``.

        The delta's rows are exactly the appended rows, so for any semiring
        ``lift(new) = lift(old) ⊕ lift(delta.rows)`` — appends are maintainable
        under every ring, including MIN/MAX.  A zero-row append is a no-op:
        it returns ``(self, None)`` without bumping the version (an empty
        delta would otherwise dirty the n−1 outward messages for nothing).
        """
        measures = dict(measures or {})
        if set(codes) != set(self.attrs):
            raise ValueError(f"append codes {sorted(codes)} != attrs {sorted(self.attrs)}")
        if set(measures) != set(self.measures):
            raise ValueError("appended rows must supply every measure column")
        new_codes = {a: np.asarray(codes[a], np.int32) for a in self.attrs}
        n_new = new_codes[self.attrs[0]].shape[0] if self.attrs else 0
        if n_new == 0:
            return self, None
        new_meas = {
            m: np.asarray(measures[m], self.measures[m].dtype) for m in self.measures
        }
        w_new = (
            np.asarray(weights, np.float32)
            if weights is not None
            else np.ones((n_new,), np.float32)
        )
        suffix = _delta_suffix(self.version, "a", new_codes, new_meas, w_new)
        delta_rows = dataclasses.replace(
            self, codes=new_codes, measures=new_meas, weights=w_new,
            version=f"{self.version}Δ{suffix}",
        )
        new_version = version or f"{self.version}+{suffix}"
        merged = dataclasses.replace(
            self,
            codes={a: np.concatenate([np.asarray(self.codes[a], np.int32), new_codes[a]])
                   for a in self.attrs},
            measures={m: np.concatenate([self.measures[m], new_meas[m]])
                      for m in self.measures},
            weights=(np.concatenate([self._materialized_weights(), w_new])
                     if (self.weights is not None or weights is not None) else None),
            version=new_version,
        )
        return merged, Delta(
            relation=self.name, old_version=self.version, new_version=new_version,
            rows=delta_rows, kind="append",
        )

    def delete_rows(
        self, row_mask: np.ndarray, version: str | None = None
    ) -> tuple["Relation", "Delta | None"]:
        """Delete the rows selected by ``row_mask``, returning ``(new, delta)``.

        The delta's rows are the deleted rows with *negated* weights — a valid
        ⊕-inverse annotation exactly when the ring has additive inverses
        (SUM/COUNT/MOMENTS); MIN/MAX/BOOL consumers must recompute instead
        (``Delta.supported_by`` reports which).  An all-False mask is a no-op
        returning ``(self, None)`` — no version bump, nothing to maintain.
        """
        row_mask = np.asarray(row_mask, bool)
        if row_mask.shape != (self.num_rows,):
            raise ValueError(f"mask shape {row_mask.shape} != ({self.num_rows},)")
        if not row_mask.any():
            return self, None
        gone_codes = {a: np.asarray(c, np.int32)[row_mask] for a, c in self.codes.items()}
        gone_meas = {m: v[row_mask] for m, v in self.measures.items()}
        gone_w = -self._materialized_weights()[row_mask]
        suffix = _delta_suffix(self.version, "d", gone_codes, gone_meas, gone_w)
        delta_rows = dataclasses.replace(
            self, codes=gone_codes, measures=gone_meas, weights=gone_w,
            version=f"{self.version}Δ{suffix}",
        )
        new_version = version or f"{self.version}+{suffix}"
        kept = self.filter_rows(~row_mask, new_version)
        return kept, Delta(
            relation=self.name, old_version=self.version, new_version=new_version,
            rows=delta_rows, kind="delete",
        )

    def compact(self, version: str | None = None) -> tuple["Relation", "Delta | None"]:
        """Physically drop tombstoned (weight-0) rows, returning ``(new, delta)``.

        The compaction delta is *empty* — tombstones lift to the exact ⊕-zero
        under every group ring, so dropping them leaves each cached message
        value-identical and ``apply_delta`` merely re-keys the n−1 outward
        messages to the new version (zero contractions).  Rings whose lift
        ignores weights (MIN/MAX/BOOL) report unsupported instead
        (``Delta.supported_by`` → False): for them compaction is the point
        where the tombstoned deletes become visible, and the one real
        recalibration happens.  Returns ``(self, None)`` when there is
        nothing to reclaim.
        """
        if self.weights is None:
            return self, None
        keep = np.asarray(self.weights, np.float32) != 0.0
        if keep.all():
            return self, None
        suffix = _delta_suffix(self.version, "c", {}, {}, ~keep)
        new_version = version or f"{self.version}+{suffix}"
        kept = self.filter_rows(keep, new_version)
        empty = self.filter_rows(np.zeros((self.num_rows,), bool),
                                 f"{self.version}Δ{suffix}")
        return kept, Delta(
            relation=self.name, old_version=self.version, new_version=new_version,
            rows=empty, kind="compact",
        )

    # -- densification ------------------------------------------------------
    @property
    def row_bucket(self) -> int:
        """Padded row count for shape-stable plan signatures (see
        :func:`row_bucket`): streaming ticks grow ``num_rows`` every flush,
        and an exact row count in the jit signature would retrace every
        compiled plan per tick."""
        return row_bucket(self.num_rows)

    def flat_codes(self, attrs: Sequence[str]) -> tuple[np.ndarray, int]:
        attrs = list(attrs)
        if not attrs:
            return np.zeros((self.num_rows,), np.int64), 1
        dims = [self.domains[a] for a in attrs]
        idx = np.ravel_multi_index(
            tuple(self.codes[a].astype(np.int64) for a in attrs), dims
        )
        return idx, int(np.prod(dims))

    def to_factor(self, ring: sr.Semiring, measure: str | None = None) -> Factor:
        rows = lift_rows(self, ring, measure)
        idx, total = self.flat_codes(self.attrs)
        field = ring.segment_reduce(rows, jnp.asarray(idx), total)
        shape = tuple(self.domains[a] for a in self.attrs)
        field = jax.tree_util.tree_map(
            lambda leaf: leaf.reshape(shape + leaf.shape[1:]), field
        )
        return Factor(tuple(self.attrs), field, ring)


def _delta_suffix(old_version: str, tag: str, codes, measures, weights) -> str:
    """Deterministic content-addressed suffix for one delta.

    Callers build the delta-rows version as ``{old}Δ{suffix}`` and the new
    relation version as ``{old}+{suffix}`` from the *same* suffix — deriving
    one from the other by splitting on ``Δ`` broke for caller-supplied
    versions that themselves contained a ``Δ`` (the split found the caller's
    delimiter first and grafted garbage into the new version).
    """
    h = hashlib.sha1()
    h.update(old_version.encode())
    h.update(tag.encode())
    for a in sorted(codes):
        h.update(codes[a].tobytes())
    for m in sorted(measures):
        h.update(np.ascontiguousarray(measures[m]).tobytes())
    if weights is not None:
        h.update(np.ascontiguousarray(weights).tobytes())
    return f"{tag}{h.hexdigest()[:10]}"


@dataclasses.dataclass(frozen=True)
class Delta:
    """A signed change taking ``relation`` from ``old_version`` to ``new_version``.

    ``rows`` is itself a :class:`Relation` (same schema) whose lift is the
    ⊕-difference between the two versions; its ``weights`` carry the sign.
    Deltas chain: applying them in sequence walks the version history.

    ``tombstoned`` marks stream-coalesced deltas whose deletes were retained
    as weight-0 rows in the new version rather than physically removed.  A
    ``"compact"`` delta (empty rows) records a tombstone-reclaiming version
    bump: the ⊕-difference is zero for group rings, so maintenance re-keys
    messages without contracting anything.
    """

    relation: str
    old_version: str
    new_version: str
    rows: Relation
    kind: str  # "append" | "delete" | "mixed" | "compact"
    tombstoned: bool = False

    @property
    def num_rows(self) -> int:
        return self.rows.num_rows

    def supported_by(self, ring: sr.Semiring) -> bool:
        """Can cached ⊕-state absorb this delta, or must consumers recompute?

        Appends always can (⊕ over a union).  Group rings absorb anything —
        deletes ride negated weights, compactions are ⊕-zero.  Idempotent
        rings (MIN/MAX/BOOL) additionally absorb *tombstoned* deltas: their
        lifts ignore weights, so the delta re-contributes values the cached
        messages already contain, and a ⊕ a = a keeps them correct for
        tombstone semantics (deletes invisible until compaction).
        """
        if self.kind == "append":
            return True
        if ring.has_add_inverse:
            return True
        return self.tombstoned and ring.idempotent_add


def lift_rows(rel: Relation, ring: sr.Semiring, measure: str | None = None) -> sr.Field:
    """Per-row semiring elements for a relation (paper §2 annotation lift)."""
    n = rel.num_rows
    w = (
        jnp.asarray(rel.weights, jnp.float32)
        if rel.weights is not None
        else jnp.ones((n,), jnp.float32)
    )
    if ring.name in ("count", "count_i64"):
        return w.astype(ring.dtype)
    if ring.name == "sum":
        col = jnp.asarray(rel.measures[measure], jnp.float32) if measure else jnp.ones((n,))
        return col * w
    if ring.name == "moments":
        if measure is None:  # relation doesn't carry the measure → ⊗-identity ⊙ count
            return (w, jnp.zeros_like(w), jnp.zeros_like(w))
        col = jnp.asarray(rel.measures[measure], jnp.float32)
        return sr.moments_lift(col, w)
    if ring.name in ("tropical_min", "tropical_max"):
        if measure is None:
            return jnp.zeros((n,), jnp.float32)  # ⊗-identity: joins add 0
        return jnp.asarray(rel.measures[measure], jnp.float32)
    if ring.name == "bool":
        return jnp.ones((n,), bool)
    raise KeyError(f"no default lift for ring {ring.name}; supply one via Query.lifts")


class Catalog:
    """Versioned relation store — the stand-in for DBMS tables.

    Readers resolve relations through ``_latest`` — the *committed watermark*.
    Writers may stage any number of versions (``put(make_latest=False)``)
    without affecting readers, then :meth:`commit` advances every flushed
    relation's latest pointer in one step, bumping the monotonic
    :attr:`watermark`.  A reader snapshotting versions (``Query.make``)
    therefore sees either all of a multi-relation tick or none of it — never
    a torn update.  ``commit_log`` keeps committed snapshots for
    introspection (tests assert reads only ever match a logged snapshot),
    bounded by :attr:`commit_retention` — but a reader can
    :meth:`pin_watermark` to hold its snapshot (and every later one) open
    across ticks: trimming only ever drops entries older than the oldest
    pinned watermark, so a long-running server session never loses the
    snapshot it is reading (the old fixed-128 deque silently dropped it).
    """

    def __init__(self, relations: Sequence[Relation] = ()):
        self._store: dict[tuple[str, str], Relation] = {}
        self._latest: dict[str, str] = {}
        self._watermark = 0
        self.commit_retention = 128
        self.commit_log: deque[tuple[int, dict[str, str]]] = deque()
        # watermark -> pin refcount (several sessions may read one snapshot)
        self._wm_pins: dict[int, int] = {}
        # device-resident flat-code cache keyed by (relation, version, attrs):
        # hoists the per-call np.ravel_multi_index + host→device transfer out
        # of the message hot path (compiled plans gather through these).
        self._dev_codes: LRU = LRU(capacity=512)
        # optional device placement for cached code arrays (a NamedSharding
        # over the engine mesh's row axis) — see set_row_placement
        self._row_placement = None
        for r in relations:
            self.put(r)

    def set_row_placement(self, placement) -> None:
        """Install a row placement applied to every cached flat-code array.

        ``Treant(mesh=...)`` passes the mesh's row-shard NamedSharding so
        sharded plans consume codes without a per-dispatch reshard copy; the
        cache is cleared so already-cached arrays re-place on next use.
        Codes are zero-padded to the power-of-two row bucket, so any equal
        block split of the leading axis is exact.
        """
        self._row_placement = placement
        self._dev_codes = LRU(capacity=512)

    def dev_flat_codes(self, rel: Relation, attrs: Sequence[str]) -> tuple[jax.Array, int]:
        """Device-resident ``rel.flat_codes(attrs)``, cached across calls.

        Codes are immutable per (name, version), so the cache never needs
        invalidation — new versions simply occupy new slots (LRU-bounded).
        Arrays are zero-padded to ``rel.row_bucket`` so they feed the
        bucket-shaped compiled plans directly: pad rows gather/aggregate at
        index 0 but carry ⊕-identity lift values, contributing nothing.
        """
        key = (rel.name, rel.version, tuple(attrs))
        hit = self._dev_codes.get(key)
        if hit is None:
            idx, total = rel.flat_codes(attrs)
            if total > np.iinfo(np.int32).max:  # pragma: no cover — huge domains
                raise ValueError(f"flat domain {total} overflows int32 codes")
            pad = rel.row_bucket - idx.size
            if pad > 0:
                idx = np.concatenate([idx, np.zeros((pad,), idx.dtype)])
            arr = jnp.asarray(idx.astype(np.int32))
            if self._row_placement is not None and (
                arr.shape[0] % getattr(self._row_placement.mesh, "size", 1) == 0
            ):
                arr = jax.device_put(arr, self._row_placement)
            hit = (arr, total)
            self._dev_codes.put(key, hit)
        return hit

    def put(self, rel: Relation, make_latest: bool = True) -> None:
        """Store a relation version; ``make_latest=False`` registers auxiliary
        versions (delta rows, staged tick output) without making them the
        default snapshot.  ``make_latest=True`` is a single-relation commit:
        it advances the watermark."""
        self._store[(rel.name, rel.version)] = rel
        if make_latest or rel.name not in self._latest:
            self._latest[rel.name] = rel.version
            self._advance_watermark()

    def commit(self, versions: Mapping[str, str]) -> int:
        """Atomically advance the latest pointer of every listed relation.

        Each version must already be staged (``put(make_latest=False)``).
        All pointers move together under ONE watermark bump — the commit
        point of a streaming tick.  Returns the new watermark.
        """
        for name, version in versions.items():
            if (name, version) not in self._store:
                raise KeyError(f"commit of unstaged version {name}@{version}")
        for name, version in versions.items():
            self._latest[name] = version
        if versions:
            self._advance_watermark()
        return self._watermark

    @property
    def watermark(self) -> int:
        return self._watermark

    def _advance_watermark(self) -> None:
        self._watermark += 1
        self.commit_log.append((self._watermark, dict(self._latest)))
        self._trim_commit_log()

    # -- snapshot-read pinning ------------------------------------------------
    def pin_watermark(self, wm: int | None = None) -> int:
        """Hold watermark ``wm`` (default: current) open: it and every later
        snapshot survive commit-log trimming until released.  Refcounted —
        pin/release pairs nest across sessions.  Returns the pinned mark."""
        wm = self._watermark if wm is None else wm
        self._wm_pins[wm] = self._wm_pins.get(wm, 0) + 1
        return wm

    def release_watermark(self, wm: int) -> None:
        c = self._wm_pins.get(wm, 0) - 1
        if c > 0:
            self._wm_pins[wm] = c
        else:
            self._wm_pins.pop(wm, None)
        self._trim_commit_log()

    @contextmanager
    def snapshot_read(self):
        """Scope a read against a pinned snapshot: yields the ``(watermark,
        versions)`` pair, guaranteed un-trimmed for the duration."""
        wm = self.pin_watermark()
        try:
            yield (wm, dict(self._latest))
        finally:
            self.release_watermark(wm)

    def _trim_commit_log(self) -> None:
        """Drop oldest snapshots beyond retention — but never a pinned one
        (or anything after it: a pinned reader may chase forward deltas)."""
        floor = min(self._wm_pins) if self._wm_pins else None
        while len(self.commit_log) > self.commit_retention:
            wm0, _ = self.commit_log[0]
            if floor is not None and wm0 >= floor:
                break
            self.commit_log.popleft()

    def get(self, name: str, version: str | None = None) -> Relation:
        v = version or self._latest[name]
        return self._store[(name, v)]

    def names(self) -> list[str]:
        return sorted(self._latest)

    def latest_version(self, name: str) -> str:
        return self._latest[name]

    def domains(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for (name, _), rel in self._store.items():
            for a, d in rel.domains.items():
                if a in out and out[a] != d:
                    raise ValueError(f"inconsistent domain for {a}")
                out[a] = d
        return out
