"""Sparse annotated relations (dictionary-encoded COO) and the catalog.

The DBMS the paper delegates storage to is replaced by this layer: a relation
is a set of dictionary-encoded attribute code columns plus numeric measure
columns.  ``lift_rows`` turns a relation into per-row semiring fields
(COUNT → 1̄, SUM → measure, MOMENTS → (1,x,x²), tropical → value, …);
``Relation.to_factor`` densifies via segment ⊕-aggregation (the
``segment_aggregate`` Pallas kernel's job on TPU).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr
from repro.core.factor import Factor


def _digest_array(a: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Predicate:
    """σ annotation: a boolean mask over one attribute's domain (paper §3.3).

    Hashable by content digest so message signatures (Prop. 2) can include it.
    """

    attr: str
    mask: np.ndarray  # bool (domain,)
    label: str = ""

    @property
    def digest(self) -> str:
        return f"{self.attr}:{_digest_array(self.mask)}"

    def __hash__(self):
        return hash(self.digest)

    def __eq__(self, other):
        return isinstance(other, Predicate) and self.digest == other.digest


def mask_in(domain: int, values: Sequence[int], attr: str = "", label: str = "") -> Predicate:
    m = np.zeros((domain,), bool)
    m[np.asarray(list(values), np.int64)] = True
    return Predicate(attr=attr, mask=m, label=label or f"{attr} IN {list(values)[:4]}")


def mask_range(domain: int, lo: int, hi: int, attr: str = "", label: str = "") -> Predicate:
    m = np.zeros((domain,), bool)
    m[lo:hi] = True
    return Predicate(attr=attr, mask=m, label=label or f"{lo}<={attr}<{hi}")


@dataclasses.dataclass(frozen=True)
class Relation:
    """Dictionary-encoded sparse annotated relation."""

    name: str
    attrs: tuple[str, ...]
    codes: Mapping[str, np.ndarray]        # attr -> int32 (N,)
    domains: Mapping[str, int]             # attr -> domain size
    measures: Mapping[str, np.ndarray] = dataclasses.field(default_factory=dict)
    weights: np.ndarray | None = None      # explicit multiplicity annotation
    version: str = "v0"

    @property
    def num_rows(self) -> int:
        return 0 if not self.attrs else int(self.codes[self.attrs[0]].shape[0])

    @property
    def key(self) -> tuple[str, str]:
        return (self.name, self.version)

    @property
    def digest(self) -> str:
        h = hashlib.sha1()
        h.update(self.name.encode())
        h.update(self.version.encode())
        return h.hexdigest()[:16]

    def with_version(self, version: str, **updates) -> "Relation":
        return dataclasses.replace(self, version=version, **updates)

    def filter_rows(self, row_mask: np.ndarray, version: str) -> "Relation":
        codes = {a: c[row_mask] for a, c in self.codes.items()}
        measures = {m: v[row_mask] for m, v in self.measures.items()}
        w = self.weights[row_mask] if self.weights is not None else None
        return dataclasses.replace(
            self, codes=codes, measures=measures, weights=w, version=version
        )

    def perturb_measure(self, measure: str, scale: float, seed: int, version: str) -> "Relation":
        """Random cell-value perturbation (paper §5.1.1 relation-update test)."""
        rng = np.random.default_rng(seed)
        col = self.measures[measure]
        new = col * (1.0 + scale * rng.standard_normal(col.shape)).astype(col.dtype)
        measures = dict(self.measures)
        measures[measure] = new
        return dataclasses.replace(self, measures=measures, version=version)

    # -- densification ------------------------------------------------------
    def flat_codes(self, attrs: Sequence[str]) -> tuple[np.ndarray, int]:
        attrs = list(attrs)
        if not attrs:
            return np.zeros((self.num_rows,), np.int64), 1
        dims = [self.domains[a] for a in attrs]
        idx = np.ravel_multi_index(
            tuple(self.codes[a].astype(np.int64) for a in attrs), dims
        )
        return idx, int(np.prod(dims))

    def to_factor(self, ring: sr.Semiring, measure: str | None = None) -> Factor:
        rows = lift_rows(self, ring, measure)
        idx, total = self.flat_codes(self.attrs)
        field = ring.segment_reduce(rows, jnp.asarray(idx), total)
        shape = tuple(self.domains[a] for a in self.attrs)
        field = jax.tree_util.tree_map(
            lambda leaf: leaf.reshape(shape + leaf.shape[1:]), field
        )
        return Factor(tuple(self.attrs), field, ring)


def lift_rows(rel: Relation, ring: sr.Semiring, measure: str | None = None) -> sr.Field:
    """Per-row semiring elements for a relation (paper §2 annotation lift)."""
    n = rel.num_rows
    w = (
        jnp.asarray(rel.weights, jnp.float32)
        if rel.weights is not None
        else jnp.ones((n,), jnp.float32)
    )
    if ring.name in ("count", "count_i64"):
        return w.astype(ring.dtype)
    if ring.name == "sum":
        col = jnp.asarray(rel.measures[measure], jnp.float32) if measure else jnp.ones((n,))
        return col * w
    if ring.name == "moments":
        if measure is None:  # relation doesn't carry the measure → ⊗-identity ⊙ count
            return (w, jnp.zeros_like(w), jnp.zeros_like(w))
        col = jnp.asarray(rel.measures[measure], jnp.float32)
        return sr.moments_lift(col, w)
    if ring.name in ("tropical_min", "tropical_max"):
        if measure is None:
            return jnp.zeros((n,), jnp.float32)  # ⊗-identity: joins add 0
        return jnp.asarray(rel.measures[measure], jnp.float32)
    if ring.name == "bool":
        return jnp.ones((n,), bool)
    raise KeyError(f"no default lift for ring {ring.name}; supply one via Query.lifts")


class Catalog:
    """Versioned relation store — the stand-in for DBMS tables."""

    def __init__(self, relations: Sequence[Relation] = ()):
        self._store: dict[tuple[str, str], Relation] = {}
        self._latest: dict[str, str] = {}
        for r in relations:
            self.put(r)

    def put(self, rel: Relation) -> None:
        self._store[(rel.name, rel.version)] = rel
        self._latest[rel.name] = rel.version

    def get(self, name: str, version: str | None = None) -> Relation:
        v = version or self._latest[name]
        return self._store[(name, v)]

    def names(self) -> list[str]:
        return sorted(self._latest)

    def latest_version(self, name: str) -> str:
        return self._latest[name]

    def domains(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for (name, _), rel in self._store.items():
            for a, d in rel.domains.items():
                if a in out and out[a] != d:
                    raise ValueError(f"inconsistent domain for {a}")
                out[a] = d
        return out
