"""Streaming ingestion: per-relation micro-batch coalescing (ISSUE 6 tentpole).

The paper's premise is dashboards over *live* joins: materialization pays off
only if sustained write traffic is absorbed without recalibrating CJTs per
row-batch.  A :class:`StreamBuffer` accumulates append/delete micro-batches
for one relation and, at each tick (``Treant.flush``), coalesces everything
pending into **one** signed :class:`~repro.relational.relation.Delta` — one
version bump and one ``apply_delta`` sweep of the n−1 outward messages per
tick, however many micro-batches arrived.

Coalescing rules:

- Rows appended *and* deleted within the same tick cancel: they never enter
  the delta (and never the relation) at all.
- Deleted pre-existing rows are **tombstoned** — kept physically at weight 0
  (the exact ⊕-zero under every group-ring lift) — and contribute negated
  original weights to the delta.  Keeping the rows makes the mixed delta
  absorbable by idempotent rings too (MIN/MAX/BOOL: lifts ignore weights,
  ⊕ is idempotent), so inverse-free rings do NOT fall back every tick.
- The buffer carries the tombstone ledger; once ``tombstone_fraction``
  crosses the compaction threshold, ``Treant.flush`` reclaims the rows via
  ``Relation.compact`` (a real recalibration for idempotent rings, scheduled
  at lowest priority — group rings just re-key).

Delete masks index the *current logical rows*: the buffered relation's rows
(tombstones included — re-deleting one is a no-op) followed by every row
appended in this tick, in arrival order.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .relation import Delta, Relation, _delta_suffix


@dataclasses.dataclass
class StreamStats:
    """Cumulative per-buffer ingest accounting (across ticks)."""

    batches: int = 0          # micro-batches accepted (append + delete calls)
    rows_appended: int = 0    # appended rows that survived into a delta
    rows_deleted: int = 0     # pre-existing rows tombstoned
    rows_cancelled: int = 0   # same-tick append+delete pairs (never materialized)
    ticks: int = 0            # coalesce() calls that produced a delta
    compactions: int = 0      # tombstone reclaims


class CompactionPolicy:
    """Per-relation compaction threshold learned from the observed delete mix.

    One global ``REPRO_COMPACTION_THRESHOLD`` mis-serves mixed workloads: an
    append-mostly relation should tolerate a deep tombstone ledger (compaction
    recalibrates idempotent rings — expensive, so defer), while a
    delete-heavy relation should reclaim early (its ledger grows every tick
    and each tombstone inflates every message contraction over the ring).

    The policy keeps an EWMA of each relation's per-tick delete fraction
    ``n_del / (n_del + n_app)`` and maps it to a threshold around the
    configured base: delete fraction 0 → ``1.5 × base`` (defer), delete
    fraction 1 → ``0.5 × base`` (eager), linear in between, clamped to
    ``[0.5 × base, min(0.9, 1.5 × base)]``.  A relation with no observations
    keeps the base threshold, and ``base <= 0`` still means "compact on any
    tombstone" regardless of the mix — existing tests and benches that pin
    the global knob keep their semantics.
    """

    def __init__(self, alpha: float = 0.25):
        self.alpha = alpha
        self._ewma: dict[str, float] = {}

    def observe(self, relation: str, n_app: int, n_del: int) -> None:
        """Fold one tick's delete mix into the relation's EWMA."""
        total = n_app + n_del
        if total <= 0:
            return
        frac = n_del / total
        prev = self._ewma.get(relation)
        self._ewma[relation] = (
            frac if prev is None else (1 - self.alpha) * prev + self.alpha * frac
        )

    def delete_mix(self, relation: str) -> float | None:
        """The learned EWMA delete fraction, or None before any observation."""
        return self._ewma.get(relation)

    def threshold(self, relation: str, base: float) -> float:
        if base <= 0:
            return base
        mix = self._ewma.get(relation)
        if mix is None:
            return base
        return min(0.9, base * (1.5 - mix))

    def state(self, base: float) -> dict[str, dict[str, float]]:
        """Learned per-relation posture for introspection/benching: the EWMA
        delete mix and the effective threshold derived from ``base``.  Only
        relations with at least one observation appear (sorted by name)."""
        return {
            name: {"ewma": ewma, "threshold": self.threshold(name, base)}
            for name, ewma in sorted(self._ewma.items())
        }


class StreamBuffer:
    """Accumulates one relation's pending micro-batches between ticks."""

    def __init__(self, rel: Relation):
        self._base = rel
        self._tombstones = rel.tombstone_count
        self.stats = StreamStats()
        self._reset_pending()

    def _reset_pending(self) -> None:
        self._app_codes: dict[str, list[np.ndarray]] = {a: [] for a in self._base.attrs}
        self._app_meas: dict[str, list[np.ndarray]] = {m: [] for m in self._base.measures}
        self._app_w: list[np.ndarray] = []
        self._app_del: list[np.ndarray] = []  # per-batch delete marks
        self._n_app = 0
        self._del_base: np.ndarray | None = None

    # -- introspection --------------------------------------------------------
    @property
    def relation(self) -> str:
        return self._base.name

    @property
    def base(self) -> Relation:
        """The relation version this buffer's pending batches chain from."""
        return self._base

    @property
    def pending_appends(self) -> int:
        return self._n_app

    @property
    def pending_deletes(self) -> int:
        n = 0 if self._del_base is None else int(self._del_base.sum())
        return n + sum(int(d.sum()) for d in self._app_del)

    @property
    def has_pending(self) -> bool:
        return self._n_app > 0 or (
            self._del_base is not None and bool(self._del_base.any())
        )

    def tombstone_fraction(self) -> float:
        """Fraction of the current base version's rows that are tombstones."""
        return self._tombstones / max(1, self._base.num_rows)

    # -- ingestion ------------------------------------------------------------
    def append(
        self,
        codes,
        measures=None,
        weights=None,
    ) -> int:
        """Queue an append micro-batch; returns the number of rows queued."""
        base = self._base
        measures = dict(measures or {})
        if set(codes) != set(base.attrs):
            raise ValueError(
                f"append codes {sorted(codes)} != attrs {sorted(base.attrs)}"
            )
        if set(measures) != set(base.measures):
            raise ValueError("appended rows must supply every measure column")
        arrs = {a: np.asarray(codes[a], np.int32) for a in base.attrs}
        n = arrs[base.attrs[0]].shape[0] if base.attrs else 0
        if n == 0:
            return 0
        for a in base.attrs:
            self._app_codes[a].append(arrs[a])
        for m in base.measures:
            self._app_meas[m].append(
                np.asarray(measures[m], base.measures[m].dtype)
            )
        self._app_w.append(
            np.asarray(weights, np.float32) if weights is not None
            else np.ones((n,), np.float32)
        )
        self._app_del.append(np.zeros((n,), bool))
        self._n_app += n
        self.stats.batches += 1
        return n

    def delete(self, row_mask) -> int:
        """Queue a delete micro-batch over the current logical rows.

        The mask covers ``base.num_rows + pending_appends`` rows: the base
        version's physical rows (tombstones included; re-deleting one is
        ignored) followed by this tick's appended rows in arrival order.
        Returns the number of rows newly marked.
        """
        row_mask = np.asarray(row_mask, bool)
        nb = self._base.num_rows
        expect = nb + self._n_app
        if row_mask.shape != (expect,):
            raise ValueError(f"mask shape {row_mask.shape} != ({expect},)")
        marked = 0
        base_part = row_mask[:nb].copy()
        if self._base.weights is not None:
            base_part &= np.asarray(self._base.weights, np.float32) != 0.0
        if self._del_base is None:
            if base_part.any():
                self._del_base = base_part
                marked += int(base_part.sum())
        else:
            newly = base_part & ~self._del_base
            self._del_base |= base_part
            marked += int(newly.sum())
        off = nb
        for d in self._app_del:
            part = row_mask[off:off + d.shape[0]]
            marked += int((part & ~d).sum())
            d |= part
            off += d.shape[0]
        self.stats.batches += 1
        return marked

    # -- tick boundary --------------------------------------------------------
    def coalesce(self, version: str | None = None) -> tuple[Relation, Delta | None]:
        """Collapse all pending micro-batches into one relation version and
        ONE signed delta; rebases the buffer onto the new version.

        Returns ``(base, None)`` when nothing pending survives (including the
        case where every appended row was deleted again within the tick).
        """
        base = self._base
        nb = base.num_rows
        # surviving appends
        if self._n_app:
            app_keep = ~np.concatenate(self._app_del)
            cancelled = int((~app_keep).sum())
            surv_codes = {
                a: np.concatenate(self._app_codes[a])[app_keep] for a in base.attrs
            }
            surv_meas = {
                m: np.concatenate(self._app_meas[m])[app_keep] for m in base.measures
            }
            surv_w = np.concatenate(self._app_w)[app_keep]
            n_surv = int(app_keep.sum())
        else:
            cancelled = n_surv = 0
            surv_codes = {a: np.zeros((0,), np.int32) for a in base.attrs}
            surv_meas = {m: np.zeros((0,), base.measures[m].dtype)
                         for m in base.measures}
            surv_w = np.zeros((0,), np.float32)
        del_mask = (
            self._del_base if self._del_base is not None
            else np.zeros((nb,), bool)
        )
        n_del = int(del_mask.sum())
        self._reset_pending()
        self.stats.rows_cancelled += cancelled
        if n_surv == 0 and n_del == 0:
            return base, None

        base_w = base._materialized_weights()
        delta_codes = {
            a: np.concatenate([surv_codes[a],
                               np.asarray(base.codes[a], np.int32)[del_mask]])
            for a in base.attrs
        }
        delta_meas = {
            m: np.concatenate([surv_meas[m], base.measures[m][del_mask]])
            for m in base.measures
        }
        delta_w = np.concatenate([surv_w, -base_w[del_mask]])
        suffix = _delta_suffix(base.version, "s", delta_codes, delta_meas, delta_w)
        new_version = version or f"{base.version}+{suffix}"
        delta_rows = dataclasses.replace(
            base, codes=delta_codes, measures=delta_meas, weights=delta_w,
            version=f"{base.version}Δ{suffix}",
        )
        # new relation: base rows (deleted ones tombstoned at weight 0)
        # followed by the surviving appends
        new_w = base_w.copy()
        new_w[del_mask] = 0.0
        keep_weights = (
            base.weights is not None or n_del > 0
            or bool((surv_w != 1.0).any())
        )
        new_rel = dataclasses.replace(
            base,
            codes={a: np.concatenate([np.asarray(base.codes[a], np.int32),
                                      surv_codes[a]]) for a in base.attrs},
            measures={m: np.concatenate([base.measures[m], surv_meas[m]])
                      for m in base.measures},
            weights=np.concatenate([new_w, surv_w]) if keep_weights else None,
            version=new_version,
        )
        kind = "append" if n_del == 0 else ("delete" if n_surv == 0 else "mixed")
        delta = Delta(
            relation=base.name, old_version=base.version,
            new_version=new_version, rows=delta_rows, kind=kind,
            tombstoned=n_del > 0,
        )
        self._base = new_rel
        self._tombstones += n_del
        self.stats.rows_appended += n_surv
        self.stats.rows_deleted += n_del
        self.stats.ticks += 1
        return new_rel, delta

    def rebase(self, rel: Relation) -> None:
        """Point the buffer at an externally produced version (compaction).

        Only valid between ticks — pending micro-batches index the old
        version's rows, so rebasing would silently misalign them.
        """
        if self.has_pending:
            raise ValueError("cannot rebase a buffer with pending micro-batches")
        self._base = rel
        self._tombstones = rel.tombstone_count
        self._reset_pending()
        self.stats.compactions += 1
