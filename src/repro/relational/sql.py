"""Restricted SQL front-end → SPJA Query IR (the middleware face of Treant).

Grammar (the paper's §3.3 parameterized SPJA form; case-insensitive):

    SELECT [attr, ...,] AGG(measure|*) FROM rel [, rel ...]
    [WHERE attr IN (v, ...) [AND attr BETWEEN lo AND hi] ...]
    [GROUP BY attr, ...]

AGG ∈ {COUNT, SUM, MIN, MAX, AVG}.  Join conditions are implicit (natural
joins over the catalog's join graph, as in the paper's system).  Relations
not mentioned in FROM are treated as R̄-removed when ``strict_from=True``.
"""

from __future__ import annotations

import re

from repro.core.query import Query
from .relation import Catalog, mask_in, mask_range

_AGG_RINGS = {
    "COUNT": "count", "SUM": "sum", "MIN": "tropical_min",
    "MAX": "tropical_max", "AVG": "moments",
}

_SELECT_RE = re.compile(
    r"^\s*SELECT\s+(?P<sel>.*?)\s+FROM\s+(?P<from>[\w\s,]+?)"
    r"(?:\s+WHERE\s+(?P<where>.*?))?"
    r"(?:\s+GROUP\s+BY\s+(?P<group>[\w\s,]+?))?\s*;?\s*$",
    re.IGNORECASE | re.DOTALL,
)
_AGG_RE = re.compile(r"(COUNT|SUM|MIN|MAX|AVG)\s*\(\s*(\*|[\w.]+)\s*\)", re.IGNORECASE)
_IN_RE = re.compile(r"([\w]+)\s+IN\s*\(([^)]*)\)", re.IGNORECASE)
_BETWEEN_RE = re.compile(r"([\w]+)\s+BETWEEN\s+(\d+)\s+AND\s+(\d+)", re.IGNORECASE)
_EQ_RE = re.compile(r"([\w]+)\s*=\s*(\d+)")


class SqlError(ValueError):
    pass


def parse(sql: str, catalog: Catalog, strict_from: bool = False) -> Query:
    m = _SELECT_RE.match(sql)
    if not m:
        raise SqlError(f"unsupported SQL shape: {sql!r}")
    sel, frm = m.group("sel"), m.group("from")
    agg = _AGG_RE.search(sel)
    if not agg:
        raise SqlError("SELECT must contain one aggregate (semi-ring SPJA only)")
    fn, arg = agg.group(1).upper(), agg.group(2)
    ring = _AGG_RINGS[fn]
    measure = None
    if arg != "*":
        if "." in arg:
            rel, col = arg.split(".")
        else:
            rel, col = _find_measure(catalog, arg)
        measure = (rel, col)
    elif fn != "COUNT":
        raise SqlError(f"{fn}(*) is not meaningful")

    group_by: tuple[str, ...] = ()
    if m.group("group"):
        group_by = tuple(a.strip() for a in m.group("group").split(",") if a.strip())

    preds = parse_predicates(m.group("where") or "", catalog)

    removed: list[str] = []
    if strict_from:
        mentioned = {r.strip() for r in frm.split(",")}
        removed = [n for n in catalog.names() if n not in mentioned]

    return Query.make(
        catalog, ring=ring, measure=measure, group_by=group_by,
        predicates=preds, removed=removed,
    )


def parse_predicates(where: str, catalog: Catalog) -> list:
    """Parse a WHERE fragment into σ Predicates (IN / BETWEEN / =).

    Shared by ``parse`` and the dashboard session layer
    (``Session.sql``), so SQL-expressed filters and typed ``SetFilter``
    events produce digest-identical predicates.
    """
    preds = []
    consumed = ""
    doms = catalog.domains()
    for pm in _IN_RE.finditer(where):
        attr = pm.group(1)
        vals = [int(v) for v in pm.group(2).split(",") if v.strip()]
        preds.append(mask_in(doms[attr], vals, attr=attr))
        consumed += pm.group(0)
    for pm in _BETWEEN_RE.finditer(where):
        attr = pm.group(1)
        preds.append(mask_range(doms[attr], int(pm.group(2)), int(pm.group(3)) + 1, attr=attr))
        consumed += pm.group(0)
    for pm in _EQ_RE.finditer(where):
        if pm.group(0) in consumed:
            continue
        attr = pm.group(1)
        preds.append(mask_in(doms[attr], [int(pm.group(2))], attr=attr))
    return preds


def _find_measure(catalog: Catalog, col: str) -> tuple[str, str]:
    for n in catalog.names():
        if col in catalog.get(n).measures:
            return n, col
    raise SqlError(f"measure column {col!r} not found in catalog")
