"""Synthetic datasets mirroring the paper's experimental schemas.

- ``chain``       Appendix D.3: R1(A1,A2)…Rr(Ar,Ar+1), fanout f, domain d.
- ``salesforce``  Fig 12-style star/snowflake: Opp fact + User→Role chain,
                  Camp, Acc dimensions (Sigma Computing dashboard, §5.1.1).
- ``flight``      §5.1.2 IDEBench-style: Flights fact + Carrier/Airport/Date.
- ``favorita``    §5.1.3 factorized-ML: Sales fact + Stores/Items/Trans/Dates
                  plus synthetic augmentation relations of varying correlation.
- ``tpch``        §5.2.1: mini customer/orders/lineitem/nation/region.
- ``tpcds_star``  §5.2.2 empty-bag experiment: Store_Sales + Time/Stores/Item.

Row counts are scaled down for the 1-vCPU container; the join-graph shapes
and relative size imbalances (large fact, small dims) match the paper.
"""

from __future__ import annotations

import numpy as np

from .relation import Catalog, Relation


def _rel(name, attrs, codes, domains, measures=None, weights=None):
    return Relation(
        name=name,
        attrs=tuple(attrs),
        codes={a: np.asarray(c, np.int32) for a, c in codes.items()},
        domains=dict(domains),
        measures={k: np.asarray(v, np.float32) for k, v in (measures or {}).items()},
        weights=weights,
    )


# ---------------------------------------------------------------------------
# Appendix D.3 chain schema
# ---------------------------------------------------------------------------

def chain(r: int, fanout: int, domain: int, seed: int = 0) -> Catalog:
    """R_i(A_i, A_{i+1}) with fanout f in both directions, domain d."""
    rels = []
    for i in range(r):
        a, b = f"A{i}", f"A{i + 1}"
        left = np.repeat(np.arange(domain), fanout)
        right = (left * fanout + np.tile(np.arange(fanout), domain)) % domain
        rels.append(
            _rel(f"R{i}", (a, b), {a: left, b: right}, {a: domain, b: domain})
        )
    return Catalog(rels)


# ---------------------------------------------------------------------------
# Salesforce-style dashboard schema (Fig 1 / Fig 12)
# ---------------------------------------------------------------------------

def salesforce(
    n_opp: int = 200_000,
    n_user: int = 2_000,
    n_camp: int = 500,
    n_acc: int = 1_000,
    n_role: int = 16,
    seed: int = 0,
) -> Catalog:
    rng = np.random.default_rng(seed)
    d = {
        "user_id": n_user, "camp_id": n_camp, "acc_id": n_acc, "role_id": n_role,
        "title": 12, "camp_type": 8, "start_q": 16, "state": 50, "stage": 6,
        "role_name": n_role,
    }
    opp = _rel(
        "Opp",
        ("user_id", "camp_id", "acc_id", "stage"),
        {
            "user_id": rng.integers(0, n_user, n_opp),
            "camp_id": rng.integers(0, n_camp, n_opp),
            "acc_id": rng.integers(0, n_acc, n_opp),
            "stage": rng.integers(0, d["stage"], n_opp),
        },
        d,
        measures={"amount": rng.gamma(2.0, 5_000.0, n_opp)},
    )
    user = _rel(
        "User",
        ("user_id", "role_id", "title"),
        {
            "user_id": np.arange(n_user),
            "role_id": rng.integers(0, n_role, n_user),
            "title": rng.integers(0, d["title"], n_user),
        },
        d,
    )
    role = _rel(
        "Role",
        ("role_id", "role_name"),
        {"role_id": np.arange(n_role), "role_name": np.arange(n_role)},
        d,
    )
    camp = _rel(
        "Camp",
        ("camp_id", "camp_type", "start_q"),
        {
            "camp_id": np.arange(n_camp),
            "camp_type": rng.integers(0, d["camp_type"], n_camp),
            "start_q": rng.integers(0, d["start_q"], n_camp),
        },
        d,
        measures={"budget": rng.gamma(2.0, 1_000.0, n_camp)},
    )
    acc = _rel(
        "Acc",
        ("acc_id", "state"),
        {"acc_id": np.arange(n_acc), "state": rng.integers(0, d["state"], n_acc)},
        d,
    )
    return Catalog([opp, user, role, camp, acc])


# ---------------------------------------------------------------------------
# Flight / IDEBench-style schema (§5.1.2)
# ---------------------------------------------------------------------------

def flight(
    n_flights: int = 300_000,
    n_airports: int = 400,
    n_carriers: int = 30,
    n_dates: int = 365,
    seed: int = 1,
) -> Catalog:
    rng = np.random.default_rng(seed)
    d = {
        "carrier_id": n_carriers, "airport_id": n_airports, "date_id": n_dates,
        "carrier_group": 6, "airport_state": 52, "airport_size": 4,
        "month": 12, "dow": 7, "delay_bucket": 10, "distance_bucket": 8,
    }
    flights = _rel(
        "Flights",
        ("carrier_id", "airport_id", "date_id", "delay_bucket", "distance_bucket"),
        {
            "carrier_id": rng.integers(0, n_carriers, n_flights),
            "airport_id": rng.integers(0, n_airports, n_flights),
            "date_id": rng.integers(0, n_dates, n_flights),
            "delay_bucket": rng.integers(0, d["delay_bucket"], n_flights),
            "distance_bucket": rng.integers(0, d["distance_bucket"], n_flights),
        },
        d,
        measures={"dep_delay": rng.gamma(1.5, 10.0, n_flights)},
    )
    carrier = _rel(
        "Carrier",
        ("carrier_id", "carrier_group"),
        {"carrier_id": np.arange(n_carriers),
         "carrier_group": rng.integers(0, d["carrier_group"], n_carriers)},
        d,
    )
    airport = _rel(
        "Airport",
        ("airport_id", "airport_state", "airport_size"),
        {"airport_id": np.arange(n_airports),
         "airport_state": rng.integers(0, d["airport_state"], n_airports),
         "airport_size": rng.integers(0, d["airport_size"], n_airports)},
        d,
    )
    dates = _rel(
        "Dates",
        ("date_id", "month", "dow"),
        {"date_id": np.arange(n_dates),
         "month": (np.arange(n_dates) // 31) % 12,
         "dow": np.arange(n_dates) % 7},
        d,
    )
    return Catalog([flights, carrier, airport, dates])


# ---------------------------------------------------------------------------
# Favorita-style ML-augmentation schema (§5.1.3, Fig 17)
# ---------------------------------------------------------------------------

def favorita(
    n_sales: int = 100_000,
    n_stores: int = 54,
    n_items: int = 400,
    n_dates: int = 120,
    seed: int = 2,
) -> Catalog:
    rng = np.random.default_rng(seed)
    d = {
        "store": n_stores, "item": n_items, "date": n_dates,
        "store_type": 5, "cluster": 17, "family": 12, "perishable": 2,
        "dow": 7, "month": 12,
    }
    sales = _rel(
        "Sales",
        ("store", "item", "date"),
        {
            "store": rng.integers(0, n_stores, n_sales),
            "item": rng.integers(0, n_items, n_sales),
            "date": rng.integers(0, n_dates, n_sales),
        },
        d,
        measures={"unit_sales": rng.gamma(2.0, 4.0, n_sales)},
    )
    stores = _rel(
        "Stores",
        ("store", "store_type", "cluster"),
        {"store": np.arange(n_stores),
         "store_type": rng.integers(0, d["store_type"], n_stores),
         "cluster": rng.integers(0, d["cluster"], n_stores)},
        d,
    )
    items = _rel(
        "Items",
        ("item", "family", "perishable"),
        {"item": np.arange(n_items),
         "family": rng.integers(0, d["family"], n_items),
         "perishable": rng.integers(0, 2, n_items)},
        d,
        measures={"item_weight": rng.gamma(2.0, 1.0, n_items)},
    )
    # transactions per (store, date) — the regression target's source
    st, dt = np.meshgrid(np.arange(n_stores), np.arange(n_dates), indexing="ij")
    base = rng.gamma(5.0, 300.0, n_stores)[st.ravel()]
    season = 1.0 + 0.3 * np.sin(2 * np.pi * dt.ravel() / 7.0)
    trans = _rel(
        "Trans",
        ("store", "date"),
        {"store": st.ravel(), "date": dt.ravel()},
        d,
        measures={"transactions": (base * season).astype(np.float32)},
    )
    dates = _rel(
        "Dates",
        ("date", "dow", "month"),
        {"date": np.arange(n_dates),
         "dow": np.arange(n_dates) % 7,
         "month": (np.arange(n_dates) // 31) % 12},
        d,
    )
    return Catalog([sales, stores, items, trans, dates])


def favorita_augmentations(
    cat: Catalog, n_per_key: int = 10, seed: int = 3
) -> list[Relation]:
    """Synthetic (k, v) augmentation relations with correlation φ to Ŷ (§5.1.3).

    φ ~ min(1, 1/Exp(10)); v = φ·Ŷ_norm + (1-φ)·noise.
    """
    rng = np.random.default_rng(seed)
    trans = cat.get("Trans")
    out: list[Relation] = []
    for key in ("store", "date", "item"):
        dom = cat.domains()[key]
        # Ŷ: mean target grouped by key (items get a synthetic proxy)
        if key in trans.attrs:
            y = np.zeros(dom)
            cnt = np.zeros(dom)
            np.add.at(y, trans.codes[key], trans.measures["transactions"])
            np.add.at(cnt, trans.codes[key], 1.0)
            yhat = y / np.maximum(cnt, 1.0)
        else:
            yhat = rng.gamma(5.0, 300.0, dom)
        yhat = (yhat - yhat.mean()) / (yhat.std() + 1e-6)
        for j in range(n_per_key):
            phi = min(1.0, 1.0 / rng.exponential(10.0))
            noise = rng.standard_normal(dom)
            v = phi * yhat + (1.0 - phi) * noise
            out.append(
                _rel(
                    f"Aug_{key}_{j}",
                    (key,),
                    {key: np.arange(dom)},
                    dict(cat.domains()),
                    measures={"v": v.astype(np.float32), "phi": np.full(dom, phi, np.float32)},
                )
            )
    return out


# ---------------------------------------------------------------------------
# TPC-H-style mini schema (§5.2.1)
# ---------------------------------------------------------------------------

def tpch(
    n_lineitem: int = 300_000,
    n_orders: int = 60_000,
    n_cust: int = 6_000,
    n_supp: int = 400,
    seed: int = 4,
) -> Catalog:
    rng = np.random.default_rng(seed)
    n_nation, n_region = 25, 5
    d = {
        "orderkey": n_orders, "custkey": n_cust, "suppkey": n_supp,
        "nationkey": n_nation, "regionkey": n_region, "s_nationkey": n_nation,
        "mktsegment": 5, "orderdate_b": 24, "shippriority": 2,
        "shipdate_b": 24, "returnflag": 3, "ptype": 10,
    }
    lineitem = _rel(
        "Lineitem",
        ("orderkey", "suppkey", "shipdate_b", "returnflag", "ptype"),
        {
            "orderkey": rng.integers(0, n_orders, n_lineitem),
            "suppkey": rng.integers(0, n_supp, n_lineitem),
            "shipdate_b": rng.integers(0, 24, n_lineitem),
            "returnflag": rng.integers(0, 3, n_lineitem),
            "ptype": rng.integers(0, 10, n_lineitem),
        },
        d,
        measures={"revenue": rng.gamma(2.0, 1_000.0, n_lineitem)},
    )
    orders = _rel(
        "Orders",
        ("orderkey", "custkey", "orderdate_b", "shippriority"),
        {
            "orderkey": np.arange(n_orders),
            "custkey": rng.integers(0, n_cust, n_orders),
            "orderdate_b": rng.integers(0, 24, n_orders),
            "shippriority": rng.integers(0, 2, n_orders),
        },
        d,
    )
    customer = _rel(
        "Customer",
        ("custkey", "mktsegment", "nationkey"),
        {
            "custkey": np.arange(n_cust),
            "mktsegment": rng.integers(0, 5, n_cust),
            "nationkey": rng.integers(0, n_nation, n_cust),
        },
        d,
    )
    # Customer and Supplier both referencing the SAME nation attribute would
    # make the join graph cyclic (the paper breaks exactly this Q5 cycle by
    # conditioning on the group-by attribute).  The default catalog keeps the
    # acyclic form: supplier nations are a separate attribute; Nation hangs
    # off Customer.
    supplier = _rel(
        "Supplier",
        ("suppkey", "s_nationkey"),
        {"suppkey": np.arange(n_supp), "s_nationkey": rng.integers(0, n_nation, n_supp)},
        d,
    )
    nation = _rel(
        "Nation",
        ("nationkey", "regionkey"),
        {"nationkey": np.arange(n_nation), "regionkey": np.arange(n_nation) % n_region},
        d,
    )
    return Catalog([lineitem, orders, customer, supplier, nation])


# ---------------------------------------------------------------------------
# TPC-DS-style star for the empty-bag experiment (§5.2.2, Fig 5)
# ---------------------------------------------------------------------------

def tpcds_star(
    n_sales: int = 400_000,
    n_stores: int = 60,
    n_times: int = 512,
    n_items: int = 2_000,
    seed: int = 5,
) -> Catalog:
    rng = np.random.default_rng(seed)
    d = {
        "store_key": n_stores, "time_key": n_times, "item_key": n_items,
        "store_size": 4, "hour": 24, "item_cat": 20,
    }
    sales = _rel(
        "Store_Sales",
        ("store_key", "time_key", "item_key"),
        {
            "store_key": rng.integers(0, n_stores, n_sales),
            "time_key": rng.integers(0, n_times, n_sales),
            "item_key": rng.integers(0, n_items, n_sales),
        },
        d,
        measures={"sales_price": rng.gamma(2.0, 20.0, n_sales)},
    )
    stores = _rel(
        "Stores",
        ("store_key", "store_size"),
        {"store_key": np.arange(n_stores),
         "store_size": rng.integers(0, 4, n_stores)},
        d,
    )
    times = _rel(
        "Time",
        ("time_key", "hour"),
        {"time_key": np.arange(n_times), "hour": np.arange(n_times) % 24},
        d,
    )
    items = _rel(
        "Item",
        ("item_key", "item_cat"),
        {"item_key": np.arange(n_items),
         "item_cat": rng.integers(0, 20, n_items)},
        d,
    )
    return Catalog([sales, stores, times, items])
