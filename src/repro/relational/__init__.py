"""Relational substrate: sparse annotated relations, schemas, generators, SQL."""

from .relation import Relation, Catalog, Delta, lift_rows, mask_in, Predicate  # noqa: F401
from .stream import StreamBuffer, StreamStats  # noqa: F401
from . import schema  # noqa: F401
