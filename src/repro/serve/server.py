"""TreantServer: N concurrent dashboard sessions over ONE Treant.

The paper positions Treant as dashboard *middleware*; everything below
``repro.serve`` still assumes one :class:`~repro.core.dashboard.Session`
driven synchronously by one caller.  This module turns that single-user
engine into a serving tier:

- **Event queue with micro-batching and backpressure.**  ``submit`` enqueues
  typed dashboard events against a bounded queue.  A newer ``SetFilter`` /
  ``ClearFilter`` on the same dimension (or ``SwapMeasure`` on the same viz)
  from the same session *coalesces* the queued one away — the user moved the
  brush again before the server got to the stale position, so it is never
  executed.  When the queue is full, ``backpressure="drain"`` synchronously
  drains one micro-batch to make room and ``"reject"`` raises
  :class:`QueueFull` (the client retries).

- **Cross-session batched fan-out.**  ``step`` drains one micro-batch with
  per-session fairness (at most one event per session per batch, FIFO among
  sessions), records every event on its session's declarative state, and
  then runs ONE fan-out for the whole batch: identical derived queries
  across sessions dedupe to a single execution (sessions over one shared
  ``DashboardSpec`` brushing the same σ — the common BI case), and the rest
  group through ``CJTEngine.execute_many``, whose ``absorb_batch_key``
  grouping is session-agnostic — so two users brushing *different* σ values
  of the same spec still share one vmapped dispatch and one calibrated
  message set.  Results are distributed per session bit-identically to a
  serial per-session apply (⊕-identity padding is ⊗-absorbing; see
  ``tests/test_batched_plans.py``).

- **Global store byte budget.**  ``max_store_bytes`` bounds the shared
  :class:`~repro.core.calibration.MessageStore`; eviction is priority-
  ordered (pin-state → recency → estimated recompute cost) and never drops
  pinned or in-flight entries — an evicted message recomputes on demand,
  bit-identically, so budgets trade latency for memory, never correctness.

- **Server-driven think-time.**  ``idle`` uses empty-queue capacity to run
  background ``flush()`` ticks (streaming ingest moves off the caller
  thread), drain the shared :class:`ThinkTimeScheduler`, and run the
  configured :class:`~repro.core.predictive.ThinkTimePolicy`'s speculative
  extras per session — σ-prefetch fan-outs and bin cubes both land in a
  *shared* pool any session may hit (a pooled γ∪{dim} cube serves every σ
  on its dimension, not just the parked digest).  The legacy
  ``TreantServer(speculate=k)`` deprecation-shims onto ``FixedKPrefetch(k)``.

Counters surface through ``Treant.cache_stats()['serve']``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

import jax

from repro.core.calibration import CJTEngine, ExecStats
from repro.core.plans import slice_bin_cube
from repro.core.predictive import (
    FixedKPrefetch,
    ThinkTimeBudget,
    ThinkTimePolicy,
    warn_deprecated_once,
)
from repro.core.dashboard import (
    ApplyResult,
    ClearFilter,
    DashboardSpec,
    InteractionResult,
    Session,
    SetFilter,
    SwapMeasure,
    Undo,
    _group_by_engine,
)
from repro.core.query import Query
from repro.core.treant import Treant


class QueueFull(RuntimeError):
    """Raised by ``submit`` under ``backpressure="reject"`` when the bounded
    event queue is at capacity (the client should retry after a beat)."""


@dataclasses.dataclass
class ServeStats:
    """Cumulative serving-tier counters (``cache_stats()['serve']``)."""

    events_submitted: int = 0
    events_processed: int = 0
    batches: int = 0                  # micro-batches drained
    coalesced_events: int = 0         # superseded while queued (never executed)
    rejected_events: int = 0          # QueueFull raises under "reject"
    backpressure_drains: int = 0      # forced drains under "drain"
    queue_peak: int = 0               # high-water queue depth
    cross_session_batch_width: int = 0  # max distinct sessions in one dispatch
    dedup_hits: int = 0               # events served by a sibling's execution
    shared_prefetch_hits: int = 0     # events served from the shared pool
    pool_cube_hits: int = 0           # events sliced from a pooled bin cube
    pool_evictions: int = 0           # shared-pool entries dropped at capacity
    background_flushes: int = 0       # flush() ticks run off the caller thread
    think_time_messages: int = 0      # calibration edges advanced while idle
    errors: int = 0                   # events whose _record raised


@dataclasses.dataclass
class _Queued:
    sid: str
    event: object
    seq: int


@dataclasses.dataclass
class _Pooled:
    """One shared-pool speculative result (any session may hit it).

    ``cost`` estimates what re-materializing the entry would take (rows the
    query's join sees); ``hot`` marks entries hit in the current micro-batch
    so they are never evicted before the batch's siblings finish reading.
    ``dim`` is set on bin-cube entries (the γ∪{dim} aggregate is sliceable
    for ANY σ on that dimension, not just the exact parked digest)."""

    factor: object
    query: Query
    cost: float = 0.0
    hot: bool = False
    dim: str | None = None


class ServerSession:
    """A client's handle on one served session.

    Wraps the underlying :class:`Session` (exposed as ``.session`` for
    reads/introspection); writes go through the server's queue so they batch
    with sibling sessions' events.
    """

    def __init__(self, server: "TreantServer", session: Session):
        self._server = server
        self.session = session
        self.id = session.id
        # per-session results of the last batch this session participated in
        self.last_result: ApplyResult | None = None
        self._pinned_wm = server.treant.catalog.pin_watermark()

    def submit(self, event) -> None:
        self._server.submit(self.id, event)

    def read(self, viz: str) -> InteractionResult:
        return self.session.read(viz)

    def query_of(self, viz: str) -> Query:
        return self.session.query_of(viz)

    def close(self) -> None:
        self._server.close_session(self.id)

    # -- snapshot pinning -----------------------------------------------------
    def _refresh_pin(self) -> None:
        """Advance the commit-log pin to the watermark this session now
        reads; the old snapshot becomes trimmable once nobody holds it."""
        cat = self._server.treant.catalog
        if self._pinned_wm != cat.watermark:
            cat.release_watermark(self._pinned_wm)
            self._pinned_wm = cat.pin_watermark()

    def _release_pin(self) -> None:
        self._server.treant.catalog.release_watermark(self._pinned_wm)


class TreantServer:
    """Admit N concurrent sessions over one Treant/store/plan-cache."""

    def __init__(
        self,
        treant: Treant,
        max_queue: int = 256,
        backpressure: str = "drain",
        max_store_bytes: int | None = None,
        think_budget_messages: int = 64,
        speculate: int = 0,
        pool_capacity: int = 256,
        policy: ThinkTimePolicy | None = None,
    ):
        if backpressure not in ("drain", "reject"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        self.treant = treant
        self.max_queue = max_queue
        self.backpressure = backpressure
        self.think_budget_messages = think_budget_messages
        self.speculate = speculate
        if speculate:
            warn_deprecated_once(
                "TreantServer(speculate=)",
                "TreantServer(speculate=k) is deprecated; pass "
                "policy=FixedKPrefetch(k) instead",
            )
            if policy is None:
                policy = FixedKPrefetch(speculate)
        # None falls back to the Treant's default policy at each idle tick
        self.policy = policy
        self.pool_capacity = pool_capacity
        if max_store_bytes is not None:
            treant.store.max_bytes = max_store_bytes
        treant._server = self
        self._queue: deque[_Queued] = deque()
        self._seq = 0
        self._sessions: dict[str, ServerSession] = {}
        # shared speculative-prefetch pool: query digest -> parked fan-out
        # result; insertion order IS recency order (hits reinsert at the
        # end), and capacity eviction takes the cheapest-to-recompute entry
        # of the coldest window — same policy as the message store's byte
        # budget, minus pins: recency, then recompute cost
        self._pool: dict[str, _Pooled] = {}
        self.stats_ = ServeStats()

    # -- sessions -------------------------------------------------------------
    def open_session(
        self, spec: DashboardSpec, name: str | None = None, calibrate: bool = True
    ) -> ServerSession:
        sess = self.treant.open_session(spec, name=name, calibrate=calibrate)
        handle = ServerSession(self, sess)
        self._sessions[handle.id] = handle
        return handle

    def close_session(self, sid: str) -> None:
        handle = self._sessions.pop(sid, None)
        if handle is None:
            return
        # drop the session's queued events (they will never be served)
        self._queue = deque(q for q in self._queue if q.sid != sid)
        handle._release_pin()
        handle.session.close()

    @property
    def sessions(self) -> tuple[str, ...]:
        return tuple(sorted(self._sessions))

    # -- event queue ----------------------------------------------------------
    def submit(self, sid: str, event) -> None:
        """Enqueue one event; coalesce superseded queued work; backpressure."""
        if sid not in self._sessions:
            raise KeyError(f"no server session {sid!r}")
        self.stats_.events_submitted += 1
        self._coalesce(sid, event)
        if len(self._queue) >= self.max_queue:
            if self.backpressure == "reject":
                self.stats_.rejected_events += 1
                raise QueueFull(
                    f"event queue at capacity ({self.max_queue}); retry"
                )
            self.stats_.backpressure_drains += 1
            self.step()
        self._queue.append(_Queued(sid, event, self._seq))
        self._seq += 1
        self.stats_.queue_peak = max(self.stats_.queue_peak, len(self._queue))

    def _coalesce(self, sid: str, event) -> None:
        """Drop queued same-session events the new one supersedes.

        A newer σ on the same dimension (SetFilter/ClearFilter share the
        last-writer-wins ``_filters[attr]`` slot) or a newer measure on the
        same viz obsoletes the queued event — the stale brush position is
        never executed.  Sessions with a queued ``Undo`` are exempt: each
        applied event pushes an undo snapshot, so dropping one would change
        what Undo reverts to.
        """
        if isinstance(event, (SetFilter, ClearFilter)):
            key = ("filter", event.attr)
        elif isinstance(event, SwapMeasure):
            key = ("measure", event.viz)
        else:
            return
        if any(q.sid == sid and isinstance(q.event, Undo) for q in self._queue):
            return

        def _key(ev):
            if isinstance(ev, (SetFilter, ClearFilter)):
                return ("filter", ev.attr)
            if isinstance(ev, SwapMeasure):
                return ("measure", ev.viz)
            return None

        before = len(self._queue)
        self._queue = deque(
            q for q in self._queue
            if not (q.sid == sid and _key(q.event) == key)
        )
        self.stats_.coalesced_events += before - len(self._queue)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # -- micro-batch draining (the cross-session fan-out) ----------------------
    def _next_batch(self) -> list[_Queued]:
        """At most one event per session, FIFO among sessions (fairness: a
        bursty session cannot starve siblings out of a batch)."""
        batch: list[_Queued] = []
        taken: set[str] = set()
        rest: deque[_Queued] = deque()
        while self._queue:
            q = self._queue.popleft()
            if q.sid in taken:
                rest.append(q)
            else:
                taken.add(q.sid)
                batch.append(q)
        self._queue = rest
        return batch

    def step(self) -> int:
        """Drain ONE micro-batch; returns the number of events processed.

        All events are recorded on their sessions' declarative state first,
        then the union of affected (session, viz) pairs executes as one
        shared fan-out: prefetch-pool hits and cross-session duplicates are
        served without execution, and the remainder dispatches through ONE
        ``execute_many`` per engine so sibling sessions' absorptions share
        vmapped plans and one calibrated message set.
        """
        batch = self._next_batch()
        if not batch:
            return 0
        self.stats_.batches += 1
        # batch boundary: last batch's pool hits lose their eviction shield
        for pooled in self._pool.values():
            pooled.hot = False
        participants: list[tuple[ServerSession, object]] = []
        for q in batch:
            handle = self._sessions.get(q.sid)
            if handle is None:  # closed while queued
                continue
            try:
                changed = handle.session._record(q.event)
            except Exception:
                self.stats_.errors += 1
                continue
            self.stats_.events_processed += 1
            if changed:
                participants.append((handle, q.event))
        self._fan_out(participants)
        for handle, _ in participants:
            handle._refresh_pin()
        return len(batch)

    def _fan_out(self, participants: list[tuple[ServerSession, object]]) -> None:
        # (handle, viz, query) for every re-rendering viz across all sessions
        work: list[tuple[ServerSession, str, Query]] = []
        derived_by_sid: dict[str, dict[str, Query]] = {}
        for handle, _ in participants:
            derived, affected = handle.session._derived_affected()
            derived_by_sid[handle.id] = derived
            for viz in affected:
                work.append((handle, viz, derived[viz]))
        if not work:
            for handle, event in participants:
                handle.last_result = ApplyResult(
                    event, (), {}, dict(handle.session._current), 0.0
                )
            return
        results: dict[tuple[str, str], InteractionResult] = {}
        # 1) prefetch: session-local first (exact _fan_out semantics), then
        #    the server's shared pool (any session may hit another's parked
        #    speculation — digests are session-agnostic), then bin cubes —
        #    session-local and pooled — which cover ANY σ on their dimension
        to_exec: list[tuple[ServerSession, str, Query]] = []
        pool_dims = sorted({
            e.dim for e in self._pool.values() if e.dim is not None
        })
        for handle, viz, q in work:
            sess = handle.session
            hit = sess._prefetched.pop((viz, q.digest), None)
            if hit is not None:
                sess.prefetch_hits += 1
                results[(handle.id, viz)] = InteractionResult(
                    hit.factor, ExecStats(prefetch_hits=1), 0.0, 0
                )
                continue
            pooled = self._pool.get(q.digest)
            if pooled is not None:
                self.stats_.shared_prefetch_hits += 1
                # a hit refreshes recency (reinsert at the warm end) and
                # shields the entry from eviction for the rest of this batch
                del self._pool[q.digest]
                self._pool[q.digest] = pooled
                pooled.hot = True
                results[(handle.id, viz)] = InteractionResult(
                    pooled.factor, ExecStats(prefetch_hits=1), 0.0, 0
                )
                continue
            sliced = sess._probe_bin_cube(viz, q)
            if sliced is not None:
                results[(handle.id, viz)] = InteractionResult(
                    sliced, ExecStats(bin_cube_hits=1), 0.0, 0
                )
                continue
            sliced = self._probe_pool_cube(sess, q, pool_dims)
            if sliced is not None:
                results[(handle.id, viz)] = InteractionResult(
                    sliced, ExecStats(bin_cube_hits=1), 0.0, 0
                )
                continue
            to_exec.append((handle, viz, q))
        # 2) dedupe identical queries across sessions: execute once, share
        #    the factor (the shared-spec same-σ case)
        first_of: dict[str, tuple[ServerSession, str, Query]] = {}
        followers: dict[str, list[tuple[ServerSession, str]]] = {}
        for handle, viz, q in to_exec:
            if q.digest in first_of:
                followers.setdefault(q.digest, []).append((handle, viz))
            else:
                first_of[q.digest] = (handle, viz, q)
        uniques = list(first_of.values())
        # 3) ONE execute_many per engine across ALL sessions: absorb_batch_key
        #    grouping is session-agnostic, so sibling sessions' differing-σ
        #    absorptions ride one vmapped dispatch
        executed: dict[str, tuple[object, ExecStats]] = {}
        pending = []
        for engine, items in _group_by_engine(
            (self.treant.engine_for(q.ring_name, q.measure), (handle, viz, q))
            for handle, viz, q in uniques
        ):
            if self.treant.batch_fanout and len(items) > 1:
                group = engine.execute_many(
                    [q for _, _, q in items], sync=False,
                    tags=[f"{h.id}:{viz}" for h, viz, _ in items],
                )
            else:
                group = []
                for handle, viz, q in items:
                    store = self.treant.store
                    store.tag = f"{handle.id}:{viz}"
                    try:
                        group.append(engine.execute(q, sync=False))
                    finally:
                        store.tag = None
            for (handle, viz, q), (factor, stats) in zip(items, group):
                executed[q.digest] = (factor, stats)
                pending.append(factor)
                self._schedule(handle, viz, q, engine)
        if pending:
            jax.block_until_ready([f.field for f in pending])
        # cross-session width: the max of (a) distinct sessions inside one
        # vmapped dispatch and (b) distinct sessions sharing one deduped
        # execution — both are "one dispatch served k sessions"
        width = max(
            (st.batch_sessions for _, st in executed.values()), default=0
        )
        for digest, flw in followers.items():
            owners = {h.id for h, _ in flw} | {first_of[digest][0].id}
            width = max(width, len(owners))
        self.stats_.cross_session_batch_width = max(
            self.stats_.cross_session_batch_width, width
        )
        # 4) distribute: leaders
        for digest, (handle, viz, q) in first_of.items():
            factor, stats = executed[digest]
            results[(handle.id, viz)] = InteractionResult(
                factor, stats, 0.0, stats.steiner_size
            )
        #    followers share the leader's factor verbatim (bit-identical by
        #    construction) and re-schedule their own calibration
        for digest, flw in followers.items():
            factor, _ = executed[digest]
            for handle, viz in flw:
                self.stats_.dedup_hits += 1
                results[(handle.id, viz)] = InteractionResult(
                    factor, ExecStats(messages_reused=1), 0.0, 0
                )
        # 5) commit per-session view state; park calibration for every
        #    re-rendered viz that was NOT a leader (leaders scheduled above)
        leaders = {(h.id, v) for h, v, _ in uniques}
        for handle, viz, q in work:
            handle.session._current[viz] = q
            if (handle.id, viz) not in leaders:
                engine = self.treant.engine_for(q.ring_name, q.measure)
                self._schedule(handle, viz, q, engine)
        for handle, event in participants:
            sess = handle.session
            derived = derived_by_sid[handle.id]
            affected = tuple(
                viz for h, viz, _ in work if h.id == handle.id
            )
            handle.last_result = ApplyResult(
                event, affected,
                {viz: results[(handle.id, viz)]
                 for viz in affected if (handle.id, viz) in results},
                derived, 0.0,
            )

    def _schedule(self, handle: ServerSession, viz: str, q: Query,
                  engine: CJTEngine) -> None:
        self.treant.scheduler.schedule(handle.id, viz, q, engine)

    def _probe_pool_cube(self, sess: Session, q: Query, pool_dims):
        """Serve ``q`` from a pooled bin cube (possibly another session's):
        for each dimension with a cube in the pool, rebuild the cube digest
        from the incoming query and slice on a match."""
        for dim in pool_dims:
            cq = sess._cube_query(q, dim)
            if cq is None:
                continue
            entry = self._pool.get(cq.digest)
            if entry is None or entry.dim != dim:
                continue
            del self._pool[cq.digest]  # recency refresh + batch shield
            self._pool[cq.digest] = entry
            entry.hot = True
            self.stats_.pool_cube_hits += 1
            sess.bin_cube_hits += 1
            engine = self.treant.engine_for(q.ring_name, q.measure)
            return slice_bin_cube(
                entry.factor, dim,
                [p.mask for p in q.predicates_on(dim)], q.group_by,
                stats=engine.plans.stats if engine.plans is not None else None,
            )
        return None

    # -- server-driven think-time ----------------------------------------------
    def idle(self, budget_messages: int | None = None) -> int:
        """Spend empty-queue capacity on background work.

        Background flush always runs first (queued stream data makes every
        other think-time item stale), then ONE global scheduler drain under
        ``budget_messages`` (default: the server's configured budget), then
        the think-time policy's speculative extras per session
        (``self.policy``, else the Treant's default) — σ prefetch and/or bin
        cubes, both published into the shared pool so ANY session hitting
        the same digest (or any σ on a pooled cube's dimension) is served.
        Returns the number of calibration edges advanced.
        """
        if self._queue:
            return 0  # queued interactive work always wins
        if any(b.has_pending for b in self.treant._streams.values()):
            self.treant.flush()
            self.stats_.background_flushes += 1
            for handle in self._sessions.values():
                handle._refresh_pin()
        budget = (
            budget_messages if budget_messages is not None
            else self.think_budget_messages
        )
        done = self.treant.scheduler.run(budget_messages=budget)
        self.stats_.think_time_messages += done
        policy = self.policy or self.treant.think_time_policy
        extras_budget = ThinkTimeBudget()
        for sid in sorted(self._sessions):
            sess = self._sessions[sid].session
            policy.extras(sess, extras_budget, time.perf_counter())
            self._absorb_prefetch(sess)
            self._absorb_cubes(sess)
        return done

    def _absorb_prefetch(self, sess: Session) -> None:
        """Publish a session's parked speculative results into the shared
        pool so ANY session hitting the same derived query is served.

        Capacity eviction mirrors the message store's policy: candidates
        come from the cold (insertion/recency) end in windows, and the
        cheapest-to-recompute entry of the window goes first.  Entries hit
        in the current batch are never evicted — a sibling session may read
        the same digest later in the same drain.  The previous policy popped
        strictly in insertion order, which threw away just-hit entries while
        keeping cold never-read ones.
        """
        for (_viz, digest), entry in sess._prefetched.items():
            if digest not in self._pool:
                self._pool[digest] = _Pooled(
                    entry.factor, entry.query, cost=self._recompute_cost(entry.query)
                )
        self._evict_pool()

    def _absorb_cubes(self, sess: Session) -> None:
        """Publish a session's parked bin cubes into the shared pool.

        A pooled cube serves any session whose derived query matches the
        cube query modulo the σ on its dimension — the server's fan-out
        probes pool entries carrying ``dim`` by rebuilding the cube digest
        from the incoming query (see ``_probe_pool_cube``)."""
        for (_viz, digest), cube in sess._bin_cubes.items():
            if digest not in self._pool:
                self._pool[digest] = _Pooled(
                    cube.factor, cube.query,
                    cost=self._recompute_cost(cube.query), dim=cube.dim,
                )
        self._evict_pool()

    def _evict_pool(self) -> None:
        WINDOW = 8
        while len(self._pool) > self.pool_capacity:
            window: list[tuple[float, int, str]] = []
            for order, (digest, pooled) in enumerate(self._pool.items()):
                if pooled.hot:
                    continue
                window.append((pooled.cost, order, digest))
                if len(window) >= WINDOW:
                    break
            if not window:
                break  # every entry is hot: admit over capacity this round
            self._pool.pop(min(window)[2])
            self.stats_.pool_evictions += 1

    def _recompute_cost(self, q: Query) -> float:
        """Rows the query's join sees — a proxy for what re-materializing
        the parked fan-out would cost if the entry were evicted."""
        try:
            cat = self.treant.catalog
            return float(sum(
                cat.get(r, q.version_of(r)).num_rows
                for r in self.treant.jt.mapping
                if self.treant._sees(q, r)
            ))
        except Exception:
            return 0.0

    # -- invalidation (called by Treant._ingest at each commit) ----------------
    def _on_commit(self, changed: Iterable[str]) -> None:
        changed = list(changed)
        self._pool = {
            d: e for d, e in self._pool.items()
            if not any(self.treant._sees(e.query, r) for r in changed)
        }

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict:
        out = dataclasses.asdict(self.stats_)
        out.update(
            queue_depth=len(self._queue),
            sessions=len(self._sessions),
            pool_entries=len(self._pool),
            store_evictions=self.treant.store.evictions,
            bytes_held=self.treant.store.nbytes,
            bytes_pinned=self.treant.store.pinned_nbytes,
            byte_budget=self.treant.store.max_bytes,
        )
        return out
