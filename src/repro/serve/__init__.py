"""Multi-tenant serving tier: one Treant, N concurrent sessions (ISSUE 8)."""

from .server import QueueFull, ServeStats, ServerSession, TreantServer

__all__ = ["QueueFull", "ServeStats", "ServerSession", "TreantServer"]
