"""Treant middleware (paper §4): dashboards, sessions, think-time calibration.

Treant sits between dashboards and the relational layer.  Offline it
registers *dashboard queries* (one per visualization) and calibrates their
CJTs (pinned in the message store).  Online it executes *interaction queries*
against the most-recent CJT of the same (session, visualization), then — in
the user's think-time — calibrates the latest interaction query in a
preemptible background pass so the *next* interaction is cheap (§4.2.1,
Example 14).

Live data is handled by ``Treant.update``: given a new relation version and
its signed :class:`~repro.relational.relation.Delta`, every tracked query's
cached CJT is delta-maintained in place (``CJTEngine.apply_delta`` — old
message ⊕ delta, stored under the bumped signature) and every stored query is
re-snapshotted to the new version, so the next interaction reads fresh data
at cache-hit speed.  Rings that cannot absorb a delta (MIN/MAX deletes) skip
maintenance; their recalibration lands in the next ``think_time`` call.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

from repro.relational.relation import Catalog, Delta, Relation
from . import semiring as sr
from .calibration import CJTEngine, DeltaStats, ExecStats, MessageStore
from .factor import Factor
from .hypertree import JTree, jt_from_catalog
from .query import Query
from . import steiner


@dataclasses.dataclass
class InteractionResult:
    factor: Factor
    stats: ExecStats
    latency_s: float
    steiner_size: int


@dataclasses.dataclass
class UpdateResult:
    relation: str
    new_version: str
    queries_maintained: int   # distinct cached CJTs updated via delta calibration
    queries_fallback: int     # CJTs that must recalibrate (no ⊕-inverse, σ moved)
    stats: list[DeltaStats]


@dataclasses.dataclass
class _VizState:
    dashboard_query: Query
    current: Query            # latest executed query (dashboard or interaction)


class Treant:
    """Dashboard accelerator managing CJTs over one join graph."""

    def __init__(
        self,
        catalog: Catalog,
        ring: sr.Semiring = sr.SUM,
        jt: JTree | None = None,
        lifts: Mapping[str, Callable] | None = None,
        max_cache_bytes: int | None = None,
        dense_rows_threshold: int = 0,
        use_plans: bool = True,
    ):
        self.catalog = catalog
        self.jt = jt or jt_from_catalog(catalog)
        self.store = MessageStore(max_bytes=max_cache_bytes)
        self.engine = CJTEngine(
            self.jt, catalog, ring, lifts=lifts, store=self.store,
            dense_rows_threshold=dense_rows_threshold, use_plans=use_plans,
        )
        # (session, viz) -> state; viz -> dashboard query
        self._dashboards: dict[str, Query] = {}
        self._sessions: dict[tuple[str, str], _VizState] = {}
        self._calibrator = None  # (generator, query digest)

    # -- offline stage (§4.1.1) ------------------------------------------------
    def register_dashboard(self, viz: str, query: Query) -> ExecStats:
        """Store the dashboard query and calibrate its CJT offline (pinned)."""
        self._dashboards[viz] = query
        return self.engine.calibrate(query, pin=True)

    # -- online stage (§4.1.2) ---------------------------------------------------
    def _state(self, session: str, viz: str) -> _VizState:
        key = (session, viz)
        if key not in self._sessions:
            q0 = self._dashboards[viz]
            self._sessions[key] = _VizState(dashboard_query=q0, current=q0)
        return self._sessions[key]

    def interact(self, session: str, viz: str, query: Query) -> InteractionResult:
        """Execute an interaction query using the latest CJT for this viz."""
        st = self._state(session, viz)
        pln = steiner.plan(self.engine, st.current, query)
        t0 = time.perf_counter()
        factor, stats = self.engine.execute(query)
        dt = time.perf_counter() - t0
        # the new query preempts any in-flight background calibration
        self._calibrator = None
        st.current = query
        return InteractionResult(factor, stats, dt, pln.size)

    def read(self, session: str, viz: str) -> InteractionResult:
        st = self._state(session, viz)
        t0 = time.perf_counter()
        factor, stats = self.engine.execute(st.current)
        return InteractionResult(factor, stats, time.perf_counter() - t0, 0)

    # -- data updates (delta calibration) ------------------------------------------
    def update(self, new_rel: Relation, delta: Delta) -> UpdateResult:
        """Apply a base-data update online, maintaining every cached CJT.

        ``new_rel`` is the post-update relation version produced by
        ``Relation.append_rows`` / ``delete_rows`` alongside ``delta``.  The
        catalog gains the new version; each distinct tracked query (dashboard
        queries and per-session current queries) whose snapshot matches
        ``delta.old_version`` is delta-maintained (old message ⊕ ΔY, stored
        under the bumped Prop-2 signature — pinned messages stay pinned), then
        re-snapshotted to the new version.  Where maintenance is impossible
        (ring without ⊕-inverse for a delete, σ-placement migration) nothing
        stale survives either: the bumped signatures simply miss, and the
        full recalibration is scheduled into the next ``think_time`` pass.
        """
        assert new_rel.name == delta.relation and new_rel.version == delta.new_version
        self.catalog.put(new_rel)
        tracked = list(self._dashboards.values()) + [
            q for st in self._sessions.values() for q in (st.dashboard_query, st.current)
        ]
        todo = {
            q.digest: q for q in tracked
            if q.version_of(delta.relation) == delta.old_version
        }
        all_stats: list[DeltaStats] = []
        maintained = fallbacks = 0
        for q in todo.values():
            _, st = self.engine.apply_delta(q, delta)
            all_stats.append(st)
            fallbacks += int(st.fallback)
            # a query the update can't even reach (relation removed / outside
            # the JT) is neither maintained nor a fallback
            maintained += int(not st.fallback and st.delta_messages > 0)

        def bump(q: Query) -> Query:
            if q.version_of(delta.relation) == delta.old_version:
                return q.with_version(delta.relation, delta.new_version)
            return q

        self._dashboards = {v: bump(q) for v, q in self._dashboards.items()}
        for st_ in self._sessions.values():
            st_.dashboard_query = bump(st_.dashboard_query)
            st_.current = bump(st_.current)
        # any in-flight background calibration targets a stale snapshot;
        # the next think_time() restarts against the updated query (cheap
        # when maintenance succeeded, a full recalibration otherwise)
        self._calibrator = None
        return UpdateResult(
            relation=delta.relation,
            new_version=delta.new_version,
            queries_maintained=maintained,
            queries_fallback=fallbacks,
            stats=all_stats,
        )

    # -- think-time calibration (§4.2.1) -------------------------------------------
    def think_time(
        self,
        session: str,
        viz: str,
        budget_messages: int | None = None,
        budget_seconds: float | None = None,
    ) -> int:
        """Calibrate the current interaction query in the background.

        Preemptible: stops when the budget is exhausted; every message
        materialized so far stays in the store and is immediately reusable
        (Fig 15's stepped latency curve comes exactly from this).
        Returns the number of edges processed.
        """
        st = self._state(session, viz)
        q = st.current
        if self._calibrator is None or self._calibrator[1] != q.digest:
            self._calibrator = (self.engine.calibrate_iter(q), q.digest)
        gen, _ = self._calibrator
        done = 0
        t0 = time.perf_counter()
        for _ in gen:
            done += 1
            if budget_messages is not None and done >= budget_messages:
                break
            if budget_seconds is not None and time.perf_counter() - t0 >= budget_seconds:
                break
        else:
            self._calibrator = None  # fully calibrated
        return done

    # -- introspection ---------------------------------------------------------------
    def cache_stats(self) -> dict:
        out = {
            "messages": len(self.store),
            "bytes": self.store.nbytes,
            "hits": self.store.hits,
            "misses": self.store.misses,
            "widen_hits": self.store.widen_hits,
            "widen_scans": self.store.widen_scans,
            "widen_scan_steps": self.store.widen_scan_steps,
        }
        if self.engine.plans is not None:
            out["plans"] = self.engine.plans.stats.as_dict()
            out["plans_cached"] = len(self.engine.plans)
        return out
