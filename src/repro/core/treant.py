"""Treant middleware (paper §4): dashboards, sessions, think-time calibration.

Treant sits between dashboards and the relational layer.  The public surface
is the declarative session layer in :mod:`repro.core.dashboard`:
``open_session(DashboardSpec)`` returns a :class:`~repro.core.dashboard.Session`
whose typed events (SetFilter, Drill, …) fan out over linked vizzes sharing
one engine / :class:`~repro.core.calibration.MessageStore` / plan cache, and
whose think-time calibration runs on the shared
:class:`~repro.core.dashboard.ThinkTimeScheduler` — a priority queue over all
(session, viz) pairs where an interaction preempts *only* the viz it changed.

``register_dashboard`` / ``interact`` / ``think_time`` / ``read`` are kept as
thin **legacy wrappers** over that layer: each legacy session name maps to a
Session whose vizzes are seeded from the registered dashboard queries.  They
behave as before except that background calibration progress on one viz now
survives interactions on another (the old single ``_calibrator`` slot
silently discarded it).

Live data is handled by ``Treant.update``: given a new relation version and
its signed :class:`~repro.relational.relation.Delta`, every tracked query's
cached CJT is delta-maintained in place (``CJTEngine.apply_delta`` — old
message ⊕ delta, stored under the bumped signature) and every stored query is
re-snapshotted to the new version, so the next interaction reads fresh data
at cache-hit speed.  Rings that cannot absorb a delta (MIN/MAX deletes) skip
maintenance; their recalibration is re-queued on the scheduler and lands in
the next ``think_time`` / ``Session.idle`` call.

Multi-ring dashboards: the primary engine serves its own ring (and
measure-free COUNT queries when the primary ring is SUM — the all-ones lift
degenerates identically);
any other ring named by a viz gets a lazily created sibling engine sharing
the same MessageStore.  Prop-2 signatures include the ring name, so the
shared store never serves one ring's message to another.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Mapping

import jax

from repro.relational.relation import Catalog, Delta, Relation
from repro.relational.stream import CompactionPolicy, StreamBuffer
from . import distributed as dist
from . import semiring as sr
from .calibration import CJTEngine, DeltaStats, ExecStats, MessageStore
from .plans import (
    PlanStats,
    batch_calibration_default,
    batch_fanout_default,
    fuse_level_default,
    use_plans_default,
)
from .dashboard import (
    ApplyResult,
    DashboardSpec,
    InteractionResult,
    Session,
    ThinkTimeScheduler,
    VizSpec,
)
from .hypertree import JTree, jt_from_catalog
from .predictive import DrainCalibration, ThinkTimeBudget, ThinkTimePolicy
from .query import Query

__all__ = [
    "Treant", "InteractionResult", "UpdateResult", "FlushResult", "IngestStats",
    "ApplyResult", "DashboardSpec", "VizSpec", "Session", "ThinkTimeScheduler",
]


def compaction_threshold_default() -> float:
    """Tombstone fraction that triggers compaction at flush
    (``REPRO_COMPACTION_THRESHOLD``, default 0.25; <= 0 disables)."""
    try:
        return float(os.environ.get("REPRO_COMPACTION_THRESHOLD", "0.25"))
    except ValueError:  # pragma: no cover — malformed env
        return 0.25


@dataclasses.dataclass
class UpdateResult:
    relation: str
    new_version: str
    queries_maintained: int   # distinct cached CJTs updated via delta calibration
    queries_fallback: int     # CJTs that must recalibrate (no ⊕-inverse, σ moved)
    stats: list[DeltaStats]


@dataclasses.dataclass
class IngestStats:
    """Cumulative streaming-ingestion counters (the coalescing invariants).

    The tentpole contract is visible here: after T flush ticks over R
    streamed relations, ``version_bumps == delta_sweeps == T·R`` no matter
    how many micro-batches each tick buffered (compactions add their own
    bump+sweep, counted separately in ``compactions``).
    """

    ticks: int = 0            # flush() calls that committed at least one delta
    version_bumps: int = 0    # committed relation version advances
    delta_sweeps: int = 0     # apply_delta maintenance sweeps (one per relation per tick)
    rows_appended: int = 0
    rows_deleted: int = 0     # tombstoned
    rows_cancelled: int = 0   # same-tick append+delete (never materialized)
    compactions: int = 0


@dataclasses.dataclass
class FlushResult:
    """Outcome of one ``Treant.flush`` tick."""

    watermark: int                    # catalog watermark after the commit
    updates: list[UpdateResult]       # one per relation with pending batches
    compactions: list[UpdateResult]   # tombstone reclaims triggered this tick

    @property
    def relations(self) -> list[str]:
        return [u.relation for u in self.updates]


class Treant:
    """Dashboard accelerator managing CJTs over one join graph."""

    def __init__(
        self,
        catalog: Catalog,
        ring: sr.Semiring = sr.SUM,
        jt: JTree | None = None,
        lifts: Mapping[str, Callable] | None = None,
        max_cache_bytes: int | None = None,
        dense_rows_threshold: int = 0,
        use_plans: bool | None = None,
        batch_fanout: bool | None = None,
        batch_calibration: bool | None = None,
        fuse_level_kernel: bool | None = None,
        compaction_threshold: float | None = None,
        mesh=None,
        policy: ThinkTimePolicy | None = None,
    ):
        # None → env defaults: REPRO_USE_PLANS gates compiled plans (the CI
        # matrix runs both legs), REPRO_BATCH_FANOUT gates the vmapped
        # sibling-absorption batching (benchmarks A/B against per-viz
        # dispatch), REPRO_BATCH_CALIBRATION gates level-batched calibration
        # passes (inert without plans — degrades to the per-edge loop),
        # REPRO_FUSE_LEVEL_KERNEL gates level-fused kernel launches (one
        # dispatch + one Pallas launch per calibration level),
        # REPRO_SHARD_DEVICES picks the row-shard mesh width (mesh=None)
        if use_plans is None:
            use_plans = use_plans_default()
        if batch_fanout is None:
            batch_fanout = batch_fanout_default()
        if batch_calibration is None:
            batch_calibration = batch_calibration_default()
        if fuse_level_kernel is None:
            fuse_level_kernel = fuse_level_default()
        if mesh is None:
            mesh = dist.make_engine_mesh()
        elif mesh is False or mesh == 0:
            mesh = None  # explicit opt-out: ignore REPRO_SHARD_DEVICES
        self.catalog = catalog
        self.jt = jt or jt_from_catalog(catalog)
        self.store = MessageStore(max_bytes=max_cache_bytes)
        self._lifts = dict(lifts or {})
        self._dense_rows_threshold = dense_rows_threshold
        self._use_plans = use_plans
        self.batch_fanout = batch_fanout
        self.batch_calibration = batch_calibration
        self.fuse_level_kernel = fuse_level_kernel
        # row-sharded execution over a device mesh: every engine's plan cache
        # shards fact-relation scans with shard_map and ⊕-all-reduces the
        # γ-indexed partials; cached row codes pre-place on the mesh so the
        # hot path never reshards
        self.mesh = mesh
        if mesh is not None:
            catalog.set_row_placement(dist.row_placement(mesh))
        self.engine = CJTEngine(
            self.jt, catalog, ring, lifts=self._lifts, store=self.store,
            dense_rows_threshold=dense_rows_threshold, use_plans=use_plans,
            batch_calibration=batch_calibration,
            fuse_level_kernel=fuse_level_kernel, mesh=mesh,
        )
        # ring name -> engine; siblings share the store (per-ring plan caches)
        self._engines: dict[str, CJTEngine] = {ring.name: self.engine}
        self.scheduler = ThinkTimeScheduler()
        # default think-time policy for sessions that don't set their own
        # (Session.idle(policy=) > Session.policy > this); DrainCalibration
        # preserves the historical idle() behavior exactly
        self.think_time_policy: ThinkTimePolicy = (
            policy if policy is not None else DrainCalibration()
        )
        self._sees_attr_memo: dict[tuple[str, tuple[str, ...]], bool] = {}
        self._dashboards: dict[str, Query] = {}
        self._sessions: dict[str, Session] = {}
        self._session_seq = 0  # monotonic: closed sessions never recycle ids
        # streaming ingestion (ISSUE 6): per-relation micro-batch buffers,
        # coalesced+committed by flush() under one catalog watermark
        self._streams: dict[str, StreamBuffer] = {}
        self.compaction_threshold = (
            compaction_threshold if compaction_threshold is not None
            else compaction_threshold_default()
        )
        # per-relation compaction thresholds learned from the observed delete
        # mix (EWMA) around the configured base; the base stays the knob
        self.compaction_policy = CompactionPolicy()
        self.ingest = IngestStats()
        # attached TreantServer (repro.serve), surfaced in cache_stats
        self._server = None

    # -- engines ---------------------------------------------------------------
    def engine_for(self, ring_name: str, measure=None) -> CJTEngine:
        """Engine executing ``ring_name`` queries (shared MessageStore).

        A *measure-free* COUNT collapses onto a SUM primary (the SUM lift is
        then all-ones, so values and signatures are both count-correct); a
        COUNT query carrying a measure must NOT — the SUM lift would sum the
        measure column — so it gets the real COUNT engine.
        """
        primary = self.engine.ring.name
        if ring_name == primary:
            return self.engine
        if primary == "sum" and ring_name == "count" and measure is None:
            return self.engine
        eng = self._engines.get(ring_name)
        if eng is None:
            eng = CJTEngine(
                self.jt, self.catalog, sr.get(ring_name), lifts=self._lifts,
                store=self.store, dense_rows_threshold=self._dense_rows_threshold,
                use_plans=self._use_plans, batch_calibration=self.batch_calibration,
                fuse_level_kernel=self.fuse_level_kernel, mesh=self.mesh,
            )
            self._engines[ring_name] = eng
        return eng

    # -- declarative sessions (the primary API) --------------------------------
    def open_session(
        self, spec: DashboardSpec, name: str | None = None, calibrate: bool = True
    ) -> Session:
        """Open a dashboard session: derive per-viz base queries from the
        spec and (by default) calibrate each base CJT offline, pinned."""
        if name is None:
            while f"sess{self._session_seq}" in self._sessions:
                self._session_seq += 1
            name = f"sess{self._session_seq}"
            self._session_seq += 1
        sid = name
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} already open")
        sess = Session(self, sid, spec, calibrate=calibrate)
        self._sessions[sid] = sess
        return sess

    def session(self, name: str) -> Session:
        return self._sessions[name]

    def _legacy_session(self, name: str) -> Session:
        """Spec-less session backing the legacy wrapper API."""
        sess = self._sessions.get(name)
        if sess is None:
            sess = Session(self, name, spec=None)
            self._sessions[name] = sess
        return sess

    # -- offline stage (§4.1.1) — legacy wrapper -------------------------------
    def register_dashboard(self, viz: str, query: Query) -> ExecStats:
        """[legacy] Store the dashboard query and calibrate its CJT (pinned).

        New code should declare vizzes in a DashboardSpec and use
        ``open_session`` instead.
        """
        self._dashboards[viz] = query
        return self.engine_for(query.ring_name, query.measure).calibrate(query, pin=True)

    def _legacy_viz(self, session: str, viz: str) -> Session:
        sess = self._legacy_session(session)
        if viz not in sess._views:
            sess.add_viz(viz, self._dashboards[viz])  # KeyError if unregistered
        return sess

    # -- online stage (§4.1.2) — legacy wrappers -------------------------------
    def interact(self, session: str, viz: str, query: Query) -> InteractionResult:
        """[legacy] Execute an interaction query using the latest CJT for
        this viz.  Preempts only this viz's pending background calibration."""
        return self._legacy_viz(session, viz).interact_query(viz, query)

    def read(self, session: str, viz: str) -> InteractionResult:
        return self._legacy_viz(session, viz).read(viz)

    # -- data updates (delta calibration) ---------------------------------------
    def update(self, new_rel: Relation, delta: Delta | None) -> UpdateResult:
        """Apply a base-data update online, maintaining every cached CJT.

        ``new_rel`` is the post-update relation version produced by
        ``Relation.append_rows`` / ``delete_rows`` alongside ``delta``.  The
        catalog gains the new version; each distinct tracked query (registered
        dashboard queries plus every open session's base and current queries)
        whose snapshot matches ``delta.old_version`` is delta-maintained (old
        message ⊕ ΔY, stored under the bumped Prop-2 signature — pinned
        messages stay pinned), then re-snapshotted to the new version.  Where
        maintenance is impossible (ring without ⊕-inverse for a delete,
        σ-placement migration) nothing stale survives either: the bumped
        signatures simply miss, and the full recalibration is re-queued on
        the scheduler for the next think-time pass.

        ``delta=None`` (the empty-update short-circuit of ``append_rows`` /
        ``delete_rows``) is a no-op: nothing to maintain, no version bump.
        """
        if delta is None:
            return UpdateResult(new_rel.name, new_rel.version, 0, 0, [])
        assert new_rel.name == delta.relation and new_rel.version == delta.new_version
        self.catalog.put(new_rel, make_latest=False)  # staged until commit
        return self._ingest([delta])[0]

    def _tracked_queries(self) -> list[Query]:
        return list(self._dashboards.values()) + [
            view.base for sess in self._sessions.values()
            for view in sess._views.values()
        ] + [
            q for sess in self._sessions.values() for q in sess._current.values()
        ] + [
            # pinned offline-calibration passes (union-carry queries under
            # batched calibration): maintaining them migrates their pins to
            # the bumped signatures, exactly like the per-viz bases
            q for sess in self._sessions.values()
            for q in sess._pinned_queries.values()
        ]

    def _sees(self, q: Query, relation: str) -> bool:
        """Can ``relation``'s data reach this query's answer?"""
        return relation not in q.removed and relation in self.jt.mapping

    def sees_attr(self, q: Query, attr: str) -> bool:
        """Does any relation still in this query's join scope carry ``attr``?

        ``ToggleRelation`` can remove the only relation holding a brushed
        dimension; a σ on that attr is then unplaceable (predicate placement
        would land on a bag none of whose visible relations has the column).
        Speculation and cube building skip such (query, attr) pairs.

        Memoized on (attr, removed-set): the answer depends only on the join
        tree and relation schemas, both fixed for this Treant's lifetime, and
        ``derive`` asks per filter per viz on every event.
        """
        key = (attr, tuple(sorted(q.removed)))
        hit = self._sees_attr_memo.get(key)
        if hit is not None:
            return hit
        out = False
        for bag in self.jt.bags_with_attr(attr):
            for rel in self.jt.relations_of(bag):
                if rel in q.removed:
                    continue
                if attr in self.catalog.get(rel).attrs:
                    out = True
                    break
            if out:
                break
        self._sees_attr_memo[key] = out
        return out

    def _ingest(
        self, deltas: list[Delta], deprioritized: bool = False
    ) -> list[UpdateResult]:
        """Maintain, commit and re-snapshot for a batch of per-relation deltas.

        The commit protocol (torn-update guard): every delta's maintenance
        runs first, against *staged* catalog versions — readers still resolve
        the old watermark and every old message stays servable.  Only when
        all n−1-message sweeps have landed does ``Catalog.commit`` advance
        the latest pointers (one watermark for the whole batch) and the
        tracked queries get re-snapshotted, so a concurrent session read sees
        either the pre-tick snapshot or the complete post-tick one.

        ``deprioritized`` marks the re-queued recalibrations of fallback
        queries as lowest-priority scheduler work (compaction passes must
        not starve interactive think-time calibration).
        """
        results: list[UpdateResult] = []
        for delta in deltas:
            todo = {
                q.digest: q for q in self._tracked_queries()
                if q.version_of(delta.relation) == delta.old_version
            }
            all_stats: list[DeltaStats] = []
            maintained = fallbacks = 0
            fallback_digests: set[str] = set()
            for q in todo.values():
                _, st = self.engine_for(q.ring_name, q.measure).apply_delta(q, delta)
                all_stats.append(st)
                fallbacks += int(st.fallback)
                if st.fallback:
                    fallback_digests.add(q.digest)
                # a query the update can't even reach (relation removed /
                # outside the JT) is neither maintained nor a fallback; a
                # compaction maintains by re-keying (zero delta messages)
                maintained += int(
                    not st.fallback
                    and (st.delta_messages > 0 or st.edges_maintained > 0)
                )
            # fallback CJTs get no pin migration (apply_delta maintained
            # nothing), but their pinned queries are version-bumped below — a
            # later Session.close would then unpin the *new* sigs (no-ops)
            # and leak the old-version pins forever.  Release them now, while
            # the pre-bump query still derives the pinned signatures; the
            # recalibration re-queued below rebuilds the CJT unpinned.
            for sess in self._sessions.values():
                for key, qp in sorted(sess._pinned_queries.items()):
                    if qp.digest in fallback_digests:
                        self.engine_for(qp.ring_name, qp.measure).unpin_query(qp)
                        del sess._pinned_queries[key]

            def bump(q: Query, delta: Delta = delta) -> Query:
                if q.version_of(delta.relation) == delta.old_version:
                    return q.with_version(delta.relation, delta.new_version)
                return q

            self._dashboards = {v: bump(q) for v, q in self._dashboards.items()}
            for sess in self._sessions.values():
                for view in sess._views.values():
                    view.base = bump(view.base)
                sess._current = {v: bump(q) for v, q in sess._current.items()}
                sess._pinned_queries = {
                    k: bump(q) for k, q in sess._pinned_queries.items()
                }
            self.ingest.delta_sweeps += 1
            results.append(UpdateResult(
                relation=delta.relation,
                new_version=delta.new_version,
                queries_maintained=maintained,
                queries_fallback=fallbacks,
                stats=all_stats,
            ))
        # ---- commit point: all latest pointers advance under ONE watermark
        self.catalog.commit({d.relation: d.new_version for d in deltas})
        self.ingest.version_bumps += len(deltas)
        # Selective invalidation: only prefetched results whose query can see
        # an updated relation are stale — their digests can never be served
        # again.  Entries on disjoint dimensions (updated relation removed
        # from the query) keep digests stable (Query.digest hashes effective
        # versions only) and stay servable.  Re-queue the sessions' bumped
        # current queries: a changed digest preempts exactly the stale parked
        # calibration, an unchanged one keeps its position and progress.
        changed = [d.relation for d in deltas]
        if self._server is not None:
            self._server._on_commit(changed)
        for sess in self._sessions.values():
            sess._prefetched = {
                k: e for k, e in sess._prefetched.items()
                if not any(self._sees(e.query, r) for r in changed)
            }
            # bin cubes invalidate under the same rule: only a cube whose
            # query can see an updated relation is stale
            sess.invalidate_bin_cubes(changed)
            for viz, q in sess._current.items():
                engine = self.engine_for(q.ring_name, q.measure)
                dep = deprioritized and not engine.is_calibrated(q)
                self.scheduler.schedule(sess.id, viz, q, engine, deprioritized=dep)
        # Absorption prewarm: the commit leaves every device cache slot for
        # the new versions cold (codes, lifts, the occasional plan retrace at
        # a row-bucket crossing).  Execute each still-calibrated affected
        # query once NOW, on the write path, so the first post-tick
        # interaction pays σ-absorption only and the warm-event tail stays
        # flat under sustained ingestion.  Fallback queries are skipped —
        # their recalibration belongs to think-time, not the flush.
        prewarmed = []
        for sess in self._sessions.values():
            for q in sess._current.values():
                if not any(self._sees(q, r) for r in changed):
                    continue
                engine = self.engine_for(q.ring_name, q.measure)
                if engine.plans is not None and engine.is_calibrated(q):
                    f, _ = engine.execute(q)
                    prewarmed.append(f)
        # drain the prewarm compute here: its results live in no store, so a
        # reader's block_until_ready would not cover them and the next
        # interaction would queue behind them on-device
        for f in prewarmed:
            jax.block_until_ready(f.field)
        return results

    # -- streaming ingestion (ISSUE 6 tentpole) ---------------------------------
    def stream(self, relation: str) -> StreamBuffer:
        """The per-relation ingestion buffer (created on first use).

        Queue micro-batches with ``stream(r).append(...)`` / ``.delete(...)``;
        nothing is visible to readers until :meth:`flush` coalesces, maintains
        and commits the tick.
        """
        buf = self._streams.get(relation)
        if buf is None:
            buf = StreamBuffer(self.catalog.get(relation))
            self._streams[relation] = buf
        return buf

    def flush(self) -> FlushResult:
        """Tick boundary: coalesce every buffer, maintain, commit, compact.

        Per streamed relation with pending micro-batches this performs
        exactly ONE version bump and ONE ``apply_delta`` sweep of the n−1
        outward messages (however many micro-batches were queued) — the
        coalescing contract, asserted by ``IngestStats``.  All relations
        commit under one catalog watermark; concurrent session reads resolve
        either the previous watermark or this one, never a mix.

        After the commit, any buffer whose tombstone fraction crossed
        ``compaction_threshold`` is compacted: one more (empty) delta that
        group rings absorb by re-keying, while inverse-free rings take their
        single real recalibration — scheduled at lowest priority so it lands
        in think-time, not in the interactive path.
        """
        deltas: list[Delta] = []
        for name in sorted(self._streams):
            buf = self._streams[name]
            before = dataclasses.replace(buf.stats)
            new_rel, delta = buf.coalesce()
            n_app = buf.stats.rows_appended - before.rows_appended
            n_del = buf.stats.rows_deleted - before.rows_deleted
            self.ingest.rows_appended += n_app
            self.ingest.rows_deleted += n_del
            self.ingest.rows_cancelled += (
                buf.stats.rows_cancelled - before.rows_cancelled
            )
            if delta is not None:
                self.compaction_policy.observe(name, n_app, n_del)
                self.catalog.put(new_rel, make_latest=False)  # stage
                deltas.append(delta)
        updates = self._ingest(deltas) if deltas else []
        if deltas:
            self.ingest.ticks += 1
        # ---- compaction (tombstone ledger) --------------------------------
        # per-relation thresholds: the learned delete-mix EWMA tightens the
        # configured base for delete-heavy relations and relaxes it for
        # append-mostly ones (base <= 0 still disables compaction globally)
        compactions: list[UpdateResult] = []
        if self.compaction_threshold > 0:
            cdeltas: list[Delta] = []
            rebased: list[tuple[StreamBuffer, Relation]] = []
            for name in sorted(self._streams):
                buf = self._streams[name]
                thr = self.compaction_policy.threshold(
                    name, self.compaction_threshold
                )
                if buf.tombstone_fraction() < thr:
                    continue
                new_rel, cdelta = buf.base.compact()
                if cdelta is None:
                    continue
                self.catalog.put(new_rel, make_latest=False)
                cdeltas.append(cdelta)
                rebased.append((buf, new_rel))
            if cdeltas:
                compactions = self._ingest(cdeltas, deprioritized=True)
                for buf, new_rel in rebased:
                    buf.rebase(new_rel)
                self.ingest.compactions += len(cdeltas)
        return FlushResult(
            watermark=self.catalog.watermark,
            updates=updates,
            compactions=compactions,
        )

    # -- think-time calibration (§4.2.1) — legacy wrapper -----------------------
    def think_time(
        self,
        session: str,
        viz: str,
        budget_messages: int | None = None,
        budget_seconds: float | None = None,
    ) -> int:
        """[legacy] Calibrate this viz's current query in the background.

        Preemptible: stops when the budget is exhausted; every message
        materialized so far stays in the store and is immediately reusable
        (Fig 15's stepped latency curve comes exactly from this), and the
        iterator position survives interactions on *other* vizzes.
        Returns the number of edges processed.  New code should use
        ``Session.idle`` which drains all of a session's pending vizzes.
        """
        sess = self._legacy_viz(session, viz)
        q = sess._current[viz]
        self.scheduler.schedule(session, viz, q, self.engine_for(q.ring_name, q.measure))
        return self.think_time_policy.run(
            sess,
            ThinkTimeBudget(
                messages=budget_messages, seconds=budget_seconds, viz=viz,
            ),
        )

    # -- introspection ---------------------------------------------------------------
    def cache_stats(self) -> dict:
        ingest = dataclasses.asdict(self.ingest)
        # learned CompactionPolicy state rides under the ingest dict so the
        # nightly bench can trend the per-relation EWMA delete mix and the
        # *effective* thresholds, not just the static base knob
        ingest["compaction"] = self.compaction_policy.state(
            self.compaction_threshold
        )
        out = {
            "messages": len(self.store),
            "bytes": self.store.nbytes,
            "hits": self.store.hits,
            "misses": self.store.misses,
            "widen_hits": self.store.widen_hits,
            "widen_scans": self.store.widen_scans,
            "widen_scan_steps": self.store.widen_scan_steps,
            "cross_viz_hits": self.store.cross_tag_hits,
            "scheduler": self.scheduler.stats(),
            "sessions": len(self._sessions),
            "watermark": self.catalog.watermark,
            "ingest": ingest,
            # bin cubes parked across all sessions (the per-dimension
            # think-time materializations of core/predictive.py)
            "bin_cubes": sum(len(s._bin_cubes) for s in self._sessions.values()),
            "bin_cube_bytes": sum(
                s.bin_cube_bytes for s in self._sessions.values()
            ),
            "bin_cube_hits": sum(
                s.bin_cube_hits for s in self._sessions.values()
            ),
        }
        if self._server is not None:
            out["serve"] = self._server.stats()
        # aggregate plan counters over the primary AND sibling-ring engines
        # (multi-ring dashboards execute on several PlanCaches); which
        # counters are high-water marks vs sums is declared by PlanStats
        # itself (MAX_FIELDS) so kernel/fusion counters added later cannot
        # silently fall in the wrong bucket
        caches = [e.plans for e in self._engines.values() if e.plans is not None]
        if caches:
            agg = PlanStats()
            for c in caches:
                for k, v in c.stats.as_dict().items():
                    setattr(
                        agg, k,
                        max(getattr(agg, k), v)
                        if k in PlanStats.MAX_FIELDS
                        else getattr(agg, k) + v,
                    )
            out["plans"] = agg.as_dict()
            out["plans_cached"] = sum(len(c) for c in caches)
        return out
