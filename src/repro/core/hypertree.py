"""Junction Hypertree (JT) construction and validation (paper §2, §3.2).

A JT is (bags, tree edges, relation mapping X).  For acyclic join graphs the
optimal JT has one bag per relation (paper §2); we build it as the
maximum-weight spanning tree of the attribute-intersection graph
(Bernstein–Goodman: the hypergraph is γ-acyclic iff that MST satisfies the
running-intersection property), then validate vertex/edge coverage + RIP.

Extensions from the paper:
  - **empty bags** (§3.2): custom bags mapped to the identity relation that
    materialize shortcut views (``insert_empty_bag``);
  - **augmentation bags** (§4.3): attach a new relation as a fresh bag on any
    bag covering its join keys (``attach_relation``).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Mapping, Sequence


class CyclicSchemaError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class JTree:
    bags: dict[str, tuple[str, ...]]          # bag name -> attrs
    adj: dict[str, tuple[str, ...]]           # bag name -> neighbor names
    mapping: dict[str, str]                   # relation name -> bag name (X)
    domains: dict[str, int]                   # attr -> domain size
    empty_bags: frozenset[str] = frozenset()  # bags mapped to 𝕀

    # -- structure queries ---------------------------------------------------
    # All queries below are pure functions of the (immutable) tree structure
    # and sit on the per-interaction hot path (root choice + signature
    # derivation evaluate them per edge per query), so they memoize into a
    # lazily-created per-instance dict.  insert_empty_bag / attach_relation
    # construct fresh JTree objects, never mutate one, so entries are stable.
    def _memo(self) -> dict:
        memo = self.__dict__.get("_memo_cache")
        if memo is None:
            object.__setattr__(self, "_memo_cache", memo := {})
        return memo

    def neighbors(self, u: str) -> tuple[str, ...]:
        return self.adj[u]

    def separator(self, u: str, v: str) -> tuple[str, ...]:
        memo = self._memo()
        key = ("sep", u, v)
        hit = memo.get(key)
        if hit is None:
            su = set(self.bags[v])
            memo[key] = hit = tuple(a for a in self.bags[u] if a in su)
        return hit

    def relations_of(self, bag: str) -> tuple[str, ...]:
        """X⁻¹(bag)."""
        memo = self._memo()
        key = ("rels", bag)
        hit = memo.get(key)
        if hit is None:
            memo[key] = hit = tuple(
                sorted(r for r, b in self.mapping.items() if b == bag)
            )
        return hit

    def directed_edges(self) -> list[tuple[str, str]]:
        out = []
        for u, nbrs in self.adj.items():
            out.extend((u, v) for v in nbrs)
        return sorted(out)

    def subtree_bags(self, u: str, away_from: str | None) -> tuple[str, ...]:
        """Bags in the subtree rooted at u when edge (u, away_from) is cut."""
        seen = {u} | ({away_from} if away_from else set())
        stack, out = [u], [u]
        while stack:
            x = stack.pop()
            for y in self.adj[x]:
                if y not in seen:
                    seen.add(y)
                    out.append(y)
                    stack.append(y)
        return tuple(out)

    def subtree_attrs(self, u: str, away_from: str | None) -> frozenset[str]:
        memo = self._memo()
        key = ("sattrs", u, away_from)
        hit = memo.get(key)
        if hit is None:
            memo[key] = hit = frozenset(
                a for b in self.subtree_bags(u, away_from) for a in self.bags[b]
            )
        return hit

    def path(self, u: str, v: str) -> list[str]:
        parent = {u: None}
        stack = [u]
        while stack:
            x = stack.pop()
            if x == v:
                break
            for y in self.adj[x]:
                if y not in parent:
                    parent[y] = x
                    stack.append(y)
        out, x = [], v
        while x is not None:
            out.append(x)
            x = parent[x]
        return out[::-1]

    def bags_with_attr(self, attr: str) -> tuple[str, ...]:
        return tuple(sorted(b for b, attrs in self.bags.items() if attr in attrs))

    def traversal_to_root(self, root: str) -> list[tuple[str, str]]:
        """Tra(root): directed edges (child→parent) in upward order (leaves first)."""
        memo = self._memo()
        key = ("tra", root)
        hit = memo.get(key)
        if hit is not None:
            return list(hit)
        order: list[tuple[str, str]] = []

        def visit(u: str, parent: str | None):
            for v in self.adj[u]:
                if v != parent:
                    visit(v, u)
                    order.append((v, u))

        visit(root, None)
        memo[key] = tuple(order)
        return order

    def calibration_levels(self, root: str) -> tuple[tuple[tuple[str, str], ...], ...]:
        """Level-synchronous calibration schedule: upward then downward passes.

        Edges are grouped by the depth of the bag *below* the cut: upward
        level k holds the child→parent edges whose child sits at depth k
        (emitted deepest-first), downward levels mirror them shallowest-first
        with the direction flipped.  All edges inside one level are
        independent — a message's inputs live strictly on the far side of its
        level boundary — so a level can execute as one batched dispatch, and
        abandoning the schedule at any level boundary leaves every completed
        level's messages servable.  Concatenated, the levels enumerate the
        same 2(n−1) directed edges as ``traversal_to_root`` + its reverse.
        """
        memo = self._memo()
        key = ("levels", root)
        hit = memo.get(key)
        if hit is not None:
            return hit
        seen = {root}
        frontier = [root]
        by_depth: list[tuple[tuple[str, str], ...]] = []
        while frontier:
            nxt = []
            for u in frontier:
                for v in self.adj[u]:
                    if v not in seen:
                        seen.add(v)
                        nxt.append((v, u))
            if nxt:
                by_depth.append(tuple(sorted(nxt)))
            frontier = [v for v, _ in nxt]
        # by_depth[k-1] holds the (child at depth k, parent) edges
        upward = list(reversed(by_depth))
        downward = [tuple((p, c) for (c, p) in lvl) for lvl in by_depth]
        memo[key] = hit = tuple(upward + downward)
        return hit

    # -- validation (paper §2: the three JT properties) ----------------------
    def validate(self) -> None:
        names = set(self.bags)
        # tree: connected with |E| = |V| - 1
        n_edges = sum(len(v) for v in self.adj.values()) // 2
        if len(names) > 1 and n_edges != len(names) - 1:
            raise ValueError(f"not a tree: {len(names)} bags, {n_edges} edges")
        if names and len(self.subtree_bags(next(iter(sorted(names))), None)) != len(names):
            raise ValueError("not connected")
        for u, nbrs in self.adj.items():
            for v in nbrs:
                if u not in self.adj[v]:
                    raise ValueError(f"asymmetric edge {u}->{v}")
        # edge coverage: X(R)'s bag covers R's attrs — checked by builder
        # running intersection: per attr, bags containing it form a subtree
        for attr in {a for attrs in self.bags.values() for a in attrs}:
            with_attr = set(self.bags_with_attr(attr))
            start = next(iter(sorted(with_attr)))
            seen = {start}
            stack = [start]
            while stack:
                x = stack.pop()
                for y in self.adj[x]:
                    if y in with_attr and y not in seen:
                        seen.add(y)
                        stack.append(y)
            if seen != with_attr:
                raise ValueError(f"running intersection violated for {attr}")


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def is_acyclic(schemas: Mapping[str, Iterable[str]]) -> bool:
    """GYO ear-elimination acyclicity test (paper §2, Example 3)."""
    rels = {n: frozenset(a) for n, a in schemas.items() if a}
    changed = True
    while changed and len(rels) > 1:
        changed = False
        names = sorted(rels)
        for n in names:
            others = [rels[m] for m in rels if m != n]
            # ear: attrs of n either unique to n, or all shared attrs are
            # contained in a single other relation
            shared = rels[n] & frozenset().union(*others) if others else frozenset()
            if any(shared <= o for o in others):
                del rels[n]
                changed = True
                break
    return len(rels) <= 1


def build_join_tree(
    schemas: Mapping[str, Sequence[str]],
    domains: Mapping[str, int],
) -> JTree:
    """One bag per relation; max-weight spanning tree on |attrs∩| (paper §2)."""
    names = sorted(schemas)
    bags = {f"bag:{n}": tuple(schemas[n]) for n in names}
    mapping = {n: f"bag:{n}" for n in names}
    bag_names = sorted(bags)
    # Kruskal on intersection weights (ties broken by name for determinism)
    edges = []
    for u, v in itertools.combinations(bag_names, 2):
        w = len(set(bags[u]) & set(bags[v]))
        edges.append((-w, u, v))
    edges.sort()
    parent = {b: b for b in bag_names}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    adj: dict[str, list[str]] = {b: [] for b in bag_names}
    for w, u, v in edges:
        if find(u) != find(v):
            parent[find(u)] = find(v)
            adj[u].append(v)
            adj[v].append(u)
    jt = JTree(
        bags=bags,
        adj={b: tuple(sorted(n)) for b, n in adj.items()},
        mapping=mapping,
        domains=dict(domains),
    )
    try:
        jt.validate()
    except ValueError as e:
        if not is_acyclic(schemas):
            raise CyclicSchemaError(
                f"join graph is cyclic; pre-join cycles first (paper §2): {e}"
            ) from e
        raise
    return jt


def insert_empty_bag(
    jt: JTree, name: str, attrs: Sequence[str], host: str, reroute: Sequence[str]
) -> JTree:
    """Insert an empty bag between ``host`` and ``reroute ⊆ neighbors(host)``.

    The empty bag materializes the shortcut view over ``attrs`` (Fig 5b: the
    (Time, Stores) bag between Store_Sales and its dimensions).  ``attrs``
    must cover each rerouted separator so RIP is preserved.
    """
    bag_name = f"bag:{name}"
    assert bag_name not in jt.bags
    attrs = tuple(attrs)
    assert set(attrs) <= set(jt.bags[host]), "empty bag attrs must come from host"
    for v in reroute:
        assert v in jt.adj[host], f"{v} is not a neighbor of {host}"
        assert set(jt.separator(host, v)) <= set(attrs), (
            f"separator({host},{v}) not covered by empty bag"
        )
    bags = dict(jt.bags)
    bags[bag_name] = attrs
    adj = {b: [x for x in nb] for b, nb in jt.adj.items()}
    for v in reroute:
        adj[host].remove(v)
        adj[v].remove(host)
        adj[v].append(bag_name)
    adj[bag_name] = list(reroute) + [host]
    adj[host].append(bag_name)
    out = JTree(
        bags=bags,
        adj={b: tuple(sorted(n)) for b, n in adj.items()},
        mapping=dict(jt.mapping),
        domains=dict(jt.domains),
        empty_bags=jt.empty_bags | {bag_name},
    )
    out.validate()
    return out


def attach_relation(
    jt: JTree, rel_name: str, rel_attrs: Sequence[str], rel_domains: Mapping[str, int]
) -> tuple[JTree, str]:
    """§4.3 augmentation: new bag for ``rel`` attached at a bag covering the
    join keys.  Returns (new JT, new bag name)."""
    rel_attrs = tuple(rel_attrs)
    keys = [a for a in rel_attrs if a in {x for at in jt.bags.values() for x in at}]
    host = None
    for b in sorted(jt.bags):
        if set(keys) <= set(jt.bags[b]):
            host = b
            break
    if host is None:
        raise ValueError(
            f"join keys {keys} span multiple bags; create an empty bag first "
            "(paper Appendix B)"
        )
    bag_name = f"bag:{rel_name}"
    bags = dict(jt.bags)
    bags[bag_name] = rel_attrs
    adj = {b: list(nb) for b, nb in jt.adj.items()}
    adj[bag_name] = [host]
    adj[host] = adj[host] + [bag_name]
    mapping = dict(jt.mapping)
    mapping[rel_name] = bag_name
    domains = dict(jt.domains)
    for a in rel_attrs:
        if a in domains and a in rel_domains and domains[a] != rel_domains[a]:
            raise ValueError(f"domain mismatch for {a}")
        domains[a] = rel_domains.get(a, domains.get(a))
    out = JTree(
        bags=bags,
        adj={b: tuple(sorted(n)) for b, n in adj.items()},
        mapping=mapping,
        domains=domains,
        empty_bags=jt.empty_bags,
    )
    out.validate()
    return out, bag_name


def jt_from_catalog(catalog) -> JTree:
    schemas = {n: catalog.get(n).attrs for n in catalog.names()}
    return build_join_tree(schemas, catalog.domains())
