"""Data cubes over CJTs (paper Appendix D).

A cuboid is just a group-by query; the CJT message cache makes the cube
lattice cheap: calibrating pivot queries with k group-by attributes makes all
(k+1)-attribute cuboids Steiner-tree-local.  ``build_cube`` materializes the
lattice up to ``h`` attrs, reusing messages throughout, and reports the same
cost split as Fig 24 (calibration time vs per-cuboid query time).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Sequence

from .calibration import CJTEngine, MessageStore
from .factor import Factor
from .query import Query


@dataclasses.dataclass
class CubeReport:
    pivot_k: int
    calibrate_s: float
    cuboids: dict[tuple[str, ...], Factor]
    query_s: dict[tuple[str, ...], float]
    messages_computed: int
    store_bytes: int

    @property
    def total_query_s(self) -> float:
        return sum(self.query_s.values())


def build_cube(
    engine: CJTEngine,
    base_query: Query,
    dims: Sequence[str],
    h: int,
    pivot_k: int | None = None,
) -> CubeReport:
    """Materialize all cuboids over ``dims`` with ≤ h group-by attrs.

    ``pivot_k``: calibrate all pivot queries with k attrs first (Appendix
    D.2's space/time dial).  k=0 calibrates only the base query.
    """
    pivot_k = 0 if pivot_k is None else pivot_k
    t0 = time.perf_counter()
    n_before = len(engine.store)
    engine.calibrate(base_query)
    for combo in itertools.combinations(sorted(dims), pivot_k) if pivot_k else ():
        engine.calibrate(base_query.with_group_by(*combo))
    calibrate_s = time.perf_counter() - t0

    cuboids: dict[tuple[str, ...], Factor] = {}
    query_s: dict[tuple[str, ...], float] = {}
    for r in range(h + 1):
        for combo in itertools.combinations(sorted(dims), r):
            q = base_query.with_group_by(*combo)
            t1 = time.perf_counter()
            f, _ = engine.execute(q)
            query_s[combo] = time.perf_counter() - t1
            cuboids[combo] = f
    return CubeReport(
        pivot_k=pivot_k,
        calibrate_s=calibrate_s,
        cuboids=cuboids,
        query_s=query_s,
        messages_computed=len(engine.store) - n_before,
        store_bytes=engine.store.nbytes,
    )


def naive_cube_cost(engine_factory, base_query: Query, dims: Sequence[str], h: int):
    """No-sharing baseline: every cuboid recomputed with a cold store."""
    times = {}
    out = {}
    for r in range(h + 1):
        for combo in itertools.combinations(sorted(dims), r):
            eng = engine_factory()
            q = base_query.with_group_by(*combo)
            t1 = time.perf_counter()
            f, _ = eng.execute(q)
            times[combo] = time.perf_counter() - t1
            out[combo] = f
    return out, times
