"""Steiner-tree planning between queries (paper §3.4.2).

The signature cache in ``calibration.py`` already *realizes* Steiner-tree
execution (cache misses are exactly the edges inside the tree); this module
makes the tree explicit for planning, introspection and for the property test
"edges recomputed ⊆ directed Steiner tree edges".
"""

from __future__ import annotations

import dataclasses

from .calibration import CJTEngine
from .hypertree import JTree
from .query import Query


def minimal_steiner_tree(jt: JTree, terminals: set[str]) -> tuple[set[str], set[tuple[str, str]]]:
    """Minimal subtree of the JT spanning ``terminals``.

    In a tree this is unique: repeatedly prune non-terminal leaves.
    Returns (nodes, undirected edges as sorted tuples).
    """
    if not terminals:
        return set(), set()
    nodes = set(jt.bags)
    adj = {u: set(jt.adj[u]) for u in nodes}
    changed = True
    while changed:
        changed = False
        for u in sorted(nodes):
            if u not in terminals and len(adj[u]) <= 1:
                for v in adj[u]:
                    adj[v].discard(u)
                nodes.discard(u)
                adj.pop(u)
                changed = True
    edges = {tuple(sorted((u, v))) for u in nodes for v in adj[u]}
    return nodes, edges


@dataclasses.dataclass(frozen=True)
class SteinerPlan:
    terminals: frozenset[str]
    nodes: frozenset[str]
    edges: frozenset[tuple[str, str]]
    root: str

    @property
    def size(self) -> int:
        return len(self.nodes)


def changed_bags(engine: CJTEngine, q_old: Query, q_new: Query) -> set[str]:
    """B_D: bags whose annotation state differs between the two queries."""
    p_old = engine.place_predicates(q_old)
    p_new = engine.place_predicates(q_new)
    out = set()
    for bag in engine.jt.bags:
        if engine.bag_state_digest(q_old, bag, p_old) != engine.bag_state_digest(
            q_new, bag, p_new
        ):
            out.add(bag)
    # γ deltas: a changed group-by attr pins (the closest bag containing) it
    for attr in set(q_old.group_by) ^ set(q_new.group_by):
        cands = engine.jt.bags_with_attr(attr)
        if cands:
            out.add(cands[0])
    return out


def plan(engine: CJTEngine, q_old: Query, q_new: Query) -> SteinerPlan:
    """Plan q_new against the CJT of q_old: B_D → minimal Steiner tree → root.

    Root choice inside the tree follows §3.3.3 (smallest estimated absorb
    cost).  If nothing changed, the plan degenerates to a single bag.
    """
    bd = changed_bags(engine, q_old, q_new)
    if not bd:
        root = engine.choose_root(q_new)
        return SteinerPlan(frozenset(), frozenset({root}), frozenset(), root)
    nodes, edges = minimal_steiner_tree(engine.jt, bd)
    placement = engine.place_predicates(q_new)
    best, best_cost = None, None
    for root in sorted(nodes):
        cost = engine._bag_rows(q_new, root)
        for (u, v) in engine.jt.traversal_to_root(root):
            cost += engine.estimate_edge_cost(q_new, u, v, placement)
        if best_cost is None or cost < best_cost:
            best, best_cost = root, cost
    return SteinerPlan(frozenset(bd), frozenset(nodes), frozenset(edges), best)


def realized_size(stats, root: str | None = None) -> int:
    """Size of the Steiner tree an execution actually realized.

    The engine's cache misses are exactly the tree's directed edges, so the
    realized size is the bag set touched by ``stats.recomputed_edges`` (plus
    the absorption root when known).  ``CJTEngine.execute`` reports the same
    number in ``ExecStats.steiner_size``; this helper exists for tests that
    cross-check the planned tree (``plan``) against the realized one.
    """
    touched = {b for edge in stats.recomputed_edges for b in edge}
    if root is not None:
        touched.add(root)
    return max(len(touched), 1)


def directed_edges_into(plan_: SteinerPlan) -> set[tuple[str, str]]:
    """All directed edges whose messages an execution rooted inside the tree
    may need to recompute (both orientations of tree edges)."""
    out = set()
    for (u, v) in plan_.edges:
        out.add((u, v))
        out.add((v, u))
    return out
