"""Declarative dashboard sessions: typed interaction events, crossfilter
fan-out, and a shared think-time scheduler (paper §4, serving layer).

The paper's Treant serves whole *dashboards* — many linked visualizations
whose interaction queries differ incrementally from one another.  This module
is the public surface for that workload:

- :class:`DashboardSpec` declares named vizzes (:class:`VizSpec`: measure,
  ring, group-by, local σ) over one catalog/join graph.
- ``Treant.open_session(spec)`` returns a :class:`Session` handle holding the
  shared *crossfilter* state (one active filter per attribute, Mosaic-style
  linked selection) plus per-viz view state (drill path, measure, toggled
  relations).
- Typed events (:class:`SetFilter`, :class:`ClearFilter`, :class:`Drill`,
  :class:`Rollup`, :class:`SwapMeasure`, :class:`ToggleRelation`,
  :class:`Undo`) are applied via :meth:`Session.apply`, which derives the
  per-viz :class:`~repro.core.query.Query` objects and fans execution out to
  every viz whose query actually changed.  All vizzes share one engine /
  :class:`~repro.core.calibration.MessageStore` / plan cache, so a message
  materialized for one viz serves its siblings (Prop-2 signatures are
  γ-independent below the carry, which is what makes crossfilter fan-out
  cheap); the fan-out dispatches every viz asynchronously and blocks once.
- :class:`ThinkTimeScheduler` replaces the old single `_calibrator` slot: a
  priority queue of pending calibrations across all (session, viz) pairs.
  An interaction preempts *only* the pending calibration of the viz(zes) it
  changed — background progress on every other viz survives (the old API
  silently discarded it).  ``Session.idle(budget_messages=...,
  budget_seconds=...)`` drains the queue most-recently-interacted first;
  preempting a budget keeps the iterator position *and* every message
  already materialized (§4.2.1).
- **Batched fan-out**: the per-event re-render dispatches every changed viz
  through ``CJTEngine.execute_many`` (one engine call per ring), which
  stacks the sibling absorptions sharing a batch signature into one vmapped
  compiled plan (:mod:`repro.core.plans`) — a warm ``SetFilter`` costs one
  plan dispatch instead of one per linked viz.  ``Treant(batch_fanout=False)``
  (or ``REPRO_BATCH_FANOUT=0``) restores the per-viz dispatch path.
- **Think-time policies** (:mod:`repro.core.predictive`): leftover
  think-time is spent by ONE :class:`~repro.core.predictive.ThinkTimePolicy`
  — ``Session.idle(policy=FixedKPrefetch(k))`` pre-executes the fan-out for
  up to ``k`` neighboring σ values of the most recent ``SetFilter``
  (:func:`speculate_filters`: adjacent brush windows for ranges, shifted
  sibling value sets for IN-lists, Mosaic-style), parking the absorbed
  per-viz results in the session's prefetch cache;
  ``PredictiveThinkTime`` additionally materializes **bin cubes** — the
  γ∪{brush-dim} aggregate per (viz, likely dim), so ANY later σ on that dim
  is an O(bins) slice.  A follow-up brush on a prefetched σ or a
  cube-covered dim is served with zero store probes and zero plan
  executions (``ExecStats.prefetch_hits`` / ``bin_cube_hits``).  The legacy
  ``idle(speculate=k)`` deprecation-shims onto ``FixedKPrefetch(k)``.
- ``Session.sql(viz, text)`` routes the restricted SQL front-end
  (:mod:`repro.relational.sql`) into the same layer.

Query derivation contract (the event layer's correctness spine, tested by
digest equality against hand-built chains): for each viz,

    base → with_measure(swap) → with_group_by(spec γ + drills)
         → with_predicate(filter) per crossfilter attr (source viz excluded)
         → relation toggles

``Query.with_predicate`` replaces by attribute and keeps the σ tuple sorted
by digest, so the chain order cannot change the digest.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Mapping

import jax

from repro.relational.relation import Predicate, mask_in, mask_range
from .calibration import CalibrationPlan, CJTEngine, ExecStats, factor_nbytes
from .plans import slice_bin_cube, slice_bin_cubes
from .predictive import (
    BrushTrajectory,
    FixedKPrefetch,
    ThinkTimeBudget,
    ThinkTimePolicy,
    _BinCube,
    think_time_config,
    warn_deprecated_once,
)
from .query import Query

if TYPE_CHECKING:  # pragma: no cover — import cycle guard (treant imports us)
    from .treant import Treant


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VizSpec:
    """One visualization: an SPJA aggregate view over the shared join graph.

    ``crossfilter=False`` opts the viz out of linked selection (it keeps its
    local σ only and is never re-rendered by SetFilter/ClearFilter events).
    """

    name: str
    measure: tuple[str, str] | None = None     # (relation, column)
    ring: str = "count"
    group_by: tuple[str, ...] = ()
    predicates: tuple[Predicate, ...] = ()     # local σ, always applied
    removed: tuple[str, ...] = ()              # R̄: relations excluded up front
    crossfilter: bool = True


@dataclasses.dataclass(frozen=True)
class DashboardSpec:
    """A named set of linked vizzes over one catalog."""

    vizzes: tuple[VizSpec, ...]

    def __post_init__(self):
        names = [v.name for v in self.vizzes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate viz names in spec: {names}")

    def viz(self, name: str) -> VizSpec:
        for v in self.vizzes:
            if v.name == name:
                return v
        raise KeyError(f"no viz {name!r} in spec")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(v.name for v in self.vizzes)


# ---------------------------------------------------------------------------
# Typed interaction events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SetFilter:
    """Set the session-wide crossfilter on ``attr``.

    Either ``values`` (IN-list) or ``lo``/``hi`` (half-open range, like
    ``mask_range``).  ``source`` names the viz that originated the brush:
    per crossfilter convention it keeps showing its own unfiltered dimension,
    so the filter is applied to every *other* crossfilter viz.
    """

    attr: str
    values: tuple[int, ...] = ()
    lo: int | None = None
    hi: int | None = None
    source: str | None = None


@dataclasses.dataclass(frozen=True)
class ClearFilter:
    attr: str


@dataclasses.dataclass(frozen=True)
class Drill:
    """Add ``attr`` to one viz's group-by (drill-down)."""

    viz: str
    attr: str


@dataclasses.dataclass(frozen=True)
class Rollup:
    """Remove ``attr`` (default: the most recent γ attr) from one viz."""

    viz: str
    attr: str | None = None


@dataclasses.dataclass(frozen=True)
class SwapMeasure:
    viz: str
    relation: str
    column: str
    ring: str = "sum"


@dataclasses.dataclass(frozen=True)
class ToggleRelation:
    """Flip a relation in/out of the join (R̄); all vizzes unless ``viz``."""

    relation: str
    viz: str | None = None


@dataclasses.dataclass(frozen=True)
class Undo:
    """Revert the last ``Session.apply`` event (declarative state only)."""


Event = (SetFilter, ClearFilter, Drill, Rollup, SwapMeasure, ToggleRelation, Undo)


_UNCACHED = object()  # cube-probe memo sentinel (None is a valid memo value)


def _group_by_engine(pairs):
    """Group ``(engine, item)`` pairs into ``[(engine, [items…])]`` in
    first-appearance order (CJTEngine instances hash by identity)."""
    groups: dict[CJTEngine, list] = {}
    for eng, item in pairs:
        groups.setdefault(eng, []).append(item)
    return list(groups.items())


def speculate_filters(ev: SetFilter, domain: int, k: int) -> list[SetFilter]:
    """Up to ``k`` likely-next σ values for the same dimension, nearest first.

    Brushes move locally: a range filter's neighbors are the adjacent windows
    of the same width (clipped at the domain edges); an IN-list's neighbors
    are the value set shifted by whole spans (sibling domain values).  The
    candidate list is deterministic — alternating +/- by distance — so
    prefetch behavior is reproducible and testable.

    Termination tracks each direction's *liveness* separately and stops only
    when both are exhausted, so the generator returns exactly
    ``min(k, feasible)`` distinct candidates.  (The previous step-count
    guards were wrong at domain edges: the IN-branch's ``abs(step·span) >
    domain`` was vacuous for the positive direction and could spin long after
    both directions left the domain, and the range branch could break before
    emitting a feasible clipped edge window in the other direction.)
    """
    out: list[SetFilter] = []
    seen = set()

    def emit(cand: SetFilter) -> bool:
        key = (cand.values, cand.lo, cand.hi)
        if key not in seen:
            seen.add(key)
            out.append(cand)
        return len(out) >= k

    if k <= 0:
        return out
    if ev.values:
        vals = sorted(set(ev.values))
        span = vals[-1] - vals[0] + 1
        pos = neg = True  # direction still inside the domain
        step = 0
        while pos or neg:
            step += 1
            off = step * span
            # a shifted IN-list is feasible only when it fits whole: once one
            # endpoint leaves the domain, every later step in that direction
            # is further out — the direction is dead
            if pos and vals[-1] + off >= domain:
                pos = False
            elif pos:
                if emit(dataclasses.replace(
                        ev, values=tuple(v + off for v in vals))):
                    return out
            if neg and vals[0] - off < 0:
                neg = False
            elif neg:
                if emit(dataclasses.replace(
                        ev, values=tuple(v - off for v in vals))):
                    return out
        return out
    if ev.lo is None or ev.hi is None:
        return out
    width = max(ev.hi - ev.lo, 1)
    pos = neg = True
    step = 0
    while pos or neg:
        step += 1
        off = step * width
        # ranges clip at the edges: a direction stays live until the clipped
        # window collapses (lo >= domain / hi <= 0); the clipped edge windows
        # themselves are feasible candidates and must be emitted
        if pos:
            lo, hi = ev.lo + off, min(ev.hi + off, domain)
            if lo >= domain or lo >= hi:
                pos = False
            elif (lo, hi) != (ev.lo, ev.hi):
                if emit(dataclasses.replace(ev, lo=lo, hi=hi)):
                    return out
        if neg:
            lo, hi = max(ev.lo - off, 0), ev.hi - off
            if hi <= 0 or lo >= hi:
                neg = False
            elif (lo, hi) != (ev.lo, ev.hi):
                if emit(dataclasses.replace(ev, lo=lo, hi=hi)):
                    return out
    return out


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class InteractionResult:
    """One viz's rendered aggregate plus execution accounting.

    ``steiner_size`` is realized from the engine's own ExecStats (bags
    touched by recomputation ∪ root) rather than planned separately (0 when
    the result came from the speculative-prefetch cache — nothing executed).
    ``latency_s`` is dispatch time for this viz; under batched fan-out the
    sibling group shares one dispatch, so grouped vizzes report the same
    value, and the device sync happens once for all vizzes (see
    ApplyResult.latency_s).
    """

    factor: object
    stats: ExecStats
    latency_s: float
    steiner_size: int


@dataclasses.dataclass
class ApplyResult:
    """Outcome of one ``Session.apply``: which vizzes re-rendered and how."""

    event: object
    affected: tuple[str, ...]
    results: dict[str, InteractionResult]
    queries: dict[str, Query]
    latency_s: float


# ---------------------------------------------------------------------------
# Think-time scheduler (replaces Treant._calibrator)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _CalTask:
    session: str
    viz: str
    digest: str
    query: Query
    engine: CJTEngine
    priority: int
    plan: CalibrationPlan | None = None
    done: int = 0
    # lowest-priority tier: compaction-triggered recalibrations run only
    # when no interactive think-time work is pending
    deprioritized: bool = False


class ThinkTimeScheduler:
    """Priority queue of pending calibrations across all (session, viz) pairs.

    Priority is *cost-weighted* (ROADMAP "scheduler cost model"): the task
    with the cheapest estimated remaining work runs first —
    shortest-job-first maximizes fully-calibrated vizzes per think-time
    budget — with recency (most recently interacted) as the tie-break.
    ``schedule`` replaces a pending task only when the query for that exact
    (session, viz) changed — that is the *only* preemption; every other pair
    keeps its parked position and its partially materialized messages.
    Exhausting a ``run`` budget parks the current task without losing
    position (§4.2.1 preemptibility).
    """

    def __init__(self):
        self._tasks: dict[tuple[str, str], _CalTask] = {}
        self._seq = 0
        self.preemptions = 0          # unfinished tasks replaced by a new query
        self.invalidations = 0        # tasks dropped by data updates / close
        self.completed = 0            # tasks fully calibrated
        self.messages = 0             # edges processed across all runs
        self.speculative_queries = 0  # prefetch queries executed during idle
        self.speculative_messages = 0  # messages those queries materialized
        self.policy_decisions = 0     # work items a ThinkTimePolicy attempted
        self.cube_builds = 0          # bin cubes materialized during idle
        self._session_preemptions: dict[str, int] = {}

    def schedule(
        self,
        session: str,
        viz: str,
        query: Query,
        engine: CJTEngine,
        deprioritized: bool = False,
    ) -> None:
        key = (session, viz)
        self._seq += 1
        t = self._tasks.get(key)
        if t is not None:
            if t.digest == query.digest:
                t.priority = self._seq  # refresh recency, keep progress
                t.deprioritized = deprioritized
                return
            self.preemptions += 1
            self._session_preemptions[session] = (
                self._session_preemptions.get(session, 0) + 1
            )
        self._tasks[key] = _CalTask(
            session, viz, query.digest, query, engine, priority=self._seq,
            deprioritized=deprioritized,
        )

    def pending(self, session: str | None = None) -> int:
        if session is None:
            return len(self._tasks)
        return sum(1 for t in self._tasks.values() if t.session == session)

    def session_preemptions(self, session: str) -> int:
        return self._session_preemptions.get(session, 0)

    def drop(self, session: str, viz: str | None = None) -> int:
        keys = [
            k for k in self._tasks
            if k[0] == session and (viz is None or k[1] == viz)
        ]
        for k in keys:
            del self._tasks[k]
        self.invalidations += len(keys)
        if viz is None:  # whole session gone: a reopened name starts fresh
            self._session_preemptions.pop(session, None)
        return len(keys)

    def clear(self) -> int:
        n = len(self._tasks)
        self._tasks.clear()
        self.invalidations += n
        return n

    def _remaining_cost(self, t: _CalTask) -> float:
        """Estimated un-materialized work left on this task's CJT: Σ of
        ``estimate_edge_cost`` over all directed edges (cached edges cost 0,
        so the estimate shrinks as the pass progresses)."""
        eng, q = t.engine, t.query
        placement = eng.place_predicates(q)
        return sum(
            eng.estimate_edge_cost(q, u, v, placement)
            for u, v in eng.jt.directed_edges()
        )

    def _pick(self, cands: list[_CalTask]) -> _CalTask:
        # deprioritized (compaction) tasks form a strictly lower tier: any
        # interactive task — whatever its cost — runs first
        return min(
            cands,
            key=lambda t: (t.deprioritized, self._remaining_cost(t), -t.priority),
        )

    def run(
        self,
        budget_messages: int | None = None,
        budget_seconds: float | None = None,
        session: str | None = None,
        viz: str | None = None,
    ) -> int:
        """Drain matching tasks by cost-weighted priority; returns edges
        processed.

        On a fully unbudgeted drain, tasks on a batch-calibration engine
        advance *level-by-level across vizzes*: the picked task and every
        other matching task on its engine step one level together, so
        sibling messages sharing a batch signature execute as one vmapped
        call (``CJTEngine.run_calibration_level``).  Any budget forces
        per-edge stepping — a message budget needs exact accounting and a
        seconds budget needs per-edge preemption — and both modes
        park/resume the same per-task position.
        """
        done = 0
        t0 = time.perf_counter()
        while True:
            cands = [
                t for t in self._tasks.values()
                if (session is None or t.session == session)
                and (viz is None or t.viz == viz)
            ]
            if not cands:
                return done
            task = self._pick(cands)
            # completed tasks are popped when re-picked, NOT when their last
            # edge lands: a caller loop like the legacy ``think_time`` polls
            # until a run returns 0, and popping eagerly would let the next
            # ``schedule`` re-enqueue the finished query forever (each poll
            # re-stepping cache-hit edges and never reaching 0)
            if task.plan is not None and task.plan.done:
                self._tasks.pop((task.session, task.viz), None)
                self.completed += 1
                continue
            engine = task.engine
            # level batching only on fully unbudgeted drains: a message
            # budget needs exact per-edge accounting, and a seconds budget
            # needs per-edge preemption (a whole cross-task level can hide a
            # multi-hundred-ms trace+compile behind the deadline check)
            use_levels = (
                budget_messages is None
                and budget_seconds is None
                and engine.batch_calibration
                and engine.plans is not None
            )
            group = (
                [t for t in cands if t.engine is engine and not (
                    t.plan is not None and t.plan.done
                )]
                if use_levels else [task]
            )
            for t in group:
                if t.plan is None:
                    t.plan = engine.calibration_plan(t.query)
            before = {id(t): t.plan.edges_left() for t in group}
            if use_levels:
                # tags attribute materializations for cross-viz sharing
                # stats; the session qualifier keeps same-named vizzes of
                # different sessions distinct
                n = engine.run_calibration_level(
                    [t.plan for t in group],
                    tags=[f"{t.session}:{t.viz}" for t in group],
                )
            else:
                left = None if budget_messages is None else budget_messages - done
                deadline = None if budget_seconds is None else t0 + budget_seconds
                store = engine.store
                store.tag = f"{task.session}:{task.viz}"
                try:
                    n = engine.step_calibration(
                        task.plan, max_edges=left, deadline=deadline
                    )
                finally:
                    store.tag = None
            done += n
            self.messages += n
            for t in group:
                t.done += before[id(t)] - t.plan.edges_left()
            if budget_messages is not None and done >= budget_messages:
                return done
            if (
                budget_seconds is not None
                and time.perf_counter() - t0 >= budget_seconds
            ):
                return done

    def speculate(
        self, session: str, items: list[tuple[str, Query, CJTEngine]]
    ) -> dict[tuple[str, str], object]:
        """Speculative mode: pre-execute likely-next fan-out queries.

        ``items`` are (viz, derived query, engine) triples for σ values the
        user has not selected yet.  Queries are grouped per engine and run
        through ``execute_many`` — the same batched absorption path a real
        event takes — with the session:viz producer tag, so the messages they
        materialize land in the shared store exactly as a real interaction's
        would.  Returns ``{(viz, query digest): absorbed factor}`` for the
        session to park in its prefetch cache.
        """
        out: dict[tuple[str, str], object] = {}
        pending = []
        for eng, group in _group_by_engine(
            (eng, (viz, q)) for viz, q, eng in items
        ):
            results = eng.execute_many(
                [q for _, q in group], sync=False,
                tags=[f"{session}:{viz}" for viz, _ in group],
            )
            for (viz, q), (factor, stats) in zip(group, results):
                out[(viz, q.digest)] = factor
                pending.append(factor)
                self.speculative_messages += stats.messages_computed
            self.speculative_queries += len(group)
        if pending:
            jax.block_until_ready([f.field for f in pending])
        return out

    def stats(self) -> dict:
        return {
            "pending": len(self._tasks),
            "preemptions": self.preemptions,
            "invalidations": self.invalidations,
            "completed": self.completed,
            "messages": self.messages,
            "speculative_queries": self.speculative_queries,
            "speculative_messages": self.speculative_messages,
            "policy_decisions": self.policy_decisions,
            "cube_builds": self.cube_builds,
        }


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _VizView:
    spec: VizSpec | None
    base: Query
    group_by: tuple[str, ...]
    measure: tuple[str, str, str] | None = None   # (relation, column, ring)
    toggled: frozenset[str] = frozenset()
    crossfilter: bool = True


@dataclasses.dataclass
class _Prefetched:
    """One parked speculative result.

    ``dist`` is the candidate's rank in :func:`speculate_filters`' nearest-
    first order (0 = the σ value right next to the anchor brush): capacity
    eviction drops the *farthest* entries first, since the nearest neighbors
    are the likeliest next interaction.  ``query`` lets ``Treant.update`` /
    ``flush`` invalidate only entries that can actually see an updated
    relation.
    """

    factor: object
    query: Query
    dist: int


class Session:
    """One user's live dashboard over a shared Treant.

    Holds the crossfilter state and per-viz view state; derives each viz's
    Query on demand (see module docstring for the derivation contract) and
    executes through the Treant's shared engine/store so sessions and
    sibling vizzes reuse each other's materialized messages.
    """

    def __init__(self, treant: "Treant", session_id: str,
                 spec: DashboardSpec | None = None, calibrate: bool = True):
        self._treant = treant
        self.id = session_id
        self.spec = spec
        self._views: dict[str, _VizView] = {}
        self._current: dict[str, Query] = {}
        # attr -> (Predicate, source viz or None)
        self._filters: dict[str, tuple[Predicate, str | None]] = {}
        self._undo: list[tuple] = []
        self.undo_depth = 64
        self.events_applied = 0
        # speculative σ prefetch: (viz, query digest) -> _Prefetched entry,
        # filled by think-time policies, served (and popped) by _fan_out
        self._prefetched: dict[tuple[str, str], _Prefetched] = {}
        self.prefetch_capacity = think_time_config().prefetch_capacity
        self.prefetch_hits = 0
        self._last_filter: SetFilter | None = None
        # bin cubes: (viz, cube-query digest) -> _BinCube, plus a per-viz
        # index of which dims have a parked cube (probe fan-in).  Unlike
        # _Prefetched entries cubes are NOT popped on hit — one cube serves
        # every subsequent σ on its dimension until data invalidates it.
        self._bin_cubes: dict[tuple[str, str], _BinCube] = {}
        self._cube_dims: dict[str, set[str]] = {}
        # (viz, dim, q.digest) -> cube-query digest (or None): pure function
        # of frozen queries, so it never goes stale — digests already fold in
        # relation versions
        self._cube_probe_memo: dict[tuple[str, str, str], str | None] = {}
        self._derive_memo: dict[tuple, dict[str, Query]] = {}
        self.bin_cube_hits = 0
        # online brush-trajectory model feeding PredictiveThinkTime
        self.trajectory = BrushTrajectory()
        # session-default think-time policy; None falls back to the Treant's
        self.policy: ThinkTimePolicy | None = None
        # offline-calibration pins, keyed by pin-time digest: with batched
        # calibration the *effective* (union-carry) queries are pinned, not
        # the per-viz bases — close()/update() release exactly these
        self._pinned_queries: dict[str, Query] = {}
        if spec is not None:
            for v in spec.vizzes:
                base = Query.make(
                    treant.catalog, ring=v.ring, measure=v.measure,
                    group_by=v.group_by, predicates=v.predicates,
                    removed=v.removed,
                )
                self._views[v.name] = _VizView(
                    spec=v, base=base, group_by=tuple(v.group_by),
                    crossfilter=v.crossfilter,
                )
                self._current[v.name] = base
            if calibrate:  # offline stage: pin the base CJTs (§4.1.1)
                # one calibrate_many per engine: sibling vizzes fuse into
                # union-carry passes and levels batch across the fan-out
                bases = [self._views[v.name].base for v in spec.vizzes]
                for eng, qs in _group_by_engine(
                    (treant.engine_for(b.ring_name, b.measure), b) for b in bases
                ):
                    _, effective = eng.calibrate_many(qs, pin=True)
                    for q in effective:
                        self._pinned_queries[q.digest] = q

    # -- plumbing -------------------------------------------------------------
    @property
    def catalog(self):
        return self._treant.catalog

    @property
    def store(self):
        return self._treant.store

    @property
    def scheduler(self) -> ThinkTimeScheduler:
        return self._treant.scheduler

    def _view(self, viz: str) -> _VizView:
        try:
            return self._views[viz]
        except KeyError:
            raise KeyError(f"no viz {viz!r} in session {self.id!r}") from None

    def add_viz(self, name: str, base: Query, crossfilter: bool = True,
                spec: VizSpec | None = None) -> None:
        """Attach a viz from an explicit base query (legacy bridge)."""
        if name in self._views:
            return
        self._views[name] = _VizView(
            spec=spec, base=base, group_by=tuple(base.group_by),
            crossfilter=crossfilter,
        )
        self._current[name] = base

    def query_of(self, viz: str) -> Query:
        """The viz's latest executed query."""
        self._view(viz)
        return self._current[viz]

    @property
    def vizzes(self) -> tuple[str, ...]:
        return tuple(sorted(self._views))

    # -- query derivation ------------------------------------------------------
    def derive(self, viz: str) -> Query:
        v = self._view(viz)
        q = v.base
        if v.measure is not None:
            rel, col, ring = v.measure
            q = q.with_measure(rel, col, ring=ring)
        q = q.with_group_by(*v.group_by)
        # toggles BEFORE filters: the final Query state (and digest) is
        # identical either way, but the visibility check below needs the
        # viz's effective removal set
        for rel in sorted(v.toggled):
            q = q.with_relation_toggled(rel)
        if v.crossfilter:
            # the brushing viz keeps its full dimension (source exclusion);
            # a σ on a dimension no relation in the viz's join scope carries
            # (ToggleRelation removed it) is dropped — crossfilter semantics
            # leave such a viz unfiltered, and the σ is unplaceable anyway
            q = q.with_filters([
                pred for _attr, (pred, source) in sorted(self._filters.items())
                if source != viz and self._treant.sees_attr(q, pred.attr)
            ])
        return q

    def _predicate_of(self, ev: SetFilter) -> Predicate:
        doms = self.catalog.domains()
        if ev.attr not in doms:
            raise KeyError(f"filter attr {ev.attr!r} not in catalog")
        if ev.values:
            return mask_in(doms[ev.attr], list(ev.values), attr=ev.attr)
        if ev.lo is None or ev.hi is None:
            raise ValueError("SetFilter needs values or a [lo, hi) range")
        return mask_range(doms[ev.attr], ev.lo, ev.hi, attr=ev.attr)

    # -- event application (the tentpole API) ---------------------------------
    def apply(self, event) -> ApplyResult:
        """Apply one typed event: update state, derive queries, fan out.

        Only vizzes whose derived query digest changed are re-executed; each
        re-executed viz's pending background calibration is preempted and
        re-scheduled for the new query (no other viz's progress is touched).
        """
        if not self._record(event):
            return ApplyResult(event, (), {}, dict(self._current), 0.0)
        return self._fan_out(event)

    def _record(self, event) -> bool:
        """Validate + apply one event to the declarative state WITHOUT
        executing anything; returns False when nothing changed (empty-stack
        Undo).  The server's micro-batch loop records every session's event
        first, then runs ONE shared cross-session fan-out."""
        if not isinstance(event, Event):
            raise TypeError(f"not a dashboard event: {event!r}")
        snapshot = self._snapshot()
        if isinstance(event, Undo):
            if not self._undo:
                return False
            self._restore(self._undo.pop())
        else:
            self._mutate(event)
            self._undo.append(snapshot)
            del self._undo[: -self.undo_depth]
        self.events_applied += 1
        return True

    def _derive_token(self) -> tuple:
        """Content token of everything :meth:`derive` reads — σ state (by
        predicate digest) plus per-viz declarative state.  ``base.digest``
        folds in relation versions, so ingestion invalidates by re-keying."""
        return (
            tuple((a, p.digest, s) for a, (p, s) in sorted(self._filters.items())),
            tuple(
                (n, v.base.digest, v.measure, v.group_by,
                 tuple(sorted(v.toggled)), v.crossfilter)
                for n, v in sorted(self._views.items())
            ),
        )

    def _derived_affected(self) -> tuple[dict[str, Query], tuple[str, ...]]:
        """Re-derive every viz and name the ones whose digest changed.

        Derivation is memoized on the declarative-state token: exploration
        is full of revisited states (backtracks, jump-and-return, Undo), and
        a replayed state reuses the frozen Query objects outright instead of
        re-running the per-viz predicate placement."""
        token = self._derive_token()
        derived = self._derive_memo.get(token)
        if derived is None:
            derived = {name: self.derive(name) for name in sorted(self._views)}
            if len(self._derive_memo) > 512:
                self._derive_memo.clear()
            self._derive_memo[token] = derived
        affected = tuple(
            name for name, q in derived.items()
            if q.digest != self._current[name].digest
        )
        return dict(derived), affected

    def _mutate(self, event) -> None:
        if isinstance(event, SetFilter):
            if event.source is not None:
                self._view(event.source)
            self._filters[event.attr] = (self._predicate_of(event), event.source)
            self._last_filter = event  # speculation anchor (σ prefetch)
            self.trajectory.observe(event)
        elif isinstance(event, ClearFilter):
            self._filters.pop(event.attr, None)
            # don't speculate around a dimension the user just abandoned
            if self._last_filter is not None and self._last_filter.attr == event.attr:
                self._last_filter = None
            self.trajectory.forget(event.attr)
        elif isinstance(event, Drill):
            v = self._view(event.viz)
            if event.attr not in self.catalog.domains():
                raise KeyError(f"drill attr {event.attr!r} not in catalog")
            v.group_by = tuple(dict.fromkeys(v.group_by + (event.attr,)))
        elif isinstance(event, Rollup):
            v = self._view(event.viz)
            if event.attr is None:
                v.group_by = v.group_by[:-1]
            else:
                v.group_by = tuple(a for a in v.group_by if a != event.attr)
        elif isinstance(event, SwapMeasure):
            v = self._view(event.viz)
            v.measure = (event.relation, event.column, event.ring)
        elif isinstance(event, ToggleRelation):
            targets = [event.viz] if event.viz is not None else list(self._views)
            for name in targets:
                v = self._view(name)
                v.toggled = v.toggled ^ {event.relation}

    def _fan_out(self, event) -> ApplyResult:
        derived, affected = self._derived_affected()
        results: dict[str, InteractionResult] = {}
        pending: list[tuple[str, object]] = []
        t0 = time.perf_counter()
        # serve speculatively-prefetched results first: the whole fan-out for
        # this σ was already executed during think-time, so the viz costs
        # zero store probes and zero plan executions now
        to_run: list[str] = []
        cube_hits: list[tuple[str, Query, object, str]] = []
        for name in affected:
            q = derived[name]
            hit = self._prefetched.pop((name, q.digest), None)
            if hit is not None:
                self.prefetch_hits += 1
                results[name] = InteractionResult(
                    hit.factor, ExecStats(prefetch_hits=1), 0.0, 0
                )
                self._current[name] = q
                self.scheduler.schedule(
                    self.id, name, q,
                    self._treant.engine_for(q.ring_name, q.measure),
                )
                continue
            # then the bin cubes: a brush on a cube-materialized dimension
            # is an O(bins) slice of the parked γ∪{dim} aggregate — also
            # zero store probes and zero plan executions, for ANY σ.  Matches
            # are collected and sliced as ONE batched compiled dispatch below.
            match = self._match_bin_cube(
                name, q, hint=getattr(event, "attr", None)
            )
            if match is not None:
                cube_hits.append((name, q, match[0], match[1]))
            else:
                to_run.append(name)
        if cube_hits:
            engine = self._treant.engine_for(
                cube_hits[0][1].ring_name, cube_hits[0][1].measure
            )
            sliced = slice_bin_cubes(
                [
                    (e.factor, dim, [p.mask for p in q.predicates_on(dim)],
                     q.group_by)
                    for _, q, e, dim in cube_hits
                ],
                stats=engine.plans.stats if engine.plans is not None else None,
            )
            for (name, q, _, _), f in zip(cube_hits, sliced):
                self.bin_cube_hits += 1
                results[name] = InteractionResult(
                    f, ExecStats(bin_cube_hits=1), 0.0, 0
                )
                self._current[name] = q
                pending.append((name, f))
                self.scheduler.schedule(
                    self.id, name, q,
                    self._treant.engine_for(q.ring_name, q.measure),
                )
        # group the rest per engine; batch_fanout dispatches each group as
        # ONE execute_many call (sibling absorptions share a vmapped plan),
        # otherwise fall back to the per-viz dispatch path
        for engine, names in _group_by_engine(
            (self._treant.engine_for(derived[n].ring_name, derived[n].measure), n)
            for n in to_run
        ):
            td = time.perf_counter()
            if self._treant.batch_fanout and len(names) > 1:
                # async dispatch: block once for the whole fan-out below
                group = engine.execute_many(
                    [derived[n] for n in names], sync=False,
                    tags=[f"{self.id}:{n}" for n in names],
                )
            else:
                group = []
                for name in names:
                    self.store.tag = f"{self.id}:{name}"
                    try:
                        group.append(engine.execute(derived[name], sync=False))
                    finally:
                        self.store.tag = None
            dt = time.perf_counter() - td
            for name, (factor, stats) in zip(names, group):
                q = derived[name]
                results[name] = InteractionResult(
                    factor, stats, dt, stats.steiner_size
                )
                self._current[name] = q
                pending.append((name, factor))
                self.scheduler.schedule(self.id, name, q, engine)
        if pending:
            jax.block_until_ready([f.field for _, f in pending])
        return ApplyResult(
            event, affected, results, derived, time.perf_counter() - t0
        )

    # -- undo state ------------------------------------------------------------
    def _snapshot(self):
        # declarative state only: _current deliberately stays untouched on
        # restore so the fan-out sees the re-derived queries as changed and
        # actually re-renders the undone vizzes
        return (
            dict(self._filters),
            {n: (v.group_by, v.measure, v.toggled) for n, v in self._views.items()},
        )

    def _restore(self, snap) -> None:
        filters, views = snap
        self._filters = dict(filters)
        # undone brush: stop speculating on it — also when the restore
        # reverts to an *older* σ on the same attr, not just to no σ
        lf = self._last_filter
        if lf is not None:
            cur = self._filters.get(lf.attr)
            if cur is None or cur[0].digest != self._predicate_of(lf).digest:
                self._last_filter = None
        for n, (gb, meas, tog) in views.items():
            if n in self._views:
                v = self._views[n]
                v.group_by, v.measure, v.toggled = gb, meas, tog

    # -- imperative bridges ----------------------------------------------------
    def interact_query(self, viz: str, query: Query) -> InteractionResult:
        """Execute an explicit Query as this viz's current view.

        Legacy/SQL escape hatch: bypasses the declarative state (Undo does
        not cover it) but shares the store, plans and scheduler — the viz's
        pending calibration is preempted iff the query changed.
        """
        self._view(viz)
        engine = self._treant.engine_for(query.ring_name, query.measure)
        self.store.tag = f"{self.id}:{viz}"
        t0 = time.perf_counter()
        try:
            factor, stats = engine.execute(query)
        finally:
            self.store.tag = None
        dt = time.perf_counter() - t0
        self._current[viz] = query
        self.scheduler.schedule(self.id, viz, query, engine)
        return InteractionResult(factor, stats, dt, stats.steiner_size)

    def sql(self, viz: str, text: str, strict_from: bool = False) -> InteractionResult:
        """Parse restricted SQL and execute it as this viz's current view."""
        from repro.relational import sql as _sql  # local: avoids import cycle

        return self.interact_query(viz, _sql.parse(text, self.catalog, strict_from))

    def read(self, viz: str) -> InteractionResult:
        """Re-execute the viz's current query (pure cache hits when warm)."""
        q = self.query_of(viz)
        engine = self._treant.engine_for(q.ring_name, q.measure)
        self.store.tag = f"{self.id}:{viz}"
        t0 = time.perf_counter()
        try:
            factor, stats = engine.execute(q)
        finally:
            self.store.tag = None
        return InteractionResult(
            factor, stats, time.perf_counter() - t0, stats.steiner_size
        )

    # -- think time ------------------------------------------------------------
    def idle(
        self,
        budget_messages: int | None = None,
        budget_seconds: float | None = None,
        speculate: int = 0,
        policy: ThinkTimePolicy | None = None,
    ) -> int:
        """Spend user think-time on this session, driven by ONE policy.

        The policy (``policy=`` argument, else ``self.policy``, else the
        Treant's default — ``DrainCalibration`` unless configured) first
        drains pending calibrations most-recently-interacted first
        (preemptible: exhausting the budget keeps iterator positions and all
        materialized messages), then — while the shared budget has slack —
        runs its speculative extras: ``FixedKPrefetch(k)`` pre-executes the
        fan-out for the k nearest σ neighbors of the last brush;
        ``PredictiveThinkTime`` builds trajectory-ranked bin cubes and
        direction-biased σ prefetch.  Returns the number of calibration
        edges processed (speculative work is reported via ``stats()``).

        ``speculate=k`` is deprecated: it maps to ``FixedKPrefetch(k)``
        (bit-identical behavior) and warns once per process.
        """
        if speculate:
            warn_deprecated_once(
                "Session.idle(speculate=)",
                "Session.idle(speculate=k) is deprecated; pass "
                "policy=FixedKPrefetch(k) instead",
            )
            if policy is None:
                policy = FixedKPrefetch(speculate)
        if policy is None:
            policy = self.policy or self._treant.think_time_policy
        return policy.run(
            self,
            ThinkTimeBudget(messages=budget_messages, seconds=budget_seconds),
        )

    def _speculate(self, k: int) -> int:
        """Pre-execute the fan-out for up to ``k`` neighbor σ values of the
        last SetFilter; park the absorbed results in the prefetch cache."""
        ev = self._last_filter
        if ev is None:
            return 0
        doms = self.catalog.domains()
        return self._speculate_candidates(ev, speculate_filters(ev, doms[ev.attr], k))

    def _speculate_candidates(self, ev: SetFilter, cands: list[SetFilter]) -> int:
        """Pre-execute the fan-out for explicit candidate σ events on
        ``ev.attr`` (candidate rank = list position, nearest/likeliest
        first); park the absorbed results in the prefetch cache."""
        items: list[tuple[str, Query, CJTEngine]] = []
        # (viz, digest) -> (query, candidate rank)
        meta: dict[tuple[str, str], tuple[Query, int]] = {}
        saved = self._filters.get(ev.attr)
        try:
            for dist, cand in enumerate(cands):
                # derive through the real contract with the candidate σ
                # swapped in, so digests match the eventual real event's
                self._filters[ev.attr] = (self._predicate_of(cand), cand.source)
                for name in sorted(self._views):
                    view = self._views[name]
                    if not view.crossfilter or name == cand.source:
                        continue
                    q = self.derive(name)
                    # a ToggleRelation may have removed every relation that
                    # carries the brush attr from this viz's join scope —
                    # executing would crash placing σ on an invisible attr
                    if not self._treant.sees_attr(q, ev.attr):
                        continue
                    key = (name, q.digest)
                    if (
                        q.digest == self._current[name].digest
                        or key in self._prefetched
                        or key in meta
                    ):
                        continue
                    meta[key] = (q, dist)
                    items.append(
                        (name, q, self._treant.engine_for(q.ring_name, q.measure))
                    )
        finally:
            if saved is None:
                self._filters.pop(ev.attr, None)
            else:
                self._filters[ev.attr] = saved
        if not items:
            return 0
        for key, factor in self.scheduler.speculate(self.id, items).items():
            q, dist = meta[key]
            self._prefetched[key] = _Prefetched(factor, q, dist)
        self._evict_prefetched()
        return len(items)

    def _evict_prefetched(self) -> None:
        """Capacity eviction, farthest-from-anchor first.

        Entries park the fan-out for σ values *near* the user's last brush;
        when ``speculate(k)`` overshoots ``prefetch_capacity`` the useful
        entries are exactly the nearest ones, so evict by descending
        speculation distance (ties: oldest insertion first).  The previous
        policy popped in dict-insertion order — which is nearest-first
        insertion — i.e. it threw away precisely the candidates most likely
        to be hit next.
        """
        while len(self._prefetched) > self.prefetch_capacity:
            victim = max(
                enumerate(self._prefetched.items()),
                key=lambda e: (e[1][1].dist, -e[0]),
            )[1][0]
            del self._prefetched[victim]

    # -- bin cubes --------------------------------------------------------------
    def _cube_query(self, q: Query, dim: str) -> Query | None:
        """The cube query serving any σ on ``dim`` for a viz whose derived
        query is ``q``: drop the σ on ``dim``, group by γ∪{dim}.  Build and
        probe both derive the key through here, so the digests meet as long
        as only the σ on ``dim`` differs.  Returns None when the dimension
        is unknown, invisible to the viz's join scope (ToggleRelation), or
        the cube would blow the cell budget."""
        doms = self.catalog.domains()
        if dim not in doms or not self._treant.sees_attr(q, dim):
            return None
        gamma = tuple(dict.fromkeys(q.group_by + (dim,)))
        cells = 1
        for a in gamma:
            cells *= doms[a]
        if cells > think_time_config().cube_cell_budget:
            return None
        return q.without_predicate(dim).with_group_by(*gamma)

    def _build_bin_cube(self, viz: str, dim: str) -> bool:
        """Materialize the γ∪{dim} cube for one viz during think-time.

        Executes through the shared engine with this session's producer tag
        (union-carry widening applies: the cube's messages are the wide ones
        sibling calibrations share), then parks the absorbed factor keyed by
        the cube query's digest."""
        q = self.derive(viz)
        cq = self._cube_query(q, dim)
        if cq is None:
            return False
        key = (viz, cq.digest)
        if key in self._bin_cubes:
            # refresh recency: the policy still predicts this cube, so it
            # must outlive the churn of transient-σ builds (LRU, not FIFO).
            # Register the dim on the entry regardless — when dim is already
            # in the viz's γ, several (viz, dim) targets collapse to the SAME
            # cube query (identical digest), and both the probe and the
            # eviction bookkeeping need the full covered-dim set.
            entry = self._bin_cubes.pop(key)
            entry.dims.add(dim)
            self._bin_cubes[key] = entry
            self._cube_dims.setdefault(viz, set()).add(dim)
            return False
        engine = self._treant.engine_for(cq.ring_name, cq.measure)
        self.store.tag = f"{self.id}:{viz}"
        try:
            factor, stats = engine.execute(cq)
        finally:
            self.store.tag = None
        if dim not in factor.attrs:  # γ collapsed the dim away: not sliceable
            return False
        self._bin_cubes[key] = _BinCube(
            factor=factor, query=cq, dim=dim, viz=viz,
            nbytes=factor_nbytes(factor),
        )
        self._cube_dims.setdefault(viz, set()).add(dim)
        self.scheduler.cube_builds += 1
        self.scheduler.speculative_messages += stats.messages_computed
        if engine.plans is not None:
            engine.plans.stats.cube_builds += 1
        self._evict_bin_cubes()
        return True

    def _match_bin_cube(self, viz: str, q: Query, hint: str | None = None):
        """Find a parked cube covering ``q``: for each dim with a cube on
        this viz, rebuild the cube key from the NEW query (only the σ on
        that dim may differ) and return ``(entry, dim)`` on a digest match.
        A σ-less match (the dim was just cleared) works too — the slice is
        then a pure marginalization, so ClearFilter hits.

        ``hint`` (the triggering event's dimension) is probed first: each
        probe costs a Query rebuild + digest, and the brushed dim is the one
        whose cube matches on the first try.  The q.digest → cube-key
        derivation is memoized: revisited dashboard states (backtracks,
        repeated jumps) skip the Query rebuild entirely.
        """
        dims = self._cube_dims.get(viz)
        if not dims:
            return None
        order = sorted(dims)
        if hint is not None and hint in dims:
            order.remove(hint)
            order.insert(0, hint)
        for dim in order:
            memo_key = (viz, dim, q.digest)
            cd = self._cube_probe_memo.get(memo_key, _UNCACHED)
            if cd is _UNCACHED:
                cq = self._cube_query(q, dim)
                cd = None if cq is None else cq.digest
                if len(self._cube_probe_memo) > 4096:
                    self._cube_probe_memo.clear()
                self._cube_probe_memo[memo_key] = cd
            if cd is None:
                continue
            entry = self._bin_cubes.pop((viz, cd), None)
            if entry is None:
                continue
            self._bin_cubes[(viz, cd)] = entry  # LRU: hit refreshes
            return entry, dim
        return None

    def _probe_bin_cube(self, viz: str, q: Query, hint: str | None = None):
        """Match + slice in one step (the single-viz probe used by the
        serving tier and tests; ``_fan_out`` batches its slices instead)."""
        match = self._match_bin_cube(viz, q, hint)
        if match is None:
            return None
        entry, dim = match
        engine = self._treant.engine_for(q.ring_name, q.measure)
        sliced = slice_bin_cube(
            entry.factor, dim,
            [p.mask for p in q.predicates_on(dim)], q.group_by,
            stats=engine.plans.stats if engine.plans is not None else None,
        )
        self.bin_cube_hits += 1
        return sliced

    def _evict_bin_cubes(self) -> None:
        """Capacity eviction, least-recently-used first: probe hits and
        still-predicted rebuild skips refresh recency, so cubes built under
        a transient σ (one-shot digests) age out ahead of the hot ones."""
        cap = think_time_config().cube_capacity
        while len(self._bin_cubes) > cap:
            key = next(iter(self._bin_cubes))
            self._drop_cube(key)

    def _drop_cube(self, key: tuple[str, str]) -> None:
        entry = self._bin_cubes.pop(key, None)
        if entry is None:
            return
        dims = self._cube_dims.get(entry.viz)
        if dims is None:
            return
        still = set()
        for e in self._bin_cubes.values():
            if e.viz == entry.viz:
                still |= e.dims
        for d in entry.dims - still:
            dims.discard(d)
        if not dims:
            self._cube_dims.pop(entry.viz, None)

    def invalidate_bin_cubes(self, changed) -> int:
        """Drop every cube whose query can see one of the ``changed``
        relations (mirrors the prefetch-cache invalidation on update/flush).
        Returns the number of cubes dropped."""
        stale = [
            k for k, e in self._bin_cubes.items()
            if any(self._treant._sees(e.query, r) for r in changed)
        ]
        for k in stale:
            self._drop_cube(k)
        return len(stale)

    @property
    def bin_cube_bytes(self) -> int:
        return sum(e.nbytes for e in self._bin_cubes.values())

    # -- filters / introspection ----------------------------------------------
    @property
    def filters(self) -> Mapping[str, Predicate]:
        return {a: p for a, (p, _) in self._filters.items()}

    def stats(self) -> dict:
        """Session introspection: per-session scheduler counters plus the
        shared store/scheduler totals (``*_total`` — Treant-wide, since
        sessions deliberately share one store and one scheduler)."""
        return {
            "vizzes": len(self._views),
            "events": self.events_applied,
            "pending_calibrations": self.scheduler.pending(self.id),
            "preemptions": self.scheduler.session_preemptions(self.id),
            "scheduler_messages_total": self.scheduler.messages,
            "cross_viz_hits_total": self.store.cross_tag_hits,
            "undo_depth": len(self._undo),
            "prefetched": len(self._prefetched),
            "prefetch_hits": self.prefetch_hits,
            "speculative_queries_total": self.scheduler.speculative_queries,
            "bin_cubes": len(self._bin_cubes),
            "bin_cube_hits": self.bin_cube_hits,
            "bin_cube_bytes": self.bin_cube_bytes,
            "trajectory": self.trajectory.state(),
        }

    def close(self) -> None:
        """Tear the session down without leaking store state (ROADMAP GC item).

        Drops pending calibrations, *unpins* every base CJT pinned at open
        (pins otherwise outlive the session forever — the store could never
        evict them), and evicts the unpinned messages this session's
        interactions produced (producer tags ``"{sid}:*"``).  Untagged
        offline-calibration messages stay cached for other sessions; a
        reopened identical dashboard re-pins the same signatures at
        cache-hit speed.
        """
        self.scheduler.drop(self.id)
        for q in self._pinned_queries.values():
            self._treant.engine_for(q.ring_name, q.measure).unpin_query(q)
        self._pinned_queries.clear()
        self.store.drop_producer(f"{self.id}:")
        self._prefetched.clear()
        self._bin_cubes.clear()
        self._cube_dims.clear()
        self._treant._sessions.pop(self.id, None)
