"""Compiled message plans: structural jit + Pallas fast paths for bag contraction.

Every CJT message is one *bag contraction*: ⊗ the bag's lifted relation with
the incoming messages, apply σ, ⊕-marginalize to the separator ∪ carried γ.
The legacy engine executed that op-by-op — un-jitted JAX dispatches plus
host-side numpy index building (``np.ravel_multi_index``, row-mask gathers)
on *every* call.  This module compiles each contraction once and re-executes
it at hardware speed:

- **Structural plan keys.**  Plans are keyed by the contraction's *structure*
  (relation attr order/domains/row count, incoming-factor shapes, ring,
  out_attrs, predicate arity) — NOT by Proposition-2 signatures.  A new
  relation version, a different predicate mask, or a delta-maintenance pass
  changes the Prop-2 signature but not the structure, so it re-executes the
  already-compiled plan (trace once, run forever).
- **Device-resident inputs.**  Flat row codes live in ``Catalog.dev_flat_codes``
  (keyed ``(relation, version, attr-tuple)``); per-row lifts and densified
  base factors are cached here.  The message hot path does no host work
  beyond dict lookups, so upward/downward passes dispatch asynchronously and
  the engine only blocks at absorption.
- **Pallas routing.**  Inside the traced plan, the ⊕-segment reduction of
  f32 scalar rings (SUM/COUNT via ``kernel_segment_op="sum"``, tropical
  MIN/MAX via ``"min"``/``"max"``) lowers to the ``segment_aggregate`` Pallas
  kernel, and the 2-factor dense contraction of arithmetic rings lowers to
  the ``semiring_contract`` Pallas kernel (interpret mode off-TPU).  Compound
  rings (MOMENTS, covariance, BOOL, int64 COUNT) keep the lax fallback.
  Off-TPU the one-hot-matmul kernels do O(N·G) work, so they are cost-gated
  (``REPRO_PLAN_KERNEL_COST``): small bags exercise the kernels, huge fact
  bags stay on the O(N) lax path until a real TPU is attached.
- **Batched plans.**  A crossfilter event fans one interaction out to every
  linked viz, and each viz's warm-path work collapses to a single absorption
  at the σ'd bag — N structurally-identical contractions that differ only in
  γ (which group-by attr the incoming message carries) and σ masks.
  ``PlanCache.run_sparse_batch`` stacks such siblings into ONE jitted call:
  members are grouped by :func:`absorb_batch_key` (root relation, incoming
  attr pattern with off-bag γ attrs canonicalized to positional
  placeholders, σ arity, out-attr pattern), γ-carried dims are padded to the
  group max with the ring's ⊕-identity (0̄ is ⊗-absorbing, so padding can
  never leak into valid slots), and the single-element plan body is
  ``jax.vmap``-ed over the stacked axis.  Stacking, padding and per-member
  slicing all happen *inside* the traced function, so a whole fan-out costs
  one dispatch instead of one per viz.  Kernel routing is unchanged: the
  vmapped body still lowers f32 SUM/COUNT and tropical rows to
  ``segment_aggregate`` under the same cost gate.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import costs as kernel_costs
from repro.kernels.segment_aggregate import ops as seg_ops
from repro.kernels.semiring_contract import ops as sc_ops
from repro.kernels.tropical_contract import ops as tc_ops
from repro.relational.relation import LRU, Predicate

from . import distributed as dist
from . import semiring as sr
from .factor import Factor, contract


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_cost_max() -> int:
    """Max one-hot-matmul work (N·G·V or G·B·A) routed to Pallas off-TPU.

    Resolution: ``REPRO_PLAN_KERNEL_COST`` env override → the measured
    crossover from the committed ``kernel_costs.json`` roofline profile →
    the historical static default (1<<19)."""
    env = os.environ.get("REPRO_PLAN_KERNEL_COST")
    if env is not None:
        return int(env)
    derived = kernel_costs.derived_plan_kernel_cost()
    return derived if derived is not None else (1 << 19)


def use_plans_default() -> bool:
    """Env-gated default for compiled plans (CI matrix: REPRO_USE_PLANS=0/1
    keeps the legacy un-jitted fallback path covered)."""
    return os.environ.get("REPRO_USE_PLANS", "1").lower() not in ("0", "false")


def batch_fanout_default() -> bool:
    """Env-gated default for batched crossfilter fan-out (REPRO_BATCH_FANOUT);
    benchmarks A/B the batched vs per-viz dispatch path through this knob."""
    return os.environ.get("REPRO_BATCH_FANOUT", "1").lower() not in ("0", "false")


def batch_calibration_default() -> bool:
    """Env-gated default for level-batched calibration passes
    (REPRO_BATCH_CALIBRATION; CI runs a 0/1 matrix axis).  When off — or when
    compiled plans are off — calibration degrades to the per-edge loop."""
    return os.environ.get("REPRO_BATCH_CALIBRATION", "1").lower() not in ("0", "false")


def calibration_union_budget() -> int:
    """Max product of γ domain sizes one union-carry calibration query may
    accumulate (REPRO_CALIBRATION_UNION_BUDGET).  Bounds the widest message a
    shared calibration pass materializes: per-row ⊗ lanes scale with the
    product, so the default keeps the fact-bag working set ~O(512·N·4B) while
    collapsing the most traces (measured knee on the crossfilter suite).

    Resolution mirrors :func:`_kernel_cost_max`: env override → roofline
    profile's derived budget → static 512."""
    env = os.environ.get("REPRO_CALIBRATION_UNION_BUDGET")
    if env is not None:
        return int(env)
    derived = kernel_costs.derived_union_budget()
    return derived if derived is not None else 512


def sparse_batch_elems() -> int:
    """Max rows·width element volume one vmapped sparse-absorption dispatch
    may carry (``REPRO_SPARSE_BATCH_ELEMS``; 0 = unbounded).

    The vmapped absorption's cost grows superlinearly with member count on
    the CPU backend (measured: break-even near width 4 at 5k fact rows,
    3-5x sequential by width 32), so one-dispatch-per-group is only
    profitable while the dispatch volume stays small.  Wider groups split
    into chunks of at least 2 members, keeping cross-session sharing intact
    while the per-dispatch cost stays near the sequential line."""
    env = os.environ.get("REPRO_SPARSE_BATCH_ELEMS")
    if env is not None:
        return int(env)
    return 1 << 18


def fuse_level_default() -> bool:
    """Env-gated default for level-fused kernel launches
    (REPRO_FUSE_LEVEL_KERNEL; CI runs a 0/1 axis).  When on — and plans plus
    level batching are on — each calibration level dispatches ONE jitted call
    whose kernel-eligible messages share a single multi-segment Pallas
    launch."""
    return os.environ.get("REPRO_FUSE_LEVEL_KERNEL", "1").lower() not in ("0", "false")


def expand_rows_field(field: sr.Field, have: Sequence[str], want: Sequence[str],
                      trailing: Sequence[int]) -> sr.Field:
    """Insert size-1 axes so leaves go (N, *have_dims, *t) → (N, *want_dims, *t).

    ``have`` must be a subsequence of ``want``; trailing statistic dims ride
    along unchanged.  Shared by the compiled plans and the legacy sparse path.
    """
    leaves, treedef = jax.tree_util.tree_flatten(field)
    out = []
    for leaf, t in zip(leaves, trailing):
        cur = list(leaf.shape)
        new_shape = [cur[0]]
        hi = 1
        for a in want:
            if a in have:
                new_shape.append(cur[hi])
                hi += 1
            else:
                new_shape.append(1)
        new_shape += cur[hi:]
        out.append(leaf.reshape(new_shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def _field_struct(field: sr.Field) -> tuple:
    return tuple((tuple(l.shape), str(l.dtype)) for l in jax.tree_util.tree_leaves(field))


@dataclasses.dataclass
class PlanStats:
    """Cumulative plan-cache counters (exposed via ``Treant.cache_stats``)."""

    plans_built: int = 0     # structural misses → new trace + compile
    plan_hits: int = 0       # executions served by an existing compiled plan
    kernel_execs: int = 0    # executions that ran a Pallas kernel path
    fallback_execs: int = 0  # executions on the lax/einsum fallback path
    # batched absorption plans (run_sparse_batch)
    batched_execs: int = 0        # vmapped batched calls dispatched
    batched_absorptions: int = 0  # absorptions served by those calls (Σ widths)
    batch_width: int = 0          # widest batch observed (max, not a sum)
    # level-batched calibration (run_message_batch): whole upward/downward
    # levels stacked into vmapped calls, plus how many message
    # materializations calibration dispatched in total (per-edge loop: one
    # per computed message; batched: one per level group)
    level_batched_execs: int = 0     # vmapped level-batch calls dispatched
    level_batched_messages: int = 0  # messages served by those calls (Σ widths)
    level_batch_width: int = 0       # widest level batch observed (max)
    calibration_dispatches: int = 0  # message dispatches issued by calibration
    # level-fused launches (run_level): every kernel-eligible message of a
    # calibration level ⊕-reduced by ONE multi-segment Pallas launch
    fused_level_launches: int = 0    # fused level launches dispatched
    fused_level_messages: int = 0    # messages served by those launches
    # cross-session batched fan-out (TreantServer): vmapped dispatches whose
    # members span >1 session, and the widest distinct-session count observed
    cross_session_execs: int = 0
    cross_session_width: int = 0
    # mesh-sharded execution (PlanCache(mesh=...)): dispatches that ran under
    # shard_map, the bytes their ⊕-all-reduce collectives carried (static per
    # plan: Σ output-factor payloads), and the worst row imbalance observed
    # (max valid rows per shard / ideal per-shard rows)
    shard_execs: int = 0
    allreduce_bytes: int = 0
    shard_imbalance: float = 0.0
    # bin cubes (core/predictive.py): think-time γ∪{dim} materializations
    # built through this engine, and warm brushes served by slicing one
    # (select + ⊕-marginalize — no plan execution, no store probe)
    cube_builds: int = 0
    cube_slices: int = 0

    # counters that are high-water marks, not sums: cross-engine aggregation
    # (Treant.cache_stats) takes max for these and Σ for everything else
    MAX_FIELDS = (
        "batch_width", "level_batch_width", "cross_session_width",
        "shard_imbalance",
    )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@functools.lru_cache(maxsize=512)
def _compiled_slice(dim: str, group_by: tuple[str, ...]):
    """One jitted select∘project per (dim, γ): Factor is a pytree with
    (attrs, ring) static, so jax.jit specializes per cube structure and the
    warm brush costs a single compiled dispatch instead of one eager op per
    σ mask plus the marginalization."""

    def run(cube, masks):
        f = cube
        for m in masks:
            f = f.select(dim, m)
        return f.project_to(group_by)

    return jax.jit(run)


@functools.lru_cache(maxsize=1024)
def _device_mask(data: bytes, shape: tuple[int, ...], dtype: str):
    """Content-addressed device copy of a σ mask: the same predicate fans
    out to every sibling viz, so without this each viz pays its own
    host→device transfer of an identical (tiny) mask."""
    return jnp.asarray(np.frombuffer(data, dtype=dtype).reshape(shape))


def _to_device_masks(masks) -> tuple:
    out = []
    for m in masks:
        arr = np.asarray(m)
        out.append(_device_mask(arr.tobytes(), arr.shape, str(arr.dtype)))
    return tuple(out)


def slice_bin_cube(cube, dim: str, masks, group_by, stats: PlanStats | None = None):
    """Serve a brush from a parked γ∪{dim} bin cube: σ as ``select`` (0̄ is
    the ⊕-identity, so zero-annotating non-matching bins is exact for every
    semiring) then ⊕-marginalize ``dim`` away via ``project_to``.  With no
    masks this serves ``ClearFilter`` (pure marginalization).  O(bins) array
    work — no store probes, no plan executions."""
    fn = _compiled_slice(dim, tuple(group_by))
    f = fn(cube, _to_device_masks(masks))
    if stats is not None:
        stats.cube_slices += 1
    return f


@functools.lru_cache(maxsize=512)
def _compiled_slice_batch(spec: tuple):
    """One jitted call covering a whole fan-out of cube slices: ``spec`` is
    a tuple of (dim, group_by) per viz, the cubes/masks ride in as pytrees.
    A 7-viz crossfilter brush costs ONE compiled dispatch instead of seven —
    the cube analog of ``batch_fanout``'s vmapped absorption groups."""

    def run(cubes, masks_list):
        outs = []
        for (dim, group_by), cube, masks in zip(spec, cubes, masks_list):
            f = cube
            for m in masks:
                f = f.select(dim, m)
            outs.append(f.project_to(group_by))
        return tuple(outs)

    return jax.jit(run)


def slice_bin_cubes(items, stats: PlanStats | None = None) -> list:
    """Batched :func:`slice_bin_cube`: ``items`` is a list of
    (cube_factor, dim, masks, group_by); returns the sliced factors in
    order, produced by a single compiled dispatch."""
    spec = tuple((dim, tuple(gb)) for _, dim, _, gb in items)
    fn = _compiled_slice_batch(spec)
    outs = fn(
        tuple(c for c, _, _, _ in items),
        tuple(_to_device_masks(m) for _, _, m, _ in items),
    )
    if stats is not None:
        stats.cube_slices += len(items)
    return list(outs)


@dataclasses.dataclass(frozen=True)
class _Plan:
    fn: Callable
    uses_kernel: bool
    # level plans only: per-group kernel routing + Σ width of fused groups
    group_kernel: tuple = ()
    fused_messages: int = 0
    # mesh-sharded plans only: the body runs under shard_map and every output
    # factor is ⊕-all-reduced; allreduce_bytes is the static Σ of those
    # collective payloads (one per output factor per dispatch)
    sharded: bool = False
    allreduce_bytes: int = 0


# ---------------------------------------------------------------------------
# sparse-bag plan: gather ⊗ rowwise → σ row mask → segment-⊕ → reshape
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _SparseMeta:
    """Static facts about one sparse contraction the level plan needs to
    route its rowwise output through the fused kernel."""

    total: int                       # flattened local-out segment count
    carried_dims: tuple[int, ...]    # γ-carried dims of the rowwise output
    use_kernel: bool
    cost: int


def _sparse_plan_parts(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
) -> tuple[Callable, Callable, Callable, _SparseMeta]:
    """The raw (un-jitted) single-contraction body shared by the scalar plan
    (jit directly) and the batched plan (pad + stack + vmap, then jit),
    split as (fn, rowwise, finalize, meta) so the level-fused plan can run
    the rowwise stage per message and hand ALL segment reductions of a level
    to one multi-segment kernel launch between rowwise and finalize."""
    rel_set = set(rel_attrs)
    local_out = tuple(a for a in out_attrs if a in rel_set)
    total = int(np.prod([doms[a] for a in local_out])) if local_out else 1

    # static replay of the carried-γ evolution across incoming messages
    steps: list[tuple[tuple, tuple, tuple, tuple, tuple]] = []
    carried: tuple[str, ...] = ()
    for m_attrs in in_attrs_list:
        shared = tuple(a for a in m_attrs if a in rel_set)
        extra = tuple(a for a in m_attrs if a not in rel_set)
        want = carried + tuple(a for a in extra if a not in carried)
        steps.append((m_attrs, shared, extra, carried, want))
        carried = want
    carried_dims = tuple(doms[a] for a in carried)
    carried_out = [a for a in out_attrs if a not in rel_set]
    assert set(carried_out) <= set(carried), (
        f"carried attrs {carried_out} not available (have {list(carried)})"
    )

    op = ring.kernel_segment_op
    vcols = int(np.prod(carried_dims)) if carried_dims else 1
    cost = n * max(total, 1) * vcols * len(ring.trailing)
    use_kernel = (
        op is not None
        and ring.dtype == jnp.float32
        and all(t == 0 for t in ring.trailing)
        and n > 0
        and (_on_tpu() or cost <= _kernel_cost_max())
    )
    interpret = not _on_tpu()
    out_shape = tuple(doms[a] for a in local_out)

    def rowwise(vals, in_fields, in_idx, pred_masks, pred_codes):
        for (m_attrs, shared, extra, have, want), field, idx in zip(
            steps, in_fields, in_idx
        ):
            mp = Factor(m_attrs, field, ring).project_to(shared + extra)
            dims = [doms[a] for a in shared]

            def gather(leaf):
                lead = leaf.reshape(
                    (int(np.prod(dims)) if shared else 1,) + leaf.shape[len(shared):]
                )
                if shared:
                    return jnp.take(lead, idx, axis=0)
                return jnp.broadcast_to(lead, (n,) + lead.shape[1:])

            leaves, treedef = jax.tree_util.tree_flatten(mp.field)
            g = jax.tree_util.tree_unflatten(treedef, [gather(l) for l in leaves])
            vals = ring.mul(
                expand_rows_field(vals, have, want, ring.trailing),
                expand_rows_field(g, extra, want, ring.trailing),
            )
        if pred_attrs:
            # σ as a rowwise ⊗ with 0̄/1̄: gather each domain mask at the row
            # codes on-device (the mask *content* is a traced arg, so new
            # selections re-execute the same compiled plan)
            rowm = pred_masks[0][pred_codes[0]]
            for mask, codes in zip(pred_masks[1:], pred_codes[1:]):
                rowm = rowm & mask[codes]
            zeros = ring.zeros((n,) + carried_dims)
            leaves, treedef = jax.tree_util.tree_flatten(vals)
            zleaves = jax.tree_util.tree_leaves(zeros)
            out = []
            for leaf, z in zip(leaves, zleaves):
                m = rowm.reshape((n,) + (1,) * (leaf.ndim - 1))
                out.append(jnp.where(m, leaf, z))
            vals = jax.tree_util.tree_unflatten(treedef, out)
        return vals

    def finalize(field):
        field = jax.tree_util.tree_map(
            lambda l: l.reshape(out_shape + l.shape[1:]), field
        )
        return Factor(local_out + carried, field, ring).project_to(out_attrs)

    def fn(vals, in_fields, in_idx, pred_masks, pred_codes, seg_idx):
        vals = rowwise(vals, in_fields, in_idx, pred_masks, pred_codes)
        if use_kernel:
            # compound rings (MOMENTS) stack their equal-shape leaves as
            # extra value columns, so count/sum/sumsq share ONE segment pass
            leaves, treedef = jax.tree_util.tree_flatten(vals)
            slab = jnp.concatenate([l.reshape((n, -1)) for l in leaves], axis=1)
            agg = seg_ops.aggregate_op(
                seg_idx, slab, total, op=op, interpret=interpret
            )
            parts = jnp.split(agg, len(leaves), axis=1) if len(leaves) > 1 else [agg]
            red = [
                p.reshape((total,) + l.shape[1:]) for p, l in zip(parts, leaves)
            ]
            field = jax.tree_util.tree_unflatten(treedef, red)
        else:
            field = ring.segment_reduce(vals, seg_idx, total)
        return finalize(field)

    meta = _SparseMeta(
        total=total, carried_dims=carried_dims, use_kernel=use_kernel, cost=cost
    )
    return fn, rowwise, finalize, meta


def _sparse_plan_fn(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
) -> tuple[Callable, bool]:
    fn, _, _, meta = _sparse_plan_parts(
        ring, rel_attrs, doms, in_attrs_list, pred_attrs, out_attrs, n
    )
    return fn, meta.use_kernel


def _build_sparse_plan(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
) -> _Plan:
    fn, use_kernel = _sparse_plan_fn(
        ring, rel_attrs, doms, in_attrs_list, pred_attrs, out_attrs, n
    )
    return _Plan(fn=jax.jit(fn), uses_kernel=use_kernel)


# ---------------------------------------------------------------------------
# mesh-sharded plans: shard_map the body over row blocks, ⊕-all-reduce γ
# ---------------------------------------------------------------------------

def _sparse_shard_specs(axis: str) -> tuple:
    """shard_map in_specs (pytree prefixes) for the (vals, in_fields, in_idx,
    pred_masks, pred_codes, seg_idx) layout every sparse plan body takes:
    row-major arrays (lifts, gather indices, σ row codes, segment ids) shard
    on the mesh axis; γ-indexed message fields and σ domain masks replicate.
    The same prefixes cover the batched (tuple-of-members) layout."""
    return (P(axis), P(), P(axis), P(), P(axis), P(axis))


def _out_factor_bytes(ring: sr.Semiring, doms: dict[str, int],
                      out_attrs: tuple[str, ...]) -> int:
    """Static payload of one ⊕-all-reduced output factor — the (|γ|, V)
    collective size (scalar-leaf approximation for compound rings)."""
    cells = int(np.prod([doms[a] for a in out_attrs])) if out_attrs else 1
    return cells * len(ring.trailing) * np.dtype(ring.dtype).itemsize


def _build_sharded_sparse_plan(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
    mesh,
    axis: str,
) -> _Plan:
    """Row-sharded single contraction over a 1-D device mesh.

    The local body is the *unchanged* rowwise → σ → segment-⊕ pipeline built
    for a 1/nshards row block (pad rows carry the ⊕-identity, so any block
    split of the padded bucket is exact); the resulting γ-indexed partial
    factor is ⊕-all-reduced before it leaves shard_map.  Every cross-shard
    message is therefore a tiny (|γ|, V) collective — never a join.
    """
    nshards = int(mesh.shape[axis])
    assert n % nshards == 0, f"row bucket {n} not divisible by mesh {nshards}"
    fn_local, _, _, meta = _sparse_plan_parts(
        ring, rel_attrs, doms, in_attrs_list, pred_attrs, out_attrs,
        n // nshards,
    )
    collective = dist.ring_collective(ring)
    assert collective is not None, "caller gates on ring_collective"

    def local(vals, in_fields, in_idx, pred_masks, pred_codes, seg_idx):
        fact = fn_local(vals, in_fields, in_idx, pred_masks, pred_codes, seg_idx)
        return dist.allreduce_field(fact, collective, axis)

    sm = dist.shard_map_compat(
        local, mesh, in_specs=_sparse_shard_specs(axis), out_specs=P()
    )
    return _Plan(
        fn=jax.jit(sm), uses_kernel=meta.use_kernel, sharded=True,
        allreduce_bytes=_out_factor_bytes(ring, doms, out_attrs),
    )


def _build_sharded_batched_sparse_plan(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
    member_dims: tuple[dict[str, int], ...],
    mesh,
    axis: str,
) -> _Plan:
    """Row-sharded variant of the vmapped batch plan: B members' rowwise
    stages run per shard (the vmap sits *inside* the local body), then each
    member's sliced output factor is ⊕-all-reduced."""
    nshards = int(mesh.shape[axis])
    assert n % nshards == 0
    bfn, use_kernel = _batched_sparse_fn(
        ring, rel_attrs, doms, in_attrs_list, pred_attrs, out_attrs,
        n // nshards, member_dims,
    )
    collective = dist.ring_collective(ring)

    def local(vals_list, in_fields_list, in_idx, pred_masks_list, pred_codes,
              seg_idx):
        facts = bfn(vals_list, in_fields_list, in_idx, pred_masks_list,
                    pred_codes, seg_idx)
        return dist.allreduce_field(facts, collective, axis)

    sm = dist.shard_map_compat(
        local, mesh, in_specs=_sparse_shard_specs(axis), out_specs=P()
    )
    bytes_ = sum(
        _out_factor_bytes(ring, {**doms, **md}, out_attrs)
        for md in member_dims
    )
    return _Plan(fn=jax.jit(sm), uses_kernel=use_kernel, sharded=True,
                 allreduce_bytes=bytes_)


# ---------------------------------------------------------------------------
# batched absorption plans: pad γ dims → stack → vmap, one dispatch per group
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AbsorbItem:
    """One pending sparse-bag absorption, deferred so siblings can batch.

    ``rel`` is the (single) relation of the absorption bag, ``vals`` its
    per-row lift, ``incoming`` the cached/computed messages from every
    neighbor, ``preds`` the σ placed on this bag, ``out_attrs`` the
    separator-free absorption output (γ restricted to the subtree).
    """

    rel: object                      # relational.Relation
    vals: sr.Field
    incoming: tuple[Factor, ...]
    preds: tuple[Predicate, ...]
    out_attrs: tuple[str, ...]


@dataclasses.dataclass
class _GroupSpec:
    """One canonicalized batch group: members in canonical order plus all
    the statics the batched / level-fused plan builders consume."""

    items: list
    stats: list | None
    in_canon: tuple
    out_canon: tuple
    member_dims: tuple
    doms: dict
    pred_attrs: tuple
    inverse: dict          # canonical position → caller position
    key: tuple             # version-free trace key


def _canon_absorption(item: AbsorbItem) -> tuple[tuple, tuple, dict[str, str]]:
    """Canonicalize off-bag (γ-carried) attrs to positional placeholders.

    Two absorptions batch iff they differ only in *which* off-bag attr each
    structural slot carries (and its domain size) — e.g. sibling vizzes
    grouping by ``airport_state`` vs ``month``.  Placeholders are assigned in
    first-appearance order scanning incoming messages then out_attrs, so the
    coincidence pattern (one attr appearing in several slots) is preserved.
    """
    rel_set = set(item.rel.attrs)
    ph: dict[str, str] = {}

    def c(a: str) -> str:
        if a in rel_set:
            return a
        if a not in ph:
            ph[a] = f"·{len(ph)}"
        return ph[a]

    in_canon = tuple(tuple(c(a) for a in m.attrs) for m in item.incoming)
    out_canon = tuple(c(a) for a in item.out_attrs)
    return in_canon, out_canon, ph


def absorb_batch_key(ring: sr.Semiring, item: AbsorbItem) -> tuple:
    """Grouping key for batchable absorptions (the *batch signature*).

    Everything the shared (in_axes=None) plan inputs depend on must be here:
    the relation version (row codes → in_idx/pred_codes/seg_idx), the rel
    attr order and domains, σ attrs, the canonical incoming/out patterns and
    the lift's field structure.  Placeholder domain sizes are deliberately
    absent — they are padded per group and only key the *trace*.
    """
    in_canon, out_canon, _ = _canon_absorption(item)
    rel = item.rel
    return (
        "sparse_batch", ring.name, rel.key, rel.attrs,
        tuple(rel.domains[a] for a in rel.attrs), rel.num_rows,
        in_canon, tuple(p.attr for p in item.preds), out_canon,
        _field_struct(item.vals),
    )


def _pad_value(zero_leaf) -> float | bool:
    """The constant ⊕-identity fill for one field leaf (identity fields are
    constant-valued in every ring here: 0.0, ±inf, False)."""
    flat = np.asarray(zero_leaf).reshape(-1)
    return flat[0].item() if flat.size else 0.0


def _make_batch_stager(
    ring: sr.Semiring,
    rel_set: set[str],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
) -> Callable:
    """Traced-side stacking of B members' inputs: γ-carried message dims pad
    to the group max with the ⊕-identity (0̄ is ⊗-absorbing, so padding can
    never leak into valid slots), then everything stacks on a new lead axis."""
    pad_vals = [_pad_value(z) for z in jax.tree_util.tree_leaves(ring.zeros(()))]

    def _stack(fields):
        return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *fields)

    def _pad_message(j: int, field: sr.Field) -> sr.Field:
        m_attrs = in_attrs_list[j]
        leaves, treedef = jax.tree_util.tree_flatten(field)
        out = []
        for leaf, t, pv in zip(leaves, ring.trailing, pad_vals):
            pads = [
                (0, (doms[a] - leaf.shape[k]) if a not in rel_set else 0)
                for k, a in enumerate(m_attrs)
            ] + [(0, 0)] * t
            out.append(jnp.pad(leaf, pads, constant_values=pv)
                       if any(p[1] for p in pads) else leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def stage(vals_list, in_fields_list, pred_masks_list):
        vals = _stack(vals_list)
        in_fields = tuple(
            _stack([_pad_message(j, member[j]) for member in in_fields_list])
            for j in range(len(in_attrs_list))
        )
        pred_masks = tuple(
            jnp.stack([pm[k] for pm in pred_masks_list])
            for k in range(len(pred_attrs))
        )
        return vals, in_fields, pred_masks

    return stage


def _slice_member(
    ring: sr.Semiring,
    fact: Factor,
    dims: dict[str, int],
    doms: dict[str, int],
    lead: int | None = None,
) -> Factor:
    """Slice one member's valid region out of a padded (optionally stacked)
    factor: placeholder dims shrink back to the member's actual sizes."""
    leaves, treedef = jax.tree_util.tree_flatten(fact.field)
    sliced = []
    for leaf, t in zip(leaves, ring.trailing):
        idx = tuple(
            ([] if lead is None else [lead])
            + [slice(0, dims.get(a, doms[a])) for a in fact.attrs]
            + [slice(None)] * t
        )
        sliced.append(leaf[idx])
    return Factor(fact.attrs, jax.tree_util.tree_unflatten(treedef, sliced), ring)


def _batched_sparse_fn(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
    member_dims: tuple[dict[str, int], ...],
) -> tuple[Callable, bool]:
    """The raw (un-jitted) B-member batch body: pad + stack + vmap the
    single-contraction fn, then slice members back out."""
    fn, use_kernel = _sparse_plan_fn(
        ring, rel_attrs, doms, in_attrs_list, pred_attrs, out_attrs, n
    )
    nmembers = len(member_dims)
    stage = _make_batch_stager(ring, set(rel_attrs), doms, in_attrs_list, pred_attrs)

    def bfn(vals_list, in_fields_list, in_idx, pred_masks_list, pred_codes, seg_idx):
        vals, in_fields, pred_masks = stage(vals_list, in_fields_list, pred_masks_list)
        batched = jax.vmap(fn, in_axes=(0, 0, None, 0, None, None))(
            vals, in_fields, in_idx, pred_masks, pred_codes, seg_idx
        )
        # slice each member's valid region back out of the padded stack
        return tuple(
            _slice_member(ring, batched, member_dims[i], doms, lead=i)
            for i in range(nmembers)
        )

    return bfn, use_kernel


def _build_batched_sparse_plan(
    ring: sr.Semiring,
    rel_attrs: tuple[str, ...],
    doms: dict[str, int],
    in_attrs_list: tuple[tuple[str, ...], ...],
    pred_attrs: tuple[str, ...],
    out_attrs: tuple[str, ...],
    n: int,
    member_dims: tuple[dict[str, int], ...],
) -> _Plan:
    """Compile B structurally-identical absorptions as ONE jitted call.

    ``in_attrs_list``/``out_attrs`` use canonical placeholder names; ``doms``
    maps placeholders to the *padded* (group-max) sizes; ``member_dims[i]``
    maps placeholders to member i's actual sizes.  Padding, stacking and the
    per-member output slicing all live inside the traced function, so the
    host dispatches exactly one executable per batch — the whole point.
    """
    bfn, use_kernel = _batched_sparse_fn(
        ring, rel_attrs, doms, in_attrs_list, pred_attrs, out_attrs, n, member_dims
    )
    return _Plan(fn=jax.jit(bfn), uses_kernel=use_kernel)


# ---------------------------------------------------------------------------
# level-fused plan: EVERY group of a calibration level in one jitted call,
# kernel-eligible groups sharing a single multi-segment Pallas launch
# ---------------------------------------------------------------------------

def _level_plan_parts(ring: sr.Semiring, group_statics: tuple) -> tuple:
    """The raw (un-jitted) level body as ``(lfn, group_kernel,
    fused_messages)`` — split from :func:`_build_level_plan` so the sharded
    variant can wrap ``lfn`` in shard_map before jitting.

    ``group_statics[g]`` is ``(rel_attrs, doms, in_canon, pred_attrs,
    out_canon, n, member_dims)`` exactly as :func:`_build_batched_sparse_plan`
    takes them (canonical placeholders, padded doms).  Per group the rowwise
    stage (gather ⊗ σ) runs as before — vmapped when the group has several
    members — but instead of one ``aggregate_op`` per member, every
    kernel-eligible member across ALL groups contributes its
    ``(seg_idx, value slab, num_segments)`` descriptor to a single
    ``level_aggregate`` launch; groups that fail the kernel gate ⊕-reduce on
    the lax path *inside the same trace*.  Either way the host dispatches one
    executable per level, which is the whole point: offline calibration goes
    from one dispatch per batch group to ≤ tree-depth launches.
    """
    parts = []
    for (rel_attrs, doms, in_canon, pred_attrs, out_canon, n, member_dims) in (
        group_statics
    ):
        fn, rowwise, finalize, meta = _sparse_plan_parts(
            ring, rel_attrs, doms, in_canon, pred_attrs, out_canon, n
        )
        nmembers = len(member_dims)
        stage = (
            _make_batch_stager(ring, set(rel_attrs), doms, in_canon, pred_attrs)
            if nmembers > 1 else None
        )
        bfn = None
        if nmembers > 1 and not meta.use_kernel:
            bfn, _ = _batched_sparse_fn(
                ring, rel_attrs, doms, in_canon, pred_attrs, out_canon, n,
                member_dims,
            )
        parts.append({
            "fn": fn, "rowwise": rowwise, "finalize": finalize, "meta": meta,
            "stage": stage, "bfn": bfn, "doms": doms,
            "member_dims": member_dims, "n": n,
        })
    group_kernel = tuple(p["meta"].use_kernel for p in parts)
    fused_messages = sum(
        len(p["member_dims"]) for p in parts if p["meta"].use_kernel
    )
    op = ring.kernel_segment_op
    interpret = not _on_tpu()
    nleaves = len(ring.trailing)

    def lfn(groups_args):
        fused_items: list = []
        fused_slots: list = []
        treedefs: dict = {}
        results: list = [None] * len(parts)
        for g, (part, args) in enumerate(zip(parts, groups_args)):
            vals_list, in_fields_list, in_idx, pred_masks_list, pred_codes, seg_idx = args
            nmembers = len(part["member_dims"])
            if not part["meta"].use_kernel:
                if nmembers == 1:
                    results[g] = (part["fn"](
                        vals_list[0], in_fields_list[0], in_idx,
                        pred_masks_list[0], pred_codes, seg_idx,
                    ),)
                else:
                    results[g] = part["bfn"](
                        vals_list, in_fields_list, in_idx, pred_masks_list,
                        pred_codes, seg_idx,
                    )
                continue
            if nmembers == 1:
                member_rvs = [part["rowwise"](
                    vals_list[0], in_fields_list[0], in_idx,
                    pred_masks_list[0], pred_codes,
                )]
            else:
                vals, in_fields, pred_masks = part["stage"](
                    vals_list, in_fields_list, pred_masks_list
                )
                rvb = jax.vmap(part["rowwise"], in_axes=(0, 0, None, 0, None))(
                    vals, in_fields, in_idx, pred_masks, pred_codes
                )
                member_rvs = [
                    jax.tree_util.tree_map(lambda l, b=b: l[b], rvb)
                    for b in range(nmembers)
                ]
            n = part["n"]
            for b, rv in enumerate(member_rvs):
                leaves, treedef = jax.tree_util.tree_flatten(rv)
                treedefs[g] = treedef
                slab = jnp.concatenate(
                    [l.reshape((n, -1)) for l in leaves], axis=1
                )
                fused_items.append((seg_idx, slab, part["meta"].total))
                fused_slots.append((g, b))
        if fused_items:
            fused_outs = seg_ops.level_aggregate(
                fused_items, op=op, interpret=interpret
            )
            fused_facts: dict = {}
            for (g, b), agg in zip(fused_slots, fused_outs):
                part = parts[g]
                total = part["meta"].total
                carried_dims = part["meta"].carried_dims
                leaf_parts = (
                    jnp.split(agg, nleaves, axis=1) if nleaves > 1 else [agg]
                )
                red = [p.reshape((total,) + carried_dims) for p in leaf_parts]
                field = jax.tree_util.tree_unflatten(treedefs[g], red)
                fact = part["finalize"](field)
                fact = _slice_member(
                    ring, fact, part["member_dims"][b], part["doms"]
                )
                fused_facts.setdefault(g, []).append(fact)
            for g, facts in fused_facts.items():
                results[g] = tuple(facts)
        return tuple(results)

    return lfn, group_kernel, fused_messages


def _build_level_plan(ring: sr.Semiring, group_statics: tuple) -> _Plan:
    lfn, group_kernel, fused_messages = _level_plan_parts(ring, group_statics)
    return _Plan(
        fn=jax.jit(lfn),
        uses_kernel=any(group_kernel),
        group_kernel=group_kernel,
        fused_messages=fused_messages,
    )


def _build_sharded_level_plan(
    ring: sr.Semiring, group_statics: tuple, mesh, axis: str,
) -> _Plan:
    """One fused level dispatch per mesh — the level stays the unit of
    collective scheduling.

    The whole level body (every group's rowwise stage plus the shared
    multi-segment kernel launch) runs per shard on local row blocks; then
    every member factor of every group is ⊕-all-reduced in one pass, so a
    level costs one shard_map dispatch and one collective round regardless
    of how many messages it carries.
    """
    nshards = int(mesh.shape[axis])
    local_statics = tuple(
        (rel_attrs, doms, in_canon, pred_attrs, out_canon, n // nshards,
         member_dims)
        for (rel_attrs, doms, in_canon, pred_attrs, out_canon, n, member_dims)
        in group_statics
    )
    lfn, group_kernel, fused_messages = _level_plan_parts(ring, local_statics)
    collective = dist.ring_collective(ring)
    assert collective is not None, "caller gates on ring_collective"

    def local(groups_args):
        return dist.allreduce_field(lfn(groups_args), collective, axis)

    per_group = _sparse_shard_specs(axis)
    sm = dist.shard_map_compat(
        local, mesh,
        in_specs=(tuple(per_group for _ in group_statics),),
        out_specs=P(),
    )
    bytes_ = sum(
        _out_factor_bytes(ring, {**doms, **md}, out_canon)
        for (_ra, doms, _ic, _pa, out_canon, _n, member_dims) in group_statics
        for md in member_dims
    )
    return _Plan(
        fn=jax.jit(sm),
        uses_kernel=any(group_kernel),
        group_kernel=group_kernel,
        fused_messages=fused_messages,
        sharded=True,
        allreduce_bytes=bytes_,
    )


# ---------------------------------------------------------------------------
# dense-bag plan: σ selects → contract (Pallas matmul / einsum / generic)
# ---------------------------------------------------------------------------

def _matmul_split(structs, out: tuple[str, ...]):
    """Decompose a 2-factor contraction as (free1, contracted) × (contracted,
    free2) if no shared attr survives to the output (no batch dims)."""
    (a1, d1), (a2, d2) = structs
    doms = {**dict(zip(a1, d1)), **dict(zip(a2, d2))}
    shared = tuple(a for a in a1 if a in set(a2))
    out_set = set(out)
    if not shared or (out_set & set(shared)):
        return None
    free1 = tuple(a for a in a1 if a in out_set)
    free2 = tuple(a for a in a2 if a in out_set)
    cost = int(
        np.prod([doms[a] for a in free1] or [1])
        * np.prod([doms[a] for a in shared])
        * np.prod([doms[a] for a in free2] or [1])
    )
    return shared, free1, free2, doms, cost


def _build_dense_plan(
    ring: sr.Semiring,
    structs: tuple[tuple[tuple[str, ...], tuple[int, ...]], ...],
    pred_spec: tuple[tuple[str, int], ...],
    out_attrs: tuple[str, ...],
) -> _Plan:
    avail = {a for attrs, _ in structs for a in attrs}
    out = tuple(a for a in out_attrs if a in avail)
    split = None
    # tropical MIN/MAX shares the matmul decomposition: its ⊗ is +, so the
    # (free1, shared) × (shared, free2) split maps 1:1 onto tropical_contract
    tropical = ring.kernel_segment_op in ("min", "max")
    if (
        (ring.is_arithmetic or tropical)
        and len(ring.trailing) == 1
        and ring.dtype == jnp.float32
        and len(structs) == 2
    ):
        cand = _matmul_split(structs, out)
        if cand is not None and (_on_tpu() or cand[4] <= _kernel_cost_max()):
            split = cand
    interpret = not _on_tpu()

    def fn(fields, pred_masks):
        factors = [Factor(attrs, f, ring) for (attrs, _), f in zip(structs, fields)]
        for (attr, fidx), mask in zip(pred_spec, pred_masks):
            factors[fidx] = factors[fidx].select(attr, mask)
        if split is not None:
            shared, free1, free2, doms, _ = split
            g1 = factors[0].project_to(free1 + shared)
            g2 = factors[1].project_to(shared + free2)
            f1sz = int(np.prod([doms[a] for a in free1])) if free1 else 1
            f2sz = int(np.prod([doms[a] for a in free2])) if free2 else 1
            csz = int(np.prod([doms[a] for a in shared]))
            if tropical:
                o = tc_ops.contract_op(
                    g1.field.reshape((f1sz, csz)),
                    g2.field.reshape((csz, f2sz)),
                    is_min=ring.kernel_segment_op == "min",
                    interpret=interpret,
                )
            else:
                o = sc_ops.contract_op(
                    g1.field.reshape((f1sz, csz)),
                    g2.field.reshape((csz, f2sz)),
                    None,
                    interpret=interpret,
                )
            field = o.reshape(
                tuple(doms[a] for a in free1) + tuple(doms[a] for a in free2)
            )
            return Factor(free1 + free2, field, ring).project_to(out)
        return contract(factors, out, ring)

    return _Plan(fn=jax.jit(fn), uses_kernel=split is not None)


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------

class PlanCache:
    """Compiled-executable cache for bag contractions (one per engine/ring).

    Holds four LRU-bounded device-resident caches: compiled plans, per-row
    lifts, densified base factors, and predicate domain masks.  All keys are
    content-addressed by (relation, version, …) or predicate digest, so no
    invalidation is ever needed — updates allocate new slots and old versions
    age out.
    """

    def __init__(
        self,
        ring: sr.Semiring,
        plan_capacity: int = 256,
        lift_capacity: int = 128,
        factor_capacity: int = 128,
        mask_capacity: int = 512,
        mesh=None,
        mesh_axis: str = dist.SHARD_AXIS,
    ):
        self.ring = ring
        self._plans = LRU(plan_capacity)
        self._lifts = LRU(lift_capacity)
        self._factors = LRU(factor_capacity)
        self._masks = LRU(mask_capacity)
        self.stats = PlanStats()
        # mesh-sharded execution: with a mesh attached and a ⊕-collective for
        # the ring, sparse/batched/level plans row-shard their bodies under
        # shard_map and ⊕-all-reduce the γ-indexed partials.  Rings without a
        # collective (BOOL: ⊕ = ∨) and relations whose row bucket does not
        # divide the mesh silently keep the unsharded plans — sharding is an
        # execution strategy, never a correctness requirement.
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.shards = int(mesh.shape[mesh_axis]) if mesh is not None else 1
        self._collective = (
            dist.ring_collective(ring) if self.shards > 1 else None
        )

    # -- device-resident input caches ---------------------------------------
    def mask_dev(self, pred: Predicate) -> jax.Array:
        m = self._masks.get(pred.digest)
        if m is None:
            m = jnp.asarray(pred.mask)
            self._masks.put(pred.digest, m)
        return m

    def lift_cached(self, key: tuple, compute: Callable[[], sr.Field]) -> sr.Field:
        v = self._lifts.get(key)
        if v is None:
            v = compute()
            self._lifts.put(key, v)
        return v

    def factor_cached(self, key: tuple, compute: Callable[[], Factor]) -> Factor:
        v = self._factors.get(key)
        if v is None:
            v = compute()
            self._factors.put(key, v)
        return v

    # -- plan execution ------------------------------------------------------
    def _account(self, entry: _Plan, traced: bool, stats) -> None:
        if traced:
            self.stats.plans_built += 1
        else:
            self.stats.plan_hits += 1
        if entry.uses_kernel:
            self.stats.kernel_execs += 1
        else:
            self.stats.fallback_execs += 1
        if stats is not None:
            stats.plan_traces += int(traced)
            stats.plan_hits += int(not traced)
            stats.kernel_execs += int(entry.uses_kernel)

    def _shard_arity(self, rel) -> int:
        """Mesh width this relation's plans shard over (1 = unsharded)."""
        if self._collective is None or rel.row_bucket % self.shards != 0:
            return 1
        return self.shards

    def _account_sharded(self, entry: _Plan, rels) -> None:
        self.stats.shard_execs += 1
        self.stats.allreduce_bytes += entry.allreduce_bytes
        for rel in rels:
            self.stats.shard_imbalance = max(
                self.stats.shard_imbalance,
                dist.shard_imbalance(rel.num_rows, rel.row_bucket, self.shards),
            )

    def sparse_key(
        self, rel, vals: sr.Field, incoming: Sequence[Factor],
        preds: Sequence[Predicate], out_attrs: Sequence[str],
    ) -> tuple:
        return (
            "sparse",
            self.ring.name,
            rel.attrs,
            tuple(rel.domains[a] for a in rel.attrs),
            rel.row_bucket,
            tuple((m.attrs, m.domain_shape) for m in incoming),
            tuple(p.attr for p in preds),
            tuple(out_attrs),
            _field_struct(vals),
        )

    def run_sparse(
        self,
        catalog,
        rel,
        vals: sr.Field,
        incoming: Sequence[Factor],
        preds: Sequence[Predicate],
        out_attrs: tuple[str, ...],
        stats=None,
    ) -> Factor:
        shards = self._shard_arity(rel)
        key = self.sparse_key(rel, vals, incoming, preds, out_attrs)
        if shards > 1:
            key = key + (("shards", shards),)
        entry = self._plans.get(key)
        traced = entry is None
        if traced:
            doms = dict(rel.domains)
            for m in incoming:
                doms.update(m.domains)
            build_args = (
                self.ring, rel.attrs, doms, tuple(m.attrs for m in incoming),
                tuple(p.attr for p in preds), tuple(out_attrs), rel.row_bucket,
            )
            entry = (
                _build_sharded_sparse_plan(*build_args, self.mesh, self.mesh_axis)
                if shards > 1 else _build_sparse_plan(*build_args)
            )
            self._plans.put(key, entry)
        rel_set = set(rel.attrs)
        in_fields, in_idx = [], []
        for m in incoming:
            shared = tuple(a for a in m.attrs if a in rel_set)
            in_fields.append(m.field)
            in_idx.append(catalog.dev_flat_codes(rel, shared)[0] if shared else None)
        pred_masks = tuple(self.mask_dev(p) for p in preds)
        pred_codes = tuple(catalog.dev_flat_codes(rel, (p.attr,))[0] for p in preds)
        local_out = tuple(a for a in out_attrs if a in rel_set)
        seg_idx, _ = catalog.dev_flat_codes(rel, local_out)
        out = entry.fn(
            vals, tuple(in_fields), tuple(in_idx), pred_masks, pred_codes, seg_idx
        )
        self._account(entry, traced, stats)
        if entry.sharded:
            self._account_sharded(entry, (rel,))
        return out

    def run_sparse_batch(
        self,
        catalog,
        items: Sequence[AbsorbItem],
        stats_list: Sequence | None = None,
    ) -> list[Factor]:
        """Execute a group of batch-compatible absorptions as one vmapped call.

        Every item must share the same :func:`absorb_batch_key` (the caller
        groups); members differ only in γ-carried attrs/domains, σ mask
        contents and incoming-factor values.  Returns per-member factors
        bit-compatible with ``run_sparse`` on integer-exact data (padding is
        the ⊕-identity, which ⊗ absorbs and ⊕ ignores).
        """
        return self._run_batch(catalog, items, stats_list, calibration=False)

    def run_message_batch(
        self,
        catalog,
        items: Sequence[AbsorbItem],
        stats_list: Sequence | None = None,
    ) -> list[Factor]:
        """Execute one calibration *level*'s batch-compatible messages as one
        vmapped call.

        A message Y(u→v) is the same bag contraction as an absorption with
        ``out_attrs = separator ∪ γ-carry``, so the whole ⊕-identity padding /
        placeholder-canonicalization machinery of :meth:`run_sparse_batch` is
        reused verbatim — only the accounting differs (``level_batched_*``
        counters instead of ``batched_*``).
        """
        return self._run_batch(catalog, items, stats_list, calibration=True)

    def run_level(
        self,
        catalog,
        item_groups: Sequence[Sequence[AbsorbItem]],
        stats_groups: Sequence[Sequence] | None = None,
    ) -> list[list[Factor]]:
        """Execute ALL of one calibration level's batch groups as ONE call.

        ``item_groups`` are the :func:`absorb_batch_key` groups of a level —
        already independent by construction (PAPER.md §4: same-level messages
        never read each other).  The compiled level plan runs every group's
        rowwise stage, fuses all kernel-eligible segment reductions into a
        single multi-segment Pallas launch (``level_aggregate``) and reduces
        the rest on the lax path inside the same trace, so the host issues
        exactly one dispatch per level instead of one per group.  Returns the
        per-group factor lists in the caller's group and member order.
        """
        specs = [
            self._group_spec(items, stats_groups[i] if stats_groups else None)
            for i, items in enumerate(item_groups)
        ]
        # canonical group order: a level's groups arrive in edge-iteration
        # order, which σ-variants can permute without changing structure —
        # sort by trace key so every permutation re-hits the same plan
        order = sorted(range(len(specs)), key=lambda i: repr(specs[i].key))
        # a level shards only when EVERY group's relation divides the mesh —
        # one collective schedule per level, no mixed dispatch
        shards = self.shards if (
            self._collective is not None
            and all(self._shard_arity(s.items[0].rel) == self.shards
                    for s in specs)
        ) else 1
        key = ("level", self.ring.name, tuple(specs[i].key for i in order))
        if shards > 1:
            key = key + (("shards", shards),)
        entry = self._plans.get(key)
        traced = entry is None
        if traced:
            statics = tuple(
                (
                    specs[i].items[0].rel.attrs, specs[i].doms,
                    specs[i].in_canon, specs[i].pred_attrs, specs[i].out_canon,
                    specs[i].items[0].rel.row_bucket, specs[i].member_dims,
                )
                for i in order
            )
            entry = (
                _build_sharded_level_plan(
                    self.ring, statics, self.mesh, self.mesh_axis
                )
                if shards > 1 else _build_level_plan(self.ring, statics)
            )
            self._plans.put(key, entry)
        outs = entry.fn(
            tuple(self._group_args(catalog, specs[i]) for i in order)
        )
        if entry.uses_kernel:
            self.stats.fused_level_launches += 1
            self.stats.fused_level_messages += entry.fused_messages
        if entry.sharded:
            self._account_sharded(entry, (s.items[0].rel for s in specs))
        results: list[list[Factor] | None] = [None] * len(specs)
        for pos, i in enumerate(order):
            spec = specs[i]
            width = len(spec.items)
            group_uses_kernel = entry.group_kernel[pos]
            if width > 1:
                # a vmapped group inside the fused launch is still a level
                # batch — keep the level_batched_* counters meaningful
                self.stats.level_batched_execs += 1
                self.stats.level_batched_messages += width
                self.stats.level_batch_width = max(
                    self.stats.level_batch_width, width
                )
            group_results = []
            for it, f, stats in zip(
                spec.items, outs[pos], spec.stats or [None] * width
            ):
                # rename canonical placeholders back to the member's attrs
                group_results.append(Factor(it.out_attrs, f.field, self.ring))
                if traced:
                    self.stats.plans_built += 1
                else:
                    self.stats.plan_hits += 1
                if group_uses_kernel:
                    self.stats.kernel_execs += 1
                else:
                    self.stats.fallback_execs += 1
                if stats is not None:
                    stats.plan_traces += int(traced)
                    stats.plan_hits += int(not traced)
                    stats.kernel_execs += int(group_uses_kernel)
                    if width > 1:
                        stats.level_batched_execs += 1
                        stats.level_batch_width = max(
                            stats.level_batch_width, width
                        )
                traced = False  # one trace per level call, not per member
            # undo the member sort: caller expects its own member order
            results[i] = [
                group_results[spec.inverse[o]] for o in range(width)
            ]
        return results  # type: ignore[return-value]

    def _group_spec(
        self,
        items: Sequence[AbsorbItem],
        stats_list: Sequence | None,
    ) -> "_GroupSpec":
        """Canonicalize one batch group: sorted member order, placeholder
        dims padded to the group max, and the version-free trace key shared
        by the batched and level-fused plans."""
        rel = items[0].rel
        canons = [_canon_absorption(it) for it in items]
        in_canon, out_canon, _ = canons[0]
        member_dims = []
        for it, (_, _, ph) in zip(items, canons):
            adoms: dict[str, int] = {}
            for m in it.incoming:
                adoms.update(m.domains)
            member_dims.append({p: adoms[a] for a, p in ph.items()})
        # canonical member order (by γ-dim signature): the trace key bakes in
        # the per-member dims positionally, so without sorting every
        # permutation of the same sibling set (e.g. when prefetch hits carve
        # different subsets out of a fan-out) would retrace + recompile
        order = sorted(
            range(len(items)), key=lambda i: tuple(sorted(member_dims[i].items()))
        )
        items = [items[o] for o in order]
        member_dims = tuple(member_dims[o] for o in order)
        if stats_list is not None:
            stats_list = [stats_list[o] for o in order]
        inverse = {o: i for i, o in enumerate(order)}
        padded = {
            p: max(md[p] for md in member_dims) for p in (member_dims[0] or {})
        }
        doms = dict(rel.domains)
        doms.update(padded)
        pred_attrs = tuple(p.attr for p in items[0].preds)
        # trace key: like the grouping key, but version-free (codes/masks/
        # fields are runtime args — only shapes matter to the trace) and with
        # the row axis bucketed, so streamed ticks re-hit the compiled plan
        # instead of retracing per version bump
        key = (
            "sparse_batch", self.ring.name, rel.attrs,
            tuple(rel.domains[a] for a in rel.attrs), rel.row_bucket,
            in_canon, pred_attrs, out_canon, _field_struct(items[0].vals),
            tuple(tuple(sorted(md.items())) for md in member_dims),
        )
        return _GroupSpec(
            items=items, stats=stats_list, in_canon=in_canon,
            out_canon=out_canon, member_dims=member_dims, doms=doms,
            pred_attrs=pred_attrs, inverse=inverse, key=key,
        )

    def _group_args(self, catalog, spec: "_GroupSpec") -> tuple:
        """Device-resident runtime inputs for one group, in the (vals_list,
        in_fields_list, in_idx, pred_masks_list, pred_codes, seg_idx) layout
        both the batched and the level-fused plan bodies take."""
        items = spec.items
        rel = items[0].rel
        rel_set = set(rel.attrs)
        in_idx = tuple(
            catalog.dev_flat_codes(rel, tuple(a for a in m.attrs if a in rel_set))[0]
            if any(a in rel_set for a in m.attrs) else None
            for m in items[0].incoming
        )
        pred_codes = tuple(
            catalog.dev_flat_codes(rel, (p.attr,))[0] for p in items[0].preds
        )
        local_out = tuple(a for a in items[0].out_attrs if a in rel_set)
        seg_idx, _ = catalog.dev_flat_codes(rel, local_out)
        return (
            tuple(it.vals for it in items),
            tuple(tuple(m.field for m in it.incoming) for it in items),
            in_idx,
            tuple(tuple(self.mask_dev(p) for p in it.preds) for it in items),
            pred_codes,
            seg_idx,
        )

    def _run_batch(
        self,
        catalog,
        items: Sequence[AbsorbItem],
        stats_list: Sequence | None,
        calibration: bool,
    ) -> list[Factor]:
        assert len(items) >= 2, "batch of one: use run_sparse"
        spec = self._group_spec(items, stats_list)
        items, stats_list, inverse = spec.items, spec.stats, spec.inverse
        rel = items[0].rel
        shards = self._shard_arity(rel)
        key = spec.key + (("shards", shards),) if shards > 1 else spec.key
        entry = self._plans.get(key)
        traced = entry is None
        if traced:
            build_args = (
                self.ring, rel.attrs, spec.doms, spec.in_canon, spec.pred_attrs,
                spec.out_canon, rel.row_bucket, spec.member_dims,
            )
            entry = (
                _build_sharded_batched_sparse_plan(
                    *build_args, self.mesh, self.mesh_axis
                )
                if shards > 1 else _build_batched_sparse_plan(*build_args)
            )
            self._plans.put(key, entry)
        outs = entry.fn(*self._group_args(catalog, spec))
        if entry.sharded:
            self._account_sharded(entry, (rel,))
        width = len(items)
        if calibration:
            self.stats.level_batched_execs += 1
            self.stats.level_batched_messages += width
            self.stats.level_batch_width = max(self.stats.level_batch_width, width)
        else:
            self.stats.batched_execs += 1
            self.stats.batched_absorptions += width
            self.stats.batch_width = max(self.stats.batch_width, width)
        results = []
        for it, f, stats in zip(items, outs, stats_list or [None] * width):
            # rename canonical placeholders back to the member's real attrs
            results.append(Factor(it.out_attrs, f.field, self.ring))
            self._account(entry, traced, stats)
            traced = False  # one trace per batched call, not per member
            if stats is not None:
                if calibration:
                    stats.level_batched_execs += 1
                    stats.level_batch_width = max(stats.level_batch_width, width)
                else:
                    stats.batched_absorptions += 1
                    stats.batch_width = max(stats.batch_width, width)
        # undo the canonical sort: caller expects its own member order
        return [results[inverse[o]] for o in range(width)]

    def run_dense(
        self,
        factors: Sequence[Factor],
        preds: Sequence[Predicate],
        out_attrs: tuple[str, ...],
        stats=None,
    ) -> Factor:
        structs = tuple((f.attrs, f.domain_shape) for f in factors)
        avail = {a for f in factors for a in f.attrs}
        pred_spec = []
        for p in preds:
            if p.attr not in avail:  # pragma: no cover — placement guarantees
                raise KeyError(f"σ({p.attr}) not available in bag")
            pred_spec.append(
                (p.attr, next(i for i, f in enumerate(factors) if p.attr in f.attrs))
            )
        pred_spec = tuple(pred_spec)
        key = ("dense", self.ring.name, structs, pred_spec, tuple(out_attrs))
        entry = self._plans.get(key)
        traced = entry is None
        if traced:
            entry = _build_dense_plan(self.ring, structs, pred_spec, tuple(out_attrs))
            self._plans.put(key, entry)
        out = entry.fn(
            tuple(f.field for f in factors), tuple(self.mask_dev(p) for p in preds)
        )
        self._account(entry, traced, stats)
        return out

    def __len__(self):
        return len(self._plans)

    def reset_stats(self):
        self.stats = PlanStats()
