"""SPJA query IR with the paper's annotation vocabulary (§3.3, Table 1).

A :class:`Query` captures::

    SELECT G, AGG(measure) FROM J WHERE [join cond] AND P GROUP BY G

as annotations over a JT:
  γ  — ``group_by`` attrs (prevent marginalization on the path to the root)
  σ  — ``predicates`` (domain masks; Table 1 σ_id)
  R* — ``rel_versions`` (update relation to a specific version)
  R̄  — ``removed`` (exclude relation from its bag)
  Σ  — compensation is *implicit* here: base messages are separator-only, so
       dropping a γ never blocks reuse; cached wider-γ messages are narrowed
       by ⊕-marginalization on lookup (see MessageStore.widen in
       calibration.py) — the exact effect of the paper's Σ annotation.

Queries are immutable and content-hashable so Proposition 2 signatures can be
derived from them.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Mapping, Sequence

from repro.relational.relation import Catalog, Predicate


@dataclasses.dataclass(frozen=True)
class Query:
    ring_name: str = "count"
    measure: tuple[str, str] | None = None            # (relation, column)
    group_by: tuple[str, ...] = ()
    predicates: tuple[Predicate, ...] = ()
    rel_versions: tuple[tuple[str, str], ...] = ()     # resolved (name, version)
    removed: frozenset[str] = frozenset()
    lift_tag: str = ""                                 # cache tag for custom lifts

    # -- constructors --------------------------------------------------------
    @staticmethod
    def make(
        catalog: Catalog,
        ring: str = "count",
        measure: tuple[str, str] | None = None,
        group_by: Sequence[str] = (),
        predicates: Sequence[Predicate] = (),
        versions: Mapping[str, str] | None = None,
        removed: Sequence[str] = (),
        lift_tag: str = "",
    ) -> "Query":
        """Snapshot relation versions so the query is self-contained."""
        versions = dict(versions or {})
        resolved = tuple(
            sorted((n, versions.get(n, catalog.latest_version(n))) for n in catalog.names())
        )
        return Query(
            ring_name=ring,
            measure=measure,
            group_by=tuple(group_by),
            predicates=tuple(sorted(predicates, key=lambda p: p.digest)),
            rel_versions=resolved,
            removed=frozenset(removed),
            lift_tag=lift_tag,
        )

    # -- interaction deltas (§4.1.2) ------------------------------------------
    def with_predicate(self, pred: Predicate) -> "Query":
        kept = tuple(p for p in self.predicates if p.attr != pred.attr)
        return dataclasses.replace(
            self, predicates=tuple(sorted(kept + (pred,), key=lambda p: p.digest))
        )

    def without_predicate(self, attr: str) -> "Query":
        return dataclasses.replace(
            self, predicates=tuple(p for p in self.predicates if p.attr != attr)
        )

    def with_group_by(self, *attrs: str) -> "Query":
        return dataclasses.replace(self, group_by=tuple(dict.fromkeys(attrs)))

    def add_group_by(self, attr: str) -> "Query":
        return self.with_group_by(*(self.group_by + (attr,)))

    def with_version(self, rel: str, version: str) -> "Query":
        vs = tuple(
            (n, version if n == rel else v) for n, v in self.rel_versions
        )
        if rel not in dict(vs):
            vs = tuple(sorted(vs + ((rel, version),)))
        return dataclasses.replace(self, rel_versions=vs)

    def with_removed(self, rel: str) -> "Query":
        return dataclasses.replace(self, removed=self.removed | {rel})

    def with_relation_toggled(self, rel: str) -> "Query":
        """Flip ``rel`` in/out of R̄ (the dashboard ToggleRelation event)."""
        return dataclasses.replace(self, removed=self.removed ^ {rel})

    def with_filters(self, preds: Sequence[Predicate]) -> "Query":
        """Apply several σ at once (one surviving predicate per attr)."""
        q = self
        for p in preds:
            q = q.with_predicate(p)
        return q

    def with_measure(self, rel: str, column: str, ring: str = "sum") -> "Query":
        return dataclasses.replace(self, measure=(rel, column), ring_name=ring)

    # -- accessors ------------------------------------------------------------
    def version_of(self, rel: str) -> str | None:
        return dict(self.rel_versions).get(rel)

    def predicates_on(self, attr: str) -> tuple[Predicate, ...]:
        return tuple(p for p in self.predicates if p.attr == attr)

    @property
    def effective_versions(self) -> tuple[tuple[str, str], ...]:
        """``rel_versions`` restricted to relations the query can see.

        A removed (R̄) relation's version cannot influence the answer, so the
        digest excludes it: version bumps on invisible relations keep the
        digest stable, which is what lets ``Treant.update``/``flush`` keep
        prefetched results and parked calibration progress for queries the
        update cannot reach.
        """
        if not self.removed:
            return self.rel_versions
        return tuple((n, v) for n, v in self.rel_versions if n not in self.removed)

    @functools.cached_property
    def digest(self) -> str:
        # cached: signature derivation hashes this on every edge of every
        # message-passing step (the instance is frozen, so it never changes)
        h = hashlib.sha1()
        h.update(repr((
            self.ring_name, self.measure, self.group_by,
            tuple(p.digest for p in self.predicates),
            self.effective_versions, tuple(sorted(self.removed)), self.lift_tag,
        )).encode())
        return h.hexdigest()[:16]

    @functools.cached_property
    def sig_key(self) -> str:
        """γ-independent digest (``digest`` minus ``group_by``).

        Proposition-2 signatures hash the annotated subtree and the
        separator — never γ (the group-by only selects which carry a message
        keeps).  Signature memos key on this so sibling crossfilter vizzes
        (same σ, different γ) and Drill/Rollup variants share one signature
        derivation instead of recomputing identical hashes per viz.
        """
        h = hashlib.sha1()
        h.update(repr((
            self.ring_name, self.measure,
            tuple(p.digest for p in self.predicates),
            self.effective_versions, tuple(sorted(self.removed)), self.lift_tag,
        )).encode())
        return h.hexdigest()[:16]

    def annotation_summary(self) -> str:  # pragma: no cover — debugging aid
        parts = [f"γ={list(self.group_by)}"]
        parts += [f"σ({p.label or p.attr})" for p in self.predicates]
        parts += [f"R̄({r})" for r in sorted(self.removed)]
        return " ".join(parts)
