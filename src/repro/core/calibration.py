"""CJT message passing, calibration, and signature-keyed message reuse.

This is the algorithmic core of the paper (§3):

- ``CJTEngine.message`` computes Y(u→v) recursively: the ⊗-product of the
  bag's (annotated) relations with all incoming messages except from v,
  ⊕-marginalized to ``separator(u,v) ∪ (γ ∩ subtree_attrs(u))`` — upward
  message passing with group-by carry (§3.3.1).
- Every message is keyed by its **Proposition 2 signature**: a structural
  hash of the annotated subtree behind the edge.  The :class:`MessageStore`
  is therefore simultaneously (a) the CJT materialization Y, (b) the
  cross-query/cross-session message cache of §4.2.2, and (c) the partial
  calibration state — a cache hit *is* message reuse, and the set of misses
  *is* the Steiner tree of §3.4.2.
- ``calibrate`` = upward + downward passes (Algorithm 1); the iterator form
  is preemptible for think-time calibration (§4.2.1).
- Σ compensation (§3.4.2) appears as ``MessageStore`` widening: a cached
  message carrying extra γ attrs is narrowed by ⊕-marginalization instead of
  recomputed.

Bags holding a single sparse relation use the factorized sparse path
(gather incoming messages at row codes, ⊗ rowwise, segment-⊕ — the DBMS
hash-join/aggregate re-expressed for the TPU, see kernels/segment_aggregate);
empty bags and densified dimension bags use dense factor contraction.

**Delta calibration** (data updates): a base-relation update only changes the
messages directed *away* from the updated bag u₀ — n−1 of the 2(n−1)
messages; everything else keeps its Prop-2 signature and is reused verbatim.
Because bag contraction distributes ⊕ over ⊗, each changed message satisfies
Y_new(u→v) = Y_old(u→v) ⊕ ΔY(u→v) where ΔY is the same contraction with the
changed input (the relation at u₀, or the incoming delta further out)
replaced by its delta — ``CJTEngine.delta_message``.
``CJTEngine.apply_delta`` walks the u₀-outward edges, combines each cached
message with its delta via ``MessageStore.apply_delta``, and stores the
result under the *new-version* signature.  Versions are part of every bag
digest, so stale entries can never be served: an unmaintained edge simply
misses and recomputes.  Deletions ride on ⊕-inverse row annotations and are
therefore gated on ``Semiring.has_add_inverse`` (MIN/MAX/BOOL fall back to
recomputation; the caller sees ``DeltaStats.fallback``).

**Compiled message plans** (core.plans): every bag contraction is traced and
jitted once per *structural* signature (relation shape/attr order, incoming
factor shapes, ring, out attrs, predicate arity) and then re-executed across
queries, interactions, versions and delta passes — a Prop-2 signature change
that keeps the structure (new version, new σ mask, delta maintenance) hits
the same compiled plan.  Flat row codes, per-row lifts and densified base
factors are device-resident caches, so the message loop does no host work
and the upward/downward passes dispatch asynchronously; ``execute`` blocks
only at absorption.  Inside a plan, f32 scalar rings (SUM/COUNT) ⊕-reduce
through the ``segment_aggregate`` Pallas kernel and tropical MIN/MAX through
its min/max ops; the 2-factor dense hot path lowers to ``semiring_contract``.
Compound rings (MOMENTS, covariance, BOOL, int64 COUNT) take the lax
fallback.  ``use_plans=False`` keeps the legacy un-jitted reference path;
plan hit/trace/kernel counters surface in ``ExecStats`` and
``Treant.cache_stats``.
"""

from __future__ import annotations

import bisect
import contextlib
import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.relation import LRU, Catalog, Delta, Predicate, Relation, lift_rows
from . import distributed as dist
from . import semiring as sr
from .factor import Factor, contract, ones_factor
from .hypertree import JTree
from .plans import (
    AbsorbItem,
    PlanCache,
    absorb_batch_key,
    batch_calibration_default,
    calibration_union_budget,
    expand_rows_field,
    fuse_level_default,
    sparse_batch_elems,
)
from .query import Query


def _h(*parts: str) -> str:
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:20]


def factor_nbytes(f: Factor) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree_util.tree_leaves(f.field))


# ---------------------------------------------------------------------------
# Message store — the materialized Y + the paper's message-level cache
# ---------------------------------------------------------------------------

class MessageStore:
    """LRU message cache keyed by Prop-2 signatures, with pinning (§4.2.2)."""

    def __init__(self, max_bytes: int | None = None):
        self.max_bytes = max_bytes
        self._data: OrderedDict[str, Factor] = OrderedDict()
        # sig -> pin refcount: several vizzes/sessions pin the same shared
        # message, and one session's close (unpin) must not strip a sibling
        # session's eviction exemption.  Keys behave like the old set.
        self._pinned: dict[str, int] = {}
        # cross-viz sharing accounting: while ``tag`` is set (the dashboard
        # layer sets it to the executing viz name), puts record the producer
        # and hits on another producer's message count as cross-tag hits
        self.tag: str | None = None
        self._producer: dict[str, str] = {}
        self.cross_tag_hits = 0
        # sig -> consumer session ids that have HIT the entry while tagged:
        # close() must not drop an entry a sibling live session still reads
        # (the server refcounts producer-tagged entries through this)
        self._users: dict[str, set[str]] = {}
        # per-entry byte sizes (overwrite-safe nbytes accounting) and
        # recompute-cost hints (``CJTEngine`` passes its ``estimate_edge_cost``
        # miss cost at put time) driving priority eviction
        self._sizes: dict[str, int] = {}
        self._cost: dict[str, float] = {}
        # in-flight protection: while an engine dispatch is open, every sig
        # it touches (get-hit or put) is exempt from eviction — a byte budget
        # must never pull a message out from under the dispatch using it
        self._inflight_depth = 0
        self._inflight: set[str] = set()
        self.evictions = 0
        # (edge, base_sig) -> {γ tuple -> full sig}: Σ-compensation index
        self._widen: dict[str, dict[tuple[str, ...], str]] = {}
        # derived probe index: per base_sig, entries sorted by |γ| (smallest
        # superset narrows cheapest) and a refcount over all widened γ attrs
        # (a probe γ ⊄ supp(refcount) can never match — skip the scan
        # entirely; refcounts make eviction-time removal O(|γ|))
        self._widen_bysize: dict[str, list[tuple[int, tuple[str, ...], str]]] = {}
        self._widen_attrs: dict[str, dict[str, int]] = {}
        # reverse map sig -> (base_sig, γ) so eviction can drop the widen
        # entries too — otherwise the index grows monotonically across
        # version bumps (dead sigs inflating every probe scan)
        self._sig_index: dict[str, tuple[str, tuple[str, ...]]] = {}
        self.hits = 0
        self.misses = 0
        self.widen_hits = 0
        self.widen_scans = 0
        self.widen_scan_steps = 0
        self.nbytes = 0

    @staticmethod
    def full_sig(base_sig: str, gamma: tuple[str, ...]) -> str:
        return f"{base_sig}|g={','.join(gamma)}"

    @contextlib.contextmanager
    def inflight(self):
        """Mark every sig touched inside the block as eviction-exempt.

        Re-entrant (engine entry points nest: execute → message → widen-put);
        the exemption set clears when the outermost dispatch closes."""
        self._inflight_depth += 1
        try:
            yield
        finally:
            self._inflight_depth -= 1
            if self._inflight_depth == 0:
                self._inflight.clear()
                # a dispatch may legitimately overshoot the budget (its own
                # working set is exempt); trim back down now that it closed
                self._evict()

    def _touch(self, sig: str) -> None:
        if self._inflight_depth > 0:
            self._inflight.add(sig)

    def get(self, base_sig: str, gamma: tuple[str, ...]) -> Factor | None:
        sig = self.full_sig(base_sig, gamma)
        f = self._data.get(sig)
        if f is not None:
            self._data.move_to_end(sig)
            self.hits += 1
            self._note_cross_hit(sig)
            self._touch(sig)
            return f
        # Σ compensation: narrow a cached wider-γ message by marginalization.
        # Indexed by |γ|: strict supersets are larger, so the scan starts past
        # size |γ| and visits candidates smallest-first.
        gset = set(gamma)
        attrs = self._widen_attrs.get(base_sig)
        if attrs is not None and all(a in attrs for a in gset):
            bysize = self._widen_bysize.get(base_sig, [])
            self.widen_scans += 1
            start = bisect.bisect_left(bysize, (len(gamma),))
            for _, g2, sig2 in bysize[start:]:
                self.widen_scan_steps += 1
                if gset <= set(g2) and sig2 in self._data:
                    wide = self._data[sig2]
                    narrowed = wide.marginalize(set(g2) - gset)
                    self._note_cross_hit(sig2)
                    self._touch(sig2)
                    self.put(base_sig, gamma, narrowed, cost=self._cost.get(sig2))
                    self.widen_hits += 1
                    return narrowed
        self.misses += 1
        return None

    def _note_cross_hit(self, sig: str) -> None:
        owner = self._producer.get(sig)
        if self.tag is not None and owner is not None and owner != self.tag:
            self.cross_tag_hits += 1
        # consumer refcount: remember which session read this entry (tags are
        # "{session}:{viz}"), so drop_producer can keep shared entries alive
        if self.tag is not None and owner is not None:
            sid = self.tag.split(":", 1)[0]
            if not owner.startswith(f"{sid}:"):
                self._users.setdefault(sig, set()).add(sid)

    def contains(self, base_sig: str, gamma: tuple[str, ...]) -> bool:
        if self.full_sig(base_sig, gamma) in self._data:
            return True
        attrs = self._widen_attrs.get(base_sig)
        if attrs is None or not all(a in attrs for a in gamma):
            return False
        return any(set(gamma) <= set(g2) for g2 in self._widen.get(base_sig, {}))

    def put(self, base_sig: str, gamma: tuple[str, ...], f: Factor,
            pin: bool = False, cost: float | None = None):
        sig = self.full_sig(base_sig, gamma)
        nb = factor_nbytes(f)
        self.nbytes += nb - self._sizes.get(sig, 0)
        self._sizes[sig] = nb
        if cost is not None:
            self._cost[sig] = cost
        self._touch(sig)
        if self.tag is not None:
            self._producer.setdefault(sig, self.tag)
        self._data[sig] = f
        self._data.move_to_end(sig)
        per_base = self._widen.setdefault(base_sig, {})
        if gamma not in per_base:  # full_sig is deterministic: insert once
            bisect.insort(
                self._widen_bysize.setdefault(base_sig, []), (len(gamma), gamma, sig)
            )
            counts = self._widen_attrs.setdefault(base_sig, {})
            for a in gamma:
                counts[a] = counts.get(a, 0) + 1
            self._sig_index[sig] = (base_sig, gamma)
        per_base[gamma] = sig
        if pin:
            self._pinned[sig] = self._pinned.get(sig, 0) + 1
        self._evict()

    def _drop_widen(self, sig: str) -> None:
        """Remove an evicted message's Σ-widening index entries."""
        hit = self._sig_index.pop(sig, None)
        if hit is None:
            return
        base_sig, gamma = hit
        per_base = self._widen.get(base_sig)
        if per_base is None:
            return
        per_base.pop(gamma, None)
        bysize = self._widen_bysize.get(base_sig, [])
        i = bisect.bisect_left(bysize, (len(gamma), gamma, sig))
        if i < len(bysize) and bysize[i] == (len(gamma), gamma, sig):
            bysize.pop(i)
        counts = self._widen_attrs.get(base_sig, {})
        for a in gamma:
            c = counts.get(a, 0) - 1
            if c > 0:
                counts[a] = c
            else:
                counts.pop(a, None)
        if not per_base:
            self._widen.pop(base_sig, None)
            self._widen_bysize.pop(base_sig, None)
            self._widen_attrs.pop(base_sig, None)

    def pin(self, base_sig: str, gamma: tuple[str, ...]):
        sig = self.full_sig(base_sig, gamma)
        self._pinned[sig] = self._pinned.get(sig, 0) + 1

    def is_pinned(self, base_sig: str, gamma: tuple[str, ...]) -> bool:
        """Pinned exactly, or through a pinned wider-γ variant (Σ-widening)."""
        if self.full_sig(base_sig, gamma) in self._pinned:
            return True
        return any(
            set(gamma) <= set(g2) and sig in self._pinned
            for g2, sig in self._widen.get(base_sig, {}).items()
        )

    def unpin(self, base_sig: str, gamma: tuple[str, ...]):
        """Drop one pin reference; the sig stays pinned while other holders
        remain (refcounted — floors at zero)."""
        sig = self.full_sig(base_sig, gamma)
        c = self._pinned.get(sig, 0) - 1
        if c > 0:
            self._pinned[sig] = c
        else:
            self._pinned.pop(sig, None)

    def apply_delta(
        self,
        old_base: str,
        new_base: str,
        gamma: tuple[str, ...],
        delta: Factor | None,
    ) -> Factor | None:
        """Maintain one message across a data update: new = old ⊕ Δ.

        Looks up the cached message under the *old* signature (Σ-widening
        applies), combines it with the delta factor, and stores the result
        under the bumped *new* signature.  ``delta=None`` means the update is
        value-preserving (a compaction: the ⊕-difference is zero) — the old
        message is re-keyed to the new signature verbatim, no arithmetic.
        A pin migrates to the new generation: the old-version message stays
        servable for queries still snapshotting the old version, but becomes
        evictable — otherwise every update would grow an unevictable pinned
        generation.
        Returns None (and stores nothing) when there is no cached message to
        maintain; the new-version message will then be computed on demand.
        """
        old = self.get(old_base, gamma)
        if old is None:
            self.misses -= 1  # probe, not a serving miss
            return None
        new = old if delta is None else old.add(delta)
        # migrate the whole DIRECT pin refcount (several sessions may hold
        # it).  A message pinned only through a wider-γ variant migrates
        # when that wider query is itself maintained — minting a fresh
        # direct pin here would orphan it (no holder ever unpins a sig it
        # never pinned; with union-carry calibration every maintained
        # narrow query would leak one pin per update).  Pin BEFORE put so a
        # byte-bounded store cannot evict the new entry inside put()'s
        # eviction sweep (same pin-first discipline as calibrate_iter).
        moved = self._pinned.pop(self.full_sig(old_base, gamma), 0)
        if moved:
            new_sig = self.full_sig(new_base, gamma)
            self._pinned[new_sig] = self._pinned.get(new_sig, 0) + moved
        self.put(new_base, gamma, new,
                 cost=self._cost.get(self.full_sig(old_base, gamma)))
        return new

    @property
    def pinned_nbytes(self) -> int:
        """Bytes held by pinned entries — the floor no budget can go below."""
        return sum(self._sizes.get(s, 0) for s in self._pinned)

    def unpin_all(self):
        self._pinned.clear()

    def _remove(self, sig: str) -> bool:
        """Drop one entry and all its bookkeeping; False when absent."""
        f = self._data.pop(sig, None)
        if f is None:
            return False
        self.nbytes -= self._sizes.pop(sig, factor_nbytes(f))
        self._producer.pop(sig, None)
        self._cost.pop(sig, None)
        self._users.pop(sig, None)
        self._drop_widen(sig)
        return True

    def drop_producer(self, prefix: str) -> int:
        """Session GC: drop unpinned entries whose producer tag starts with
        ``prefix`` (tags are ``"{session}:{viz}"``, so a session passes
        ``f"{sid}:"``).  Entries another consumer still pins survive; untagged
        entries (offline base calibration) are shared and never dropped here.
        An entry another LIVE session has read (consumer refcount, recorded
        on tagged cross-producer hits) is not dropped either — ownership is
        reassigned to a surviving reader so a later close can still GC it.
        Purely an eviction policy — the store is a cache, so correctness is
        unaffected and a later query simply recomputes."""
        sid = prefix.split(":", 1)[0]
        # this session stops being a consumer of anything it read
        for users in self._users.values():
            users.discard(sid)
        sigs = [s for s, owner in self._producer.items() if owner.startswith(prefix)]
        n = 0
        for sig in sigs:
            survivors = self._users.get(sig)
            if survivors:
                # a sibling live session still references this entry: hand
                # ownership to the (deterministically) first surviving reader
                heir = sorted(survivors)[0]
                survivors.discard(heir)
                self._producer[sig] = f"{heir}:*"
                if not survivors:
                    self._users.pop(sig, None)
                continue
            if sig in self._pinned:
                continue
            if self._remove(sig):
                n += 1
        return n

    def _evict(self):
        """Byte-budget eviction: pin-state → recency → recompute cost.

        Pinned and in-flight entries are exempt outright.  Among the rest,
        candidates are taken from the cold (LRU) end in windows: evicting the
        cheapest-to-recompute entry of the oldest window realizes the
        recency-then-cost ordering without a full-store scan per eviction.
        If every entry is exempt the store stays over budget — correctness
        beats the budget."""
        if self.max_bytes is None or self.nbytes <= self.max_bytes:
            return
        WINDOW = 8
        while self.nbytes > self.max_bytes:
            window: list[tuple[float, int, str]] = []
            for order, sig in enumerate(self._data):
                if sig in self._pinned or sig in self._inflight:
                    continue
                window.append((self._cost.get(sig, 0.0), order, sig))
                if len(window) >= WINDOW:
                    break
            if not window:
                return  # everything left is pinned or in-flight
            _, _, victim = min(window)
            self._remove(victim)
            self.evictions += 1

    def __len__(self):
        return len(self._data)

    def block_until_ready(self) -> None:
        """Barrier on every cached factor: message passing dispatches
        asynchronously, so think-time calibration can leave device work in
        flight — benchmarks drain it here before starting a timer."""
        jax.block_until_ready([f.field for f in self._data.values()])

    def reset_stats(self):
        self.hits = self.misses = self.widen_hits = 0
        self.widen_scans = self.widen_scan_steps = 0

    def snapshot(self):
        """Cheap state snapshot (factors are immutable) — used by benchmarks
        to warm XLA's jit cache without polluting the message cache."""
        return (
            OrderedDict(self._data),
            {k: dict(v) for k, v in self._widen.items()},
            dict(self._pinned), self.nbytes,
            (self.hits, self.misses, self.widen_hits),
            (dict(self._producer), self.cross_tag_hits),
            (dict(self._sizes), dict(self._cost),
             {k: set(v) for k, v in self._users.items()}, self.evictions),
        )

    def restore(self, snap):
        self._data, self._widen, self._pinned, self.nbytes, stats = (
            OrderedDict(snap[0]), {k: dict(v) for k, v in snap[1].items()},
            dict(snap[2]), snap[3], snap[4],
        )
        self.hits, self.misses, self.widen_hits = stats
        self._producer, self.cross_tag_hits = dict(snap[5][0]), snap[5][1]
        self._sizes = dict(snap[6][0])
        self._cost = dict(snap[6][1])
        self._users = {k: set(v) for k, v in snap[6][2].items()}
        self.evictions = snap[6][3]
        self._widen_bysize = {
            b: sorted((len(g), g, s) for g, s in d.items())
            for b, d in self._widen.items()
        }
        self._widen_attrs = {}
        for b, d in self._widen.items():
            counts = self._widen_attrs.setdefault(b, {})
            for g in d:
                for a in g:
                    counts[a] = counts.get(a, 0) + 1
        self._sig_index = {
            s: (b, g) for b, d in self._widen.items() for g, s in d.items()
        }


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

LiftFn = Callable[[Relation], sr.Field]


@dataclasses.dataclass
class ExecStats:
    messages_computed: int = 0
    messages_reused: int = 0
    rows_scanned: int = 0
    recomputed_edges: list = dataclasses.field(default_factory=list)
    # compiled message plans (core.plans): structural traces vs warm re-runs,
    # and how many executions took a Pallas kernel path
    plan_traces: int = 0
    plan_hits: int = 0
    kernel_execs: int = 0
    # batched absorption (execute_many): 1 when this query's absorption rode
    # a vmapped sibling batch; batch_width is that batch's total width, and
    # batch_sessions counts the distinct sessions that batch served (>1 only
    # under the server's cross-session fan-out)
    batched_absorptions: int = 0
    batch_width: int = 0
    batch_sessions: int = 0
    # result served from the session's speculative-prefetch cache: nothing
    # executed at all (no store probes, no plan dispatch)
    prefetch_hits: int = 0
    # result sliced out of a parked γ∪{dim} bin cube (select + ⊕-marginalize
    # over the brush dimension): no store probes, no plan dispatch either,
    # but unlike a prefetch hit the cube survives to serve the NEXT σ too
    bin_cube_hits: int = 0
    # realized Steiner tree (§3.4.2): bags touched by recomputed messages
    # plus the absorption root — 1 when everything was served from cache
    steiner_size: int = 0
    # level-batched calibration: vmapped level-batch calls this query's
    # calibration rode (and the widest), plus how many message dispatches the
    # pass issued in total — per-edge: one per computed message; batched: one
    # per level group (a batch dispatch is attributed to its first member)
    level_batched_execs: int = 0
    level_batch_width: int = 0
    calibration_dispatches: int = 0


@dataclasses.dataclass
class DeltaStats:
    """Outcome of one ``CJTEngine.apply_delta`` maintenance pass."""

    delta_rows: int = 0          # |Δ| — rows in the signed delta
    delta_messages: int = 0      # ΔY factors computed (≤ n−1 vs 2(n−1) full)
    edges_maintained: int = 0    # cached messages updated as old ⊕ Δ
    edges_skipped: int = 0       # outward edges with nothing cached to maintain
    fallback: bool = False       # ring cannot absorb the delta (e.g. MIN delete)


@dataclasses.dataclass
class CalibrationPlan:
    """Parked position of one query's level-synchronous calibration pass.

    ``levels`` is the JT's level schedule for ``root`` (upward then downward;
    see ``JTree.calibration_levels``); ``pos``/``offset`` track progress at
    level / intra-level granularity, so the pass can be resumed by either the
    batched level executor or the per-edge budget stepper — both leave every
    already-materialized message servable (§4.2.1 preemptibility).
    """

    query: Query
    placement: Mapping[str, tuple[Predicate, ...]]
    root: str
    levels: tuple[tuple[tuple[str, str], ...], ...]
    pin: bool = False
    pos: int = 0      # completed levels
    offset: int = 0   # edges completed inside levels[pos]

    @property
    def done(self) -> bool:
        return self.pos >= len(self.levels)

    def edges_left(self) -> int:
        if self.done:
            return 0
        return sum(len(lv) for lv in self.levels[self.pos:]) - self.offset


class CJTEngine:
    """Query execution and calibration over one JT (one dashboard join graph)."""

    def __init__(
        self,
        jt: JTree,
        catalog: Catalog,
        ring: sr.Semiring,
        lifts: Mapping[str, LiftFn] | None = None,
        store: MessageStore | None = None,
        dense_rows_threshold: int = 0,
        use_plans: bool = True,
        plan_cache: PlanCache | None = None,
        batch_calibration: bool | None = None,
        fuse_level_kernel: bool | None = None,
        mesh=None,
    ):
        self.jt = jt
        self.catalog = catalog
        self.ring = ring
        self.lifts = dict(lifts or {})
        self.store = store if store is not None else MessageStore()
        # relations with ≤ threshold rows are densified (dense contraction
        # path); bigger ones use the sparse segment path
        self.dense_rows_threshold = dense_rows_threshold
        # row-sharded execution over a device mesh: plans shard their bodies
        # with shard_map and ⊕-all-reduce γ-indexed partials; a shared
        # plan_cache keeps its own mesh (caller wires consistency)
        self.mesh = mesh
        # compiled message plans: jitted bag contractions keyed structurally
        # (use_plans=False keeps the legacy un-jitted reference path)
        if plan_cache is not None and plan_cache.ring.name != ring.name:
            raise ValueError(
                f"plan_cache ring {plan_cache.ring.name!r} != engine ring {ring.name!r}"
            )
        self.plans = (plan_cache or PlanCache(ring, mesh=mesh)) if use_plans else None
        # level-batched calibration passes (None → REPRO_BATCH_CALIBRATION);
        # without compiled plans the flag is inert and calibration degrades to
        # the per-edge loop
        if batch_calibration is None:
            batch_calibration = batch_calibration_default()
        self.batch_calibration = batch_calibration
        # level-fused kernel launches (None → REPRO_FUSE_LEVEL_KERNEL): route
        # ALL of a calibration level's batch groups through ONE jitted
        # PlanCache.run_level call whose kernel-eligible messages share a
        # single multi-segment Pallas launch; inert without plans + batching
        if fuse_level_kernel is None:
            fuse_level_kernel = fuse_level_default()
        self.fuse_level_kernel = fuse_level_kernel
        # Prop-2 signature memo, LRU-bounded: keyed by (query digest, edge),
        # so a long-lived session's interaction stream cannot leak memory
        self._sig_memo: LRU = LRU(capacity=8192)
        # σ-placement memo: placement is a pure function of (σ digests, R̄,
        # versions) — a crossfilter fan-out derives N sibling queries with
        # identical annotations, so they share one placement computation
        self._placement_memo: LRU = LRU(capacity=1024)

    # -- annotation placement (§3.3, §3.4.2 shrinking) ------------------------
    def place_predicates(self, q: Query) -> dict[str, tuple[Predicate, ...]]:
        """Deterministically place each σ on the cheapest bag containing its attr.

        Cheapest = fewest underlying rows; this realizes the paper's shrinking
        heuristic (annotations migrate off large fact bags onto dimension
        bags) while keeping placement a pure function of the query, which the
        Prop-2 signatures require.
        """
        key = (
            tuple(p.digest for p in q.predicates), q.removed, q.rel_versions,
        )
        hit = self._placement_memo.get(key)
        if hit is not None:
            return hit
        placed: dict[str, list[Predicate]] = {}
        for p in q.predicates:
            cands = self.jt.bags_with_attr(p.attr)
            if not cands:
                raise KeyError(f"predicate attr {p.attr} not in any bag")
            cands = sorted(cands, key=lambda b: (self._bag_rows(q, b), b))
            placed.setdefault(cands[0], []).append(p)
        out = {b: tuple(sorted(ps, key=lambda p: p.digest)) for b, ps in placed.items()}
        self._placement_memo.put(key, out)
        return out

    def _bag_rows(self, q: Query, bag: str) -> int:
        rels = [r for r in self.jt.relations_of(bag) if r not in q.removed]
        if not rels:
            return 1
        return sum(self.catalog.get(r, q.version_of(r)).num_rows for r in rels)

    # -- Proposition 2 signatures ---------------------------------------------
    def bag_state_digest(self, q: Query, bag: str, placement) -> str:
        rels = [r for r in self.jt.relations_of(bag) if r not in q.removed]
        rel_part = ";".join(f"{r}@{q.version_of(r)}" for r in sorted(rels))
        pred_part = ";".join(p.digest for p in placement.get(bag, ()))
        meas = ""
        if q.measure and q.measure[0] in rels:
            meas = f"{q.measure[0]}.{q.measure[1]}"
        return _h("bag", bag, rel_part, pred_part, meas, q.ring_name, q.lift_tag)

    def subtree_sig(self, q: Query, u: str, v: str, placement) -> str:
        """Structural hash of the annotated subtree rooted at u, cut at (u,v).

        Memo-keyed by the γ-independent ``Query.sig_key``: sibling vizzes of
        one crossfilter fan-out (same σ, different γ) resolve to the same
        subtree signatures, so the whole fan-out derives them once.
        """
        key = (q.sig_key, u, v)
        hit = self._sig_memo.get(key)
        if hit is not None:
            return hit
        child_sigs = sorted(
            self.subtree_sig(q, i, u, placement) for i in self.jt.neighbors(u) if i != v
        )
        sig = _h("sub", self.bag_state_digest(q, u, placement), *child_sigs,
                 ",".join(self.jt.separator(u, v)) if v else "")
        self._sig_memo[key] = sig
        return sig

    def gamma_carry(self, q: Query, u: str, v: str) -> tuple[str, ...]:
        """γ attrs that must survive the u→v message beyond the separator.

        Separator attrs are kept by every message regardless of γ, so they
        are excluded from the carry — a query grouping by separator attrs
        then reuses base-calibration messages verbatim (this is what makes
        the Fig 5b empty-bag view free to query).  Memoized alongside the
        signature memo: root choice evaluates it once per (root, edge) pair.
        """
        key = (q.group_by, "γ", u, v)
        hit = self._sig_memo.get(key)
        if hit is not None:
            return hit
        sub = self.jt.subtree_attrs(u, v)
        sep = set(self.jt.separator(u, v))
        out = tuple(sorted((set(q.group_by) & sub) - sep))
        self._sig_memo[key] = out
        return out

    def edge_sig(self, q: Query, u: str, v: str, placement) -> str:
        """Message identity (Prop. 2): depends on u's annotated subtree and the
        separator, NOT on v's identity — so an augmentation bag (§4.3) attached
        anywhere with the same join key reuses the host's outgoing message."""
        sep = ",".join(self.jt.separator(u, v))
        return _h("edge", u, sep, self.subtree_sig(q, u, v, placement))

    # -- message passing (§3.3.1) ---------------------------------------------
    def message(self, q: Query, u: str, v: str, placement=None, stats: ExecStats | None = None) -> Factor:
        placement = self.place_predicates(q) if placement is None else placement
        base = self.edge_sig(q, u, v, placement)
        gamma = self.gamma_carry(q, u, v)
        cached = self.store.get(base, gamma)
        if cached is not None:
            if stats:
                stats.messages_reused += 1
            return cached
        incoming = [
            self.message(q, i, u, placement, stats) for i in self.jt.neighbors(u) if i != v
        ]
        sep = self.jt.separator(u, v)
        out_attrs = tuple(dict.fromkeys(sep + gamma))
        f = self._bag_contract(q, u, incoming, out_attrs, placement, stats)
        self.store.put(base, gamma, f, cost=self._edge_cost_hint(q, u, out_attrs))
        if stats:
            stats.messages_computed += 1
            stats.recomputed_edges.append((u, v))
        return f

    def _edge_cost_hint(self, q: Query, u: str, out_attrs: tuple[str, ...]) -> float:
        """Recompute-cost hint for a freshly materialized message (same model
        as ``estimate_edge_cost``'s miss cost: source rows + output size) —
        drives the store's priority eviction under a byte budget."""
        out_size = 1.0
        for a in out_attrs:
            out_size *= self.jt.domains.get(a, 1)
        return self._bag_rows(q, u) + out_size

    def absorb(self, q: Query, root: str, placement=None, stats=None, keep=None) -> Factor:
        """Absorption at root (§3.3.1) then projection to γ (or ``keep``)."""
        placement = self.place_predicates(q) if placement is None else placement
        incoming = [self.message(q, i, root, placement, stats) for i in self.jt.neighbors(root)]
        keep = tuple(keep) if keep is not None else q.group_by
        avail = set(self.jt.subtree_attrs(root, None))
        out_attrs = tuple(a for a in dict.fromkeys(keep) if a in avail)
        return self._bag_contract(q, root, incoming, out_attrs, placement, stats)

    # -- bag-local contraction -------------------------------------------------
    def _bag_contract(
        self, q: Query, bag: str, incoming: list[Factor], out_attrs: tuple[str, ...],
        placement, stats=None,
    ) -> Factor:
        rel_names = [r for r in self.jt.relations_of(bag) if r not in q.removed]
        preds = placement.get(bag, ())
        rels = [self.catalog.get(r, q.version_of(r)) for r in rel_names]
        if stats:
            stats.rows_scanned += sum(r.num_rows for r in rels)
        sparse_rels = [r for r in rels if r.num_rows > self.dense_rows_threshold]
        if len(sparse_rels) == 1 and len(rels) == 1:
            return self._sparse_bag(q, rels[0], incoming, preds, out_attrs, stats)
        return self._dense_bag(q, rels, incoming, preds, out_attrs, stats)

    def _lift_id(self, rel_name: str):
        """Cache-key component identifying which lift produces a relation's
        rows: None for the default lift, the custom fn object itself
        otherwise (a shared PlanCache must not serve engine A's lift to
        engine B; keying by the object keeps it alive, so no id reuse)."""
        return self.lifts.get(rel_name)

    def _lift(self, q: Query, rel: Relation) -> sr.Field:
        if self.plans is not None:
            measure = q.measure[1] if q.measure and q.measure[0] == rel.name else None
            key = (rel.key, self.ring.name, measure, q.lift_tag, self._lift_id(rel.name))
            return self.plans.lift_cached(
                key, lambda: self._pad_lift(self._lift_impl(q, rel), rel)
            )
        return self._lift_impl(q, rel)

    def _pad_lift(self, vals: sr.Field, rel: Relation) -> sr.Field:
        """Pad per-row lift values to ``rel.row_bucket`` with the ⊕-identity.

        Compiled plans trace against the bucketed row count (shape-stable
        across streamed ticks); identity rows are ⊗-absorbing and aggregate
        into segment 0 as ⊕-no-ops, so padding is exact for every ring.
        Only the plans path pads — the un-jitted reference path works on
        exact ``num_rows`` arrays.
        """
        pad = rel.row_bucket - rel.num_rows
        if pad > 0:
            zeros = self.ring.zeros((pad,))
            vals = jax.tree_util.tree_map(
                lambda a, z: jnp.concatenate([a, z], axis=0), vals, zeros
            )
        if self.plans is not None and self.plans._shard_arity(rel) > 1:
            # commit the cached lift to the row-shard placement so sharded
            # plans consume it without a per-dispatch reshard copy
            vals = dist.place_rows(vals, self.plans.mesh, self.plans.mesh_axis)
        return vals

    def _lift_impl(self, q: Query, rel: Relation) -> sr.Field:
        if rel.name in self.lifts:
            return self.lifts[rel.name](rel)
        measure = None
        if q.measure and q.measure[0] == rel.name:
            measure = q.measure[1]
        return lift_rows(rel, self.ring, measure)

    def _base_factor(self, q: Query, rel: Relation) -> Factor:
        """Densified base relation, device-cached when plans are enabled."""
        ring = self.ring
        measure = q.measure[1] if q.measure and q.measure[0] == rel.name else None
        if rel.name in self.lifts:
            if self.plans is None:
                return self._dense_lifted(q, rel)
            key = ("lifted", rel.key, ring.name, q.lift_tag, self._lift_id(rel.name))
            return self.plans.factor_cached(key, lambda: self._dense_lifted(q, rel))
        if self.plans is None:
            return rel.to_factor(ring, measure)
        key = ("base", rel.key, ring.name, measure)
        return self.plans.factor_cached(key, lambda: rel.to_factor(ring, measure))

    def _dense_bag(self, q, rels, incoming, preds, out_attrs, stats=None) -> Factor:
        ring = self.ring
        factors = [self._base_factor(q, r) for r in rels] + list(incoming)
        if not factors:
            return Factor((), ring.ones(()), ring)
        if self.plans is not None:
            return self.plans.run_dense(factors, preds, out_attrs, stats)
        avail = {a for f in factors for a in f.attrs}
        for p in preds:
            if p.attr not in avail:  # pragma: no cover — placement guarantees
                raise KeyError(f"σ({p.attr}) not available in bag")
            mask = jnp.asarray(p.mask)
            # apply on the first factor containing the attr (masking is
            # idempotent but once suffices)
            for i, f in enumerate(factors):
                if p.attr in f.attrs:
                    factors[i] = f.select(p.attr, mask)
                    break
        out = tuple(a for a in out_attrs if a in avail)
        return contract(factors, out, ring)

    def _dense_lifted(self, q, rel: Relation) -> Factor:
        if self.plans is not None:
            vals = self._lift(q, rel)
            return self.plans.run_sparse(
                self.catalog, rel, vals, [], (), tuple(rel.attrs)
            )
        rows = self._lift(q, rel)
        idx, total = rel.flat_codes(rel.attrs)
        field = self.ring.segment_reduce(rows, jnp.asarray(idx), total)
        shape = tuple(rel.domains[a] for a in rel.attrs)
        field = jax.tree_util.tree_map(lambda l: l.reshape(shape + l.shape[1:]), field)
        return Factor(tuple(rel.attrs), field, self.ring)

    def _sparse_bag(self, q, rel: Relation, incoming, preds, out_attrs, stats=None) -> Factor:
        """Factorized sparse path: gather ⊗ rowwise, segment-⊕ to out_attrs.

        With plans enabled this is one compiled executable re-run with
        device-cached codes; ``_sparse_reference`` is the un-jitted reference
        path (also the tiered-compile eager leg of batched calibration).
        """
        vals = self._lift(q, rel)  # leaves: (N, *trailing)
        if self.plans is not None:
            return self.plans.run_sparse(
                self.catalog, rel, vals, incoming, preds, tuple(out_attrs), stats
            )
        return self._sparse_reference(rel, vals, incoming, preds, out_attrs)

    def _sparse_reference(
        self, rel: Relation, vals: sr.Field, incoming, preds, out_attrs
    ) -> Factor:
        ring = self.ring
        n = rel.num_rows
        carried: list[str] = []
        carried_dims: list[int] = []
        expand_field = expand_rows_field

        for m in incoming:
            shared = [a for a in m.attrs if a in rel.attrs]
            extra = [a for a in m.attrs if a not in rel.attrs]
            mp = m.project_to(tuple(shared + extra))
            # flatten shared dims, gather rows
            dims = [rel.domains[a] for a in shared]
            idx = np.zeros((n,), np.int64)
            if shared:
                idx = np.ravel_multi_index(
                    tuple(rel.codes[a].astype(np.int64) for a in shared), dims
                )
            idxj = jnp.asarray(idx)

            def gather(leaf, t):
                lead = leaf.reshape((int(np.prod(dims)) if shared else 1,) + leaf.shape[len(shared):])
                return jnp.take(lead, idxj, axis=0) if shared else jnp.broadcast_to(
                    lead, (n,) + lead.shape[1:]
                )

            leaves, treedef = jax.tree_util.tree_flatten(mp.field)
            g = jax.tree_util.tree_unflatten(
                treedef, [gather(l, t) for l, t in zip(leaves, ring.trailing)]
            )
            want = carried + [a for a in extra if a not in carried]
            vals = ring.mul(
                expand_field(vals, carried, want, ring.trailing),
                expand_field(g, extra, want, ring.trailing),
            )
            carried = want
            carried_dims = [self.jt.domains[a] for a in carried]

        # σ: row masks (predicates always reference bag-local attrs)
        if preds:
            row_mask = np.ones((n,), bool)
            for p in preds:
                row_mask &= p.mask[rel.codes[p.attr]]
            rm = jnp.asarray(row_mask)
            zeros = ring.zeros((n,) + tuple(carried_dims))
            leaves, treedef = jax.tree_util.tree_flatten(vals)
            zleaves = jax.tree_util.tree_leaves(zeros)
            out = []
            for leaf, z, t in zip(leaves, zleaves, ring.trailing):
                m = rm.reshape((n,) + (1,) * (leaf.ndim - 1))
                out.append(jnp.where(m, leaf, z))
            vals = jax.tree_util.tree_unflatten(treedef, out)

        local_out = [a for a in out_attrs if a in rel.attrs]
        carried_out = [a for a in out_attrs if a not in rel.attrs]
        assert set(carried_out) <= set(carried), (
            f"carried attrs {carried_out} not available (have {carried})"
        )
        idx, total = rel.flat_codes(local_out)
        field = ring.segment_reduce(vals, jnp.asarray(idx), total)
        # (total, *carried_dims, *trailing) → (*local_doms, *carried, *trailing)
        shape = tuple(rel.domains[a] for a in local_out)
        field = jax.tree_util.tree_map(
            lambda l: l.reshape(shape + l.shape[1:]), field
        )
        f = Factor(tuple(local_out) + tuple(carried), field, ring)
        return f.project_to(out_attrs)

    # -- root choice (§3.3.3) ---------------------------------------------------
    def estimate_edge_cost(self, q: Query, u: str, v: str, placement) -> float:
        """Cost of materializing Y(u→v): 0 when cached, else rows + out size.

        The signature/γ/size derivation is fused into one memo entry keyed by
        the γ-independent ``sig_key`` + ``group_by`` (both determine every
        component); only the store-containment probe runs live, since the
        store changes between calls.
        """
        key = (q.sig_key, q.group_by, "est", u, v)
        hit = self._sig_memo.get(key)
        if hit is None:
            base = self.edge_sig(q, u, v, placement)
            gamma = self.gamma_carry(q, u, v)
            out_attrs = tuple(dict.fromkeys(self.jt.separator(u, v) + gamma))
            out_size = 1.0
            for a in out_attrs:
                out_size *= self.jt.domains[a]
            hit = (
                self.store.full_sig(base, gamma), base, gamma,
                self._bag_rows(q, u) + out_size,
            )
            self._sig_memo[key] = hit
        full, base, gamma, miss_cost = hit
        if full in self.store._data or self.store.contains(base, gamma):
            return 0.0
        return miss_cost

    def _bags_by_rows(self, q: Query) -> list[tuple[int, str]]:
        """Candidate roots in ascending underlying-row order (memoized per
        version/R̄ snapshot — placement and γ don't change bag sizes)."""
        key = ("rootorder", q.rel_versions, q.removed)
        hit = self._placement_memo.get(key)
        if hit is None:
            hit = sorted((self._bag_rows(q, b), b) for b in self.jt.bags)
            self._placement_memo.put(key, hit)
        return hit

    def choose_root(self, q: Query, placement=None) -> str:
        """argmin over bags of (edges to recompute + absorption rows).

        Candidates are scanned in ascending row order, so with a warm store
        the first bag whose traversal is fully cached wins after a single
        scan (every later candidate already costs ≥ its own rows ≥ this
        root's total) — the warm-event fast path.  Each directed edge is
        estimated at most once per call.  Ties break toward fewer rows, then
        bag name (a pure function of query + store state, as Prop-2 needs).
        """
        placement = self.place_predicates(q) if placement is None else placement
        edge_cost: dict[tuple[str, str], float] = {}
        best, best_cost = None, None
        for rows, root in self._bags_by_rows(q):
            if best_cost is not None and rows >= best_cost:
                break
            cost = float(rows)
            for a, b in self.jt.traversal_to_root(root):
                c = edge_cost.get((a, b))
                if c is None:
                    edge_cost[(a, b)] = c = self.estimate_edge_cost(q, a, b, placement)
                cost += c
                if best_cost is not None and cost >= best_cost:
                    break
            else:
                if best_cost is None or cost < best_cost:
                    best, best_cost = root, cost
        return best

    # -- public API ---------------------------------------------------------------
    def execute(
        self, q: Query, root: str | None = None, sync: bool = True
    ) -> tuple[Factor, ExecStats]:
        """Execute ``q``: message passing to ``root``, absorption, γ-projection.

        Messages are dispatched asynchronously (no host sync between edges —
        plan inputs are device-resident); ``sync=True`` blocks once on the
        absorbed result so callers observe completed work.
        """
        stats = ExecStats()
        placement = self.place_predicates(q)
        root = root or self.choose_root(q, placement)
        with self.store.inflight():
            f = self.absorb(q, root, placement, stats)
        out = f.project_to(q.group_by)
        # the cache misses ARE the Steiner tree (§3.4.2): report its realized
        # size directly instead of planning it a second time (Treant used to)
        touched = {b for edge in stats.recomputed_edges for b in edge}
        stats.steiner_size = len(touched | {root})
        if sync:
            jax.block_until_ready(out.field)
        return out, stats

    def execute_many(
        self,
        queries: Sequence[Query],
        sync: bool = True,
        tags: Sequence[str | None] | None = None,
    ) -> list[tuple[Factor, ExecStats]]:
        """Execute several queries, batching structurally-identical absorptions.

        The crossfilter fan-out path: each query's message passing runs
        sequentially (warm events are pure store hits there), but the final
        root absorptions — the dominant warm-event cost, one plan dispatch
        per viz — are grouped by :func:`~repro.core.plans.absorb_batch_key`
        and every group of siblings executes as ONE vmapped jitted call
        (``PlanCache.run_sparse_batch``).  ``tags[i]`` is set as the store's
        producer tag while query i's messages materialize (cross-viz-hit
        accounting), matching what ``Session._fan_out`` did per viz.

        Batched and sequential execution are metamorphically equivalent:
        padding is the ⊕-identity and the store evolves in the same query
        order, so results are bit-identical on integer-exact data
        (``tests/test_batched_plans.py``).  Dense/densified bags and
        ``use_plans=False`` engines simply fall back to per-query absorption.
        """
        with self.store.inflight():
            return self._execute_many_inflight(queries, sync, tags)

    def _execute_many_inflight(
        self,
        queries: Sequence[Query],
        sync: bool = True,
        tags: Sequence[str | None] | None = None,
    ) -> list[tuple[Factor, ExecStats]]:
        results: list[Factor | None] = [None] * len(queries)
        all_stats: list[ExecStats] = []
        roots: list[str] = []
        deferred: list[tuple[int, AbsorbItem]] = []
        for i, q in enumerate(queries):
            stats = ExecStats()
            all_stats.append(stats)
            placement = self.place_predicates(q)
            root = self.choose_root(q, placement)
            roots.append(root)
            old_tag = self.store.tag
            if tags is not None and tags[i] is not None:
                self.store.tag = tags[i]
            try:
                incoming = [
                    self.message(q, u, root, placement, stats)
                    for u in self.jt.neighbors(root)
                ]
            finally:
                self.store.tag = old_tag
            keep = tuple(q.group_by)
            avail = set(self.jt.subtree_attrs(root, None))
            out_attrs = tuple(a for a in dict.fromkeys(keep) if a in avail)
            rel_names = [r for r in self.jt.relations_of(root) if r not in q.removed]
            rels = [self.catalog.get(r, q.version_of(r)) for r in rel_names]
            sparse = (
                len(rels) == 1
                and rels[0].num_rows > self.dense_rows_threshold
                and self.plans is not None
                and len(queries) > 1
            )
            if sparse:
                stats.rows_scanned += rels[0].num_rows
                deferred.append((i, AbsorbItem(
                    rel=rels[0], vals=self._lift(q, rels[0]),
                    incoming=tuple(incoming),
                    preds=placement.get(root, ()), out_attrs=out_attrs,
                )))
            else:
                results[i] = self._bag_contract(
                    q, root, incoming, out_attrs, placement, stats
                )
        groups: dict[tuple, list[tuple[int, AbsorbItem]]] = {}
        for i, item in deferred:
            groups.setdefault(absorb_batch_key(self.ring, item), []).append((i, item))
        for group in groups.values():
            for members in self._absorb_chunks(group):
                if len(members) == 1:
                    i, item = members[0]
                    results[i] = self.plans.run_sparse(
                        self.catalog, item.rel, item.vals, list(item.incoming),
                        list(item.preds), item.out_attrs, all_stats[i],
                    )
                    continue
                fs = self.plans.run_sparse_batch(
                    self.catalog, [item for _, item in members],
                    [all_stats[i] for i, _ in members],
                )
                for (i, _), f in zip(members, fs):
                    results[i] = f
                # cross-session batching accounting: how many distinct
                # sessions this ONE vmapped dispatch served (tags are
                # "{session}:{viz}"; the server's fan-out is the only caller
                # that mixes sessions in one execute_many)
                if tags is not None:
                    owners = {
                        tags[i].split(":", 1)[0]
                        for i, _ in members if tags[i] is not None
                    }
                    for i, _ in members:
                        all_stats[i].batch_sessions = len(owners)
                    if self.plans is not None and len(owners) > 1:
                        ps = self.plans.stats
                        ps.cross_session_execs += 1
                        ps.cross_session_width = max(
                            ps.cross_session_width, len(owners)
                        )
        outs: list[tuple[Factor, ExecStats]] = []
        for i, q in enumerate(queries):
            out = results[i].project_to(q.group_by)
            stats = all_stats[i]
            touched = {b for edge in stats.recomputed_edges for b in edge}
            stats.steiner_size = len(touched | {roots[i]})
            outs.append((out, stats))
        if sync:
            jax.block_until_ready([f.field for f, _ in outs])
        return outs

    def _absorb_chunks(
        self, members: list[tuple[int, "AbsorbItem"]]
    ) -> list[list[tuple[int, "AbsorbItem"]]]:
        """Split one ``absorb_batch_key`` group into bounded-volume chunks.

        One vmapped dispatch per group stops paying off once rows·width
        grows past the backend's profitable regime (see
        :func:`sparse_batch_elems`); chunks keep a floor of 2 members so
        sibling sessions still share a dispatch at any fact-table size."""
        budget = sparse_batch_elems()
        if budget <= 0 or len(members) <= 2:
            return [members]
        rows = max(members[0][1].rel.num_rows, 1)
        cap = max(2, budget // rows)
        return [members[j:j + cap] for j in range(0, len(members), cap)]

    def calibrate(
        self, q: Query, root: str | None = None, pin: bool = False,
        batch: bool | None = None,
    ) -> ExecStats:
        stats = ExecStats()
        if self._batch_enabled(batch):
            plan = self.calibration_plan(q, root=root, pin=pin)
            while not plan.done:
                self.run_calibration_level([plan], [stats])
            return stats
        for _ in self.calibrate_iter(q, root=root, pin=pin, stats=stats):
            pass
        return stats

    def calibrate_iter(
        self, q: Query, root: str | None = None, pin: bool = False, stats=None
    ) -> Iterable[tuple[str, str]]:
        """Algorithm 1: upward then downward passes; yields after each edge.

        Preemptible: abandoning the iterator keeps all already-materialized
        messages in the store (think-time calibration, §4.2.1).  This is the
        per-edge reference loop; see ``calibrate_levels_iter`` for the
        level-batched form.
        """
        placement = self.place_predicates(q)
        root = root or self.choose_root(q, placement)
        upward = self.jt.traversal_to_root(root)
        downward = [(v, u) for (u, v) in reversed(upward)]
        stats = stats if stats is not None else ExecStats()
        for (u, v) in upward + downward:
            if pin:
                # pin BEFORE materializing so a tight LRU can't evict the
                # message between put() and pin()
                base = self.edge_sig(q, u, v, placement)
                self.store.pin(base, self.gamma_carry(q, u, v))
            before = stats.messages_computed
            self.message(q, u, v, placement, stats)
            self._count_dispatches(stats, stats.messages_computed - before)
            yield (u, v)

    # -- level-batched calibration (think-time batching, §4.2.1) ---------------
    def _batch_enabled(self, batch: bool | None = None) -> bool:
        if batch is None:
            batch = self.batch_calibration
        return bool(batch) and self.plans is not None

    def _count_dispatches(self, stats: ExecStats | None, k: int) -> None:
        """Account ``k`` calibration message dispatches (per-edge: one per
        computed message; batched: one per level group)."""
        if k <= 0:
            return
        if stats is not None:
            stats.calibration_dispatches += k
        if self.plans is not None:
            self.plans.stats.calibration_dispatches += k

    def calibration_plan(
        self, q: Query, root: str | None = None, pin: bool = False
    ) -> CalibrationPlan:
        """Derive the level-synchronous schedule for one calibration pass."""
        placement = self.place_predicates(q)
        root = root or self.choose_root(q, placement)
        return CalibrationPlan(
            q, placement, root, self.jt.calibration_levels(root), pin
        )

    def step_calibration(
        self, plan: CalibrationPlan, max_edges: int | None = None, stats=None,
        deadline: float | None = None,
    ) -> int:
        """Advance a parked pass edge-by-edge (exact budget granularity).

        The scheduler's budgeted path: level batching would overshoot a
        tight message budget, so budgeted runs step single messages and
        park mid-level — the level executor resumes from the same position.
        ``deadline`` (a ``time.perf_counter`` timestamp) is re-checked after
        every edge, so a seconds budget preempts without the caller having
        to re-enter (and re-prioritize) per edge.
        """
        n = 0
        stats = stats if stats is not None else ExecStats()
        with self.store.inflight():
            while not plan.done and (max_edges is None or n < max_edges):
                u, v = plan.levels[plan.pos][plan.offset]
                if plan.pin:
                    base = self.edge_sig(plan.query, u, v, plan.placement)
                    self.store.pin(base, self.gamma_carry(plan.query, u, v))
                before = stats.messages_computed
                self.message(plan.query, u, v, plan.placement, stats)
                self._count_dispatches(stats, stats.messages_computed - before)
                plan.offset += 1
                n += 1
                if plan.offset >= len(plan.levels[plan.pos]):
                    plan.pos += 1
                    plan.offset = 0
                if deadline is not None and time.perf_counter() >= deadline:
                    break
        return n

    @contextlib.contextmanager
    def _tagged(self, tag: str | None):
        """Temporarily set the store's producer tag (cross-viz accounting)."""
        if tag is None:
            yield
            return
        old = self.store.tag
        self.store.tag = tag
        try:
            yield
        finally:
            self.store.tag = old

    def _message_item(self, q: Query, u: str, v: str, placement, stats, tag) -> AbsorbItem | None:
        """Build the deferred batch item for message Y(u→v), or None when the
        bag takes the dense path (then the caller computes directly)."""
        if self.plans is None:
            return None
        rel_names = [r for r in self.jt.relations_of(u) if r not in q.removed]
        if len(rel_names) != 1:
            return None
        rel = self.catalog.get(rel_names[0], q.version_of(rel_names[0]))
        if rel.num_rows <= self.dense_rows_threshold:
            return None
        gamma = self.gamma_carry(q, u, v)
        out_attrs = tuple(dict.fromkeys(self.jt.separator(u, v) + gamma))
        before = stats.messages_computed if stats else 0
        with self._tagged(tag):
            # previous levels put these; recursion recomputes an evicted one
            incoming = tuple(
                self.message(q, i, u, placement, stats)
                for i in self.jt.neighbors(u) if i != v
            )
        if stats:
            self._count_dispatches(stats, stats.messages_computed - before)
            stats.rows_scanned += rel.num_rows
        return AbsorbItem(
            rel=rel, vals=self._lift(q, rel), incoming=incoming,
            preds=placement.get(u, ()), out_attrs=out_attrs,
        )

    def run_calibration_level(
        self,
        plans: Sequence[CalibrationPlan],
        stats_list: Sequence[ExecStats] | None = None,
        tags: Sequence[str | None] | None = None,
    ) -> int:
        """Advance every unfinished plan by one level, batching across plans.

        All messages inside one level are independent, so the level executes
        as a unit: duplicates across sibling plans (equal Prop-2 signature +
        γ) materialize once, messages sharing an ``absorb_batch_key`` batch
        signature execute as ONE vmapped jitted call
        (``PlanCache.run_message_batch`` — γ-domain padding with the
        ⊕-identity, exactly like batched absorption), and dense/densified
        bags fall back to the per-edge message path.  With
        ``fuse_level_kernel`` on, ALL batch groups of the level collapse
        further into one ``PlanCache.run_level`` dispatch whose
        kernel-eligible messages share a single multi-segment Pallas launch,
        so a whole calibration pass costs ≤ #levels dispatches.  Returns the
        number of edges advanced; a partially-stepped level (``plan.offset``)
        is finished first.
        """
        with self.store.inflight():
            return self._run_level_inflight(plans, stats_list, tags)

    def _run_level_inflight(
        self,
        plans: Sequence[CalibrationPlan],
        stats_list: Sequence[ExecStats] | None = None,
        tags: Sequence[str | None] | None = None,
    ) -> int:
        live = [i for i, p in enumerate(plans) if not p.done]
        if not live:
            return 0
        if stats_list is None:
            stats_list = [ExecStats() for _ in plans]
        n = 0
        todo: list[tuple[int, str, str, str, tuple[str, ...]]] = []
        for i in live:
            p = plans[i]
            level = p.levels[p.pos][p.offset:]
            for (u, v) in level:
                base = self.edge_sig(p.query, u, v, p.placement)
                gamma = self.gamma_carry(p.query, u, v)
                if p.pin:
                    # pin-before-materialize, as in calibrate_iter
                    self.store.pin(base, gamma)
                todo.append((i, u, v, base, gamma))
            p.pos += 1
            p.offset = 0
            n += len(level)
        deferred: list[tuple[int, str, str, str, tuple[str, ...], AbsorbItem]] = []
        pending_sigs: set[str] = set()
        for i, u, v, base, gamma in todo:
            st = stats_list[i]
            tag = tags[i] if tags is not None else None
            p = plans[i]
            with self._tagged(tag):
                cached = self.store.get(base, gamma)
            if cached is not None:
                st.messages_reused += 1
                continue
            if self.store.full_sig(base, gamma) in pending_sigs:
                # a sibling plan materializes this exact message below
                st.messages_reused += 1
                continue
            item = self._message_item(p.query, u, v, p.placement, st, tag)
            if item is None:
                # dense/densified fallback goes through message(), which
                # re-probes the sig our level probe above already counted —
                # compensate so miss accounting matches the per-edge loop
                self.store.misses -= 1
                before = st.messages_computed
                with self._tagged(tag):
                    self.message(p.query, u, v, p.placement, st)
                self._count_dispatches(st, st.messages_computed - before)
                continue
            pending_sigs.add(self.store.full_sig(base, gamma))
            deferred.append((i, u, v, base, gamma, item))
        groups: dict[tuple, list] = {}
        for rec in deferred:
            groups.setdefault(absorb_batch_key(self.ring, rec[5]), []).append(rec)
        group_list = list(groups.values())

        def _store_group(members, fs):
            for (i, u, v, base, gamma, item), f in zip(members, fs):
                st = stats_list[i]
                tag = tags[i] if tags is not None else None
                cost = item.rel.num_rows + float(
                    np.prod([self.jt.domains.get(a, 1) for a in item.out_attrs])
                )
                with self._tagged(tag):
                    self.store.put(base, gamma, f, cost=cost)
                st.messages_computed += 1
                st.recomputed_edges.append((u, v))

        if group_list and self.fuse_level_kernel:
            # level fusion: ALL groups ride one jitted run_level call — the
            # kernel-eligible ones share a single multi-segment Pallas
            # launch — so the whole level costs ONE dispatch
            fs_groups = self.plans.run_level(
                self.catalog,
                [[m[5] for m in members] for members in group_list],
                [[stats_list[m[0]] for m in members] for members in group_list],
            )
            self._count_dispatches(stats_list[group_list[0][0][0]], 1)
            for members, fs in zip(group_list, fs_groups):
                _store_group(members, fs)
            return n

        for members in group_list:
            sts = [stats_list[m[0]] for m in members]
            if len(members) == 1:
                _, _, _, _, _, item = members[0]
                fs = [self.plans.run_sparse(
                    self.catalog, item.rel, item.vals, list(item.incoming),
                    list(item.preds), item.out_attrs, sts[0],
                )]
            else:
                fs = self.plans.run_message_batch(
                    self.catalog, [m[5] for m in members], sts,
                )
            self._count_dispatches(sts[0], 1)
            _store_group(members, fs)
        return n

    def calibrate_levels_iter(
        self, q: Query, root: str | None = None, pin: bool = False, stats=None
    ) -> Iterable[tuple[tuple[str, str], ...]]:
        """Level-batched Algorithm 1: yields the edge tuple of each completed
        level (upward levels deepest-first, then downward).  Preemptible at
        level granularity — abandoning the iterator keeps every completed
        level's messages servable (§4.2.1)."""
        plan = self.calibration_plan(q, root=root, pin=pin)
        stats_list = [stats if stats is not None else ExecStats()]
        while not plan.done:
            level = plan.levels[plan.pos]
            self.run_calibration_level([plan], stats_list)
            yield level

    def _gamma_lanes(self, gamma: Sequence[str]) -> int:
        lanes = 1
        for a in gamma:
            lanes *= self.jt.domains.get(a, 1)
        return lanes

    def _union_carry(self, queries: Sequence[Query]) -> list[Query]:
        """Fuse same-``sig_key`` queries into union-γ calibration passes.

        One message carrying γ₁∪γ₂ serves both queries: Prop-2 base
        signatures are γ-independent, and the store narrows a wider-γ cached
        message by ⊕-marginalization on lookup (Σ-compensation, §3.4.2) — so
        calibrating the union calibrates every member.  Greedy first-fit
        bounded by ``calibration_union_budget()`` caps the γ-domain product
        of the widest message a fused pass materializes.
        """
        budget = calibration_union_budget()
        slots: list[tuple[str, Query, tuple[str, ...]]] = []
        for q in queries:
            placed = False
            for j, (sk, rep, union) in enumerate(slots):
                if sk != q.sig_key:
                    continue
                merged = tuple(dict.fromkeys(union + q.group_by))
                if merged == union or self._gamma_lanes(merged) <= budget:
                    slots[j] = (sk, rep, merged)
                    placed = True
                    break
            if not placed:
                slots.append((q.sig_key, q, tuple(q.group_by)))
        out, seen = [], set()
        for _, rep, union in slots:
            eff = rep.with_group_by(*union)
            if eff.digest not in seen:
                seen.add(eff.digest)
                out.append(eff)
        return out

    def calibrate_many(
        self, queries: Sequence[Query], pin: bool = False,
        batch: bool | None = None,
    ) -> tuple[list[ExecStats], list[Query]]:
        """Calibrate several queries' CJTs together (dashboard offline stage).

        With batched calibration enabled, sibling queries fuse into
        union-carry passes (``_union_carry``), every pass shares one root —
        calibration touches all 2(n−1) directed edges regardless of root, so
        a common root aligns the level schedules — and the passes advance
        level-synchronously through ``run_calibration_level``, batching
        same-signature messages across passes into vmapped calls.  Returns
        ``(stats per effective pass, effective queries)``; pins land on the
        *effective* queries, which the caller must hold for unpinning.
        """
        if not queries:
            return [], []
        if not self._batch_enabled(batch):
            return (
                [self.calibrate(q, pin=pin, batch=False) for q in queries],
                list(queries),
            )
        effective = self._union_carry(queries)
        root = self.choose_root(effective[0])
        plans = [self.calibration_plan(q, root=root, pin=pin) for q in effective]
        stats_list = [ExecStats() for _ in effective]
        while any(not p.done for p in plans):
            self.run_calibration_level(plans, stats_list)
        return stats_list, effective

    def unpin_query(self, q: Query, root: str | None = None) -> int:
        """Release this query's calibration pins (Session GC: a closed
        session's base CJT must become evictable).  Messages stay cached and
        servable — only the eviction exemption is dropped.  Returns the
        number of previously-pinned edges released."""
        placement = self.place_predicates(q)
        n = 0
        for u, v in self.jt.directed_edges():
            base = self.edge_sig(q, u, v, placement)
            gamma = self.gamma_carry(q, u, v)
            if self.store.full_sig(base, gamma) in self.store._pinned:
                n += 1
            self.store.unpin(base, gamma)
        return n

    # -- delta calibration (data updates) ---------------------------------------
    def delta_message(
        self,
        q_new: Query,
        q_delta: Query,
        u: str,
        v: str,
        placement,
        via: str | None = None,
        delta_in: Factor | None = None,
    ) -> Factor:
        """ΔY(u→v): the u→v contraction with the changed input swapped for its delta.

        Bag contraction distributes ⊕ over ⊗ (it is multilinear in the bag's
        relations and in each incoming message), so replacing exactly the
        changed input by its ⊕-difference yields the ⊕-difference of the
        output.  ``via=None`` means u itself hosts the updated relation and
        ``q_delta`` (which pins that relation to its delta-rows version)
        drives the contraction; otherwise ``delta_in`` is ΔY(via→u) from the
        previous hop and every other input is an unchanged cached message.
        """
        gamma = self.gamma_carry(q_new, u, v)
        out_attrs = tuple(dict.fromkeys(self.jt.separator(u, v) + gamma))
        incoming = [
            self.message(q_new, i, u, placement)
            for i in self.jt.neighbors(u)
            if i != v and i != via
        ]
        if via is None:
            return self._bag_contract(q_delta, u, incoming, out_attrs, placement)
        return self._bag_contract(q_new, u, incoming + [delta_in], out_attrs, placement)

    def apply_delta(self, q: Query, delta: Delta) -> tuple[Query, DeltaStats]:
        """Maintain this query's cached messages across a base-data update.

        Returns ``(q_new, stats)`` where ``q_new`` is ``q`` re-snapshotted to
        ``delta.new_version``.  Only the n−1 messages directed away from the
        updated bag u₀ change; they are updated as old ⊕ ΔY in u₀-outward
        order, reusing every off-path cached message.  The new messages are
        stored under new-version Prop-2 signatures (the version is part of
        every bag digest), so a stale pre-update message can never serve a
        post-update query.  The catalog must already contain the new relation
        version.  When the ring cannot absorb the delta (no ⊕-inverse for a
        delete) or σ-placement migrated between versions, nothing is
        maintained and ``stats.fallback`` is set — queries then recompute on
        demand (schedule via think-time).
        """
        stats = DeltaStats(delta_rows=delta.num_rows)
        q_new = q.with_version(delta.relation, delta.new_version)
        if delta.relation in q.removed or delta.relation not in self.jt.mapping:
            return q_new, stats  # update invisible to this query's CJT
        if q.version_of(delta.relation) != delta.old_version:
            raise ValueError(
                f"delta chains {delta.relation}@{delta.old_version} but the "
                f"query snapshot is @{q.version_of(delta.relation)}"
            )
        if not delta.supported_by(self.ring):
            stats.fallback = True
            return q_new, stats
        self.catalog.put(delta.rows, make_latest=False)
        placement_old = self.place_predicates(q)
        placement_new = self.place_predicates(q_new)
        if placement_old != placement_new:
            # row-count ordering flipped and a σ migrated bags: old messages
            # were built under a different annotation layout — unsound to ⊕.
            stats.fallback = True
            return q_new, stats
        u0 = self.jt.mapping[delta.relation]
        q_delta = q_new.with_version(delta.relation, delta.rows.version)
        upward = self.jt.traversal_to_root(u0)  # (child, parent): parent is u₀-side
        toward_u0 = {c: p for (c, p) in upward}
        # an empty delta (compaction) is the ⊕-zero: every outward message is
        # value-identical under the new version — re-key, contract nothing
        empty = delta.num_rows == 0
        dmsgs: dict[tuple[str, str], Factor] = {}
        with self.store.inflight():
            for (c, p) in reversed(upward):  # edges nearest u₀ first
                u, v = p, c  # the changed direction points away from u₀
                d = None
                if not empty:
                    via = None if u == u0 else toward_u0[u]
                    d = self.delta_message(
                        q_new, q_delta, u, v, placement_new,
                        via=via, delta_in=None if via is None else dmsgs[(via, u)],
                    )
                    dmsgs[(u, v)] = d
                    stats.delta_messages += 1
                old_base = self.edge_sig(q, u, v, placement_old)
                new_base = self.edge_sig(q_new, u, v, placement_new)
                gamma = self.gamma_carry(q_new, u, v)
                if self.store.apply_delta(old_base, new_base, gamma, d) is not None:
                    stats.edges_maintained += 1
                else:
                    stats.edges_skipped += 1
        return q_new, stats

    def is_calibrated(self, q: Query) -> bool:
        placement = self.place_predicates(q)
        for u, v in self.jt.directed_edges():
            base = self.edge_sig(q, u, v, placement)
            if not self.store.contains(base, self.gamma_carry(q, u, v)):
                return False
        return True

    def check_calibration(self, q: Query) -> bool:
        """Definitional check (§3.4.1): adjacent absorptions agree on separators."""
        placement = self.place_predicates(q)
        for u, v in self.jt.directed_edges():
            if u > v:
                continue
            sep = self.jt.separator(u, v)
            au = self.absorb(q, u, placement, keep=sep).project_to(sep)
            av = self.absorb(q, v, placement, keep=sep).project_to(sep)
            lu = jax.tree_util.tree_leaves(au.field)
            lv = jax.tree_util.tree_leaves(av.field)
            for x, y in zip(lu, lv):
                if not np.allclose(np.asarray(x, np.float64), np.asarray(y, np.float64), rtol=1e-4, atol=1e-5):
                    return False
        return True
