"""Commutative semirings over JAX pytrees.

The paper (§2) annotates tuples with elements of a commutative semiring
(D, ⊕, ⊗, 0, 1); joins multiply annotations, group-bys add them.  Here a
semiring *element field* is a pytree of arrays sharing leading "domain"
dimensions (one per categorical attribute).  Scalar rings (COUNT/SUM) use a
single array; compound rings (AVG/VAR/covariance) use tuples of arrays with
trailing statistic dimensions.

Every ring implements:
  zeros/ones(shape)       identity fields for ⊕ / ⊗
  mul(a, b)               pointwise ⊗ of aligned fields
  add_reduce(a, axes)     ⊕-marginalization over domain axes
  add(a, b)               pointwise ⊕ (used for incremental updates)
  lift(...)               raw column(s) → element field
  trailing_ndims(leaf_i)  number of non-domain trailing dims per leaf

Rings additionally declare ``has_add_inverse``: True iff every element has an
⊕-inverse (the ring is actually a commutative *group* under ⊕).  Delta
calibration (calibration.CJTEngine.apply_delta) relies on this to encode
deletions as negatively-weighted rows: SUM/COUNT/MOMENTS/covariance admit it,
tropical MIN/MAX and BOOL do not (a delete there forces recomputation).

The (ℝ, +, ×) rings additionally expose an einsum fast path used by
``factor.contract`` so that hot contractions lower to MXU matmuls (and to the
``semiring_contract`` Pallas kernel on TPU).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Field = Any  # pytree of arrays with shared leading domain dims


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A commutative semiring over array pytrees."""

    name: str
    dtype: jnp.dtype
    # pointwise ops on aligned fields
    _mul: Callable[[Field, Field], Field]
    _add: Callable[[Field, Field], Field]
    _reduce: Callable[[Field, tuple[int, ...]], Field]
    _zeros: Callable[[tuple[int, ...]], Field]
    _ones: Callable[[tuple[int, ...]], Field]
    # trailing (non-domain) dims for each leaf of the field pytree, in
    # tree-flatten order.  Scalar rings: (0,).
    trailing: tuple[int, ...] = (0,)
    # True iff (⊕,⊗) == (+,×): enables the einsum/MXU fast path.
    is_arithmetic: bool = False
    # True iff ⊕ has inverses (ring is a group under ⊕): enables encoding
    # deletions as negatively-weighted delta rows (delta calibration).
    has_add_inverse: bool = False
    # True iff ⊕ is idempotent (a ⊕ a = a: MIN/MAX/BOOL).  Idempotent rings
    # can absorb *tombstoned* deletes (rows kept at weight 0, so the lift —
    # which ignores weights for these rings — re-contributes values already
    # folded into the cached messages) without an ⊕-inverse; the deletes
    # become visible at the next compaction, which physically drops the
    # tombstones and recalibrates.
    idempotent_add: bool = False
    # ⊕-segment-reduction over the leading (row) axis; None → segment_sum
    # per leaf (valid whenever ⊕ is +).
    _segment: Callable[[Field, jax.Array, int], Field] | None = None
    # ⊕ as a segment_aggregate Pallas-kernel op name ("sum"/"min"/"max"),
    # or None when the ring must take the lax fallback path (compound rings,
    # non-f32 dtypes).  Consumed by core.plans when compiling message plans.
    kernel_segment_op: str | None = None

    # -- public API ---------------------------------------------------------
    def mul(self, a: Field, b: Field) -> Field:
        return self._mul(a, b)

    def add(self, a: Field, b: Field) -> Field:
        return self._add(a, b)

    def add_reduce(self, a: Field, axes: Sequence[int]) -> Field:
        axes = tuple(axes)
        if not axes:
            return a
        return self._reduce(a, axes)

    def segment_reduce(self, values: Field, segment_ids: jax.Array, num_segments: int) -> Field:
        """⊕-aggregate per-row fields into ``num_segments`` dense groups.

        This is the TPU-native replacement for DBMS hash aggregation: rows of
        a sparse annotated relation collapse into a dense factor over the
        group attrs (accelerated by the ``segment_aggregate`` Pallas kernel).
        """
        if self._segment is not None:
            return self._segment(values, segment_ids, num_segments)
        return _tree_map(
            lambda v: jax.ops.segment_sum(v, segment_ids, num_segments), values
        )

    def zeros(self, shape: tuple[int, ...]) -> Field:
        return self._zeros(tuple(shape))

    def ones(self, shape: tuple[int, ...]) -> Field:
        return self._ones(tuple(shape))

    def leaves(self, a: Field) -> list[jax.Array]:
        return jax.tree_util.tree_leaves(a)

    def domain_shape(self, a: Field) -> tuple[int, ...]:
        leaf = self.leaves(a)[0]
        t = self.trailing[0]
        return leaf.shape[: leaf.ndim - t] if t else leaf.shape

    def expand_field(self, a: Field, src_axes: tuple[int, ...], out_shape: tuple[int, ...]) -> Field:
        """Broadcast field with domain dims at positions src_axes into out_shape.

        Domain dims are first transposed into target order (reshape alone
        would silently scramble out-of-order attrs).
        """
        order = sorted(range(len(src_axes)), key=lambda i: src_axes[i])
        leaves, treedef = jax.tree_util.tree_flatten(a)
        out = []
        for leaf, t in zip(leaves, self.trailing):
            dom_nd = leaf.ndim - t
            leaf = jnp.transpose(
                leaf, tuple(order) + tuple(range(dom_nd, leaf.ndim))
            )
            perm_shape = [1] * len(out_shape) + list(leaf.shape[dom_nd:])
            for pos, i in enumerate(order):
                perm_shape[src_axes[i]] = leaf.shape[pos]
            reshaped = leaf.reshape(perm_shape)
            out.append(jnp.broadcast_to(reshaped, tuple(out_shape) + leaf.shape[dom_nd:]))
        return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Scalar arithmetic rings: COUNT / SUM  (ℝ or ℕ, +, ×, 0, 1)
# ---------------------------------------------------------------------------

def _arith(name: str, dtype) -> Semiring:
    return Semiring(
        name=name,
        dtype=dtype,
        _mul=lambda a, b: a * b,
        _add=lambda a, b: a + b,
        _reduce=lambda a, axes: jnp.sum(a, axis=axes),
        _zeros=lambda s: jnp.zeros(s, dtype),
        _ones=lambda s: jnp.ones(s, dtype),
        trailing=(0,),
        is_arithmetic=True,
        has_add_inverse=True,
        kernel_segment_op="sum" if dtype == jnp.float32 else None,
    )


COUNT = _arith("count", jnp.float32)   # float for MXU; exact for moderate ints
SUM = _arith("sum", jnp.float32)
COUNT_I64 = _arith("count_i64", jnp.int64)  # exact variant for property tests


# ---------------------------------------------------------------------------
# Tropical rings: MIN / MAX aggregates  (ℝ∪{±∞}, min/max, +, ∞/-∞, 0)
# ---------------------------------------------------------------------------

def _tropical(name: str, reducer, zero_val) -> Semiring:
    dtype = jnp.float32
    seg = jax.ops.segment_min if reducer is jnp.minimum else jax.ops.segment_max
    return Semiring(
        name=name,
        dtype=dtype,
        _mul=lambda a, b: a + b,
        _add=lambda a, b: reducer(a, b),
        _reduce=lambda a, axes: (jnp.min if reducer is jnp.minimum else jnp.max)(a, axis=axes),
        _zeros=lambda s: jnp.full(s, zero_val, dtype),
        _ones=lambda s: jnp.zeros(s, dtype),
        trailing=(0,),
        is_arithmetic=False,
        idempotent_add=True,
        _segment=lambda v, ids, n: seg(v, ids, n),
        kernel_segment_op="min" if reducer is jnp.minimum else "max",
    )


TROPICAL_MIN = _tropical("tropical_min", jnp.minimum, jnp.inf)
TROPICAL_MAX = _tropical("tropical_max", jnp.maximum, -jnp.inf)


# ---------------------------------------------------------------------------
# Boolean ring (∨, ∧): Yannakakis semi-join reductions
# ---------------------------------------------------------------------------

BOOL = Semiring(
    name="bool",
    dtype=jnp.bool_,
    _mul=lambda a, b: jnp.logical_and(a, b),
    _add=lambda a, b: jnp.logical_or(a, b),
    _reduce=lambda a, axes: jnp.any(a, axis=axes),
    _zeros=lambda s: jnp.zeros(s, jnp.bool_),
    _ones=lambda s: jnp.ones(s, jnp.bool_),
    trailing=(0,),
    is_arithmetic=False,
    idempotent_add=True,
    _segment=lambda v, ids, n: jax.ops.segment_sum(v.astype(jnp.int32), ids, n) > 0,
)


# ---------------------------------------------------------------------------
# AVG / VARIANCE ring: elements (c, s, s2); var = s2/c - (s/c)^2  (paper §2)
# ---------------------------------------------------------------------------

def _moments_mul(a, b):
    (c1, s1, q1), (c2, s2, q2) = a, b
    return (c1 * c2, c1 * s2 + c2 * s1, c1 * q2 + c2 * q1 + 2.0 * s1 * s2)


MOMENTS = Semiring(
    name="moments",
    dtype=jnp.float32,
    _mul=_moments_mul,
    _add=lambda a, b: _tree_map(jnp.add, a, b),
    _reduce=lambda a, axes: _tree_map(lambda x: jnp.sum(x, axis=axes), a),
    _zeros=lambda s: tuple(jnp.zeros(s, jnp.float32) for _ in range(3)),
    _ones=lambda s: (jnp.ones(s, jnp.float32), jnp.zeros(s, jnp.float32), jnp.zeros(s, jnp.float32)),
    trailing=(0, 0, 0),
    is_arithmetic=False,
    has_add_inverse=True,
    # ⊕ is leafwise +, so the plan layer stacks (c, s, q) as three f32 value
    # columns and routes all of them through ONE "sum" segment pass
    kernel_segment_op="sum",
)


def moments_lift(value: jax.Array, count: jax.Array | None = None) -> Field:
    """Lift a measure column: element (c, c·x, c·x²).

    ``count`` is the row multiplicity; scaling every component makes
    count = -1 the exact ⊕-inverse (delete deltas) and multiplicity-w rows
    aggregate as w copies would.
    """
    c = jnp.ones_like(value) if count is None else count
    return (c, c * value, c * value * value)


def moments_finalize(field: Field) -> dict[str, jax.Array]:
    c, s, q = field
    mean = s / jnp.maximum(c, 1.0)
    var = q / jnp.maximum(c, 1.0) - mean * mean
    return {"count": c, "sum": s, "mean": mean, "var": var}


# ---------------------------------------------------------------------------
# Covariance (linear-regression) ring — Schleich et al. [69], paper §4.3.
# Element: (c, s ∈ ℝ^k, Q ∈ ℝ^{k×k}) over a global feature index space of
# size k.  ⊗: (c1c2, c1·s2 + c2·s1, c1·Q2 + c2·Q1 + s1 s2ᵀ + s2 s1ᵀ); ⊕: +.
# Training solves the normal equations on the fully-marginalized element.
# ---------------------------------------------------------------------------

def make_covariance_ring(k: int) -> Semiring:
    def mul(a, b):
        (c1, s1, q1), (c2, s2, q2) = a, b
        c = c1 * c2
        s = c1[..., None] * s2 + c2[..., None] * s1
        outer = s1[..., :, None] * s2[..., None, :]
        q = (
            c1[..., None, None] * q2
            + c2[..., None, None] * q1
            + outer
            + jnp.swapaxes(outer, -1, -2)
        )
        return (c, s, q)

    return Semiring(
        name=f"covariance[{k}]",
        dtype=jnp.float32,
        _mul=mul,
        _add=lambda a, b: _tree_map(jnp.add, a, b),
        _reduce=lambda a, axes: _tree_map(lambda x: jnp.sum(x, axis=axes), a),
        _zeros=lambda s: (
            jnp.zeros(s, jnp.float32),
            jnp.zeros(s + (k,), jnp.float32),
            jnp.zeros(s + (k, k), jnp.float32),
        ),
        _ones=lambda s: (
            jnp.ones(s, jnp.float32),
            jnp.zeros(s + (k,), jnp.float32),
            jnp.zeros(s + (k, k), jnp.float32),
        ),
        trailing=(0, 1, 2),
        is_arithmetic=False,
        has_add_inverse=True,
    )


def covariance_lift(k: int, feature_ids: Sequence[int], columns: Sequence[jax.Array]) -> Field:
    """Lift local feature columns (each (N,)) into the k-dim covariance ring."""
    n = columns[0].shape[0] if columns else 0
    c = jnp.ones((n,), jnp.float32)
    s = jnp.zeros((n, k), jnp.float32)
    for fid, col in zip(feature_ids, columns):
        s = s.at[:, fid].set(col.astype(jnp.float32))
    q = s[:, :, None] * s[:, None, :]
    return (c, s, q)


REGISTRY: dict[str, Semiring] = {
    r.name: r for r in (COUNT, SUM, COUNT_I64, TROPICAL_MIN, TROPICAL_MAX, BOOL, MOMENTS)
}


def get(name: str) -> Semiring:
    return REGISTRY[name]
