"""Predictive think-time: ONE policy object owns the idle budget.

Treant's core claim is that user *think-time* can hide the cost of the next
interaction.  Before this module the budget was split across three ad-hoc
consumers reached through three divergent entry points (``Session.idle``'s
``speculate=k``, ``TreantServer(speculate=)``, ``Treant.think_time``).  Now a
single :class:`ThinkTimePolicy` decides — under one shared
:class:`ThinkTimeBudget` — how idle capacity is spent across the think-time
work items:

- **calibration drains** (the scheduler's pending CJT passes — always first:
  an uncalibrated viz makes every later interaction slow),
- **per-dimension bin cubes** (:class:`PredictiveThinkTime` only): for a viz
  with group-by γ and a likely-next brush dimension *d*, materialize the
  γ∪{d} aggregate *without* the σ on d — the union-carry widening substrate
  (PR 5) means its messages are the wide ones calibration would share anyway.
  Any later ``SetFilter``/``ClearFilter`` on *d* is then served by slicing
  the cube (``Factor.select`` + ⊕-marginalize, exact for every semiring —
  the paper applies σ by zero-annotating non-matching tuples, and 0̄ is the
  ⊕-identity): **zero store probes, zero plan executions**, for *any* σ on
  the dimension — strictly better than k-nearest σ prefetch, which only
  covers the k adjacent windows,
- **residual σ prefetch** (whole-fan-out pre-execution for predicted next σ
  values, direction-biased),
- **background flush** (server tier; stays ahead of the policy because queued
  stream data makes every other item stale).

Policies:

- :class:`DrainCalibration` — calibration only; the default, and exactly what
  ``Session.idle()`` with no arguments always did.
- :class:`FixedKPrefetch` — calibration, then the legacy k-nearest σ
  prefetch.  ``speculate=k`` deprecation-shims onto this.
- :class:`PredictiveThinkTime` — ranks cube builds and prefetch candidates
  with a per-session :class:`BrushTrajectory` model (direction/dwell EWMAs,
  dimension-switch probability, next-viz prior with the crossfilter source
  viz first).

Config: every think-time knob resolves HERE, once, into a typed
:class:`ThinkTimeConfig` (pattern of ``kernels/costs.py``: env override wins,
cached, ``reset_think_time_config()`` for tests) — including the
``REPRO_CALIBRATION_UNION_BUDGET`` interplay: the default cube cell budget is
a multiple of the union-carry budget, because a bin cube IS a union-carry
message set whose widest factor carries the γ∪{d} product.
"""

from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import TYPE_CHECKING

from .plans import calibration_union_budget
from .query import Query

if TYPE_CHECKING:  # pragma: no cover — cycle guard (dashboard imports us)
    from .dashboard import Session, SetFilter


# ---------------------------------------------------------------------------
# Typed think-time config (the one place every speculation knob resolves)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ThinkTimeConfig:
    """All think-time/speculation knobs, resolved once with env overrides.

    ``union_budget`` is the resolved ``REPRO_CALIBRATION_UNION_BUDGET`` (env →
    roofline profile → static 512; see ``plans.calibration_union_budget``);
    ``cube_cell_budget`` defaults to ``32 ×`` that budget because the widest
    factor a cube build materializes carries the γ∪{dim} domain product —
    the same quantity the union budget bounds for shared calibration passes,
    minus the per-row ⊗-lane pressure (cubes absorb once, they don't carry
    lanes through the whole fact scan on every message).
    """

    prefetch_capacity: int = 128   # REPRO_PREFETCH_CAPACITY
    prefetch_k: int = 2            # REPRO_PREFETCH_K (predictive residual σ)
    bin_cubes: bool = True         # REPRO_BIN_CUBE (0 disables cube builds)
    cube_builds_per_idle: int = 4  # REPRO_BIN_CUBE_MAX_DIMS
    cube_capacity: int = 64        # REPRO_BIN_CUBE_CAPACITY (per session)
    cube_cell_budget: int = 16384  # REPRO_BIN_CUBE_CELLS (γ∪{dim} ∏ domains)
    union_budget: int = 512        # resolved REPRO_CALIBRATION_UNION_BUDGET


_UNSET = object()
_config_cache: object = _UNSET


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        return int(raw)
    except ValueError:  # pragma: no cover — malformed env
        return default


def _env_bool(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.lower() not in ("0", "false")


def think_time_config(refresh: bool = False) -> ThinkTimeConfig:
    """The resolved (and cached) think-time config; env overrides win."""
    global _config_cache
    if refresh or _config_cache is _UNSET:
        union = calibration_union_budget()
        _config_cache = ThinkTimeConfig(
            prefetch_capacity=_env_int("REPRO_PREFETCH_CAPACITY", 128),
            prefetch_k=_env_int("REPRO_PREFETCH_K", 2),
            bin_cubes=_env_bool("REPRO_BIN_CUBE", True),
            cube_builds_per_idle=_env_int("REPRO_BIN_CUBE_MAX_DIMS", 4),
            cube_capacity=_env_int("REPRO_BIN_CUBE_CAPACITY", 64),
            cube_cell_budget=_env_int("REPRO_BIN_CUBE_CELLS", 32 * union),
            union_budget=union,
        )
    return _config_cache


def reset_think_time_config() -> None:
    """Drop the cached config (tests that flip env knobs call this)."""
    global _config_cache
    _config_cache = _UNSET


# ---------------------------------------------------------------------------
# Deprecation shims (warn exactly once per process)
# ---------------------------------------------------------------------------

_warned: set[str] = set()


def warn_deprecated_once(key: str, message: str) -> None:
    """Emit ``DeprecationWarning`` the FIRST time ``key`` is seen.

    A dashboard session can call ``idle(speculate=k)`` thousands of times a
    minute; one warning is signal, thousands are noise.  Tests pin the
    exactly-once contract via :func:`reset_deprecation_warnings`.
    """
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


def reset_deprecation_warnings() -> None:
    _warned.clear()


# ---------------------------------------------------------------------------
# Budget
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ThinkTimeBudget:
    """One shared budget for a think-time tick.

    ``messages`` bounds calibration edges, ``seconds`` bounds wall time for
    the whole tick (calibration AND speculative work), ``viz`` optionally
    scopes the drain to one viz (the legacy ``Treant.think_time`` contract).
    """

    messages: int | None = None
    seconds: float | None = None
    viz: str | None = None

    def slack(self, t0: float, done_messages: int) -> bool:
        """Is there budget left after the calibration drain?"""
        if self.seconds is not None and time.perf_counter() - t0 >= self.seconds:
            return False
        if self.messages is not None and done_messages >= self.messages:
            return False
        return True

    def seconds_left(self, t0: float) -> bool:
        return (
            self.seconds is None
            or time.perf_counter() - t0 < self.seconds
        )


# ---------------------------------------------------------------------------
# Bin cubes (the parked per-dimension materializations)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _BinCube:
    """One parked γ∪{dim} aggregate for (viz, dim).

    ``query`` is the cube query (viz's derived query minus the σ on ``dim``,
    grouped by γ∪{dim}) — its digest is the park key, and ``Treant.update`` /
    ``flush`` use it to invalidate only cubes that can *see* a changed
    relation.  Unlike ``_Prefetched`` entries, a cube is NOT popped on a hit:
    it serves every subsequent σ on its dimension until invalidated.

    ``dims`` is the full set of brush dimensions this cube covers: when a
    dim is already in the viz's γ, several (viz, dim) targets collapse to
    the SAME cube query (identical digest), and eviction bookkeeping must
    not forget a covered dim just because a *different* cube that happened
    to share it was dropped.
    """

    factor: object
    query: Query
    dim: str
    viz: str
    nbytes: int = 0
    dims: set = dataclasses.field(default_factory=set)

    def __post_init__(self):
        self.dims.add(self.dim)


# ---------------------------------------------------------------------------
# Per-session brush-trajectory model
# ---------------------------------------------------------------------------

class BrushTrajectory:
    """Lightweight online model of one session's brush stream.

    Tracks, with EWMAs (decay ``alpha``):

    - ``direction``: per-attr signed brush drift (+1 = the σ window moves up
      the domain) — biases which σ-prefetch candidates run first;
    - ``dwell``: seconds between brushes — how much think-time a tick can
      expect (surfaced for introspection/benchmarks);
    - ``switch_prob``: probability the NEXT brush lands on a *different*
      dimension — ranks cube dimensions (low switch probability → the
      current dimension dominates);
    - attr/viz recency plus each dimension's last crossfilter source viz —
      the "which viz next" prior (the viz the user is brushing from first).
    """

    def __init__(self, alpha: float = 0.4):
        self.alpha = alpha
        self.direction: dict[str, float] = {}
        self.dwell: float = 0.0
        self.switch_prob: float = 0.5
        self.events: int = 0
        self.last: "SetFilter | None" = None
        self._last_t: float | None = None
        self._attr_recency: list[str] = []   # most recent LAST
        self._viz_recency: list[str] = []    # brush source vizzes, recent LAST
        self._source: dict[str, str | None] = {}

    @staticmethod
    def _anchor(ev: "SetFilter") -> int | None:
        if ev.values:
            return min(ev.values)
        return ev.lo

    def observe(self, ev: "SetFilter", now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        a = self.alpha
        prev = self.last
        if self._last_t is not None:
            gap = max(now - self._last_t, 0.0)
            self.dwell = gap if self.events <= 1 else (
                (1 - a) * self.dwell + a * gap
            )
        if prev is not None:
            switched = 1.0 if prev.attr != ev.attr else 0.0
            self.switch_prob = (1 - a) * self.switch_prob + a * switched
            if prev.attr == ev.attr:
                p0, p1 = self._anchor(prev), self._anchor(ev)
                if p0 is not None and p1 is not None and p1 != p0:
                    step = 1.0 if p1 > p0 else -1.0
                    cur = self.direction.get(ev.attr, 0.0)
                    self.direction[ev.attr] = (1 - a) * cur + a * step
        self.last = ev
        self._last_t = now
        self.events += 1
        if ev.attr in self._attr_recency:
            self._attr_recency.remove(ev.attr)
        self._attr_recency.append(ev.attr)
        self._source[ev.attr] = ev.source
        if ev.source is not None:
            if ev.source in self._viz_recency:
                self._viz_recency.remove(ev.source)
            self._viz_recency.append(ev.source)

    def forget(self, attr: str) -> None:
        """The user abandoned this dimension (ClearFilter): stop predicting
        around it, but keep it in the recency tail — backtracks are common."""
        if self.last is not None and self.last.attr == attr:
            self.last = None

    # -- predictions ----------------------------------------------------------
    def ranked_dims(self) -> list[str]:
        """Brush dimensions by predicted next-brush probability.

        The most recent dimension leads unless the switch probability says
        the user hops dimensions (then the *previous* dimension — the classic
        A/B crossfilter alternation — outranks it).  Older dimensions follow
        most-recent-first: exploratory backtracking revisits recent ground.
        """
        recent = list(reversed(self._attr_recency))
        if len(recent) >= 2 and self.switch_prob > 0.5:
            recent[0], recent[1] = recent[1], recent[0]
        return recent

    def ranked_vizzes(self, names: list[str]) -> list[str]:
        """``names`` reordered by the next-viz prior: crossfilter source
        vizzes of recent brushes first (most recent first), then the rest in
        the given order."""
        srcs = [v for v in reversed(self._viz_recency) if v in names]
        rest = [v for v in names if v not in srcs]
        return srcs + rest

    def source_of(self, attr: str) -> str | None:
        return self._source.get(attr)

    def next_filters(self, domain: int, k: int) -> list["SetFilter"]:
        """Up to ``k`` predicted next σ values for the last-brushed dim.

        The nearest-first alternating candidates of ``speculate_filters``
        reordered by the learned drift: with a positive direction EWMA the
        up-domain neighbors run first (ties keep nearest-first order), so a
        steadily advancing brush gets its next window prefetched at rank 0.
        """
        from .dashboard import speculate_filters  # local: import cycle

        ev = self.last
        if ev is None or k <= 0:
            return []
        cands = speculate_filters(ev, domain, 2 * k)
        drift = self.direction.get(ev.attr, 0.0)
        if abs(drift) > 1e-9:
            anchor = self._anchor(ev) or 0
            sign = 1.0 if drift > 0 else -1.0

            def key(item):
                rank, c = item
                pos = self._anchor(c)
                along = pos is not None and (pos - anchor) * sign > 0
                return (0 if along else 1, rank)

            cands = [c for _, c in sorted(enumerate(cands), key=key)]
        return cands[:k]

    def state(self) -> dict:
        return {
            "events": self.events,
            "dwell_ewma_s": round(self.dwell, 6),
            "switch_prob": round(self.switch_prob, 4),
            "direction": {a: round(v, 4) for a, v in self.direction.items()},
            "ranked_dims": self.ranked_dims(),
        }


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

class ThinkTimePolicy:
    """Base policy: drain pending calibration, then (subclass) extras.

    ``run`` is what ``Session.idle`` / ``Treant.think_time`` call with the
    whole budget; the server drains the shared scheduler once globally and
    calls :meth:`extras` per session instead (see ``TreantServer.idle``).
    Returns the number of calibration edges processed, preserving the legacy
    ``idle``/``think_time`` return contract.
    """

    name = "policy"

    def run(self, session: "Session", budget: ThinkTimeBudget) -> int:
        t0 = time.perf_counter()
        done = session.scheduler.run(
            budget_messages=budget.messages,
            budget_seconds=budget.seconds,
            session=session.id,
            viz=budget.viz,
        )
        if budget.slack(t0, done):
            self.extras(session, budget, t0)
        return done

    def extras(self, session: "Session", budget: ThinkTimeBudget,
               t0: float) -> None:
        """Speculative work after the calibration drain (default: none)."""


class DrainCalibration(ThinkTimePolicy):
    """Calibration only — the default policy, and exactly the behavior of
    ``Session.idle()`` with no speculation configured."""

    name = "drain"


class FixedKPrefetch(ThinkTimePolicy):
    """The legacy ``speculate=k`` heuristic as a policy: after the drain,
    pre-execute the fan-out for the k nearest neighbor σ values of the last
    brush.  ``Session.idle(speculate=k)`` and ``TreantServer(speculate=k)``
    deprecation-shim onto ``FixedKPrefetch(k)`` — bit-identical behavior."""

    name = "fixed_k"

    def __init__(self, k: int):
        self.k = int(k)

    def extras(self, session: "Session", budget: ThinkTimeBudget,
               t0: float) -> None:
        if self.k > 0:
            session._speculate(self.k)


class PredictiveThinkTime(ThinkTimePolicy):
    """Trajectory-ranked think-time: bin cubes first, then biased σ prefetch.

    Work items, in rank order (each consumes the shared ``seconds`` budget;
    every attempted item counts as one ``policy_decisions`` tick):

    1. **Bin cubes** for (viz, dim) pairs — dims by ``ranked_dims()`` (the
       dimension-switch EWMA), vizzes by ``ranked_vizzes()`` (crossfilter
       source vizzes first), skipping each dim's own source viz (its query
       never carries that σ) and anything over the cube cell budget.  At
       most ``cube_builds_per_idle`` builds per tick.
    2. **Residual σ prefetch** for the last-brushed dimension,
       direction-biased (``next_filters``), covering the cold gap while a
       cube is not (yet) buildable — e.g. the dimension blew the cell
       budget.

    With no brush history the policy degrades to :class:`DrainCalibration`
    exactly — ``idle()`` on a fresh session stays calibration-only.
    """

    name = "predictive"

    def __init__(
        self,
        cube_builds_per_idle: int | None = None,
        prefetch_k: int | None = None,
        config: ThinkTimeConfig | None = None,
    ):
        self._cube_builds = cube_builds_per_idle
        self._prefetch_k = prefetch_k
        self._config = config

    def config(self) -> ThinkTimeConfig:
        return self._config if self._config is not None else think_time_config()

    def cube_targets(self, session: "Session") -> list[tuple[str, str]]:
        """Ranked (viz, dim) cube candidates for this session."""
        traj = session.trajectory
        names = [
            n for n in sorted(session._views)
            if session._views[n].crossfilter
        ]
        out: list[tuple[str, str]] = []
        for dim in traj.ranked_dims():
            src = traj.source_of(dim)
            for viz in traj.ranked_vizzes(names):
                if viz != src:
                    out.append((viz, dim))
        return out

    def extras(self, session: "Session", budget: ThinkTimeBudget,
               t0: float) -> None:
        cfg = self.config()
        traj = session.trajectory
        if traj.last is None and not traj.ranked_dims():
            return
        decisions = 0
        if cfg.bin_cubes:
            cap = (
                self._cube_builds if self._cube_builds is not None
                else cfg.cube_builds_per_idle
            )
            built = 0
            for viz, dim in self.cube_targets(session):
                if built >= cap or not budget.seconds_left(t0):
                    break
                decisions += 1
                if session._build_bin_cube(viz, dim):
                    built += 1
        ev = traj.last
        k = self._prefetch_k if self._prefetch_k is not None else cfg.prefetch_k
        if ev is not None and k > 0 and budget.seconds_left(t0):
            doms = session.catalog.domains()
            if ev.attr in doms:
                cands = traj.next_filters(doms[ev.attr], k)
                if cands:
                    decisions += 1
                    session._speculate_candidates(ev, cands)
        session.scheduler.policy_decisions += decisions
