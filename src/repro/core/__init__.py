# The paper's primary contribution: Calibrated Junction Hypertrees (CJT) and
# the Treant dashboard accelerator, re-hosted as TPU-native JAX.
from . import semiring  # noqa: F401
from .factor import Factor, contract, brute_force_join_aggregate, ones_factor  # noqa: F401
from .hypertree import (  # noqa: F401
    JTree, build_join_tree, jt_from_catalog, insert_empty_bag, attach_relation,
    is_acyclic, CyclicSchemaError,
)
from .query import Query  # noqa: F401
from .calibration import (  # noqa: F401
    CalibrationPlan, CJTEngine, MessageStore, ExecStats, DeltaStats,
)
from .plans import PlanCache, PlanStats  # noqa: F401
from .predictive import (  # noqa: F401
    BrushTrajectory, DrainCalibration, FixedKPrefetch, PredictiveThinkTime,
    ThinkTimeBudget, ThinkTimeConfig, ThinkTimePolicy,
    reset_deprecation_warnings, reset_think_time_config, think_time_config,
)
from .dashboard import (  # noqa: F401
    ApplyResult, ClearFilter, DashboardSpec, Drill, InteractionResult,
    Rollup, Session, SetFilter, SwapMeasure, ThinkTimeScheduler,
    ToggleRelation, Undo, VizSpec, speculate_filters,
)
from .treant import FlushResult, IngestStats, Treant, UpdateResult  # noqa: F401
from . import steiner  # noqa: F401
from .ml import FactorizedLinearRegression, FeatureSpec, FitResult  # noqa: F401
from .cube import build_cube, naive_cube_cost, CubeReport  # noqa: F401
