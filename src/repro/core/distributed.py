"""Distributed CJT message passing with shard_map (multi-pod posture).

The paper runs message passing as SQL against a DBMS cluster; on a TPU pod
the natural mapping is domain sharding: each factor/message is sharded along
one attribute's domain, and

  - **forward** (upward) messages marginalize the *sharded* attribute →
    local partial contraction + ``psum_scatter`` (a reduce-scatter per edge);
  - **backward** (downward/calibration) messages marginalize the *replicated*
    attribute → ``all_gather`` + local contraction.

So a full calibration pass over a chain of r factors costs exactly r-1
reduce-scatters + r-1 all-gathers over the mesh axis — the collective
schedule reported in EXPERIMENTS.md §Dry-run for the ``treant_dashboard``
config.  Messages stay sharded end-to-end; nothing materializes the join.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax moved shard_map out of experimental around 0.6; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


def calibrate_chain_reference(factors: list[jax.Array]) -> tuple[list, list]:
    """Single-device oracle: forward/backward messages of a chain CJT.

    factors[i]: (d_i, d_{i+1}) arithmetic-ring factor between A_i and A_{i+1}.
    Returns (fwd, bwd): fwd[i] over A_{i+1} (message bag_i→bag_{i+1}),
    bwd[i] over A_{i+1} (message bag_{i+1}→bag_i).
    """
    r = len(factors)
    fwd, bwd = [None] * (r - 1), [None] * (r - 1)
    m = jnp.ones((factors[0].shape[0],), factors[0].dtype)
    for i in range(r - 1):
        m = m @ factors[i]              # Σ_{A_i} F_i ⊗ m   → over A_{i+1}
        fwd[i] = m
    m = jnp.ones((factors[-1].shape[1],), factors[0].dtype)
    for i in range(r - 2, -1, -1):
        m = factors[i + 1] @ m          # Σ_{A_{i+2}} F_{i+1} ⊗ m → over A_{i+1}
        bwd[i] = m
    return fwd, bwd


def chain_absorptions_reference(factors, fwd, bwd):
    """Absorption at every bag: the calibrated per-bag views."""
    r = len(factors)
    out = []
    for i in range(r):
        f = factors[i]
        if i > 0:
            f = f * fwd[i - 1][:, None]
        if i < r - 1:
            f = f * bwd[i][None, :]
        out.append(f)
    return out


def make_chain_calibrate(mesh: Mesh, axis: str, r: int, d: int, dtype=jnp.float32):
    """Build a jitted sharded calibration fn for a chain of r (d,d) factors.

    Sharding: factor i is (A_i sharded, A_{i+1} replicated); every message is
    sharded along its own attribute.
    """
    n = mesh.shape[axis]
    assert d % n == 0, f"domain {d} not divisible by mesh axis {n}"

    def _local(factors):
        # factors: list of local blocks (d/n, d)
        fwd = []
        m = jnp.ones((d // n,), dtype)
        for i in range(r - 1):
            partial_msg = m @ factors[i]                       # (d,) partial over local A_i rows
            m = jax.lax.psum_scatter(
                partial_msg, axis, scatter_dimension=0, tiled=True
            )                                                   # (d/n,) over A_{i+1}
            fwd.append(m)
        bwd = []
        m = jnp.ones((d // n,), dtype)
        for i in range(r - 2, -1, -1):
            full = jax.lax.all_gather(m, axis, tiled=True)      # (d,) over A_{i+2}
            m = factors[i + 1] @ full                           # (d/n,) over A_{i+1}
            bwd.append(m)
        bwd = bwd[::-1]
        # total-count absorption at bag 0 (scalar sanity output)
        full_b = jax.lax.all_gather(bwd[0], axis, tiled=True) if r > 1 else None
        f0 = factors[0]
        total_local = (
            jnp.sum(f0 @ full_b) if full_b is not None else jnp.sum(f0)
        )
        total = jax.lax.psum(total_local, axis)
        return fwd, bwd, total

    shard = shard_spec = P(axis, None)
    msg_spec = P(axis)
    fn = _shard_map(
        _local,
        mesh=mesh,
        in_specs=([shard_spec] * r,),
        out_specs=([msg_spec] * (r - 1), [msg_spec] * (r - 1), P()),
    )
    return jax.jit(fn)


def make_chain_calibrate_multi(mesh: Mesh, axis: str, r: int, d: int,
                               n_measures: int, dtype=jnp.float32):
    """Beyond-paper: fuse V measure semirings into ONE calibration pass.

    The paper materializes messages per aggregate (one SPJA query each).
    Stacking the V annotation columns turns every message matvec into a
    (d/n, d)×(d, V) matmul: factors are read from HBM once instead of V
    times (memory term ÷V) and the MXU gets a real contraction.  Messages
    and collectives carry (d/n, V) blocks.

    Factor annotations: (d/n, d) structural counts shared by all measures;
    per-measure leaf annotations enter at bag 0 as a (d/n, V) block.
    """
    n = mesh.shape[axis]
    assert d % n == 0

    def _local(factors, leaf_vals):
        fwd = []
        m = leaf_vals                                        # (d/n, V)
        for i in range(r - 1):
            partial_msg = jnp.einsum("kv,kd->dv", m, factors[i])
            m = jax.lax.psum_scatter(partial_msg, axis, scatter_dimension=0, tiled=True)
            fwd.append(m)                                    # (d/n, V)
        bwd = []
        m = jnp.ones((d // n, n_measures), dtype)
        for i in range(r - 2, -1, -1):
            full = jax.lax.all_gather(m, axis, tiled=True)   # (d, V)
            m = factors[i + 1] @ full                        # (d/n, V)
            bwd.append(m)
        bwd = bwd[::-1]
        # absorption at the last bag: ⊕ over its own factor too
        total_local = jnp.einsum("kv,k->v", fwd[-1], factors[-1].sum(axis=1))
        totals = jax.lax.psum(total_local, axis)
        return fwd, bwd, totals

    msg_spec = P(axis, None)
    fn = _shard_map(
        _local,
        mesh=mesh,
        in_specs=([P(axis, None)] * r, P(axis, None)),
        out_specs=([msg_spec] * (r - 1), [msg_spec] * (r - 1), P()),
    )
    return jax.jit(fn)


def chain_multi_specs(mesh: Mesh, axis: str, r: int, d: int, n_measures: int,
                      dtype=jnp.float32):
    sh = NamedSharding(mesh, P(axis, None))
    factors = [jax.ShapeDtypeStruct((d, d), dtype, sharding=sh) for _ in range(r)]
    leaf = jax.ShapeDtypeStruct((d, n_measures), dtype, sharding=sh)
    return factors, leaf


def place_chain_factors(mesh: Mesh, axis: str, factors_np: list[np.ndarray]):
    sh = NamedSharding(mesh, P(axis, None))
    return [jax.device_put(jnp.asarray(f), sh) for f in factors_np]


def chain_factor_specs(mesh: Mesh, axis: str, r: int, d: int, dtype=jnp.float32):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    sh = NamedSharding(mesh, P(axis, None))
    return [jax.ShapeDtypeStruct((d, d), dtype, sharding=sh) for _ in range(r)]
