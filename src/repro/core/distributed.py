"""Engine-facing sharding layer for CJT execution over a device mesh.

Row sharding (the engine path)
------------------------------
Semiring ⊕ is associative, so a bag contraction row-shards cleanly: split
the fact relation's rows across a 1-D mesh axis (dimension relations and
incoming γ-indexed messages stay replicated), run the rowwise lift →
σ-mask → ``segment_aggregate`` pipeline per shard, and ⊕-all-reduce the
γ-indexed partials — ``psum`` for rings with leafwise + (SUM/COUNT/
MOMENTS), ``pmin``/``pmax`` for the tropical rings.  Every cross-shard
message is a tiny ``(|γ|, V)`` collective; nothing ever materializes a
join.  :mod:`repro.core.plans` builds the sharded plans; this module owns
mesh acquisition, the ring → collective mapping, and the row placement
helpers.

All acquisition is lazy: importing this module must never touch devices
(CI hosts without a mesh import it fine), and ``shard_map`` is resolved on
first use to absorb the jax API drift (moved out of ``experimental``
around 0.6; ``check_rep`` renamed to ``check_vma``).

Domain sharding (chain demo)
----------------------------
The original seed demo below shards factors along one attribute's *domain*
instead: forward messages marginalize the sharded attribute (local partial
contraction + ``psum_scatter``), backward messages marginalize the
replicated one (``all_gather`` + local contraction) — r-1 reduce-scatters
+ r-1 all-gathers per calibration pass over a chain of r factors.  It is
kept as a collective-schedule reference; the engine uses row sharding.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Name of the 1-D mesh axis the engine row-shards over.
SHARD_AXIS = "shard"

# Lazily-resolved shard_map entry point (jax moved it out of experimental
# around 0.6; resolving at import time would pin the API before user code
# can configure the platform).
_shard_map_fn = None


def _resolve_shard_map():
    global _shard_map_fn
    if _shard_map_fn is None:
        fn = getattr(jax, "shard_map", None)
        if fn is None:
            from jax.experimental.shard_map import shard_map as fn
        _shard_map_fn = fn
    return _shard_map_fn


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions.

    The engine's local bodies run interpret-mode Pallas kernels, for which
    jax has no replication rule — ``check_rep=False`` (``check_vma`` on
    newer jax) is required, and is sound here because every output is
    ⊕-all-reduced before it leaves the local body.
    """
    sm = _resolve_shard_map()
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    except TypeError:  # jax ≥ 0.6 renamed the kwarg
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)


def shard_devices() -> int:
    """Mesh width requested via ``REPRO_SHARD_DEVICES`` (0/1/unset → off)."""
    try:
        n = int(os.environ.get("REPRO_SHARD_DEVICES", "0"))
    except ValueError:
        return 0
    return n if n > 1 else 0


def make_engine_mesh(devices: int | None = None) -> Mesh | None:
    """Lazily build the engine's 1-D row-shard mesh, or ``None`` when off.

    ``devices=None`` reads ``REPRO_SHARD_DEVICES``.  Returns ``None`` (run
    unsharded) rather than raising when the host cannot provide the
    devices — CI supplies them via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    n = shard_devices() if devices is None else int(devices)
    if n <= 1:
        return None
    try:
        if jax.device_count() < n:
            return None
    except RuntimeError:  # backend init failed — run unsharded
        return None
    from repro.runtime.compat import make_mesh

    return make_mesh((n,), (SHARD_AXIS,))


def ring_collective(ring):
    """⊕-all-reduce for a ring's γ-indexed partials, or ``None``.

    ``None`` means the ring's ⊕ has no mesh collective here (BOOL: ⊕ = ∨)
    and callers must fall back to the unsharded plan.
    """
    op = getattr(ring, "kernel_segment_op", None)
    if op == "min":
        return jax.lax.pmin
    if op == "max":
        return jax.lax.pmax
    if op == "sum" or getattr(ring, "has_add_inverse", False):
        return jax.lax.psum
    return None


def allreduce_field(field, collective, axis: str = SHARD_AXIS):
    """⊕-all-reduce every leaf of a field/Factor pytree over ``axis``."""
    return jax.tree_util.tree_map(lambda leaf: collective(leaf, axis), field)


def row_placement(mesh: Mesh, axis: str = SHARD_AXIS) -> NamedSharding:
    """Sharding that splits leading-axis rows across the mesh (rest replicated)."""
    return NamedSharding(mesh, P(axis))


def place_rows(field, mesh: Mesh, axis: str = SHARD_AXIS):
    """Commit every leaf of a row-major pytree to the row-shard placement.

    Pre-placing cached row arrays (flat codes, padded lifts) means jit'd
    sharded plans consume them without a per-dispatch reshard copy.
    """
    sh = row_placement(mesh, axis)
    return jax.tree_util.tree_map(lambda leaf: jax.device_put(leaf, sh), field)


def shard_imbalance(num_rows: int, bucket: int, nshards: int) -> float:
    """Max valid rows per shard / ideal per-shard rows (≥ 1.0 when nonempty).

    Rows are packed low (pad rows carry the ⊕-identity at the top of the
    bucket), so the fullest shard is the first block.
    """
    if nshards <= 1 or num_rows <= 0:
        return 1.0 if num_rows > 0 else 0.0
    block = bucket // nshards
    return min(block, num_rows) * nshards / num_rows


# --------------------------------------------------------------------------
# Domain-sharded chain demo (seed reference; see module docstring)
# --------------------------------------------------------------------------


def calibrate_chain_reference(factors: list[jax.Array]) -> tuple[list, list]:
    """Single-device oracle: forward/backward messages of a chain CJT.

    factors[i]: (d_i, d_{i+1}) arithmetic-ring factor between A_i and A_{i+1}.
    Returns (fwd, bwd): fwd[i] over A_{i+1} (message bag_i→bag_{i+1}),
    bwd[i] over A_{i+1} (message bag_{i+1}→bag_i).
    """
    r = len(factors)
    fwd, bwd = [None] * (r - 1), [None] * (r - 1)
    m = jnp.ones((factors[0].shape[0],), factors[0].dtype)
    for i in range(r - 1):
        m = m @ factors[i]              # Σ_{A_i} F_i ⊗ m   → over A_{i+1}
        fwd[i] = m
    m = jnp.ones((factors[-1].shape[1],), factors[0].dtype)
    for i in range(r - 2, -1, -1):
        m = factors[i + 1] @ m          # Σ_{A_{i+2}} F_{i+1} ⊗ m → over A_{i+1}
        bwd[i] = m
    return fwd, bwd


def chain_absorptions_reference(factors, fwd, bwd):
    """Absorption at every bag: the calibrated per-bag views."""
    r = len(factors)
    out = []
    for i in range(r):
        f = factors[i]
        if i > 0:
            f = f * fwd[i - 1][:, None]
        if i < r - 1:
            f = f * bwd[i][None, :]
        out.append(f)
    return out


def make_chain_calibrate(mesh: Mesh, axis: str, r: int, d: int, dtype=jnp.float32):
    """Build a jitted sharded calibration fn for a chain of r (d,d) factors.

    Sharding: factor i is (A_i sharded, A_{i+1} replicated); every message is
    sharded along its own attribute.
    """
    n = mesh.shape[axis]
    assert d % n == 0, f"domain {d} not divisible by mesh axis {n}"

    def _local(factors):
        # factors: list of local blocks (d/n, d)
        fwd = []
        m = jnp.ones((d // n,), dtype)
        for i in range(r - 1):
            partial_msg = m @ factors[i]                       # (d,) partial over local A_i rows
            m = jax.lax.psum_scatter(
                partial_msg, axis, scatter_dimension=0, tiled=True
            )                                                   # (d/n,) over A_{i+1}
            fwd.append(m)
        bwd = []
        m = jnp.ones((d // n,), dtype)
        for i in range(r - 2, -1, -1):
            full = jax.lax.all_gather(m, axis, tiled=True)      # (d,) over A_{i+2}
            m = factors[i + 1] @ full                           # (d/n,) over A_{i+1}
            bwd.append(m)
        bwd = bwd[::-1]
        # total-count absorption at bag 0 (scalar sanity output)
        full_b = jax.lax.all_gather(bwd[0], axis, tiled=True) if r > 1 else None
        f0 = factors[0]
        total_local = (
            jnp.sum(f0 @ full_b) if full_b is not None else jnp.sum(f0)
        )
        total = jax.lax.psum(total_local, axis)
        return fwd, bwd, total

    shard_spec = P(axis, None)
    msg_spec = P(axis)
    fn = _resolve_shard_map()(
        _local,
        mesh=mesh,
        in_specs=([shard_spec] * r,),
        out_specs=([msg_spec] * (r - 1), [msg_spec] * (r - 1), P()),
    )
    return jax.jit(fn)


def make_chain_calibrate_multi(mesh: Mesh, axis: str, r: int, d: int,
                               n_measures: int, dtype=jnp.float32):
    """Beyond-paper: fuse V measure semirings into ONE calibration pass.

    The paper materializes messages per aggregate (one SPJA query each).
    Stacking the V annotation columns turns every message matvec into a
    (d/n, d)×(d, V) matmul: factors are read from HBM once instead of V
    times (memory term ÷V) and the MXU gets a real contraction.  Messages
    and collectives carry (d/n, V) blocks.

    Factor annotations: (d/n, d) structural counts shared by all measures;
    per-measure leaf annotations enter at bag 0 as a (d/n, V) block.
    """
    n = mesh.shape[axis]
    assert d % n == 0

    def _local(factors, leaf_vals):
        fwd = []
        m = leaf_vals                                        # (d/n, V)
        for i in range(r - 1):
            partial_msg = jnp.einsum("kv,kd->dv", m, factors[i])
            m = jax.lax.psum_scatter(partial_msg, axis, scatter_dimension=0, tiled=True)
            fwd.append(m)                                    # (d/n, V)
        bwd = []
        m = jnp.ones((d // n, n_measures), dtype)
        for i in range(r - 2, -1, -1):
            full = jax.lax.all_gather(m, axis, tiled=True)   # (d, V)
            m = factors[i + 1] @ full                        # (d/n, V)
            bwd.append(m)
        bwd = bwd[::-1]
        # absorption at the last bag: ⊕ over its own factor too
        total_local = jnp.einsum("kv,k->v", fwd[-1], factors[-1].sum(axis=1))
        totals = jax.lax.psum(total_local, axis)
        return fwd, bwd, totals

    msg_spec = P(axis, None)
    fn = _resolve_shard_map()(
        _local,
        mesh=mesh,
        in_specs=([P(axis, None)] * r, P(axis, None)),
        out_specs=([msg_spec] * (r - 1), [msg_spec] * (r - 1), P()),
    )
    return jax.jit(fn)


def chain_multi_specs(mesh: Mesh, axis: str, r: int, d: int, n_measures: int,
                      dtype=jnp.float32):
    sh = NamedSharding(mesh, P(axis, None))
    factors = [jax.ShapeDtypeStruct((d, d), dtype, sharding=sh) for _ in range(r)]
    leaf = jax.ShapeDtypeStruct((d, n_measures), dtype, sharding=sh)
    return factors, leaf


def place_chain_factors(mesh: Mesh, axis: str, factors_np: list[np.ndarray]):
    sh = NamedSharding(mesh, P(axis, None))
    return [jax.device_put(jnp.asarray(f), sh) for f in factors_np]


def chain_factor_specs(mesh: Mesh, axis: str, r: int, d: int, dtype=jnp.float32):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    sh = NamedSharding(mesh, P(axis, None))
    return [jax.ShapeDtypeStruct((d, d), dtype, sharding=sh) for _ in range(r)]
