"""Dense semiring factors — the TPU-native form of annotated relations.

A :class:`Factor` is the dense counterpart of the paper's annotated relation:
for categorical attributes A1..Am with domain sizes d1..dm it stores a
semiring field over the full domain product (shape (d1,..,dm) plus any
trailing statistic dims of the ring).  Join ≙ pointwise ⊗ after broadcast
alignment; group-by ≙ ⊕-reduction over marginalized axes — exactly equations
(1)/(2) of the paper, vectorized.

``contract`` implements early marginalization / variable elimination (§2):
for arithmetic rings it lowers the whole elimination to a single
``jnp.einsum`` (MXU matmuls on TPU; the ``semiring_contract`` Pallas kernel
covers the 2-factor hot path); for non-arithmetic rings (tropical, bool,
compound) it runs a greedy elimination with pointwise ⊗ / ⊕-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import semiring as sr


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Factor:
    attrs: tuple[str, ...]
    field: sr.Field
    ring: sr.Semiring

    # -- pytree plumbing (ring/attrs are static) ---------------------------
    def tree_flatten(self):
        return (self.field,), (self.attrs, self.ring)

    @classmethod
    def tree_unflatten(cls, aux, children):
        attrs, ring = aux
        return cls(attrs=attrs, field=children[0], ring=ring)

    # -- structure ----------------------------------------------------------
    @property
    def domain_shape(self) -> tuple[int, ...]:
        return self.ring.domain_shape(self.field)

    @property
    def domains(self) -> dict[str, int]:
        return dict(zip(self.attrs, self.domain_shape))

    def axis(self, attr: str) -> int:
        return self.attrs.index(attr)

    def __repr__(self):  # pragma: no cover
        doms = ",".join(f"{a}:{d}" for a, d in self.domains.items())
        return f"Factor[{self.ring.name}]({doms})"

    # -- algebra -------------------------------------------------------------
    def align_to(self, attrs: tuple[str, ...], domains: Mapping[str, int]) -> "Factor":
        """Broadcast this factor's field into the given attr ordering."""
        out_shape = tuple(domains[a] for a in attrs)
        src_axes = tuple(attrs.index(a) for a in self.attrs)
        field = self.ring.expand_field(self.field, src_axes, out_shape)
        return Factor(attrs, field, self.ring)

    def product(self, other: "Factor") -> "Factor":
        assert self.ring.name == other.ring.name, "ring mismatch"
        doms = {**self.domains, **other.domains}
        for a in set(self.attrs) & set(other.attrs):
            if self.domains[a] != other.domains[a]:
                raise ValueError(f"domain mismatch on {a}")
        attrs = tuple(dict.fromkeys(self.attrs + other.attrs))
        a = self.align_to(attrs, doms)
        b = other.align_to(attrs, doms)
        return Factor(attrs, self.ring.mul(a.field, b.field), self.ring)

    def marginalize(self, drop: Iterable[str]) -> "Factor":
        drop = [a for a in drop if a in self.attrs]
        if not drop:
            return self
        axes = tuple(sorted(self.attrs.index(a) for a in drop))
        keep = tuple(a for a in self.attrs if a not in drop)
        return Factor(keep, self.ring.add_reduce(self.field, axes), self.ring)

    def project_to(self, keep: Sequence[str]) -> "Factor":
        out = self.marginalize([a for a in self.attrs if a not in set(keep)])
        # reorder to requested order
        keep = tuple(a for a in keep if a in out.attrs)
        if keep == out.attrs:
            return out
        perm = tuple(out.attrs.index(a) for a in keep)
        tperm = lambda leaf, t: jnp.transpose(
            leaf, perm + tuple(range(len(out.attrs), len(out.attrs) + t))
        )
        leaves, treedef = jax.tree_util.tree_flatten(out.field)
        field = jax.tree_util.tree_unflatten(
            treedef, [tperm(l, t) for l, t in zip(leaves, out.ring.trailing)]
        )
        return Factor(keep, field, out.ring)

    def select(self, attr: str, mask: jax.Array) -> "Factor":
        """Apply a predicate as a 0/1 domain mask (σ annotation).

        Uses ``where(mask, x, 0̄)`` so it is ring-agnostic (the paper applies σ
        by zero-annotating non-matching tuples, footnote 3).
        """
        ax = self.axis(attr)
        nd = len(self.attrs)
        mshape = [1] * nd
        mshape[ax] = mask.shape[0]
        m = mask.reshape(mshape)
        zeros = self.ring.zeros(self.domain_shape)
        leaves, treedef = jax.tree_util.tree_flatten(self.field)
        zleaves = jax.tree_util.tree_leaves(zeros)
        out = []
        for leaf, zleaf, t in zip(leaves, zleaves, self.ring.trailing):
            mm = m.reshape(mshape + [1] * t)
            out.append(jnp.where(mm, leaf, zleaf))
        return Factor(self.attrs, jax.tree_util.tree_unflatten(treedef, out), self.ring)

    def add(self, other: "Factor") -> "Factor":
        other = other.project_to(self.attrs)
        return Factor(self.attrs, self.ring.add(self.field, other.field), self.ring)

    def scalar(self):
        assert not self.attrs, f"not fully marginalized: {self.attrs}"
        return self.field


def ones_factor(ring: sr.Semiring, attrs: tuple[str, ...], domains: Mapping[str, int]) -> Factor:
    """The identity relation 𝕀 over the given attrs (paper §3.2, empty bags)."""
    return Factor(attrs, ring.ones(tuple(domains[a] for a in attrs)), ring)


# ---------------------------------------------------------------------------
# Contraction (early marginalization / variable elimination)
# ---------------------------------------------------------------------------

_EINSUM_ALPHABET = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"


def _einsum_contract(factors: Sequence[Factor], keep: tuple[str, ...], ring) -> Factor:
    all_attrs = tuple(dict.fromkeys(a for f in factors for a in f.attrs))
    if len(all_attrs) > len(_EINSUM_ALPHABET):  # pragma: no cover
        raise ValueError("too many attributes for einsum path")
    sym = {a: _EINSUM_ALPHABET[i] for i, a in enumerate(all_attrs)}
    keep = tuple(a for a in keep if a in all_attrs)
    sub = ",".join("".join(sym[a] for a in f.attrs) for f in factors)
    sub += "->" + "".join(sym[a] for a in keep)
    field = jnp.einsum(sub, *[f.field for f in factors], optimize=True)
    return Factor(keep, field, ring)


def _generic_contract(factors: list[Factor], keep: tuple[str, ...], ring) -> Factor:
    """Greedy variable elimination with pointwise ⊗ and ⊕-reduce (§2, Ex. 3)."""
    keep_set = set(keep)
    factors = list(factors)
    elim = [a for f in factors for a in f.attrs if a not in keep_set]
    # eliminate cheapest-degree attrs first (min-fill-lite heuristic)
    elim = sorted(dict.fromkeys(elim), key=lambda a: sum(a in f.attrs for f in factors))
    for attr in elim:
        cluster = [f for f in factors if attr in f.attrs]
        rest = [f for f in factors if attr not in f.attrs]
        prod = cluster[0]
        for f in cluster[1:]:
            prod = prod.product(f)
        factors = rest + [prod.marginalize([attr])]
    out = factors[0]
    for f in factors[1:]:
        out = out.product(f)
    return out.project_to(keep)


def contract(
    factors: Sequence[Factor],
    keep: Sequence[str],
    ring: sr.Semiring | None = None,
) -> Factor:
    """⊕-marginalize the ⊗-product of ``factors`` down to ``keep`` attrs.

    This is the message/absorption primitive: every CJT message is
    ``contract(bag relations + incoming messages, separator ∪ carried γ)``.
    """
    factors = list(factors)
    assert factors, "empty contraction"
    ring = ring or factors[0].ring
    keep = tuple(dict.fromkeys(keep))
    if ring.is_arithmetic and len(ring.trailing) == 1:
        return _einsum_contract(factors, keep, ring)
    return _generic_contract(factors, keep, ring)


def brute_force_join_aggregate(
    factors: Sequence[Factor], keep: Sequence[str], ring: sr.Semiring | None = None
) -> Factor:
    """Oracle: materialize the full ⊗-join, then ⊕-reduce (paper Fig 2c).

    Exponential in the number of attributes — tests only.
    """
    factors = list(factors)
    ring = ring or factors[0].ring
    full = factors[0]
    for f in factors[1:]:
        full = full.product(f)
    return full.project_to(tuple(dict.fromkeys(keep)))
