"""Factorized ML over CJTs (paper §4.3): linear regression via the covariance
semiring, plus 2-bag augmentation.

Training a linear model over a join is one semiring aggregation: lift each
relation's local features into the covariance ring (c, s, Q), message-pass to
a scalar element, and solve the normal equations from Q.  Augmenting with a
relation r(key, v) attaches a new bag at a bag containing ``key`` and uses r
as the message-passing root — the Steiner tree is exactly {host, r}, so every
base message is reused and each candidate costs ONE message (the paper's 10×
over per-model factorized retraining).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.relational.relation import Catalog, Relation
from . import semiring as sr
from .calibration import CJTEngine, ExecStats, MessageStore
from .hypertree import JTree, attach_relation, jt_from_catalog
from .query import Query


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    relation: str
    column: str              # measure column, or attr name if categorical
    categorical: bool = False

    def slots(self, catalog: Catalog) -> int:
        if not self.categorical:
            return 1
        return catalog.get(self.relation).domains[self.column]

    @property
    def tag(self) -> str:
        return f"{self.relation}.{self.column}{'#cat' if self.categorical else ''}"


@dataclasses.dataclass
class FitResult:
    weights: np.ndarray
    r2: float
    sse: float
    sst: float
    stats: ExecStats


class FactorizedLinearRegression:
    """Ridge linear regression over an acyclic join, factorized via CJT.

    Feature layout in the covariance ring: [intercept, features..., aug_slot,
    target].  ``aug_slot`` is reserved so every augmentation candidate shares
    the ring (and therefore the message signatures) of the base model.
    """

    def __init__(
        self,
        catalog: Catalog,
        features: Sequence[FeatureSpec],
        target: FeatureSpec,
        jt: JTree | None = None,
        ridge: float = 1e-3,
        store: MessageStore | None = None,
    ):
        self.catalog = catalog
        self.jt = jt or jt_from_catalog(catalog)
        self.features = list(features)
        self.target = target
        self.ridge = ridge
        # global slot layout
        self.slot_of: dict[str, tuple[int, int]] = {}
        idx = 0
        self.slot_of["__intercept__"] = (idx, idx + 1); idx += 1
        for f in self.features:
            n = f.slots(catalog)
            self.slot_of[f.tag] = (idx, idx + n); idx += n
        self.slot_of["__aug__"] = (idx, idx + 1); idx += 1
        self.slot_of["__target__"] = (idx, idx + 1); idx += 1
        self.k = idx
        self.ring = sr.make_covariance_ring(self.k)
        self.store = store if store is not None else MessageStore()
        self.lift_tag = hashlib.sha1(
            ("|".join(sorted(self.slot_of)) + f"k={self.k}").encode()
        ).hexdigest()[:12]
        self.engine = CJTEngine(
            self.jt, catalog, self.ring,
            lifts={n: self._make_lift(n) for n in catalog.names()},
            store=self.store,
        )

    # -- lifting -----------------------------------------------------------------
    def _relation_features(self, rel_name: str) -> list[tuple[FeatureSpec, tuple[int, int]]]:
        out = []
        for f in self.features:
            if f.relation == rel_name:
                out.append((f, self.slot_of[f.tag]))
        return out

    def _make_lift(self, rel_name: str):
        feats = self._relation_features(rel_name)
        is_target_rel = self.target.relation == rel_name
        is_intercept_rel = is_target_rel  # intercept rides on the target relation
        t_lo, _ = self.slot_of["__target__"]
        i_lo, _ = self.slot_of["__intercept__"]
        k = self.k

        def lift(rel: Relation) -> sr.Field:
            n = rel.num_rows
            s = np.zeros((n, k), np.float32)
            if is_intercept_rel:
                s[:, i_lo] = 1.0
            for spec, (lo, hi) in feats:
                if spec.categorical:
                    codes = rel.codes[spec.column]
                    s[np.arange(n), lo + codes] = 1.0
                else:
                    s[:, lo] = rel.measures[spec.column]
            if is_target_rel:
                s[:, t_lo] = rel.measures[self.target.column]
            sj = jnp.asarray(s)
            c = jnp.ones((n,), jnp.float32)
            q = sj[:, :, None] * sj[:, None, :]
            return (c, sj, q)

        return lift

    def _aug_lift(self, column: str):
        a_lo, _ = self.slot_of["__aug__"]
        k = self.k

        def lift(rel: Relation) -> sr.Field:
            n = rel.num_rows
            s = np.zeros((n, k), np.float32)
            s[:, a_lo] = rel.measures[column]
            sj = jnp.asarray(s)
            c = jnp.ones((n,), jnp.float32)
            q = sj[:, :, None] * sj[:, None, :]
            return (c, sj, q)

        return lift

    # -- solving ------------------------------------------------------------------
    def _feature_slots(self, with_aug: bool) -> list[int]:
        idx = list(range(*self.slot_of["__intercept__"]))
        for f in self.features:
            idx.extend(range(*self.slot_of[f.tag]))
        if with_aug:
            idx.extend(range(*self.slot_of["__aug__"]))
        return idx

    def _solve(self, element, with_aug: bool, stats: ExecStats) -> FitResult:
        c, s, q = [np.asarray(x, np.float64) for x in element]
        t = self.slot_of["__target__"][0]
        F = self._feature_slots(with_aug)
        A = q[np.ix_(F, F)] + self.ridge * np.eye(len(F))
        b = q[F, t]
        w = np.linalg.solve(A, b)
        sse = float(q[t, t] - 2.0 * w @ b + w @ (q[np.ix_(F, F)] @ w))
        sst = float(q[t, t] - (s[t] ** 2) / max(c, 1.0))
        r2 = 1.0 - sse / max(sst, 1e-12)
        return FitResult(weights=w, r2=r2, sse=sse, sst=sst, stats=stats)

    def _base_query(self, catalog: Catalog | None = None) -> Query:
        return Query.make(
            catalog or self.catalog, ring=self.ring.name, lift_tag=self.lift_tag
        )

    def fit(self) -> FitResult:
        q = self._base_query()
        factor, stats = self.engine.execute(q)
        return self._solve(factor.field, with_aug=False, stats=stats)

    def calibrate(self) -> ExecStats:
        """Calibrate the base CJT so augmentations become single-message."""
        return self.engine.calibrate(self._base_query(), pin=True)

    # -- augmentation (§4.3, Fig 11) --------------------------------------------------
    def fit_augmented(self, aug: Relation, column: str = "v") -> FitResult:
        """Join a candidate augmentation relation and refit.

        Builds JT' = JT + bag(aug) attached at a host covering the join key,
        roots message passing at the new bag; all base messages are reused
        via the shared store.
        """
        jt2, bag = attach_relation(self.jt, aug.name, aug.attrs, aug.domains)
        cat2 = Catalog([self.catalog.get(n) for n in self.catalog.names()] + [aug])
        lifts = {n: self._make_lift(n) for n in self.catalog.names()}
        lifts[aug.name] = self._aug_lift(column)
        eng2 = CJTEngine(jt2, cat2, self.ring, lifts=lifts, store=self.store)
        q = self._base_query(cat2)
        stats = ExecStats()
        factor = eng2.absorb(q, bag, stats=stats)
        return self._solve(factor.field, with_aug=True, stats=stats)

    def fit_unfactorized_baseline(self, aug: Relation | None = None, column: str = "v") -> FitResult:
        """``Fac`` baseline: full message passing with a cold store each time."""
        if aug is None:
            eng = CJTEngine(
                self.jt, self.catalog, self.ring,
                lifts={n: self._make_lift(n) for n in self.catalog.names()},
                store=MessageStore(),
            )
            q = self._base_query()
            factor, stats = eng.execute(q)
            return self._solve(factor.field, with_aug=False, stats=stats)
        jt2, bag = attach_relation(self.jt, aug.name, aug.attrs, aug.domains)
        cat2 = Catalog([self.catalog.get(n) for n in self.catalog.names()] + [aug])
        lifts = {n: self._make_lift(n) for n in self.catalog.names()}
        lifts[aug.name] = self._aug_lift(column)
        eng2 = CJTEngine(jt2, cat2, self.ring, lifts=lifts, store=MessageStore())
        q = self._base_query(cat2)
        stats = ExecStats()
        factor = eng2.absorb(q, bag, stats=stats)
        return self._solve(factor.field, with_aug=True, stats=stats)
