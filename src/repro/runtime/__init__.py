from .sharding import ShardingRules, make_rules, pspec_for, sharding_for, act_specs, batch_specs  # noqa: F401
from .step import make_train_step, make_prefill_step, make_decode_step  # noqa: F401
