"""Version-compat shims over jax API drift.

Two call sites in this repo broke across jax releases:

- ``jax.sharding.AxisType`` (and ``jax.make_mesh(..., axis_types=...)``
  accepting it) only exists in newer jax; older releases auto-type every
  axis.  ``make_mesh`` requests Auto axes when the enum exists and silently
  gets the same behavior when it doesn't.
- ``Compiled.cost_analysis()`` returned a one-element list of dicts in older
  jax and a plain dict in newer.  ``cost_analysis``/``compiled_flops``
  normalize to a dict.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              explicit: bool = False) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto (or Explicit) axis types where supported."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    kind = axis_type.Explicit if explicit else axis_type.Auto
    return jax.make_mesh(axis_shapes, axis_names, axis_types=(kind,) * len(axis_names))


def cost_analysis(compiled) -> Mapping[str, float]:
    """Normalized ``Compiled.cost_analysis()``: always a (possibly empty) dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def compiled_flops(compiled) -> float:
    return float(cost_analysis(compiled).get("flops", 0.0))
