"""Logical-axis → mesh-axis sharding rules with divisibility fallback.

Every parameter/cache leaf is declared once with logical axes (models/lm.py
``Spec``); this module maps them onto the production mesh:

  single pod  : (data=16, model=16)          fsdp=(data,)        tensor=model
  multi pod   : (pod=2, data=16, model=16)   fsdp=(pod, data)    tensor=model

Rules are *requests*: a dim whose size is not divisible by the mesh axes it
maps to falls back to replication (e.g. deepseek's 56 q-heads on a 16-way
tensor axis — the flat head projection dim 7168 still shards; granite's
49155-way vocab replicates).  A mesh axis is also never used twice in one
PartitionSpec (first dim wins).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lm import Spec, _map_specs, param_specs


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    table: Mapping[str, tuple[str, ...]]   # logical axis -> mesh axes

    def axes_for(self, logical: Any) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.table.get(logical, ()))


def make_rules(mesh: Mesh, shape: ShapeConfig | None = None, multi_pod: bool | None = None) -> ShardingRules:
    if multi_pod is None:
        multi_pod = "pod" in mesh.axis_names
    fsdp = ("pod", "data") if multi_pod else ("data",)
    tensor = ("model",)
    table: dict[str, tuple[str, ...]] = {
        # parameters
        "embed": fsdp,
        "vocab": tensor,
        "heads_flat": tensor,
        "kv_flat": tensor,
        "mlp": tensor,
        "experts": tensor,
        "ssm_inner": tensor,
        "layers": (), "group": (),
        # activations / caches
        "act_batch": fsdp,
        "act_seq": (),
        "act_embed": tensor,
        "act_heads": tensor,
        "act_ff": tensor,
        "cache_seq": tensor,
        "kv_heads": (),
        "act_vocab": tensor,
        "act_experts": tensor,
    }
    if shape is not None and shape.kind == "decode" and shape.global_batch < _n(mesh, fsdp):
        # long-context decode (batch=1): nothing to shard on batch; spread the
        # KV cache/sequence over the whole mesh instead.
        table["act_batch"] = ()
        table["cache_seq"] = fsdp + tensor
    return ShardingRules(mesh=mesh, table=table)


def _n(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def pspec_for(spec_shape: tuple[int, ...], logical_axes: tuple, rules: ShardingRules) -> P:
    used: set[str] = set()
    entries = []
    for dim, logical in zip(spec_shape, logical_axes):
        axes = [a for a in rules.axes_for(logical) if a not in used]
        if axes and dim % _n(rules.mesh, tuple(axes)) == 0:
            used.update(axes)
            entries.append(tuple(axes) if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_for(spec: Spec, rules: ShardingRules) -> NamedSharding:
    return NamedSharding(rules.mesh, pspec_for(spec.shape, spec.axes, rules))


def tree_shardings(spec_tree, rules: ShardingRules):
    return _map_specs(spec_tree, lambda _, s: sharding_for(s, rules))


def tree_abstract(spec_tree, rules: ShardingRules, default_dtype):
    import jax.numpy as jnp

    def build(_, s: Spec):
        dt = s.dtype or default_dtype
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(dt), sharding=sharding_for(s, rules))

    return _map_specs(spec_tree, build)


# ---------------------------------------------------------------------------
# Activation sharding constraints handed into the forward pass
# ---------------------------------------------------------------------------

def act_specs(cfg: ModelConfig, rules: ShardingRules) -> dict:
    """PartitionSpecs for with_sharding_constraint sites inside the model."""
    r = rules

    def p(*logicals, dims):
        return NamedSharding(r.mesh, pspec_for(dims, logicals, r))

    d = cfg.d_model
    # context-parallel attention: put the tensor axis on the sequence dim of
    # q/k/v instead of heads (deepseek: 56 heads ∤ 16)
    seq_ax = "act_embed" if getattr(cfg, "attn_seq_shard", False) else "act_seq"
    head_ax = None if getattr(cfg, "attn_seq_shard", False) else "act_heads"
    resid_ax = "act_embed" if getattr(cfg, "resid_shard", True) else None
    out = {
        "resid": p("act_batch", "act_seq", resid_ax, dims=(1 << 30, 1 << 30, d)),
        "qkv": p("act_batch", seq_ax, head_ax, None,
                 dims=(1 << 30, 1 << 30, cfg.n_heads, cfg.d_head)),
        "kv": p("act_batch", seq_ax, "kv_heads", None,
                dims=(1 << 30, 1 << 30, cfg.n_kv_heads, cfg.d_head)),
        "ff": p("act_batch", "act_seq", "act_ff", dims=(1 << 30, 1 << 30, cfg.d_ff)),
        "logits": p("act_batch", "act_seq", "act_vocab", dims=(1 << 30, 1 << 30, cfg.vocab)),
    }
    if cfg.moe:
        out["expert_in"] = p(None, "act_experts", None, None,
                             dims=(1 << 30, cfg.moe.n_experts, 1 << 30, d))
        out["expert_ff"] = p(None, "act_experts", None, None,
                             dims=(1 << 30, cfg.moe.n_experts, 1 << 30, cfg.moe.d_ff))
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules, dtype) -> dict:
    """ShapeDtypeStructs (with shardings) for one device batch."""
    import jax.numpy as jnp

    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    m = rules.mesh

    def sds(shape_, logicals, dt):
        return jax.ShapeDtypeStruct(
            shape_, jnp.dtype(dt),
            sharding=NamedSharding(m, pspec_for(shape_, logicals, rules)),
        )

    out = {}
    if cfg.input_mode == "embeddings":
        out["embeds"] = sds((b, s, cfg.d_model), ("act_batch", "act_seq", "act_embed"), dtype)
    else:
        out["tokens"] = sds((b, s), ("act_batch", "act_seq"), "int32")
    if cfg.input_mode == "tokens+vision":
        out["vision"] = sds(
            (b, cfg.n_vision_tokens, cfg.d_model), ("act_batch", None, "act_embed"), dtype
        )
    if shape.kind == "train":
        out["labels"] = sds((b, s), ("act_batch", "act_seq"), "int32")
    return out
