"""Jitted step builders: train (grad + AdamW, optional microbatch accumulation
and manual-DP int8-compressed gradient reduction), prefill, decode.

Each builder returns (fn, in_specs, out_shardings-ready jit) so the dry-run
can ``.lower().compile()`` against ShapeDtypeStructs and the train driver can
run the same function on real arrays.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.optim import adamw
from .sharding import ShardingRules, act_specs, tree_shardings, tree_abstract


def layer_slice_constraint(cfg: ModelConfig, rules: ShardingRules):
    """Shardings for ONE stacked-layer slice: re-asserted inside the scan body
    so GSPMD keeps per-layer weights sharded instead of hoisting a full FSDP
    all-gather of the whole stack out of the loop (which alone is
    params·(1/model_axis) bytes — 42 GiB for nemotron-4-340b)."""
    from jax.sharding import NamedSharding
    from repro.models.lm import param_specs, _map_specs, Spec
    from .sharding import pspec_for

    specs = param_specs(cfg)
    if "layers" not in specs:
        return None

    def slice_sharding(_, s: Spec):
        return NamedSharding(rules.mesh, pspec_for(s.shape[1:], s.axes[1:], rules))

    return _map_specs(specs["layers"], slice_sharding)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    rules: ShardingRules | None = None,
    accum: int = 1,
    donate: bool = True,
):
    """Returns a jitted train_step(params, opt_state, batch) → (params, opt, metrics)."""
    acts = act_specs(cfg, rules) if rules is not None else {}
    if rules is not None:
        lc = layer_slice_constraint(cfg, rules)
        if lc is not None:
            acts["layer_params"] = lc

    def loss_fn(params, batch):
        loss, metrics = lm.forward_train(params, cfg, batch, acts=acts)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            # microbatch gradient accumulation: batch leading dim splits into
            # (accum, b/accum); bf16 accumulators keep the ≥100B budget.
            def split(x):
                b = x.shape[0]
                return x.reshape((accum, b // accum) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params
            )
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        params, opt_state, om = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(train_step, donate_argnums=donate_argnums)


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules | None = None,
                      shape: ShapeConfig | None = None):
    acts = act_specs(cfg, rules) if rules is not None else {}

    def prefill(params, batch):
        return lm.forward_prefill(params, cfg, batch, acts=acts)

    out_shardings = None
    if rules is not None and shape is not None:
        # the produced KV/state caches must leave the step sharded (seq over
        # the tensor axis) — a 32k cache replicated per device is tens of GiB
        from jax.sharding import NamedSharding, PartitionSpec as P
        from .sharding import pspec_for
        cache_sh = tree_shardings(
            lm.cache_specs(cfg, shape.global_batch, shape.seq_len), rules
        )
        logits_sh = NamedSharding(
            rules.mesh,
            pspec_for((shape.global_batch, cfg.vocab), ("act_batch", "act_vocab"), rules),
        )
        out_shardings = (logits_sh, cache_sh)
    return jax.jit(prefill, out_shardings=out_shardings)


def make_decode_step(cfg: ModelConfig, rules: ShardingRules | None = None, donate: bool = True):
    acts = act_specs(cfg, rules) if rules is not None else {}

    def decode(params, batch, caches, pos):
        return lm.forward_decode(params, cfg, batch, caches, pos, acts=acts)

    return jax.jit(decode, donate_argnums=(2,) if donate else ())


def abstract_train_state(cfg: ModelConfig, opt_cfg, rules: ShardingRules, param_dtype="bfloat16"):
    """(params, opt_state) ShapeDtypeStructs with shardings for the dry-run."""
    pspecs = lm.param_specs(cfg)
    params = tree_abstract(pspecs, rules, param_dtype)
    opt = tree_abstract(adamw.opt_state_specs(cfg, opt_cfg), rules, "float32")
    return params, opt


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules, dtype="bfloat16"):
    specs = lm.cache_specs(cfg, shape.global_batch, shape.seq_len)
    return tree_abstract(specs, rules, dtype)
