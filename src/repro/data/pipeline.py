"""Training data pipeline + straggler monitoring.

``TokenPipeline`` produces deterministic synthetic token streams (seeded by
(shard, step) so restarts resume bit-identically), packs them into fixed
(batch, seq) blocks, and prefetches on a background thread so host data work
overlaps the device step.  On a cluster each process would draw its own shard
range (``jax.process_index()``); here one process owns all shards.

``StragglerMonitor`` tracks a step-time EWMA and flags outliers — the hook a
real deployment uses to trigger hot-spare swap / data re-balancing; the train
driver logs and (in simulation) re-balances by skipping the slow shard.
"""

from __future__ import annotations

import queue
import threading
import time

import numpy as np


def synth_batch(vocab: int, batch: int, seq: int, step: int, shard: int = 0, d_model=None, mode="tokens"):
    rng = np.random.default_rng((step * 9_973 + shard) % (2**63))
    if mode == "embeddings":
        return {
            "embeds": rng.standard_normal((batch, seq, d_model)).astype(np.float32),
            "labels": rng.integers(0, vocab, (batch, seq)).astype(np.int32),
        }
    out = {
        "tokens": rng.integers(0, vocab, (batch, seq)).astype(np.int32),
        "labels": rng.integers(0, vocab, (batch, seq)).astype(np.int32),
    }
    return out


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, mode="tokens", d_model=None,
                 n_vision_tokens: int = 0, prefetch: int = 2, start_step: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.mode, self.d_model = mode, d_model
        self.n_vision = n_vision_tokens
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._produce, daemon=True)
        self._t.start()

    def _make(self, step: int):
        b = synth_batch(self.vocab, self.batch, self.seq, step,
                        d_model=self.d_model, mode=self.mode)
        if self.mode == "tokens+vision":
            rng = np.random.default_rng(step + 17)
            b["vision"] = rng.standard_normal(
                (self.batch, self.n_vision, self.d_model)
            ).astype(np.float32)
        return b

    def _produce(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._make(self._step), timeout=0.2)
                self._step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._t.join(timeout=2)


class StragglerMonitor:
    def __init__(self, alpha: float = 0.1, threshold: float = 2.0, warmup: int = 3):
        self.alpha, self.threshold, self.warmup = alpha, threshold, warmup
        self.ewma = None
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_slow = self.count > self.warmup and dt > self.threshold * self.ewma
        if is_slow:
            self.flagged.append((step, dt))
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_slow
