from .pipeline import TokenPipeline, StragglerMonitor, synth_batch  # noqa: F401
