from .adamw import AdamWConfig, init_opt_state, apply_updates, opt_state_specs, lr_at  # noqa: F401
from .compression import compress_int8, decompress_int8, CompressionState  # noqa: F401
