"""AdamW with warmup-cosine schedule, global-norm clipping, and configurable
moment dtypes (m in bf16 + v in fp32 by default — the ≥100B-parameter memory
budget in EXPERIMENTS.md §Dry-run depends on this split)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.lm import Spec, param_specs, _map_specs


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "bfloat16"
    v_dtype: str = "float32"


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: AdamWConfig):
    return {
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, cfg.m_dtype), params),
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, cfg.v_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(model_cfg, cfg: AdamWConfig) -> dict:
    """Spec pytree mirroring ``init_opt_state`` (same logical axes as params)."""
    ps = param_specs(model_cfg)
    m = _map_specs(ps, lambda _, s: Spec(s.shape, s.axes, init="zeros", dtype=cfg.m_dtype))
    v = _map_specs(ps, lambda _, s: Spec(s.shape, s.axes, init="zeros", dtype=cfg.v_dtype))
    return {"m": m, "v": v, "step": Spec((), (), init="zeros", dtype="int32")}


def global_norm(tree):
    # square in the native dtype, reduce with an fp32 accumulator: never
    # materializes an fp32 cast of a (possibly 100B-parameter) bf16 leaf, and
    # never ravels (which would break GSPMD shardings and replicate)
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(
        jnp.sum(jax.lax.square(l), dtype=jnp.float32) for l in leaves
    ))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step; moment/param dtypes are preserved leaf-wise."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd_slice(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    def upd(p, g, m, v):
        # Stream layer-stacked leaves through lax.map so the fp32 update
        # chain's transients are one layer-slice, not the whole tree (a
        # ≥100B-parameter leaf otherwise costs ~6 fp32 copies at once).
        # The optimization_barrier pins the casts inside the loop — XLA
        # otherwise hoists them out, recreating full-stack fp32 copies.
        if p.ndim >= 3 and p.shape[0] <= 256:
            def body(t):
                return upd_slice(*jax.lax.optimization_barrier(t))
            return jax.lax.map(body, (p, g, m, v))
        return upd_slice(p, g, m, v)

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
