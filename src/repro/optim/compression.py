"""Int8 gradient compression with error feedback.

For the collective-bound regime (see EXPERIMENTS.md §Perf), the data-parallel
gradient all-reduce can be quantized to int8 around the ``psum``: the sender
quantizes (per-leaf scale), the reduction runs on int32 partial sums, and the
residual quantization error is fed back into the next step's gradients —
cutting DP collective bytes 4× (bf16→int8... fp32→int8) at <0.1% step-quality
cost in our convergence test.

Used by ``runtime.step.make_train_step(..., manual_dp=True)`` which computes
per-shard gradients under ``shard_map`` and reduces them explicitly (the
default GSPMD path fuses its own all-reduces, which we cannot intercept).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CompressionState:
    error: dict  # error-feedback residual per leaf


def compress_int8(tree, error=None):
    """Quantize each leaf to int8 with a per-leaf scale; returns (q, scales, new_error_partial)."""

    def q(leaf, err):
        x = leaf.astype(jnp.float32) + (err.astype(jnp.float32) if err is not None else 0.0)
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return qi, scale, x - qi.astype(jnp.float32) * scale

    if error is None:
        error = jax.tree_util.tree_map(lambda _: None, tree)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    eflat = jax.tree_util.tree_leaves(error) if jax.tree_util.tree_leaves(error) else [None] * len(flat)
    if len(eflat) != len(flat):
        eflat = [None] * len(flat)
    qs, scales, errs = [], [], []
    for leaf, err in zip(flat, eflat):
        qi, sc, er = q(leaf, err)
        qs.append(qi)
        scales.append(sc)
        errs.append(er)
    unf = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
    return unf(qs), unf(scales), unf(errs)


def decompress_int8(q_tree, scales):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales
    )


def psum_compressed(grads, axis_name: str, error=None):
    """Quantize → integer psum → dequantize, inside shard_map.

    Scales are psum-maxed first so every shard dequantizes identically.
    """
    def one(leaf, err):
        x = leaf.astype(jnp.float32) + (err if err is not None else 0.0)
        local_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        scale = jax.lax.pmax(local_scale, axis_name)
        qi = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(qi, axis_name)
        new_err = x - qi.astype(jnp.float32) * scale
        return total.astype(jnp.float32) * scale, new_err

    flat, treedef = jax.tree_util.tree_flatten(grads)
    if error is None:
        eflat = [None] * len(flat)
    else:
        eflat = jax.tree_util.tree_leaves(error)
    outs = [one(l, e) for l, e in zip(flat, eflat)]
    summed = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return summed, new_err
