# Pallas TPU kernels for the paper's compute hot-spots (validated with
# interpret=True on CPU; REPRO_PALLAS=1 or a TPU backend enables compilation):
#   semiring_contract  - MXU-tiled (+,x) message contraction with fused sigma
#   tropical_contract  - VPU-tiled (min,+)/(max,+) contraction
#   segment_aggregate  - sparse fact-table rows -> dense factor via one-hot matmul
from .semiring_contract import ops as semiring_ops  # noqa: F401
from .tropical_contract import ops as tropical_ops  # noqa: F401
from .segment_aggregate import ops as segment_ops  # noqa: F401
