"""Jit'd wrapper for segment_aggregate with row/group padding.

Sharded composition: both :func:`aggregate_op` and :func:`level_aggregate`
are *shard-local* — inside ``shard_map`` they see the shard's row block
(codes and value slab sliced on the leading axis; segment ids stay global)
and produce a full ``(num_segments, v)`` partial that the caller must
⊕-all-reduce over the mesh axis (``psum``/``pmin``/``pmax``; see
``repro.core.distributed.ring_collective``).  ⊕-identity row padding makes
any equal block split of a padded row bucket exact, and the Pallas kernels
require ``check_rep=False`` on the enclosing ``shard_map`` (jax has no
replication rule for ``pallas_call`` — ``distributed.shard_map_compat``
handles this).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import (
    segment_aggregate,
    level_segment_aggregate,
    DEFAULT_TG,
    DEFAULT_TN,
)
from .ref import segment_aggregate_ref


@partial(jax.jit, static_argnames=("num_segments", "op", "interpret"))
def aggregate_op(codes, values, num_segments: int, op: str = "sum", interpret: bool = True):
    n = codes.shape[0]
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    v = values.shape[1]
    tn = min(DEFAULT_TN, max(8, n))
    tg = min(DEFAULT_TG, max(8, num_segments))
    pad_n = (-n) % tn
    pad_g = (-num_segments) % tg
    if pad_n:
        # padded rows carry the ⊕-identity so they are no-ops in any group
        ident = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}[op]
        codes = jnp.concatenate([codes, jnp.full((pad_n,), num_segments + pad_g - 1, codes.dtype)])
        values = jnp.concatenate([values, jnp.full((pad_n, v), ident, values.dtype)])
    out = segment_aggregate(
        codes, values, num_segments + pad_g, op=op, tn=tn, tg=tg, interpret=interpret
    )[:num_segments]
    return out[:, 0] if squeeze else out


def level_aggregate(items, op: str = "sum", interpret: bool = True):
    """Fuse several independent ``(codes, values, num_segments)`` segment
    reductions into ONE ``level_segment_aggregate`` launch.

    Each item j is one same-level message: ``codes`` (n_j,) int32 local
    segment ids in [0, g_j), ``values`` (n_j, v_j) row slab.  Rows are padded
    to the tile multiple with code -1 (matches no segment), columns to the
    common width and segments to the tile multiple with the ⊕-identity; local
    ids shift by the running segment offset so the concatenated launch is
    block-diagonal.  Returns the per-item (g_j, v_j) dense outputs.

    Traced helper — call it inside a jitted plan (shapes are static there);
    eager calls work too via pallas interpret mode.
    """
    assert items, "level_aggregate of zero messages"
    ident = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}[op]
    v_max = max(v.shape[1] for _, v, _ in items)
    tn = min(DEFAULT_TN, max(8, max(c.shape[0] for c, _, _ in items)))
    tg = min(DEFAULT_TG, max(8, max(g for _, _, g in items)))
    all_codes, all_vals = [], []
    row_blocks, seg_blocks, tile_start, tile_first = [], [], [], []
    row_off = seg_off = 0
    spans = []
    for codes, values, g in items:
        n = codes.shape[0]
        pad_n = (-n) % tn
        pad_g = (-g) % tg
        codes = codes.astype(jnp.int32) + seg_off
        if pad_n:
            codes = jnp.concatenate([codes, jnp.full((pad_n,), -1, jnp.int32)])
        if values.shape[1] < v_max or pad_n:
            values = jnp.pad(
                values,
                ((0, pad_n), (0, v_max - values.shape[1])),
                constant_values=ident,
            )
        all_codes.append(codes)
        all_vals.append(values.astype(jnp.float32))
        n_blocks = (n + pad_n) // tn
        g_blocks = (g + pad_g) // tg
        for s in range(g_blocks):
            for r in range(n_blocks):
                row_blocks.append(row_off // tn + r)
                seg_blocks.append(seg_off // tg + s)
                tile_start.append(seg_off + s * tg)
                tile_first.append(1 if r == 0 else 0)
        spans.append((seg_off, g))
        row_off += n + pad_n
        seg_off += g + pad_g
    out = level_segment_aggregate(
        jnp.concatenate(all_codes),
        jnp.concatenate(all_vals),
        jnp.asarray(row_blocks, jnp.int32),
        jnp.asarray(seg_blocks, jnp.int32),
        jnp.asarray(tile_start, jnp.int32),
        jnp.asarray(tile_first, jnp.int32),
        seg_off,
        op=op,
        tn=tn,
        tg=tg,
        interpret=interpret,
    )
    return [
        out[off : off + g, : v.shape[1]]
        for (off, g), (_, v, _) in zip(spans, items)
    ]


def aggregate(codes, values, num_segments, op="sum", use_kernel=True):
    if use_kernel:
        return aggregate_op(codes, values, num_segments, op=op,
                            interpret=jax.default_backend() != "tpu")
    return segment_aggregate_ref(codes, values, num_segments, op)
