"""Jit'd wrapper for segment_aggregate with row/group padding."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import segment_aggregate, DEFAULT_TG, DEFAULT_TN
from .ref import segment_aggregate_ref


@partial(jax.jit, static_argnames=("num_segments", "op", "interpret"))
def aggregate_op(codes, values, num_segments: int, op: str = "sum", interpret: bool = True):
    n = codes.shape[0]
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    v = values.shape[1]
    tn = min(DEFAULT_TN, max(8, n))
    tg = min(DEFAULT_TG, max(8, num_segments))
    pad_n = (-n) % tn
    pad_g = (-num_segments) % tg
    if pad_n:
        # padded rows carry the ⊕-identity so they are no-ops in any group
        ident = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}[op]
        codes = jnp.concatenate([codes, jnp.full((pad_n,), num_segments + pad_g - 1, codes.dtype)])
        values = jnp.concatenate([values, jnp.full((pad_n, v), ident, values.dtype)])
    out = segment_aggregate(
        codes, values, num_segments + pad_g, op=op, tn=tn, tg=tg, interpret=interpret
    )[:num_segments]
    return out[:, 0] if squeeze else out


def aggregate(codes, values, num_segments, op="sum", use_kernel=True):
    if use_kernel:
        return aggregate_op(codes, values, num_segments, op=op,
                            interpret=jax.default_backend() != "tpu")
    return segment_aggregate_ref(codes, values, num_segments, op)
