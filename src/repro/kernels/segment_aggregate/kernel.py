"""Pallas TPU kernel: sparse → dense segment aggregation (one-hot matmul).

The leaf step of every CJT message over a fact table: N dictionary-encoded
rows with per-row annotation vectors collapse into a dense (G, V) factor.
TPUs have no efficient random scatter, so the DBMS hash-aggregate is
re-thought for the MXU: each (TN rows × TG groups) tile builds the one-hot
membership matrix ``codes[n] == group_ids[g]`` and contracts it with the
value slab — turning data-dependent scatter into dense matmul.

  out[g, v] ⊕= Σ_n  1[codes[n] == g] · values[n, v]      (⊕ ∈ {sum, min, max})

Grid: (G/TG, N/TN) with rows innermost (accumulation), so each output tile
stays resident in VMEM across the row stream.

``level_segment_aggregate`` extends this to a *multi-segment* launch: all
independent messages of one calibration level share a single block-diagonal
grid (per-message ``(offset, num_segments)`` descriptors become a static
work-tile table), so a whole level costs one kernel dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TN = 512
DEFAULT_TG = 128


def _kernel(codes_ref, vals_ref, o_ref, *, op: str, tg: int):
    if op == "sum":
        init = 0.0
    elif op == "min":
        init = jnp.inf
    else:
        init = -jnp.inf

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    codes = codes_ref[...]                       # (TN,)
    vals = vals_ref[...].astype(jnp.float32)     # (TN, V)
    g0 = pl.program_id(0) * tg
    gids = g0 + jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], tg), 1)
    onehot = (codes[:, None] == gids)            # (TN, TG) bool
    if op == "sum":
        o_ref[...] += jnp.dot(
            onehot.astype(jnp.float32).T, vals, preferred_element_type=jnp.float32
        )
    else:
        big = jnp.where(onehot[:, :, None], vals[:, None, :], init)  # (TN, TG, V)
        red = jnp.min(big, axis=0) if op == "min" else jnp.max(big, axis=0)
        cur = o_ref[...]
        o_ref[...] = jnp.minimum(cur, red) if op == "min" else jnp.maximum(cur, red)


def segment_aggregate(
    codes: jax.Array,      # (N,) int32 group ids in [0, G)
    values: jax.Array,     # (N, V) row annotations
    num_segments: int,
    op: str = "sum",
    tn: int = DEFAULT_TN,
    tg: int = DEFAULT_TG,
    interpret: bool = True,
) -> jax.Array:
    n, v = values.shape
    g = num_segments
    tn = min(tn, n)
    tg = min(tg, g)
    assert n % tn == 0 and g % tg == 0, (n, g, tn, tg)
    grid = (g // tg, n // tn)
    return pl.pallas_call(
        functools.partial(_kernel, op=op, tg=tg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i, k: (k,)),
            pl.BlockSpec((tn, v), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((tg, v), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, v), jnp.float32),
        interpret=interpret,
    )(codes, values)


# ---------------------------------------------------------------------------
# level kernel: many independent segment aggregations in ONE launch
# ---------------------------------------------------------------------------

def _level_kernel(row_ref, seg_ref, start_ref, first_ref, codes_ref, vals_ref,
                  o_ref, *, op: str, tg: int):
    del row_ref, seg_ref  # consumed by the index maps only
    if op == "sum":
        init = 0.0
    elif op == "min":
        init = jnp.inf
    else:
        init = -jnp.inf
    i = pl.program_id(0)

    @pl.when(first_ref[i] == 1)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    codes = codes_ref[...]                       # (TN,) global segment ids
    vals = vals_ref[...].astype(jnp.float32)     # (TN, V)
    g0 = start_ref[i]                            # first global id of this tile
    gids = g0 + jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], tg), 1)
    onehot = (codes[:, None] == gids)            # (TN, TG) bool; pad rows (-1)
    if op == "sum":                              # match nothing → identity
        o_ref[...] += jnp.dot(
            onehot.astype(jnp.float32).T, vals, preferred_element_type=jnp.float32
        )
    else:
        big = jnp.where(onehot[:, :, None], vals[:, None, :], init)
        red = jnp.min(big, axis=0) if op == "min" else jnp.max(big, axis=0)
        cur = o_ref[...]
        o_ref[...] = jnp.minimum(cur, red) if op == "min" else jnp.maximum(cur, red)


def level_segment_aggregate(
    codes: jax.Array,              # (ΣN_j,) int32 GLOBAL segment ids; pad rows -1
    values: jax.Array,             # (ΣN_j, V) row slabs, col-padded to common V
    row_blocks: jax.Array,         # (T,) int32 per-tile input row-block index
    seg_blocks: jax.Array,         # (T,) int32 per-tile output segment block
    tile_start: jax.Array,         # (T,) int32: first global id of each tile
    tile_first: jax.Array,         # (T,) int32: 1 → first row tile for its block
    total_segments: int,           # ΣG_j (tile-aligned)
    op: str = "sum",
    tn: int = DEFAULT_TN,
    tg: int = DEFAULT_TG,
    interpret: bool = True,
) -> jax.Array:
    """One grid over the block-diagonal union of several segment reductions.

    The fused 'level kernel' behind one-launch-per-calibration-level: each
    same-level message j contributes a (rows_j, segs_j) aggregation whose row
    and segment ranges are tile-aligned and disjoint in the concatenated
    operands.  A 1-D grid walks a work-tile table held in scalar-prefetch
    memory (index maps pick each tile's row/segment block from it) — for
    every message, rows innermost per output tile so each (TG, V) block stays
    resident across its row stream — and no (row tile, segment tile) pair
    from *different* messages ever meets, so total work stays Σ_j N_j·G_j·V
    instead of (ΣN)(ΣG)V.
    """
    n, v = values.shape
    t = row_blocks.shape[0]
    assert seg_blocks.shape[0] == t and n % tn == 0 and total_segments % tg == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((tn,), lambda i, row, seg, st, ft: (row[i],)),
            pl.BlockSpec((tn, v), lambda i, row, seg, st, ft: (row[i], 0)),
        ],
        out_specs=pl.BlockSpec((tg, v), lambda i, row, seg, st, ft: (seg[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_level_kernel, op=op, tg=tg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((total_segments, v), jnp.float32),
        interpret=interpret,
    )(row_blocks, seg_blocks, tile_start, tile_first, codes, values)
