"""Pallas TPU kernel: sparse → dense segment aggregation (one-hot matmul).

The leaf step of every CJT message over a fact table: N dictionary-encoded
rows with per-row annotation vectors collapse into a dense (G, V) factor.
TPUs have no efficient random scatter, so the DBMS hash-aggregate is
re-thought for the MXU: each (TN rows × TG groups) tile builds the one-hot
membership matrix ``codes[n] == group_ids[g]`` and contracts it with the
value slab — turning data-dependent scatter into dense matmul.

  out[g, v] ⊕= Σ_n  1[codes[n] == g] · values[n, v]      (⊕ ∈ {sum, min, max})

Grid: (G/TG, N/TN) with rows innermost (accumulation), so each output tile
stays resident in VMEM across the row stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TN = 512
DEFAULT_TG = 128


def _kernel(codes_ref, vals_ref, o_ref, *, op: str, tg: int):
    if op == "sum":
        init = 0.0
    elif op == "min":
        init = jnp.inf
    else:
        init = -jnp.inf

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    codes = codes_ref[...]                       # (TN,)
    vals = vals_ref[...].astype(jnp.float32)     # (TN, V)
    g0 = pl.program_id(0) * tg
    gids = g0 + jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], tg), 1)
    onehot = (codes[:, None] == gids)            # (TN, TG) bool
    if op == "sum":
        o_ref[...] += jnp.dot(
            onehot.astype(jnp.float32).T, vals, preferred_element_type=jnp.float32
        )
    else:
        big = jnp.where(onehot[:, :, None], vals[:, None, :], init)  # (TN, TG, V)
        red = jnp.min(big, axis=0) if op == "min" else jnp.max(big, axis=0)
        cur = o_ref[...]
        o_ref[...] = jnp.minimum(cur, red) if op == "min" else jnp.maximum(cur, red)


def segment_aggregate(
    codes: jax.Array,      # (N,) int32 group ids in [0, G)
    values: jax.Array,     # (N, V) row annotations
    num_segments: int,
    op: str = "sum",
    tn: int = DEFAULT_TN,
    tg: int = DEFAULT_TG,
    interpret: bool = True,
) -> jax.Array:
    n, v = values.shape
    g = num_segments
    tn = min(tn, n)
    tg = min(tg, g)
    assert n % tn == 0 and g % tg == 0, (n, g, tn, tg)
    grid = (g // tg, n // tn)
    return pl.pallas_call(
        functools.partial(_kernel, op=op, tg=tg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i, k: (k,)),
            pl.BlockSpec((tn, v), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((tg, v), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, v), jnp.float32),
        interpret=interpret,
    )(codes, values)
