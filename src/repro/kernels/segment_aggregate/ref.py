"""Pure-jnp oracle for segment_aggregate."""

import jax
import jax.numpy as jnp


def segment_aggregate_ref(codes, values, num_segments, op="sum"):
    values = values.astype(jnp.float32)
    if op == "sum":
        return jax.ops.segment_sum(values, codes, num_segments)
    if op == "min":
        return jax.ops.segment_min(values, codes, num_segments)
    return jax.ops.segment_max(values, codes, num_segments)
