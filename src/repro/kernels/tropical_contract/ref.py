"""Pure-jnp oracle for tropical_contract."""

import jax.numpy as jnp


def tropical_contract_ref(m, r, is_min=True):
    slab = m.astype(jnp.float32)[:, :, None] + r.astype(jnp.float32)[None, :, :]
    return jnp.min(slab, axis=1) if is_min else jnp.max(slab, axis=1)
