"""Pallas TPU kernel: tropical (min,+)/(max,+) contraction.

C[g, a] = min_b ( M[g, b] + R[b, a] )   (or max).

MIN/MAX semiring messages (e.g. Fig 21's MAX(COUNT) over the empty bag)
cannot use the MXU — this is a VPU kernel: each (TG, TA) output tile
accumulates a broadcast-add/reduce over TB-sized slabs of the contracted
axis, so VMEM holds one (TG, TB, TA) intermediate at a time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILES = (16, 128, 128)  # (TG, TB, TA): (16·128·128)·4B = 1 MiB slab


def _kernel(m_ref, r_ref, o_ref, *, is_min: bool):
    init = jnp.inf if is_min else -jnp.inf

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    m = m_ref[...].astype(jnp.float32)          # (TG, TB)
    r = r_ref[...].astype(jnp.float32)          # (TB, TA)
    slab = m[:, :, None] + r[None, :, :]        # (TG, TB, TA)
    red = jnp.min(slab, axis=1) if is_min else jnp.max(slab, axis=1)
    cur = o_ref[...]
    o_ref[...] = jnp.minimum(cur, red) if is_min else jnp.maximum(cur, red)


def tropical_contract(
    m: jax.Array,                   # (G, B)
    r: jax.Array,                   # (B, A)
    is_min: bool = True,
    tiles: tuple[int, int, int] = DEFAULT_TILES,
    interpret: bool = True,
) -> jax.Array:
    g, b = m.shape
    b2, a = r.shape
    assert b == b2
    tg, tb, ta = (min(tiles[0], g), min(tiles[1], b), min(tiles[2], a))
    assert g % tg == 0 and b % tb == 0 and a % ta == 0
    grid = (g // tg, a // ta, b // tb)
    return pl.pallas_call(
        functools.partial(_kernel, is_min=is_min),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tg, tb), lambda i, j, k: (i, k)),
            pl.BlockSpec((tb, ta), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tg, ta), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g, a), jnp.float32),
        interpret=interpret,
    )(m, r)
