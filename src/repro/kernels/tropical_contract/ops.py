"""Jit'd wrapper for tropical_contract with identity-padding."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import tropical_contract, DEFAULT_TILES
from .ref import tropical_contract_ref


def _pad_to(x, mult, axis, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("is_min", "interpret"))
def contract_op(m, r, is_min: bool = True, interpret: bool = True):
    g, b = m.shape
    a = r.shape[1]
    ident = jnp.inf if is_min else -jnp.inf  # ⊕-identity pads the contracted axis
    tg = min(DEFAULT_TILES[0], max(8, g))
    tb = min(DEFAULT_TILES[1], max(8, b))
    ta = min(DEFAULT_TILES[2], max(8, a))
    mp = _pad_to(_pad_to(m, tg, 0, ident), tb, 1, ident)
    rp = _pad_to(_pad_to(r, tb, 0, ident), ta, 1, ident)
    # note: inf + -inf cannot occur — both operands pad with the same sign
    out = tropical_contract(mp, rp, is_min=is_min, tiles=(tg, tb, ta), interpret=interpret)
    return out[:g, :a]


def contract(m, r, is_min=True, use_kernel=True):
    if use_kernel:
        return contract_op(m, r, is_min=is_min, interpret=jax.default_backend() != "tpu")
    return tropical_contract_ref(m, r, is_min)
