"""Jit'd wrapper with shape padding; selects the Pallas kernel or the jnp ref.

On CPU (this container) the kernel runs in ``interpret=True`` mode for
correctness validation; on a TPU build set ``REPRO_PALLAS=1`` to compile it.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from .kernel import semiring_contract, DEFAULT_TILES
from .ref import semiring_contract_ref


def use_pallas() -> bool:
    return os.environ.get("REPRO_PALLAS", "0") == "1" or jax.default_backend() == "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@partial(jax.jit, static_argnames=("interpret",))
def contract_op(m, r, mask=None, interpret: bool = True):
    """Padded semiring contraction; returns (G, A) fp32."""
    g, b = m.shape
    a = r.shape[1]
    tg = min(DEFAULT_TILES[0], max(8, g))
    tb = min(DEFAULT_TILES[1], max(8, b))
    ta = min(DEFAULT_TILES[2], max(8, a))
    mp, _ = _pad_to(m, tg, 0)
    mp, _ = _pad_to(mp, tb, 1)
    rp, _ = _pad_to(r, tb, 0)
    rp, _ = _pad_to(rp, ta, 1)
    mk = None
    if mask is not None:
        mk, _ = _pad_to(mask.astype(jnp.float32), tb, 0)
    out = semiring_contract(mp, rp, mk, tiles=(tg, tb, ta), interpret=interpret)
    return out[:g, :a]


def contract(m, r, mask=None):
    if use_pallas():
        return contract_op(m, r, mask, interpret=jax.default_backend() != "tpu")
    return semiring_contract_ref(m, r, mask)
