"""Pure-jnp oracle for semiring_contract."""

import jax.numpy as jnp


def semiring_contract_ref(m, r, mask=None):
    m = m.astype(jnp.float32)
    if mask is not None:
        m = m * mask.astype(jnp.float32)[None, :]
    return m @ r.astype(jnp.float32)
