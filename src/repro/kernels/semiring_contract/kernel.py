"""Pallas TPU kernel: arithmetic-semiring contraction (the CJT message hot path).

Computes  C[g, a] = Σ_b  M[g, b] ⊗ R[b, a]   over the (+, ×) ring, with an
optional fused σ mask on the contracted (separator) axis — i.e. one message
step ``⊕_b (incoming ⊗ bag)`` with selection push-down, as an MXU matmul.

Tiling: (TG, TB) × (TB, TA) blocks in VMEM, fp32 accumulation in the output
block; grid is (G/TG, A/TA, B/TB) with the contraction dimension innermost so
each output tile is initialized at b==0 and accumulated across b steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILES = (128, 128, 128)  # (TG, TB, TA) — MXU-aligned


def _kernel(m_ref, r_ref, mask_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = m_ref[...].astype(jnp.float32)
    if mask_ref is not None:
        m = m * mask_ref[...].astype(jnp.float32)[None, :]
    r = r_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(m, r, preferred_element_type=jnp.float32)


def semiring_contract(
    m: jax.Array,                  # (G, B)
    r: jax.Array,                  # (B, A)
    mask: jax.Array | None = None,  # (B,) 0/1 σ mask on the contracted axis
    tiles: tuple[int, int, int] = DEFAULT_TILES,
    interpret: bool = True,
) -> jax.Array:
    g, b = m.shape
    b2, a = r.shape
    assert b == b2, (m.shape, r.shape)
    tg, tb, ta = (min(tiles[0], g), min(tiles[1], b), min(tiles[2], a))
    assert g % tg == 0 and b % tb == 0 and a % ta == 0, (m.shape, r.shape, tiles)
    grid = (g // tg, a // ta, b // tb)

    in_specs = [
        pl.BlockSpec((tg, tb), lambda i, j, k: (i, k)),
        pl.BlockSpec((tb, ta), lambda i, j, k: (k, j)),
    ]
    args = [m, r]
    if mask is not None:
        in_specs.append(pl.BlockSpec((tb,), lambda i, j, k: (k,)))
        args.append(mask)
        kern = _kernel
    else:
        kern = functools.partial(_masked_none_kernel)

    out = pl.pallas_call(
        kern if mask is not None else _masked_none_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tg, ta), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g, a), jnp.float32),
        interpret=interpret,
    )(*args)
    return out


def _masked_none_kernel(m_ref, r_ref, o_ref):
    _kernel(m_ref, r_ref, None, o_ref)
