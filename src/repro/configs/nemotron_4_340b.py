"""Nemotron-4 340B: GQA + squared-ReLU MLP [arXiv:2402.16819; unverified]."""
from .base import ModelConfig, register


@register("nemotron-4-340b")
def make() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_head=192,
        d_ff=73728, vocab=256000, mlp="squared_relu",
        source="[arXiv:2402.16819; unverified]",
    )
