"""DeepSeek-Coder 33B: llama-arch GQA [arXiv:2401.14196; hf]."""
from .base import ModelConfig, register


@register("deepseek-coder-33b")
def make() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
        d_ff=19200, vocab=32256, mlp="swiglu", rope_theta=100_000.0,
        source="[arXiv:2401.14196; hf]",
    )
