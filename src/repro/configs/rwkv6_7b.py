"""RWKV6-7B "Finch": attention-free, data-dependent decay [arXiv:2404.05892; hf].

SSM family (O(1) state): eligible for long_500k decode.
"""
from .base import ModelConfig, RWKVConfig, register


@register("rwkv6-7b")
def make() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_head=64,
        d_ff=14336, vocab=65536, mlp="squared_relu",
        rwkv=RWKVConfig(head_dim=64, lora_rank=64, chunk=32),
        pattern="rwkv", sub_quadratic=True,
        source="[arXiv:2404.05892; hf]",
    )
