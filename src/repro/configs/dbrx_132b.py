"""DBRX-Base: 132B-total / 36B-active fine-grained MoE [hf:databricks/dbrx-base; unverified]."""
from .base import ModelConfig, MoEConfig, register


@register("dbrx-132b")
def make() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=10752, vocab=100352, mlp="swiglu",
        moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752),
        rope_theta=500_000.0,
        source="[hf:databricks/dbrx-base; unverified]",
    )
