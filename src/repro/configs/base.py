"""Model/shape configuration schema + registry for the assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    capacity_factor: float = 1.25
    group: int = 256               # dispatch group size (tokens)


@dataclasses.dataclass(frozen=True)
class SSMConfig:                   # Mamba2 / SSD
    state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv: int = 4
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class RWKVConfig:                  # RWKV6 "Finch"
    head_dim: int = 64
    lora_rank: int = 64
    chunk: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    mlp: str = "swiglu"            # swiglu | squared_relu | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    pattern: str = "uniform"       # uniform | vlm | zamba | rwkv
    cross_every: int = 5           # vlm: 1 cross-attn layer per this many
    n_vision_tokens: int = 1024
    shared_attn_every: int = 6     # zamba: shared block period
    input_mode: str = "tokens"     # tokens | embeddings | tokens+vision
    sub_quadratic: bool = False    # eligible for long_500k
    # attention implementation knobs (hillclimb dials)
    attn_mode: str = "full_masked"     # full_masked | divide
    # context-parallel attention: shard q/k/v on the SEQUENCE dim over the
    # tensor axis (for archs whose head count doesn't divide it)
    attn_seq_shard: bool = False
    # shard the residual stream's d_model over the tensor axis (Megatron-SP
    # style; saves activation memory but all-gathers at every matmul input)
    resid_shard: bool = True
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512
    attn_min_block: int = 1024
    # compile-shape knobs
    remat: str = "full"            # full | dots | none
    loss_chunk: int = 512          # sequence chunking for the xent loss
    scan_groups: int | None = None  # √L nested layer scan (activation memory dial)
    # analysis mode: unroll every lax.scan so XLA cost analysis counts each
    # iteration (HloCostAnalysis visits while bodies ONCE — see DESIGN.md §8)
    unroll_scans: bool = False
    # source provenance, e.g. "[arXiv:2306.05284; hf]"
    source: str = ""

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.d_attn + 2 * d * self.n_kv_heads * self.d_head + self.d_attn * d
        if self.mlp == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = attn + mlp + 2 * d
        if self.moe:
            e_f = self.moe.d_ff
            expert = (3 if self.mlp == "swiglu" else 2) * d * e_f
            per_layer = attn + self.moe.n_experts * expert + d * self.moe.n_experts + 2 * d
        if self.pattern == "rwkv":
            # time-mix ≈ 4.5 d² + lora, channel-mix = 2 d f
            per_layer = int(4.5 * d * d) + 2 * d * f + 2 * d
        if self.pattern == "zamba":
            ssm = self.ssm
            d_in = ssm.expand * d
            per_layer = d * (2 * d_in + 2 * ssm.state + d_in // ssm.head_dim) + d_in * d + 2 * d
        emb = v * d * (1 if self.input_mode == "embeddings" else 2)
        n = per_layer * self.n_layers + emb + d
        if self.pattern == "zamba":  # add the shared block once
            n += 4 * d * self.d_attn + 3 * d * self.d_ff
        return n

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discounts inactive experts)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        e_f = self.moe.d_ff
        expert = (3 if self.mlp == "swiglu" else 2) * d * e_f
        inactive = (self.moe.n_experts - self.moe.top_k) * expert * self.n_layers
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: int | None = None  # gradient-accumulation microbatch (train)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def smoke_config(cfg: ModelConfig, d_model: int = 64, vocab: int = 128) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width,
    few experts, tiny embeddings), per the brief's smoke-test requirement."""
    kw: dict = dict(
        name=f"{cfg.name}-smoke", d_model=d_model, vocab=vocab,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=d_model // 4, d_ff=d_model * 2,
        loss_chunk=32, attn_q_chunk=32, attn_kv_chunk=32, attn_min_block=32,
    )
    if cfg.pattern == "uniform":
        kw["n_layers"] = 2
    elif cfg.pattern == "vlm":
        kw.update(n_layers=6, cross_every=3, n_vision_tokens=8)
    elif cfg.pattern == "zamba":
        kw.update(
            n_layers=8, shared_attn_every=3,
            ssm=SSMConfig(state=16, head_dim=16, expand=2, conv=4, chunk=16),
            n_kv_heads=4,
        )
    elif cfg.pattern == "rwkv":
        kw.update(
            n_layers=2, rwkv=RWKVConfig(head_dim=16, lora_rank=8, chunk=16),
            n_kv_heads=4,
        )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff=d_model, group=64,
                              capacity_factor=cfg.moe.capacity_factor)
    return dataclasses.replace(cfg, **kw)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    from . import ALL_ARCHS  # noqa: F401 — ensure modules imported
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from . import ALL_ARCHS
    return list(ALL_ARCHS)
