"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""

ALL_ARCHS = [
    "musicgen-medium",
    "dbrx-132b",
    "granite-moe-1b-a400m",
    "nemotron-4-15b",
    "nemotron-4-340b",
    "deepseek-coder-33b",
    "stablelm-12b",
    "llama-3.2-vision-90b",
    "zamba2-1.2b",
    "rwkv6-7b",
]

from .base import ModelConfig, MoEConfig, SSMConfig, RWKVConfig, ShapeConfig, SHAPES, get_config, list_archs  # noqa: F401,E402
from . import (  # noqa: F401,E402 — populate the registry
    musicgen_medium, dbrx_132b, granite_moe_1b_a400m, nemotron_4_15b,
    nemotron_4_340b, deepseek_coder_33b, stablelm_12b, llama_3_2_vision_90b,
    zamba2_1_2b, rwkv6_7b,
)
