"""Granite-3.0 1B-a400m MoE [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ModelConfig, MoEConfig, register


@register("granite-moe-1b-a400m")
def make() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
        d_ff=512, vocab=49155, mlp="swiglu",
        moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, group=128),
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
    )
