"""Nemotron-4 15B: GQA + squared-ReLU MLP [arXiv:2402.16819; unverified]."""
from .base import ModelConfig, register


@register("nemotron-4-15b")
def make() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=24576, vocab=256000, mlp="squared_relu",
        source="[arXiv:2402.16819; unverified]",
    )
