"""StableLM-2 12B: llama-style GQA [hf:stabilityai/stablelm-2-12b; hf]."""
from .base import ModelConfig, register


@register("stablelm-12b")
def make() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=160,
        d_ff=13824, vocab=100352, mlp="swiglu",
        source="[hf:stabilityai/stablelm-2-1_6b; hf]",
    )
