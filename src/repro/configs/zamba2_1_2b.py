"""Zamba2-1.2B: Mamba2 backbone + shared attention block [arXiv:2411.15242; hf].

Hybrid (sub-quadratic state): eligible for long_500k decode.
"""
from .base import ModelConfig, SSMConfig, register


@register("zamba2-1.2b")
def make() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
        d_ff=8192, vocab=32000, mlp="swiglu",
        ssm=SSMConfig(state=64, head_dim=64, expand=2, conv=4, chunk=64),
        pattern="zamba", shared_attn_every=6, sub_quadratic=True,
        source="[arXiv:2411.15242; hf]",
    )
