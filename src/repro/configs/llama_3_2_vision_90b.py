"""Llama-3.2-Vision 90B backbone: cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

[vlm]: the vision tower is a STUB — ``input_specs`` feeds precomputed patch
embeddings (B, n_vision_tokens, d_model); the 100-layer text backbone with 20
gated cross-attention layers is modeled in full.
"""
from .base import ModelConfig, register


@register("llama-3.2-vision-90b")
def make() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=28672, vocab=128256, mlp="swiglu", rope_theta=500_000.0,
        pattern="vlm", cross_every=5, n_vision_tokens=1024,
        input_mode="tokens+vision",
        source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
    )
