"""MusicGen-Medium decoder backbone over EnCodec tokens [arXiv:2306.05284; hf].

[audio]: the EnCodec frontend is a STUB — ``input_specs`` feeds precomputed
frame embeddings (B, S, d_model); the backbone + 2048-way codebook head are
modeled in full.
"""
from .base import ModelConfig, register


@register("musicgen-medium")
def make() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_head=64,
        d_ff=6144, vocab=2048, mlp="gelu", input_mode="embeddings",
        source="[arXiv:2306.05284; hf]",
    )
