"""Fig 13 analog + crossfilter fan-out: the declarative session layer.

Two parts:

1. **Crossfilter suite** (the new event API): four linked vizzes over the
   Flight schema in one session.  One ``SetFilter`` event re-renders the
   three sibling vizzes; warm per-event latency is compared against
   executing the same three derived queries on a *cold* system (fresh
   MessageStore + fresh plan caches — the paper's Factorized baseline, as in
   ``baselines.cold_engine``).  Asserts the acceptance criteria: ≥3 vizzes
   re-rendered, warm/cold speedup ≥ 5x, and sibling vizzes hitting each
   other's materialized messages (``cross_viz_hits > 0``).

2. **Salesforce legacy suite** (Fig 13): the original dashboard interaction
   set driven through the legacy ``register_dashboard``/``interact``/
   ``think_time`` wrappers, proving the compatibility surface end-to-end.

``REPRO_BENCH_SCALE`` scales both fact tables (CI smoke uses 0.05).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (
    CJTEngine, DashboardSpec, MessageStore, Query, SetFilter, Treant, VizSpec,
    jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in, mask_range

from .baselines import NaiveExecutor, cold_engine
from .common import emit, time_fn, timed_interact


def eng_cold_exec(cat, jt, q):
    eng = cold_engine(cat, sr.SUM, jt)
    f, _ = eng.execute(q)
    import jax
    jax.block_until_ready(f.field)
    return f


# ---------------------------------------------------------------------------
# Crossfilter fan-out (new session API)
# ---------------------------------------------------------------------------

def crossfilter_spec() -> DashboardSpec:
    return DashboardSpec(vizzes=(
        VizSpec("by_state", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("airport_state",)),
        VizSpec("by_month", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("month",)),
        VizSpec("by_size", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("airport_size",)),
        VizSpec("by_carrier", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("carrier_group",)),
    ))


def run_crossfilter(scale: float = 1.0) -> float:
    cat = schema.flight(n_flights=max(2_000, int(100_000 * scale)))
    jt = jt_from_catalog(cat)
    treant = Treant(cat, ring=sr.SUM, jt=jt)

    t_off, _ = time_fn(
        lambda: treant.open_session(crossfilter_spec(), name="bench"),
        repeats=1, warmup=0,
    )
    sess = treant.session("bench")
    emit("crossfilter/CalibrateOffline", t_off, "4 linked vizzes, pinned")

    # warm-up: compile every structure once, then calibrate in think-time
    for ev in (
        SetFilter("carrier_group", values=(0, 1), source="by_carrier"),
        SetFilter("airport_size", values=(1, 2), source="by_size"),
    ):
        sess.apply(ev)
        sess.idle()

    # timed warm events: re-brushes with fresh σ values (plans + off-path
    # messages warm; only the Steiner tree of each event recomputes)
    events = [
        SetFilter("carrier_group", values=(2, 3), source="by_carrier"),
        SetFilter("carrier_group", values=(4,), source="by_carrier"),
        SetFilter("airport_size", values=(0, 3), source="by_size"),
        SetFilter("carrier_group", values=(0, 2), source="by_carrier"),
    ]
    warm_lat, fanouts = [], []
    last_queries: list[Query] = []
    for ev in events:
        t0 = time.perf_counter()
        res = sess.apply(ev)
        warm_lat.append(time.perf_counter() - t0)
        fanouts.append(len(res.affected))
        last_queries = [sess.query_of(v) for v in res.affected]
        sess.idle()
    warm = float(np.median(warm_lat))
    assert min(fanouts) >= 3, f"SetFilter fan-out below 3 linked vizzes: {fanouts}"
    emit("crossfilter/warm_event", warm, f"fan-out={fanouts}")

    # cold baseline: the same three derived queries on a cold system (fresh
    # store + fresh plan caches = baselines.cold_engine semantics)
    def cold_exec():
        eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
        outs = [eng.execute(q)[0] for q in last_queries]
        import jax
        jax.block_until_ready([f.field for f in outs])
        return outs

    t_cold, _ = time_fn(cold_exec, repeats=1, warmup=0)
    emit("crossfilter/cold_3q", t_cold, "fresh store + plans per event")
    speedup = t_cold / max(warm, 1e-9)
    emit("crossfilter/event_speedup", speedup / 1e6, f"warm vs cold = {speedup:.1f}x")
    assert speedup >= 5.0, (
        f"warm crossfilter event only {speedup:.1f}x faster than cold store"
    )

    st = sess.stats()
    emit("crossfilter/cross_viz_hits", st["cross_viz_hits_total"] / 1e6,
         f"sibling message-store hits = {st['cross_viz_hits_total']}")
    assert st["cross_viz_hits_total"] > 0, "sibling vizzes shared no messages"
    emit("crossfilter/scheduler_messages", st["scheduler_messages_total"] / 1e6,
         f"think-time edges = {st['scheduler_messages_total']}")
    return speedup


# ---------------------------------------------------------------------------
# Salesforce legacy suite (Fig 13, via the compatibility wrappers)
# ---------------------------------------------------------------------------

def interactions(cat, q0: Query) -> list[tuple[str, Query]]:
    d = cat.domains()
    out = [
        ("sel_role", q0.with_predicate(mask_in(d["role_name"], [1, 2], attr="role_name"))),
        ("sel_title", q0.with_predicate(mask_in(d["title"], [0, 3, 5], attr="title"))),
        ("sel_start_q", q0.with_predicate(mask_range(d["start_q"], 4, 12, attr="start_q"))),
        ("sel_state", q0.with_predicate(mask_in(d["state"], list(range(10)), attr="state"))),
        ("grp_title", q0.add_group_by("title")),
        ("grp_state", q0.add_group_by("state")),
    ]
    camp2 = cat.get("Camp").perturb_measure("budget", 0.1, seed=7, version="v1")
    cat.put(camp2)
    out.append(("update_Camp", q0.with_version("Camp", "v1")))
    out.append(("remove_Acc", q0.with_removed("Acc")))
    return out


def run(scale: float = 1.0):
    cat = schema.salesforce(n_opp=int(200_000 * scale))
    jt = jt_from_catalog(cat)
    naive = NaiveExecutor(cat, "Opp")

    q_single = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    q_pie = q_single.with_group_by("camp_type")

    treant = Treant(cat, ring=sr.SUM, jt=jt)
    t_off, _ = time_fn(lambda: [
        treant.register_dashboard("single", q_single),
        treant.register_dashboard("pie", q_pie),
    ], repeats=1, warmup=0)
    emit("salesforce/CalibrateOffline", t_off, "both dashboards")

    speedups = []
    for viz, q0 in [("single", q_single), ("pie", q_pie)]:
        for name, q in interactions(cat, q0):
            t_n, r_n = time_fn(naive.execute, q, repeats=2, warmup=0)
            t_f, _ = time_fn(lambda: eng_cold_exec(cat, jt, q), repeats=1, warmup=1)
            t_t, res = timed_interact(treant, "u1", viz, q)
            r_t = np.asarray(res.factor.field, np.float64)
            ok = np.allclose(np.asarray(r_n).ravel(), r_t.ravel(), rtol=1e-3, atol=1e-3)
            speed = t_n / max(t_t, 1e-9)
            speedups.append(speed)
            emit(f"salesforce/{viz}/{name}/naive", t_n)
            emit(f"salesforce/{viz}/{name}/factorized", t_f)
            emit(f"salesforce/{viz}/{name}/treant", t_t,
                 f"speedup={speed:.0f}x match={ok} steiner={res.steiner_size}")
            # think-time calibration for the next interaction (§4.2.1)
            t_cal, _ = time_fn(lambda: treant.think_time("u1", viz), repeats=1, warmup=0)
            emit(f"salesforce/{viz}/{name}/calibrate_online", t_cal)
    st = treant.cache_stats()
    emit("salesforce/store_bytes", st["bytes"] / 1e12, f"messages={st['messages']}")
    emit("salesforce/median_speedup", float(np.median(speedups)) / 1e6,
         f"median naive/treant = {np.median(speedups):.0f}x")
    return speedups


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    run_crossfilter(scale=scale)
    run(scale=scale)


if __name__ == "__main__":
    main()
