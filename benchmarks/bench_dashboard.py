"""Fig 13 analog — Salesforce dashboard: Naive vs Factorized vs Treant.

Two visualizations (single value; pie grouped by camp_type) and the paper's
interaction set: selections on role/title/start-date/state, group-by toggles,
a Camp cell-perturbation update, and removing Acc.  Also reports
CalibrateOffline and CalibrateOnline costs and the message-store footprint.
"""

from __future__ import annotations

import numpy as np

from repro.core import Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in, mask_range

from .baselines import NaiveExecutor, cold_engine
from .common import emit, time_fn, timed_interact


def interactions(cat, q0: Query) -> list[tuple[str, Query]]:
    d = cat.domains()
    out = [
        ("sel_role", q0.with_predicate(mask_in(d["role_name"], [1, 2], attr="role_name"))),
        ("sel_title", q0.with_predicate(mask_in(d["title"], [0, 3, 5], attr="title"))),
        ("sel_start_q", q0.with_predicate(mask_range(d["start_q"], 4, 12, attr="start_q"))),
        ("sel_state", q0.with_predicate(mask_in(d["state"], list(range(10)), attr="state"))),
        ("grp_title", q0.add_group_by("title")),
        ("grp_state", q0.add_group_by("state")),
    ]
    camp2 = cat.get("Camp").perturb_measure("budget", 0.1, seed=7, version="v1")
    cat.put(camp2)
    out.append(("update_Camp", q0.with_version("Camp", "v1")))
    out.append(("remove_Acc", q0.with_removed("Acc")))
    return out


def run(scale: float = 1.0):
    cat = schema.salesforce(n_opp=int(200_000 * scale))
    jt = jt_from_catalog(cat)
    naive = NaiveExecutor(cat, "Opp")

    q_single = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    q_pie = q_single.with_group_by("camp_type")

    treant = Treant(cat, ring=sr.SUM, jt=jt)
    t_off, _ = time_fn(lambda: [
        treant.register_dashboard("single", q_single),
        treant.register_dashboard("pie", q_pie),
    ], repeats=1, warmup=0)
    emit("salesforce/CalibrateOffline", t_off, "both dashboards")

    speedups = []
    for viz, q0 in [("single", q_single), ("pie", q_pie)]:
        for name, q in interactions(cat, q0):
            t_n, r_n = time_fn(naive.execute, q, repeats=2, warmup=0)
            t_f, r_f = time_fn(lambda: eng_cold_exec(cat, jt, q), repeats=1, warmup=1)
            t_t, res = timed_interact(treant, "u1", viz, q)
            r_t = np.asarray(res.factor.field, np.float64)
            if q.removed or q.version_of("Camp") == "v1":
                pass  # naive handles these too
            ok = np.allclose(np.asarray(r_n).ravel(), np.sort_complex(r_t.ravel()).real
                             if False else r_t.ravel(), rtol=1e-3, atol=1e-3)
            speed = t_n / max(t_t, 1e-9)
            speedups.append(speed)
            emit(f"salesforce/{viz}/{name}/naive", t_n)
            emit(f"salesforce/{viz}/{name}/factorized", t_f)
            emit(f"salesforce/{viz}/{name}/treant", t_t,
                 f"speedup={speed:.0f}x match={ok}")
            # think-time calibration for the next interaction (§4.2.1)
            t_cal, _ = time_fn(lambda: treant.think_time("u1", viz), repeats=1, warmup=0)
            emit(f"salesforce/{viz}/{name}/calibrate_online", t_cal)
    st = treant.cache_stats()
    emit("salesforce/store_bytes", st["bytes"] / 1e12, f"messages={st['messages']}")
    emit("salesforce/median_speedup", float(np.median(speedups)) / 1e6,
         f"median naive/treant = {np.median(speedups):.0f}x")
    return speedups


def eng_cold_exec(cat, jt, q):
    eng = cold_engine(cat, sr.SUM, jt)
    f, _ = eng.execute(q)
    import jax
    jax.block_until_ready(f.field)
    return f


def main():
    run(scale=5.0)  # 1M-row fact: the paper's >100x regime


if __name__ == "__main__":
    main()
