"""Fig 13 analog + crossfilter fan-out: the declarative session layer.

Three parts:

1. **Crossfilter suite** (the new event API): four linked vizzes over the
   Flight schema in one session.  One ``SetFilter`` event re-renders the
   three sibling vizzes; warm per-event latency is compared against (a) the
   same events on the *per-viz dispatch* path (``batch_fanout=False`` — the
   pre-batching baseline, one plan dispatch per sibling) and (b) the same
   three derived queries on a *cold* system (fresh MessageStore + fresh plan
   caches — the paper's Factorized baseline, as in ``baselines.cold_engine``).
   Asserts the acceptance criteria: ≥3 vizzes re-rendered, warm/cold
   speedup ≥ 5x, batched/unbatched ≥ 1.5x at full scale with
   ``batched_absorptions > 0``, and sibling vizzes hitting each other's
   materialized messages (``cross_viz_hits > 0``).

2. **Speculative σ prefetch**: after ``Session.idle(speculate=)``, a
   re-brush on a prefetched σ value must perform **zero** plan executions
   and zero store probes (pure prefetch-cache hit).

3. **Salesforce legacy suite** (Fig 13): the original dashboard interaction
   set driven through the legacy ``register_dashboard``/``interact``/
   ``think_time`` wrappers, proving the compatibility surface end-to-end.

``REPRO_BENCH_SCALE`` scales both fact tables (CI smoke uses 0.05).  All
randomness is seed-pinned (see ``common.seeded_rng``): BENCH_dashboard.json
is reproducible run-to-run.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (
    CJTEngine, DashboardSpec, MessageStore, Query, SetFilter, Treant, VizSpec,
    jt_from_catalog, speculate_filters,
)
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in, mask_range

from .baselines import NaiveExecutor, cold_engine
from .common import emit, time_fn, timed_interact

# pinned schema seeds (reproducible BENCH_*.json deltas)
FLIGHT_SEED = 1
SALESFORCE_SEED = 0


def eng_cold_exec(cat, jt, q):
    eng = cold_engine(cat, sr.SUM, jt)
    f, _ = eng.execute(q)
    import jax
    jax.block_until_ready(f.field)
    return f


# ---------------------------------------------------------------------------
# Crossfilter fan-out (new session API)
# ---------------------------------------------------------------------------

def crossfilter_spec() -> DashboardSpec:
    """Eight linked vizzes (a realistic Mosaic-scale dashboard): one brush
    fans out to seven siblings, which is exactly the regime the vmapped
    batch absorption targets — the single-γ siblings share one batch
    signature and execute as one compiled call."""
    m = ("Flights", "dep_delay")
    return DashboardSpec(vizzes=(
        VizSpec("by_state", measure=m, ring="sum", group_by=("airport_state",)),
        VizSpec("by_month", measure=m, ring="sum", group_by=("month",)),
        VizSpec("by_size", measure=m, ring="sum", group_by=("airport_size",)),
        VizSpec("by_carrier", measure=m, ring="sum", group_by=("carrier_group",)),
        VizSpec("by_dow", measure=m, ring="sum", group_by=("dow",)),
        VizSpec("by_delay", measure=m, ring="sum", group_by=("delay_bucket",)),
        VizSpec("by_distance", measure=m, ring="sum", group_by=("distance_bucket",)),
        VizSpec("state_by_size", measure=m, ring="sum",
                group_by=("airport_state", "airport_size")),
    ))


WARMUP_EVENTS = (
    SetFilter("carrier_group", values=(0, 1), source="by_carrier"),
    SetFilter("airport_size", values=(1, 2), source="by_size"),
)
# timed warm events: re-brushes with fresh σ values (plans + off-path
# messages warm; only the Steiner tree of each event recomputes).  Eight
# events keep the median robust against scheduler noise on a 1-vCPU box.
TIMED_EVENTS = (
    SetFilter("carrier_group", values=(2, 3), source="by_carrier"),
    SetFilter("carrier_group", values=(4,), source="by_carrier"),
    SetFilter("airport_size", values=(0, 3), source="by_size"),
    SetFilter("carrier_group", values=(0, 2), source="by_carrier"),
    SetFilter("carrier_group", values=(1, 5), source="by_carrier"),
    SetFilter("airport_size", values=(2,), source="by_size"),
    SetFilter("carrier_group", values=(3, 4), source="by_carrier"),
    SetFilter("carrier_group", values=(5,), source="by_carrier"),
)


def _prewarm_process():
    """Pay the process-wide one-time costs (jax backend init, pallas
    interpret machinery, jit infra) on a throwaway mini dashboard so the
    timed offline phases below measure steady-state compile+execute — the
    first Treant to calibrate would otherwise absorb the warmup and skew
    the batched-vs-per-edge offline ratio."""
    warm_cat = schema.flight(n_flights=2_000, seed=FLIGHT_SEED)
    tw = Treant(warm_cat, ring=sr.SUM, jt=jt_from_catalog(warm_cat))
    tw.open_session(crossfilter_spec(), name="prewarm")


def _open_session(cat, jt, batch_fanout: bool, batch_calibration: bool):
    """Open + calibrate a session (the timed offline stage, §4.1.1).

    Returns the Treant, the offline wall time and the number of message
    dispatches the offline stage issued (-1 with plans off)."""
    treant = Treant(cat, ring=sr.SUM, jt=jt, batch_fanout=batch_fanout,
                    batch_calibration=batch_calibration)
    t_off, _ = time_fn(
        lambda: treant.open_session(crossfilter_spec(), name="bench"),
        repeats=1, warmup=0,
    )
    st = treant.cache_stats()
    dispatches = st["plans"]["calibration_dispatches"] if "plans" in st else -1
    return treant, t_off, dispatches


def _warm_session(treant):
    """Warm every plan structure — two untimed event passes: the first
    compiles the pre-calibration plan structures, the second the
    post-calibration ones (once think-time has fully calibrated a σ family,
    choose_root settles on the cheapest bag, which is a different absorption
    structure than the cold pick)."""
    sess = treant.session("bench")
    for ev in WARMUP_EVENTS + TIMED_EVENTS + TIMED_EVENTS:
        sess.apply(ev)
        sess.idle()
    return sess


def _timed_pass(treant, sess):
    """One timed pass over the warm-event sequence; returns per-event
    latencies, fan-out widths and the last event's derived queries."""
    lat, fanouts = [], []
    last_queries: list[Query] = []
    for ev in TIMED_EVENTS:
        # drain async think-time calibration before timing: the warm event
        # must measure its own cost, not the idle()-dispatched device work
        treant.store.block_until_ready()
        t0 = time.perf_counter()
        res = sess.apply(ev)
        lat.append(time.perf_counter() - t0)
        fanouts.append(len(res.affected))
        last_queries = [sess.query_of(v) for v in res.affected]
        sess.idle()
    return lat, fanouts, last_queries


def _plan_execs(treant) -> int:
    st = treant.cache_stats()
    if "plans" not in st:
        return -1  # plans disabled (REPRO_USE_PLANS=0): not countable
    return st["plans"]["plans_built"] + st["plans"]["plan_hits"]


def run_crossfilter(scale: float = 1.0) -> float:
    cat = schema.flight(n_flights=max(2_000, int(100_000 * scale)),
                        seed=FLIGHT_SEED)
    jt = jt_from_catalog(cat)

    # A/B the offline stage back-to-back FIRST (process prewarmed, no other
    # work interleaved): level-batched calibration (union-carry passes +
    # vmapped level groups) vs the per-edge reference loop.  Then warm both
    # legs and interleave their timed event passes — back-to-back
    # interleaving keeps machine drift (GC, page cache, sibling processes)
    # out of the batched-vs-unbatched ratio.
    _prewarm_process()
    treant, t_off, disp_b = _open_session(
        cat, jt, batch_fanout=True, batch_calibration=True
    )
    emit("crossfilter/CalibrateOffline", t_off,
         "8 linked vizzes, pinned (level-batched)")
    treant_u, t_off_u, disp_u = _open_session(
        cat, jt, batch_fanout=False, batch_calibration=False
    )
    emit("crossfilter/CalibrateOffline_per_edge", t_off_u,
         "per-edge calibration loop (PR-4 path)")
    off_speedup = t_off_u / max(t_off, 1e-9)
    emit("crossfilter/offline_batch_speedup", off_speedup / 1e6,
         f"level-batched vs per-edge offline = {off_speedup:.2f}x")
    emit("crossfilter/calibration_dispatches", max(disp_b, 0) / 1e6,
         f"batched={disp_b} per_edge={disp_u}")
    if disp_b >= 0:
        # dispatch counts are structural, not timing — assert at every scale
        assert 0 < disp_b < disp_u, (
            f"level-batched offline did not reduce dispatches: "
            f"{disp_b} vs {disp_u}"
        )
        # the regression-gated offline dispatch count (lower is better):
        # with level fusion every calibration pass costs ≤ #levels launches
        emit("crossfilter/offline_dispatches", disp_b / 1e6,
             f"fused offline dispatches = {disp_b}")
        if treant.fuse_level_kernel:
            max_levels = max(
                len(jt.calibration_levels(b)) for b in jt.bags
            )
            assert disp_b <= max_levels, (
                f"level fusion left {disp_b} offline dispatches "
                f"(tree depth bounds levels at {max_levels})"
            )
            fused = treant.cache_stats()["plans"]
            emit("crossfilter/fused_level_launches",
                 fused["fused_level_launches"] / 1e6,
                 f"launches={fused['fused_level_launches']} "
                 f"messages={fused['fused_level_messages']}")
            assert fused["fused_level_launches"] > 0, (
                "offline calibration never took the fused level kernel"
            )
        if scale >= 1.0:
            assert off_speedup >= 1.3, (
                f"level-batched offline calibration only {off_speedup:.2f}x "
                f"vs the per-edge loop"
            )
    sess = _warm_session(treant)
    sess_u = _warm_session(treant_u)
    lvl = treant.cache_stats().get("plans")
    if lvl is not None:
        # think-time idles drain level-by-level across vizzes: the σ'd
        # sibling calibrations are where the vmapped level batches fire
        # (offline union-carry passes fuse most same-pattern pairs away)
        emit("crossfilter/level_batched_execs", lvl["level_batched_execs"] / 1e6,
             f"calls={lvl['level_batched_execs']} "
             f"messages={lvl['level_batched_messages']} "
             f"width={lvl['level_batch_width']}")
        assert lvl["level_batched_execs"] > 0, (
            "think-time level drain never batched sibling messages"
        )
    # the two legs must render identical aggregates (float ⊕-order differs
    # through union-carry narrowing, so allclose rather than bitwise here;
    # bit-identity on integer data is tests/test_level_calibration.py's job)
    for viz in sess.vizzes:
        fb = np.asarray(sess.read(viz).factor.field, np.float64)
        fu = np.asarray(sess_u.read(viz).factor.field, np.float64)
        assert np.allclose(fb, fu, rtol=1e-5, atol=1e-5), (
            f"batched/per-edge calibration disagree on {viz}"
        )
    lat_b, lat_u = [], []
    fanouts, last_queries = [], []
    for _ in range(3):
        lat, fanouts, last_queries = _timed_pass(treant, sess)
        lat_b += lat
        lat, _, _ = _timed_pass(treant_u, sess_u)
        lat_u += lat
    warm = float(np.median(lat_b))
    warm_unbatched = float(np.median(lat_u))
    assert min(fanouts) >= 7, f"SetFilter fan-out below 7 linked vizzes: {fanouts}"
    emit("crossfilter/warm_event", warm, f"fan-out={fanouts} (batched)")
    emit("crossfilter/warm_event_unbatched", warm_unbatched,
         "per-viz dispatch (PR-3 path)")
    batch_speedup = warm_unbatched / max(warm, 1e-9)
    emit("crossfilter/batch_speedup", batch_speedup / 1e6,
         f"batched vs per-viz dispatch = {batch_speedup:.2f}x")
    plans = treant.cache_stats().get("plans")
    if plans is not None:
        emit("crossfilter/batched_absorptions", plans["batched_absorptions"] / 1e6,
             f"batched={plans['batched_absorptions']} "
             f"calls={plans['batched_execs']} width={plans['batch_width']}")
        assert plans["batched_absorptions"] > 0, "fan-out never batched"
        if scale >= 1.0:
            # floor guard only — the trajectory of this ratio is tracked by
            # benchmarks/check_regression.py against the committed baseline,
            # which is robust to host drift in a way a hard constant is not
            assert batch_speedup >= 1.25, (
                f"batched warm SetFilter only {batch_speedup:.2f}x vs the "
                f"per-viz dispatch path"
            )

    # cold baseline: the same three derived queries on a cold system (fresh
    # store + fresh plan caches = baselines.cold_engine semantics)
    def cold_exec():
        eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
        outs = [eng.execute(q)[0] for q in last_queries]
        import jax
        jax.block_until_ready([f.field for f in outs])
        return outs

    t_cold, _ = time_fn(cold_exec, repeats=1, warmup=0)
    emit("crossfilter/cold_3q", t_cold, "fresh store + plans per event")
    speedup = t_cold / max(warm, 1e-9)
    emit("crossfilter/event_speedup", speedup / 1e6, f"warm vs cold = {speedup:.1f}x")
    assert speedup >= 5.0, (
        f"warm crossfilter event only {speedup:.1f}x faster than cold store"
    )

    # speculative σ prefetch: think-time pre-materializes the neighbor
    # brushes; the re-brush must execute NOTHING (0 plan executions)
    anchor = SetFilter("carrier_group", values=(1, 2), source="by_carrier")
    sess.apply(anchor)
    sess.idle(speculate=2)
    cand = speculate_filters(anchor, cat.domains()["carrier_group"], 1)[0]
    execs0 = _plan_execs(treant)
    t0 = time.perf_counter()
    res = sess.apply(cand)
    t_prefetch = time.perf_counter() - t0
    execs = _plan_execs(treant) - execs0
    hits = sum(r.stats.prefetch_hits for r in res.results.values())
    emit("crossfilter/prefetched_rebrush", t_prefetch,
         f"plan_execs={max(execs, 0)} prefetch_hits={hits}")
    assert hits == len(res.affected) >= 7, "re-brush missed the prefetch cache"
    if execs0 >= 0:
        assert execs == 0, f"prefetched re-brush executed {execs} plans"
    emit("crossfilter/prefetch_speedup", (warm / max(t_prefetch, 1e-9)) / 1e6,
         f"prefetched vs warm batched = {warm / max(t_prefetch, 1e-9):.1f}x")

    st = sess.stats()
    emit("crossfilter/cross_viz_hits", st["cross_viz_hits_total"] / 1e6,
         f"sibling message-store hits = {st['cross_viz_hits_total']}")
    assert st["cross_viz_hits_total"] > 0, "sibling vizzes shared no messages"
    emit("crossfilter/scheduler_messages", st["scheduler_messages_total"] / 1e6,
         f"think-time edges = {st['scheduler_messages_total']}")
    return speedup


# ---------------------------------------------------------------------------
# Salesforce legacy suite (Fig 13, via the compatibility wrappers)
# ---------------------------------------------------------------------------

def interactions(cat, q0: Query) -> list[tuple[str, Query]]:
    d = cat.domains()
    out = [
        ("sel_role", q0.with_predicate(mask_in(d["role_name"], [1, 2], attr="role_name"))),
        ("sel_title", q0.with_predicate(mask_in(d["title"], [0, 3, 5], attr="title"))),
        ("sel_start_q", q0.with_predicate(mask_range(d["start_q"], 4, 12, attr="start_q"))),
        ("sel_state", q0.with_predicate(mask_in(d["state"], list(range(10)), attr="state"))),
        ("grp_title", q0.add_group_by("title")),
        ("grp_state", q0.add_group_by("state")),
    ]
    camp2 = cat.get("Camp").perturb_measure("budget", 0.1, seed=7, version="v1")
    cat.put(camp2)
    out.append(("update_Camp", q0.with_version("Camp", "v1")))
    out.append(("remove_Acc", q0.with_removed("Acc")))
    return out


def run(scale: float = 1.0):
    cat = schema.salesforce(n_opp=int(200_000 * scale), seed=SALESFORCE_SEED)
    jt = jt_from_catalog(cat)
    naive = NaiveExecutor(cat, "Opp")

    q_single = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    q_pie = q_single.with_group_by("camp_type")

    treant = Treant(cat, ring=sr.SUM, jt=jt)
    t_off, _ = time_fn(lambda: [
        treant.register_dashboard("single", q_single),
        treant.register_dashboard("pie", q_pie),
    ], repeats=1, warmup=0)
    emit("salesforce/CalibrateOffline", t_off, "both dashboards")

    speedups = []
    for viz, q0 in [("single", q_single), ("pie", q_pie)]:
        for name, q in interactions(cat, q0):
            t_n, r_n = time_fn(naive.execute, q, repeats=2, warmup=0)
            t_f, _ = time_fn(lambda: eng_cold_exec(cat, jt, q), repeats=1, warmup=1)
            t_t, res = timed_interact(treant, "u1", viz, q)
            r_t = np.asarray(res.factor.field, np.float64)
            ok = np.allclose(np.asarray(r_n).ravel(), r_t.ravel(), rtol=1e-3, atol=1e-3)
            speed = t_n / max(t_t, 1e-9)
            speedups.append(speed)
            emit(f"salesforce/{viz}/{name}/naive", t_n)
            emit(f"salesforce/{viz}/{name}/factorized", t_f)
            emit(f"salesforce/{viz}/{name}/treant", t_t,
                 f"speedup={speed:.0f}x match={ok} steiner={res.steiner_size}")
            # think-time calibration for the next interaction (§4.2.1)
            t_cal, _ = time_fn(lambda: treant.think_time("u1", viz), repeats=1, warmup=0)
            emit(f"salesforce/{viz}/{name}/calibrate_online", t_cal)
    st = treant.cache_stats()
    emit("salesforce/store_bytes", st["bytes"] / 1e12, f"messages={st['messages']}")
    emit("salesforce/median_speedup", float(np.median(speedups)) / 1e6,
         f"median naive/treant = {np.median(speedups):.0f}x")
    return speedups


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    run_crossfilter(scale=scale)
    run(scale=scale)


if __name__ == "__main__":
    main()
