"""Fig 24/25 analog — data cubes over CJTs (Appendix D).

Builds all cuboids with ≤ 3 group-by attrs over the flight star schema for
pivot dimensionality k ∈ {0, 1, 2}: calibration cost grows with k while
per-cuboid query time falls (smaller Steiner trees / direct cache hits).
Also reports the message-store footprint (Fig 25's data size).
"""

from __future__ import annotations

import numpy as np

from repro.core import CJTEngine, MessageStore, Query, build_cube, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema

from .common import emit


DIMS = ("carrier_group", "airport_state", "month", "dow")


def run(scale: float = 0.2):
    cat = schema.flight(n_flights=int(300_000 * scale))
    jt = jt_from_catalog(cat)
    base = Query.make(cat, ring="count")

    # warm the jit caches so k=0 isn't charged for compilation
    warm = CJTEngine(jt, cat, sr.COUNT, store=MessageStore())
    build_cube(warm, base, DIMS[:2], h=1, pivot_k=0)

    for k in (0, 1, 2):
        eng = CJTEngine(jt, cat, sr.COUNT, store=MessageStore())
        rep = build_cube(eng, base, DIMS, h=3, pivot_k=k)
        emit(f"cube/k{k}/calibrate", rep.calibrate_s)
        emit(f"cube/k{k}/query_total", rep.total_query_s,
             f"{len(rep.cuboids)} cuboids store={rep.store_bytes/1e6:.1f}MB")
        worst = max(rep.query_s.items(), key=lambda kv: kv[1])
        emit(f"cube/k{k}/query_worst", worst[1], "+".join(worst[0]) or "apex")

    # correctness: cuboids marginalize consistently (apex == any rollup)
    eng = CJTEngine(jt, cat, sr.COUNT, store=MessageStore())
    rep = build_cube(eng, base, DIMS, h=2, pivot_k=1)
    apex = float(np.asarray(rep.cuboids[()].field))
    for combo, f in rep.cuboids.items():
        assert abs(float(np.asarray(f.field).sum()) - apex) / apex < 1e-5, combo
    emit("cube/rollup_consistency", 0.0, "all cuboids sum to apex")


def main():
    run()


if __name__ == "__main__":
    main()
