"""§Perf hillclimb report: baseline vs variant roofline terms.

Reads baselines from artifacts/dryrun and variants from artifacts/hillclimb,
derives the three roofline terms for each, and prints before → after per
variant with the delta on each term.  Appended to EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import json
from pathlib import Path

from .roofline import CHIPS, HBM_BW, ICI_BW, PEAK_FLOPS, model_flops

ART = Path(__file__).resolve().parents[1] / "artifacts"


def terms(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    if "analysis" in cell:
        ex = cell["analysis"]["extrapolated"]
        flops, bytes_, wire = ex["flops"], ex["bytes"], ex["wire_bytes"]
    else:  # treant cells: production program IS the full program (no scans)
        flops = cell["cost_raw"]["flops"]
        bytes_ = cell["cost_raw"]["bytes_accessed"]
        wire = cell["collectives_schedule"]["wire_bytes"]
    return {
        "compute": flops / PEAK_FLOPS,
        "memory": bytes_ / HBM_BW,
        "collective": wire / ICI_BW,
        "flops": flops, "bytes": bytes_, "wire": wire,
    }


def main():
    base_cache: dict[str, dict] = {}
    for var_path in sorted((ART / "hillclimb").glob("*.json")):
        var = json.loads(var_path.read_text())
        arch, shape, mesh = var["arch"], var["shape"], var["mesh"]
        tag = var_path.stem.split("__")[-1]
        base_name = f"{arch}__{shape}__{mesh}.json"
        if base_name not in base_cache:
            bp = ART / "dryrun" / base_name
            if not bp.exists():
                bp = ART / "dryrun" / f"{arch}__chain__{mesh}.json"
            base_cache[base_name] = json.loads(bp.read_text())
        base = base_cache[base_name]
        tb, tv = terms(base), terms(var)
        if not tb or not tv:
            print(f"{arch} × {shape} [{tag}]: variant status={var.get('status')}")
            continue
        scale_b = scale_v = 1.0
        if arch == "treant_dashboard":
            scale_v = 1.0 / max(var.get("n_measures", 1), 1)  # per-measure terms
        print(f"\n## {arch} × {shape} [{tag}]")
        for t in ("compute", "memory", "collective"):
            b, v = tb[t] * scale_b, tv[t] * scale_v
            delta = (v - b) / b * 100 if b else float("nan")
            print(f"  {t:10s}: {b:.3e} s → {v:.3e} s  ({delta:+.1f}%)")
        dom = max(("compute", "memory", "collective"), key=lambda t: tb[t] * scale_b)
        print(f"  dominant-at-baseline: {dom}")


if __name__ == "__main__":
    main()
