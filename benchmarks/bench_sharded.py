"""Sharded CJT execution: fact-scan rows/sec scaling over a simulated mesh.

Measures the tentpole claim of ISSUE 9: row-sharding the fact relation
across a device mesh turns the per-query fact scan (rowwise lift +
segment-⊕) into an embarrassingly parallel map whose only cross-shard
traffic is a tiny ``(|γ|, V)`` ⊕-all-reduce — so warm-query throughput on a
scan-bound workload scales with mesh width.  The workload keeps the
dimension relations tiny and the fact large, cycles through distinct σ
masks so every execute computes real messages (no store hits), and times
the steady state with plans compiled.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
sharded leg does); widths beyond ``jax.device_count()`` are skipped.  The
``≥2x scaling 1→8 devices`` acceptance assert only fires where the host can
physically parallelize (≥4 cores) — a 1-core container still emits the
metrics for trend tracking, it just cannot exhibit scaling.

Emitted following the suite ratio convention (value/1e6 so the stored JSON
value IS the figure): ``sharded/rows_per_sec_{n}dev`` and
``sharded/scaleup_8dev``.
"""

from __future__ import annotations

import os
import time

import numpy as np

import jax

from repro.core import Query, Treant, jt_from_catalog
from repro.core import distributed as dist
from repro.core import semiring as sr
from repro.relational.relation import Catalog, Relation, mask_in

from .common import emit, seeded_rng

WIDTHS = (1, 2, 4, 8)
DOM_A = 32  # predicate attribute: one distinct σ mask per timed execute


def _catalog(scale: float) -> Catalog:
    rng = seeded_rng("sharded/catalog")
    n = max(20_000, int(400_000 * scale))
    doms = {"a": DOM_A, "b": 7, "c": 5, "d": 8}
    codes = {a: rng.integers(0, doms[a], n).astype(np.int32) for a in ("a", "b")}
    meas = {"m": rng.integers(0, 16, n).astype(np.float32)}
    rels = [Relation("F", ("a", "b"), codes, doms, measures=meas)]
    for name, attrs, rows in (("S", ("b", "c"), 60), ("T", ("c", "d"), 40)):
        rels.append(Relation(
            name, attrs,
            {a: rng.integers(0, doms[a], rows).astype(np.int32) for a in attrs},
            doms,
        ))
    return Catalog(rels)


def _queries(cat: Catalog, k: int) -> list[Query]:
    """k queries with distinct single-value σ masks on the fact: same plan
    (shapes/key identical), different data — every execute is a real scan."""
    return [
        Query.make(
            cat, ring="sum", measure=("F", "m"), group_by=("d",),
            predicates=(mask_in(DOM_A, [i % DOM_A], attr="a"),),
        )
        for i in range(k)
    ]


def _rows_per_sec(ndev: int, scale: float, iters: int) -> float:
    mesh = dist.make_engine_mesh(ndev)
    cat = _catalog(scale)
    t = Treant(cat, ring=sr.SUM, jt=jt_from_catalog(cat), use_plans=True,
               mesh=mesh if ndev > 1 else 0)
    n_rows = cat.get("F").num_rows
    qs = _queries(cat, iters + 2)
    for q in qs[:2]:  # compile the (sharded) plan + warm the code cache
        jax.block_until_ready(t.engine.execute(q)[0].field)
    t0 = time.perf_counter()
    for q in qs[2:]:
        jax.block_until_ready(t.engine.execute(q)[0].field)
    dt = time.perf_counter() - t0
    if ndev > 1:
        st = t.cache_stats()["plans"]
        assert st["shard_execs"] > 0, "sharded leg executed unsharded"
        assert st["allreduce_bytes"] > 0
    return n_rows * iters / max(dt, 1e-9)


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    iters = max(4, int(12 * min(1.0, scale * 4)))
    rps: dict[int, float] = {}
    for ndev in WIDTHS:
        if ndev > 1 and jax.device_count() < ndev:
            print(f"# sharded: skipping {ndev}dev "
                  f"(only {jax.device_count()} devices)", flush=True)
            continue
        rps[ndev] = _rows_per_sec(ndev, scale, iters)
        emit(f"sharded/rows_per_sec_{ndev}dev", rps[ndev] / 1e6,
             f"rows={max(20_000, int(400_000 * scale))} iters={iters}")
    if 8 in rps and 1 in rps:
        scaleup = rps[8] / max(rps[1], 1e-9)
        emit("sharded/scaleup_8dev", scaleup / 1e6,
             f"1dev={rps[1]:.0f} 8dev={rps[8]:.0f} rows/s "
             f"cores={os.cpu_count()}")
        min_scaleup = float(
            os.environ.get("REPRO_SHARD_BENCH_MIN_SCALEUP", "2.0")
        )
        if (os.cpu_count() or 1) >= 4:
            # acceptance bar (ISSUE 9): ≥2x rows/sec 1→8 simulated devices.
            # Only meaningful where the host can run shards in parallel — a
            # 1-core container serializes every device and shows ~1x.
            assert scaleup >= min_scaleup, (
                f"sharded scaling {scaleup:.2f}x < {min_scaleup}x (1→8 devices)"
            )


if __name__ == "__main__":
    main()
