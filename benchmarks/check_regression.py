"""CI perf-regression gate: compare a fresh BENCH_dashboard.json against the
committed baseline snapshot in ``benchmarks/baselines/``.

The smoke bench uploads ``BENCH_*.json`` artifacts on every CI run, but until
this gate nothing ever *compared* them — a silent warm-event regression
could land unnoticed.  This script fails (exit 1) when a gated metric
regresses beyond its per-metric tolerance:

- latency metrics (``warm_event``) regress when they grow;
- speedup-ratio metrics (``event_speedup``, ``prefetch_speedup``, …)
  regress when they shrink.

It is **scale-aware**: ratio metrics that only separate from noise at full
scale (``batch_speedup`` is ~1.0 at the CI smoke scale 0.05, where per-event
work is sub-millisecond) carry a ``min_scale`` and are skipped below it —
the nightly full-scale workflow is where they are recorded.

Usage::

    python -m benchmarks.check_regression                 # CI gate
    python -m benchmarks.check_regression --self-test     # prove it fires
    python -m benchmarks.check_regression --write-baseline  # refresh snapshot

Baseline refresh procedure (see ROADMAP.md): after an *intentional* perf
change, regenerate the smoke-scale summary on the matrix leg and commit it::

    REPRO_BENCH_SCALE=0.05 REPRO_USE_PLANS=1 \
        PYTHONPATH=src python -m benchmarks.run dashboard
    PYTHONPATH=src python -m benchmarks.check_regression --write-baseline
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys


@dataclasses.dataclass(frozen=True)
class Metric:
    """Gate spec for one BENCH metric (values are the emitted us_per_call
    column; ratio metrics are emitted as ratio/1e6 so the value IS the
    ratio)."""

    lower_is_better: bool
    tolerance: float          # fractional regression allowed (0.20 = 20%)
    min_scale: float = 0.0    # skip below this REPRO_BENCH_SCALE


# Per-metric tolerances.  The three headline metrics fail the PR on >20%
# regression; ratio metrics meaningful only at full scale are nightly-gated.
GATED: dict[str, Metric] = {
    "crossfilter/warm_event": Metric(lower_is_better=True, tolerance=0.20),
    "crossfilter/event_speedup": Metric(lower_is_better=False, tolerance=0.20),
    "crossfilter/prefetch_speedup": Metric(lower_is_better=False, tolerance=0.20),
    "crossfilter/batch_speedup": Metric(
        lower_is_better=False, tolerance=0.20, min_scale=1.0
    ),
    "crossfilter/offline_batch_speedup": Metric(
        lower_is_better=False, tolerance=0.20, min_scale=1.0
    ),
}


def plans_leg() -> str:
    return "1" if os.environ.get("REPRO_USE_PLANS", "1").lower() not in (
        "0", "false"
    ) else "0"


def default_baseline(scale: float) -> str:
    """Baselines are keyed by plans leg AND scale band: absolute latencies
    at smoke scale are not comparable to full scale, and the full-scale
    snapshot (nightly gate) holds only the host-robust ratio metrics."""
    here = os.path.dirname(os.path.abspath(__file__))
    suffix = ".scale1" if scale >= 1.0 else ""
    return os.path.join(
        here, "baselines", f"BENCH_dashboard.plans{plans_leg()}{suffix}.json"
    )


def compare(
    fresh: dict, baseline: dict, scale: float
) -> tuple[list[str], list[str]]:
    """Returns (failures, report lines)."""
    failures: list[str] = []
    report: list[str] = []
    for name, spec in GATED.items():
        if scale < spec.min_scale:
            report.append(
                f"SKIP  {name}: scale {scale} < {spec.min_scale} "
                f"(full-scale-only ratio metric)"
            )
            continue
        if name not in baseline:
            report.append(f"SKIP  {name}: not in baseline")
            continue
        if name not in fresh:
            failures.append(f"{name}: missing from fresh summary")
            report.append(f"FAIL  {name}: missing from fresh summary")
            continue
        base, now = float(baseline[name]), float(fresh[name])
        if spec.lower_is_better:
            limit = base * (1.0 + spec.tolerance)
            bad = now > limit
            delta = (now - base) / base if base else 0.0
        else:
            limit = base * (1.0 - spec.tolerance)
            bad = now < limit
            delta = (base - now) / base if base else 0.0
        verdict = "FAIL" if bad else "ok"
        report.append(
            f"{verdict:>4}  {name}: baseline={base:.3f} fresh={now:.3f} "
            f"limit={limit:.3f} (regression {delta * 100:+.1f}%, "
            f"tol {spec.tolerance * 100:.0f}%)"
        )
        if bad:
            failures.append(
                f"{name}: {now:.3f} vs baseline {base:.3f} "
                f"(> {spec.tolerance * 100:.0f}% regression)"
            )
    return failures, report


def self_test(fresh: dict | None, baseline: dict | None) -> int:
    """Dry run proving the gate fires: a deliberate tolerance-violating
    baseline edit must produce failures, and an in-tolerance wiggle must
    not.  Uses the real summaries when available, synthetic ones otherwise
    (so the self-test runs before any bench has ever executed)."""
    if not baseline:
        baseline = {
            "crossfilter/warm_event": 20_000.0,
            "crossfilter/event_speedup": 50.0,
            "crossfilter/prefetch_speedup": 6.0,
            "crossfilter/batch_speedup": 1.6,
            "crossfilter/offline_batch_speedup": 1.6,
        }
    if not fresh:
        fresh = dict(baseline)
    ok = True

    # 1) identical summaries: must pass at every scale
    failures, _ = compare(dict(baseline), dict(baseline), scale=1.0)
    if failures:
        print(f"self-test: clean comparison failed: {failures}")
        ok = False

    # 2) deliberate tolerance-violating edit on each gated metric: must fail
    for name, spec in GATED.items():
        if name not in baseline:
            continue
        bad = dict(fresh) if name in fresh else dict(baseline)
        factor = (1.0 + 2 * spec.tolerance) if spec.lower_is_better else (
            1.0 - 2 * spec.tolerance
        )
        bad[name] = float(baseline[name]) * factor
        failures, _ = compare(bad, baseline, scale=max(spec.min_scale, 1.0))
        if not any(name in f for f in failures):
            print(f"self-test: gate did NOT fire on a 2x-tolerance "
                  f"regression of {name}")
            ok = False
        # within tolerance: must not fire
        mild = dict(bad)
        mild_factor = (1.0 + spec.tolerance / 2) if spec.lower_is_better else (
            1.0 - spec.tolerance / 2
        )
        mild[name] = float(baseline[name]) * mild_factor
        failures, _ = compare(mild, baseline, scale=max(spec.min_scale, 1.0))
        if any(name in f for f in failures):
            print(f"self-test: gate fired inside tolerance for {name}")
            ok = False

    # 3) scale-awareness: a full-scale-only metric must be skipped (not
    # failed) at the smoke scale even when catastrophically regressed
    bad = dict(baseline)
    bad["crossfilter/batch_speedup"] = 0.01
    failures, _ = compare(bad, baseline, scale=0.05)
    if any("batch_speedup" in f for f in failures):
        print("self-test: full-scale-only metric gated at smoke scale")
        ok = False

    print(f"self-test: {'PASS — the gate fires' if ok else 'FAIL'}")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", default="BENCH_dashboard.json",
                    help="freshly produced bench summary")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline (default: benchmarks/baselines/"
                         "BENCH_dashboard.plans<REPRO_USE_PLANS>.json)")
    ap.add_argument("--scale", type=float,
                    default=float(os.environ.get("REPRO_BENCH_SCALE", "1.0")),
                    help="bench scale the fresh summary was produced at")
    ap.add_argument("--self-test", action="store_true",
                    help="prove the gate fires on a deliberate regression")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy --fresh over the baseline (refresh procedure)")
    args = ap.parse_args()

    baseline_path = args.baseline or default_baseline(args.scale)

    def load(path):
        if not os.path.exists(path):
            return None
        with open(path) as fh:
            return json.load(fh)

    if args.self_test:
        return self_test(load(args.fresh), load(baseline_path))

    if args.write_baseline:
        if args.scale >= 1.0:
            print("the full-scale baseline is a hand-curated ratio subset — "
                  "edit it directly (see benchmarks/baselines/README.md)")
            return 1
        if not os.path.exists(args.fresh):
            print(f"no fresh summary at {args.fresh}; run the bench first")
            return 1
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        shutil.copyfile(args.fresh, baseline_path)
        print(f"baseline refreshed: {baseline_path}")
        return 0

    fresh = load(args.fresh)
    if fresh is None:
        print(f"no fresh summary at {args.fresh}; run "
              f"`python -m benchmarks.run dashboard` first")
        return 1
    baseline = load(baseline_path)
    if baseline is None:
        # a missing baseline is not a regression (e.g. a brand-new matrix
        # leg) — but say so loudly and point at the refresh procedure
        print(f"WARNING: no baseline at {baseline_path}; skipping the gate. "
              f"Commit one via --write-baseline.")
        return 0
    failures, report = compare(fresh, baseline, args.scale)
    print(f"perf-regression gate: {args.fresh} vs {baseline_path} "
          f"(scale {args.scale})")
    for line in report:
        print(f"  {line}")
    if failures:
        print(f"REGRESSION: {len(failures)} gated metric(s) out of tolerance")
        return 1
    print("gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
