"""CI perf-regression gate: compare fresh BENCH_*.json summaries against the
committed baseline snapshots in ``benchmarks/baselines/``.

The smoke bench uploads ``BENCH_*.json`` artifacts on every CI run, but until
this gate nothing ever *compared* them — a silent warm-event regression
could land unnoticed.  This script fails (exit 1) when a gated metric
regresses beyond its per-metric tolerance:

- latency metrics (``warm_event``) regress when they grow;
- speedup-ratio and throughput metrics (``event_speedup``,
  ``prefetch_speedup``, ``ingest/rows_per_sec``, …) regress when they shrink.

Metrics are routed to their producing suite by name prefix
(``crossfilter/* → BENCH_dashboard.json``, ``ingest/* → BENCH_ingest.json``);
each suite has its own baseline file, keyed by plans leg and scale band.  A
suite whose fresh summary is absent (a matrix leg that doesn't run it) is
skipped, not failed.

It is **scale-aware**: ratio metrics that only separate from noise at full
scale (``batch_speedup`` is ~1.0 at the CI smoke scale 0.05, where per-event
work is sub-millisecond; the ingest p99 tail likewise) carry a ``min_scale``
and are skipped below it — the nightly full-scale workflow is where they are
recorded.

Usage::

    python -m benchmarks.check_regression                 # CI gate
    python -m benchmarks.check_regression --self-test     # prove it fires
    python -m benchmarks.check_regression --write-baseline  # refresh snapshots

Baseline refresh procedure (see ROADMAP.md): after an *intentional* perf
change, regenerate the smoke-scale summaries on the matrix leg and commit::

    REPRO_BENCH_SCALE=0.05 REPRO_USE_PLANS=1 \
        PYTHONPATH=src python -m benchmarks.run dashboard ingest
    PYTHONPATH=src python -m benchmarks.check_regression --write-baseline
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys


@dataclasses.dataclass(frozen=True)
class Metric:
    """Gate spec for one BENCH metric (values are the emitted us_per_call
    column; ratio metrics are emitted as ratio/1e6 so the value IS the
    ratio)."""

    lower_is_better: bool
    tolerance: float          # fractional regression allowed (0.20 = 20%)
    min_scale: float = 0.0    # skip below this REPRO_BENCH_SCALE


# Per-metric tolerances.  The headline metrics fail the PR on >20%
# regression; ratio metrics meaningful only at full scale are nightly-gated.
# The sustained-ingestion throughput gets a wider band (0.30): rows/sec is a
# wall-clock figure on shared runners, noisier than the paired ratios.
GATED: dict[str, Metric] = {
    "crossfilter/warm_event": Metric(lower_is_better=True, tolerance=0.20),
    "crossfilter/event_speedup": Metric(lower_is_better=False, tolerance=0.20),
    "crossfilter/prefetch_speedup": Metric(lower_is_better=False, tolerance=0.20),
    "crossfilter/batch_speedup": Metric(
        lower_is_better=False, tolerance=0.20, min_scale=1.0
    ),
    "crossfilter/offline_batch_speedup": Metric(
        lower_is_better=False, tolerance=0.20, min_scale=1.0
    ),
    # fused offline dispatch count (emitted count/1e6 so the value IS the
    # count): ≤ tree depth with level fusion on; any integer increase means a
    # level stopped fusing, which 20% tolerance on 4 always catches
    "crossfilter/offline_dispatches": Metric(lower_is_better=True, tolerance=0.20),
    "ingest/rows_per_sec": Metric(lower_is_better=False, tolerance=0.30),
    "ingest/p99_ratio": Metric(
        lower_is_better=True, tolerance=0.20, min_scale=1.0
    ),
    # serving tier: sustained throughput is wall-clock (30% band like
    # rows_per_sec); the cross-session width is structural (sessions per
    # dispatch — any drop means batching broke); the 64-session speedup
    # ratio is host-robust but only separates from noise at full scale
    "serve/events_per_sec_shared64": Metric(
        lower_is_better=False, tolerance=0.30
    ),
    "serve/cross_session_width": Metric(lower_is_better=False, tolerance=0.20),
    "serve/speedup_shared64": Metric(
        lower_is_better=False, tolerance=0.25, min_scale=1.0
    ),
    # exploratory-BI bin cubes: the hit rate is structural (1.0 means every
    # timed jump/backtrack was served by slicing a parked cube — any drop
    # means a σ shape escaped the cube path), gated at every scale; the
    # cube-vs-σ-prefetch speedup only separates from noise at full scale
    "explore/brush_cube_hit_rate": Metric(lower_is_better=False, tolerance=0.20),
    "explore/warm_brush_cube": Metric(lower_is_better=True, tolerance=0.25),
    "explore/cube_speedup": Metric(
        lower_is_better=False, tolerance=0.25, min_scale=1.0
    ),
    # sharded execution: throughput is wall-clock on shared runners (30%
    # band); the 1→8-device scale-up ratio is paired on the same host so it
    # gets a tighter band — any structural loss of shard parallelism (a
    # relation falling back to unsharded dispatch) collapses it well past 25%
    "sharded/rows_per_sec_8dev": Metric(lower_is_better=False, tolerance=0.30),
    "sharded/scaleup_8dev": Metric(lower_is_better=False, tolerance=0.25),
}

# metric-name prefix -> producing suite (the BENCH_<suite>.json file)
PREFIX_SUITE = {
    "crossfilter": "dashboard",
    "salesforce": "dashboard",
    "ingest": "ingest",
    "serve": "serve",
    "explore": "explore",
    "sharded": "sharded",
}


def suite_of(name: str) -> str:
    return PREFIX_SUITE[name.split("/", 1)[0]]


def gated_suites() -> list[str]:
    return sorted({suite_of(name) for name in GATED})


def plans_leg() -> str:
    return "1" if os.environ.get("REPRO_USE_PLANS", "1").lower() not in (
        "0", "false"
    ) else "0"


def baseline_path(suite: str, scale: float) -> str:
    """Baselines are keyed by suite, plans leg AND scale band: absolute
    latencies at smoke scale are not comparable to full scale, and the
    full-scale snapshots (nightly gate) hold only the host-robust ratio
    metrics."""
    here = os.path.dirname(os.path.abspath(__file__))
    suffix = ".scale1" if scale >= 1.0 else ""
    return os.path.join(
        here, "baselines", f"BENCH_{suite}.plans{plans_leg()}{suffix}.json"
    )


def fresh_path(suite: str, fresh_dir: str) -> str:
    return os.path.join(fresh_dir, f"BENCH_{suite}.json")


def compare(
    fresh: dict, baseline: dict, scale: float
) -> tuple[list[str], list[str]]:
    """Returns (failures, report lines).  ``fresh``/``baseline`` are the
    merged per-suite summaries; a metric whose suite produced no fresh
    summary this run is absent from ``fresh`` *and* flagged in it under
    ``"__missing__<suite>"`` — those are skipped."""
    failures: list[str] = []
    report: list[str] = []
    for name, spec in GATED.items():
        if scale < spec.min_scale:
            report.append(
                f"SKIP  {name}: scale {scale} < {spec.min_scale} "
                f"(full-scale-only ratio metric)"
            )
            continue
        if f"__missing__{suite_of(name)}" in fresh:
            report.append(
                f"SKIP  {name}: no fresh BENCH_{suite_of(name)}.json this run"
            )
            continue
        if name not in baseline:
            report.append(f"SKIP  {name}: not in baseline")
            continue
        if name not in fresh:
            failures.append(f"{name}: missing from fresh summary")
            report.append(f"FAIL  {name}: missing from fresh summary")
            continue
        base, now = float(baseline[name]), float(fresh[name])
        if spec.lower_is_better:
            limit = base * (1.0 + spec.tolerance)
            bad = now > limit
            delta = (now - base) / base if base else 0.0
        else:
            limit = base * (1.0 - spec.tolerance)
            bad = now < limit
            delta = (base - now) / base if base else 0.0
        verdict = "FAIL" if bad else "ok"
        report.append(
            f"{verdict:>4}  {name}: baseline={base:.3f} fresh={now:.3f} "
            f"limit={limit:.3f} (regression {delta * 100:+.1f}%, "
            f"tol {spec.tolerance * 100:.0f}%)"
        )
        if bad:
            failures.append(
                f"{name}: {now:.3f} vs baseline {base:.3f} "
                f"(> {spec.tolerance * 100:.0f}% regression)"
            )
    return failures, report


def self_test(fresh: dict | None, baseline: dict | None) -> int:
    """Dry run proving the gate fires: a deliberate tolerance-violating
    baseline edit must produce failures, and an in-tolerance wiggle must
    not.  Uses the real summaries when available, synthetic ones otherwise
    (so the self-test runs before any bench has ever executed)."""
    if not baseline:
        baseline = {
            "crossfilter/warm_event": 20_000.0,
            "crossfilter/event_speedup": 50.0,
            "crossfilter/prefetch_speedup": 6.0,
            "crossfilter/batch_speedup": 1.6,
            "crossfilter/offline_batch_speedup": 1.6,
            "crossfilter/offline_dispatches": 4.0,
            "ingest/rows_per_sec": 300_000.0,
            "ingest/p99_ratio": 1.1,
            "serve/events_per_sec_shared64": 2_000.0,
            "serve/cross_session_width": 64.0,
            "serve/speedup_shared64": 6.0,
            "explore/brush_cube_hit_rate": 1.0,
            "explore/warm_brush_cube": 2_000.0,
            "explore/cube_speedup": 4.0,
            "sharded/rows_per_sec_8dev": 5_000_000.0,
            "sharded/scaleup_8dev": 2.5,
        }
    if not fresh or any(k.startswith("__missing__") for k in fresh):
        fresh = dict(baseline)
    ok = True

    # 1) identical summaries: must pass at every scale
    failures, _ = compare(dict(baseline), dict(baseline), scale=1.0)
    if failures:
        print(f"self-test: clean comparison failed: {failures}")
        ok = False

    # 2) deliberate tolerance-violating edit on each gated metric: must fail
    for name, spec in GATED.items():
        if name not in baseline:
            continue
        bad = dict(fresh) if name in fresh else dict(baseline)
        factor = (1.0 + 2 * spec.tolerance) if spec.lower_is_better else (
            1.0 - 2 * spec.tolerance
        )
        bad[name] = float(baseline[name]) * factor
        failures, _ = compare(bad, baseline, scale=max(spec.min_scale, 1.0))
        if not any(name in f for f in failures):
            print(f"self-test: gate did NOT fire on a 2x-tolerance "
                  f"regression of {name}")
            ok = False
        # within tolerance: must not fire
        mild = dict(bad)
        mild_factor = (1.0 + spec.tolerance / 2) if spec.lower_is_better else (
            1.0 - spec.tolerance / 2
        )
        mild[name] = float(baseline[name]) * mild_factor
        failures, _ = compare(mild, baseline, scale=max(spec.min_scale, 1.0))
        if any(name in f for f in failures):
            print(f"self-test: gate fired inside tolerance for {name}")
            ok = False

    # 3) scale-awareness: a full-scale-only metric must be skipped (not
    # failed) at the smoke scale even when catastrophically regressed
    bad = dict(baseline)
    bad["crossfilter/batch_speedup"] = 0.01
    bad["ingest/p99_ratio"] = 50.0
    failures, _ = compare(bad, baseline, scale=0.05)
    if any("batch_speedup" in f or "p99_ratio" in f for f in failures):
        print("self-test: full-scale-only metric gated at smoke scale")
        ok = False

    # 4) suite routing: an absent fresh suite summary is a skip, not a fail
    routed = dict(baseline)
    routed.pop("ingest/rows_per_sec", None)
    routed["__missing__ingest"] = 1.0
    failures, _ = compare(routed, baseline, scale=1.0)
    if any("rows_per_sec" in f for f in failures):
        print("self-test: missing suite summary treated as a regression")
        ok = False

    print(f"self-test: {'PASS — the gate fires' if ok else 'FAIL'}")
    return 0 if ok else 1


def load(path):
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def load_merged(fresh_dir: str, scale: float) -> tuple[dict, dict, list[str]]:
    """Merge every gated suite's fresh + baseline summaries; returns
    (fresh, baseline, lines) where absent suites are marked in ``fresh``."""
    fresh: dict = {}
    baseline: dict = {}
    lines: list[str] = []
    for suite in gated_suites():
        fp = fresh_path(suite, fresh_dir)
        f = load(fp)
        if f is None:
            fresh[f"__missing__{suite}"] = 1.0
            lines.append(f"note: no fresh summary at {fp} (suite skipped)")
            continue
        fresh.update(f)
        bp = baseline_path(suite, scale)
        b = load(bp)
        if b is None:
            lines.append(
                f"WARNING: no baseline at {bp}; its metrics are skipped. "
                f"Commit one via --write-baseline."
            )
            continue
        baseline.update(b)
    return fresh, baseline, lines


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the fresh BENCH_<suite>.json "
                         "summaries (default: cwd)")
    ap.add_argument("--scale", type=float,
                    default=float(os.environ.get("REPRO_BENCH_SCALE", "1.0")),
                    help="bench scale the fresh summaries were produced at")
    ap.add_argument("--self-test", action="store_true",
                    help="prove the gate fires on a deliberate regression")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy each fresh summary over its baseline "
                         "(refresh procedure)")
    args = ap.parse_args()

    if args.self_test:
        fresh, baseline, _ = load_merged(args.fresh_dir, args.scale)
        return self_test(fresh, baseline)

    if args.write_baseline:
        if args.scale >= 1.0:
            print("the full-scale baselines are hand-curated ratio subsets — "
                  "edit them directly (see benchmarks/baselines/README.md)")
            return 1
        wrote = 0
        for suite in gated_suites():
            fp = fresh_path(suite, args.fresh_dir)
            if not os.path.exists(fp):
                print(f"note: no fresh summary at {fp}; suite not refreshed")
                continue
            bp = baseline_path(suite, args.scale)
            os.makedirs(os.path.dirname(bp), exist_ok=True)
            shutil.copyfile(fp, bp)
            print(f"baseline refreshed: {bp}")
            wrote += 1
        if not wrote:
            print("no fresh summaries found; run the benches first")
            return 1
        return 0

    fresh, baseline, lines = load_merged(args.fresh_dir, args.scale)
    if all(k.startswith("__missing__") for k in fresh):
        print(f"no fresh summaries in {args.fresh_dir}; run "
              f"`python -m benchmarks.run dashboard ingest` first")
        return 1
    print(f"perf-regression gate: {args.fresh_dir}/BENCH_*.json vs "
          f"benchmarks/baselines (plans{plans_leg()}, scale {args.scale})")
    for line in lines:
        print(f"  {line}")
    failures, report = compare(fresh, baseline, args.scale)
    for line in report:
        print(f"  {line}")
    if failures:
        print(f"REGRESSION: {len(failures)} gated metric(s) out of tolerance")
        return 1
    print("gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
