"""Fig 23 analog — JT message passing vs naive join on chain schemas.

Total-count query over r ∈ [2..8] chained relations at three fanouts.
``No-JT`` materializes the join pairwise (rows grow ~ d·f^r); ``JT`` runs
factorized message passing (rows stay ~ d·f per edge).
"""

from __future__ import annotations

import numpy as np

from repro.core import CJTEngine, MessageStore, Query, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema

from .common import emit, time_fn


def naive_chain_count(cat) -> float:
    names = sorted(cat.names())
    rel = cat.get(names[0])
    left = np.stack([rel.codes[rel.attrs[0]], rel.codes[rel.attrs[1]]], 1)
    weights = np.ones(len(left), np.float64)
    frontier = left[:, 1]
    for name in names[1:]:
        r = cat.get(name)
        a, b = r.attrs
        # hash join frontier (values of a) with r
        order = np.argsort(r.codes[a], kind="stable")
        ra = r.codes[a][order]
        rb = r.codes[b][order]
        starts = np.searchsorted(ra, frontier, side="left")
        ends = np.searchsorted(ra, frontier, side="right")
        counts = ends - starts
        idx = np.repeat(starts, counts) + _ragged_arange(counts)
        weights = np.repeat(weights, counts)
        frontier = rb[idx]
    return float(weights.sum())


def _ragged_arange(counts):
    total = counts.sum()
    out = np.arange(total)
    offs = np.repeat(np.cumsum(counts) - counts, counts)
    return out - offs


def run(max_r: int = 8, domain: int = 256):
    for fanout, label in [(2, "low"), (3, "mid"), (4, "high")]:
        for r in range(2, max_r + 1):
            cat = schema.chain(r, fanout=fanout, domain=domain)
            q = Query.make(cat, ring="count")

            def jt_exec():
                eng = CJTEngine(jt_from_catalog(cat), cat, sr.COUNT, store=MessageStore())
                f, _ = eng.execute(q)
                return float(np.asarray(f.field))

            t_jt, v_jt = time_fn(jt_exec, repeats=1, warmup=0)
            emit(f"chain/{label}/r{r}/JT", t_jt, f"count={v_jt:.3g}")
            if fanout ** r * domain <= 40_000_000:
                t_no, v_no = time_fn(lambda: naive_chain_count(cat), repeats=1, warmup=0)
                assert abs(v_no - v_jt) / max(v_no, 1) < 1e-6
                emit(f"chain/{label}/r{r}/No-JT", t_no,
                     f"rows={fanout**r * domain:.3g}")


def main():
    run(max_r=7)


if __name__ == "__main__":
    main()
