"""Compiled message plans: jit + Pallas fast path vs the legacy un-jitted engine.

Same dashboard/interaction/update workload run twice — once on the legacy
op-by-op path (``use_plans=False``: host-side index building + un-jitted
dispatch per message) and once through the compiled plan cache — timing each
warm interaction with the message store restored to its pre-interaction
state (plan/XLA caches warm, the paper's §5.2 protocol).  Reports per-query
latencies, the median warm-plan speedup, an update-maintenance comparison,
and the plan-cache counters (kernel-path executions must be > 0).

``REPRO_BENCH_SCALE`` scales the fact table (CI smoke uses 0.05).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in, mask_range

from .common import emit


def _interactions(cat, q0: Query) -> list[tuple[str, Query]]:
    d = cat.domains()
    return [
        ("sel_role", q0.with_predicate(mask_in(d["role_name"], [1, 2], attr="role_name"))),
        ("sel_title", q0.with_predicate(mask_in(d["title"], [0, 3, 5], attr="title"))),
        ("sel_start_q", q0.with_predicate(mask_range(d["start_q"], 4, 12, attr="start_q"))),
        ("sel_state", q0.with_predicate(mask_in(d["state"], list(range(10)), attr="state"))),
        ("grp_title", q0.add_group_by("title")),
        ("grp_state", q0.add_group_by("state")),
        ("remove_Acc", q0.with_removed("Acc")),
    ]


def _timed_interact(tre: Treant, viz: str, q: Query, repeats: int = 3):
    """Median warm latency; run 0 warms plan traces/XLA and is discarded."""
    snap = tre.store.snapshot()
    ts, res = [], None
    for _ in range(repeats + 1):
        tre.store.restore(snap)
        t0 = time.perf_counter()
        res = tre.interact("u1", viz, q)
        jax.block_until_ready(res.factor.field)
        ts.append(time.perf_counter() - t0)
    tre.store.restore(snap)
    return float(np.median(ts[1:])), res


def _setup(n_opp: int, use_plans: bool):
    cat = schema.salesforce(n_opp=n_opp)
    jt = jt_from_catalog(cat)
    tre = Treant(cat, ring=sr.SUM, jt=jt, use_plans=use_plans)
    q0 = Query.make(cat, ring="sum", measure=("Opp", "amount"),
                    group_by=("camp_type",))
    t0 = time.perf_counter()
    tre.register_dashboard("pie", q0)
    t_off = time.perf_counter() - t0
    return cat, tre, q0, t_off


def _bench_update(cat, tre: Treant, q0: Query, seed: int) -> float:
    """Time one warm maintained update + read.  The first append traces the
    delta plans; the second (same |Δ| → same structure) is the timed one."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for i in range(2):
        camp = cat.get("Camp")
        new_rel, delta = camp.append_rows(
            {a: rng.integers(0, camp.domains[a], 64).astype(np.int32)
             for a in camp.attrs},
            {m: rng.random(64).astype(np.float32) * 100 for m in camp.measures},
        )
        t0 = time.perf_counter()
        tre.update(new_rel, delta)
        res = tre.read("u1", "pie")
        jax.block_until_ready(res.factor.field)
        t = time.perf_counter() - t0
    return t


def run(scale: float = 1.0):
    n_opp = int(100_000 * scale)
    sides = {}
    for mode, use_plans in [("legacy", False), ("compiled", True)]:
        cat, tre, q0, t_off = _setup(n_opp, use_plans)
        sides[mode] = (cat, tre, q0)
        emit(f"compiled/offline_calibrate/{mode}", t_off)

    speedups = []
    for name, _ in _interactions(sides["legacy"][0], sides["legacy"][2]):
        per = {}
        for mode in ("legacy", "compiled"):
            cat, tre, q0 = sides[mode]
            q = dict(_interactions(cat, q0))[name]
            per[mode], per[f"res_{mode}"] = _timed_interact(tre, "pie", q)
        match = np.allclose(
            np.asarray(per["res_legacy"].factor.field, np.float64),
            np.asarray(per["res_compiled"].factor.field, np.float64),
            rtol=1e-4, atol=1e-4,
        )
        speed = per["legacy"] / max(per["compiled"], 1e-9)
        speedups.append(speed)
        emit(f"compiled/{name}/legacy", per["legacy"])
        emit(f"compiled/{name}/compiled", per["compiled"],
             f"speedup={speed:.1f}x match={match}")

    # non-time rows carry their unit in the name (_x ratio, _count) so the
    # BENCH_*.json artifact stays honest about what each value is
    med = float(np.median(speedups))
    emit("compiled/median_interaction_speedup_x", med / 1e6,
         f"median legacy/compiled = {med:.1f}x")

    # MOMENTS warm interaction: the compound (c, s, q) ring rides the segment
    # kernel as three stacked f32 columns, so the compiled side must report
    # kernel-path executions on its moments sibling engine
    per = {}
    for mode in ("legacy", "compiled"):
        cat, tre, q0 = sides[mode]
        q_mom = Query.make(cat, ring="moments", measure=("Opp", "amount"),
                           group_by=("camp_type",))
        per[mode], per[f"res_{mode}"] = _timed_interact(tre, "pie", q_mom)
    match = all(
        np.allclose(np.asarray(a, np.float64), np.asarray(b, np.float64),
                    rtol=1e-4, atol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(per["res_legacy"].factor.field),
                        jax.tree_util.tree_leaves(per["res_compiled"].factor.field)))
    emit("compiled/moments_avg/legacy", per["legacy"])
    emit("compiled/moments_avg/compiled", per["compiled"],
         f"speedup={per['legacy'] / max(per['compiled'], 1e-9):.1f}x match={match}")
    mom_stats = sides["compiled"][1]._engines["moments"].plans.stats
    assert mom_stats.kernel_execs > 0, \
        f"MOMENTS interaction must hit the stacked-leaf kernel path, {mom_stats}"
    emit("compiled/moments_kernel_execs_count", mom_stats.kernel_execs / 1e6,
         f"moments kernel execs = {mom_stats.kernel_execs}")

    upd = {m: _bench_update(sides[m][0], sides[m][1], sides[m][2], seed=41)
           for m in ("legacy", "compiled")}
    emit("compiled/update_then_read/legacy", upd["legacy"])
    emit("compiled/update_then_read/compiled", upd["compiled"],
         f"speedup={upd['legacy'] / max(upd['compiled'], 1e-9):.1f}x")

    st = sides["compiled"][1].cache_stats()
    plans = st["plans"]
    emit("compiled/plans_built_count", plans["plans_built"] / 1e6,
         f"hits={plans['plan_hits']}")
    emit("compiled/kernel_execs_count", plans["kernel_execs"] / 1e6,
         f"fallback={plans['fallback_execs']} (kernel-path execs must be > 0)")
    return med


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    run(scale=scale)


if __name__ == "__main__":
    main()
