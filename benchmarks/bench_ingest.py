"""Streaming delta ingestion: sustained micro-batched writes vs warm reads.

Two scenarios over the Flight schema:

1. **Sustained ingest (SUM)** — a crossfilter session keeps brushing while
   append/delete micro-batches stream into the fact relation and ``flush``
   coalesces them into one delta per tick.  Measures the sustained ingestion
   rate (rows/sec through coalesce+maintain+commit) and compares the warm
   event-latency tail (p99) during ingestion against a no-ingest baseline —
   the tentpole's acceptance bar is p99 within 1.3x.  Asserts the coalescing
   invariant (one version bump + one apply_delta sweep per tick, however many
   micro-batches were queued) and stream≡rebuild parity on every viz.

2. **Inverse-free delete stream (MIN)** — tombstoned delete ticks against
   TROPICAL_MIN must absorb without recalibrating (calibration dispatches
   flat across ticks); the one real recalibration happens only when the
   tombstone ledger crosses the compaction threshold, and it lands in
   think-time.

Ratio metrics follow the suite convention (emitted as ratio/1e6 so the JSON
value IS the ratio); ``ingest/rows_per_sec`` likewise records rows/1e6·s so
the value is the raw rows/sec figure.  All randomness is pinned through
``common.seeded_rng`` — BENCH_ingest.json is reproducible run-to-run.
"""

from __future__ import annotations

import contextlib
import gc
import os
import time

import numpy as np

from repro.core import (
    CJTEngine, DashboardSpec, MessageStore, SetFilter, Treant, VizSpec,
    jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational import schema

from .common import emit, seeded_rng

FLIGHT_SEED = 1
BATCHES_PER_TICK = 4


def ingest_spec(ring: str = "sum") -> DashboardSpec:
    m = ("Flights", "dep_delay")
    return DashboardSpec(vizzes=(
        VizSpec("by_state", measure=m, ring=ring, group_by=("airport_state",)),
        VizSpec("by_month", measure=m, ring=ring, group_by=("month",)),
        VizSpec("by_carrier", measure=m, ring=ring, group_by=("carrier_group",)),
        VizSpec("by_size", measure=m, ring=ring, group_by=("airport_size",)),
    ))


EVENTS = (
    SetFilter("carrier_group", values=(2, 3), source="by_carrier"),
    SetFilter("airport_size", values=(0, 3), source="by_size"),
    SetFilter("carrier_group", values=(4,), source="by_carrier"),
    SetFilter("airport_size", values=(2,), source="by_size"),
    SetFilter("carrier_group", values=(1, 5), source="by_carrier"),
    SetFilter("carrier_group", values=(0, 2), source="by_carrier"),
)


def _prewarm_process():
    warm_cat = schema.flight(n_flights=2_000, seed=FLIGHT_SEED)
    tw = Treant(warm_cat, ring=sr.SUM, jt=jt_from_catalog(warm_cat))
    tw.open_session(ingest_spec(), name="prewarm")


def _warm(sess):
    for ev in EVENTS + EVENTS:
        sess.apply(ev)
        sess.idle()


@contextlib.contextmanager
def _no_gc():
    """Pause the collector during a timed pass: a cyclic-GC sweep landing
    inside a sub-ms event is a multi-ms outlier that dominates a small-n p99
    (both the baseline and the ingest pass are equally affected — pausing
    keeps the *ratio* honest).  One collection runs at pass exit."""
    on = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        if on:
            gc.enable()
        gc.collect()


def _event_pass(treant, sess, per_event=None):
    """One pass over EVENTS; ``per_event(i)`` runs (untimed) before each
    event — the ingestion work interleaved with the interactive stream."""
    lat = []
    with _no_gc():
        for i, ev in enumerate(EVENTS):
            if per_event is not None:
                per_event(i)
            treant.store.block_until_ready()
            t0 = time.perf_counter()
            sess.apply(ev)
            lat.append(time.perf_counter() - t0)
            sess.idle()
    return lat


def _queue_tick(rng, buf, rows: int):
    """Queue BATCHES_PER_TICK append micro-batches plus one delete batch."""
    rel = buf.base
    per = max(1, rows // BATCHES_PER_TICK)
    for _ in range(BATCHES_PER_TICK):
        buf.append(
            {a: rng.integers(0, rel.domains[a], per) for a in rel.attrs},
            measures={m: rng.gamma(1.5, 10.0, per).astype(np.float32)
                      for m in rel.measures},
        )
    # tombstone ~0.1% of the live base rows + cancel a few fresh appends
    mask = np.zeros(rel.num_rows + buf.pending_appends, bool)
    live = np.flatnonzero(rel._materialized_weights() != 0.0)
    n_del = max(1, live.size // 1000)
    mask[rng.choice(live, n_del, replace=False)] = True
    mask[rel.num_rows + rng.choice(buf.pending_appends, 2, replace=False)] = True
    buf.delete(mask)
    return per * BATCHES_PER_TICK + n_del + 2


def run_sustained(scale: float = 1.0):
    rng = seeded_rng("ingest/sustained")
    cat = schema.flight(n_flights=max(2_000, int(100_000 * scale)),
                        seed=FLIGHT_SEED)
    jt = jt_from_catalog(cat)
    _prewarm_process()
    t = Treant(cat, ring=sr.SUM, jt=jt, compaction_threshold=0.0)
    sess = t.open_session(ingest_spec(), name="bench")
    _warm(sess)

    # -- no-ingest baseline: the warm event tail with a quiet write path
    lat_base = []
    for _ in range(5):
        lat_base += _event_pass(t, sess)
    p99_base = float(np.percentile(lat_base, 99))
    emit("ingest/p99_warm_event_no_ingest", p99_base,
         f"median={np.median(lat_base) * 1e6:.0f}us n={len(lat_base)}")

    # -- sustained ingestion: one coalesced tick before every event
    tick_rows = max(200, int(2_000 * scale))
    rows_total = 0
    flush_seconds = 0.0
    ticks0 = t.ingest.ticks
    bumps0, sweeps0 = t.ingest.version_bumps, t.ingest.delta_sweeps

    def ingest_tick(_i):
        nonlocal rows_total, flush_seconds
        rows = _queue_tick(rng, t.stream("Flights"), tick_rows)
        t.store.block_until_ready()
        t0 = time.perf_counter()
        res = t.flush()
        flush_seconds += time.perf_counter() - t0
        rows_total += rows
        assert res.relations == ["Flights"] and not res.compactions
        assert all(u.queries_fallback == 0 for u in res.updates)

    lat_ingest = []
    for _ in range(5):
        lat_ingest += _event_pass(t, sess, per_event=ingest_tick)
    p99_ingest = float(np.percentile(lat_ingest, 99))
    n_ticks = t.ingest.ticks - ticks0

    # the coalescing contract: one bump + one sweep per tick, despite
    # BATCHES_PER_TICK+1 micro-batches per tick
    assert n_ticks == len(lat_ingest)
    assert t.ingest.version_bumps - bumps0 == n_ticks, t.ingest
    assert t.ingest.delta_sweeps - sweeps0 == n_ticks, t.ingest
    rows_per_sec = rows_total / max(flush_seconds, 1e-9)
    emit("ingest/rows_per_sec", rows_per_sec / 1e6,
         f"rows={rows_total} ticks={n_ticks} "
         f"batches/tick={BATCHES_PER_TICK + 1} flush_s={flush_seconds:.3f}")
    emit("ingest/flush_tick", flush_seconds / n_ticks,
         f"coalesce+maintain+commit, {rows_total // n_ticks} rows/tick")
    emit("ingest/p99_warm_event_ingest", p99_ingest,
         f"median={np.median(lat_ingest) * 1e6:.0f}us n={len(lat_ingest)}")
    ratio = p99_ingest / max(p99_base, 1e-9)
    emit("ingest/p99_ratio", ratio / 1e6,
         f"ingest vs no-ingest p99 = {ratio:.2f}x")
    if scale >= 1.0 and "plans" in t.cache_stats():
        # acceptance bar, compiled leg only — at smoke scale sub-ms events
        # put the ratio in the scheduler-noise regime (gated nightly via the
        # scale1 baseline instead), and the un-jitted plans-off reference leg
        # is host-bound at ~8ms/event where run-to-run noise straddles the
        # bar (its ratio is still emitted above for the nightly artifacts)
        assert ratio <= 1.3, (
            f"sustained ingestion degraded warm p99 {ratio:.2f}x (> 1.3x)"
        )

    # learned compaction posture must surface through cache_stats: the fact
    # relation saw a mixed append/delete stream, so its EWMA + effective
    # threshold are part of the ingest dict (nightly artifacts trend them)
    comp = t.cache_stats()["ingest"]["compaction"]
    assert "Flights" in comp, comp
    assert 0.0 <= comp["Flights"]["ewma"] <= 1.0, comp
    emit("ingest/compaction_ewma_flights", comp["Flights"]["ewma"] / 1e6,
         f"threshold={comp['Flights']['threshold']:.3f} (base=0.0)")

    # stream-then-flush ≡ rebuild on every viz (float data: allclose; the
    # bit-identity contract on integer data is tests/test_stream_ingest.py's)
    cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore(),
                     use_plans=False)
    for viz in sess.vizzes:
        got = np.asarray(sess.read(viz).factor.field, np.float64)
        want, _ = cold.execute(sess.query_of(viz))
        assert np.allclose(got, np.asarray(want.field, np.float64),
                           rtol=1e-4, atol=1e-4), f"{viz} diverged from rebuild"
    sess.close()
    return ratio


def run_min_compaction(scale: float = 1.0):
    rng = seeded_rng("ingest/min_compaction")
    cat = schema.flight(n_flights=max(2_000, int(20_000 * scale)),
                        seed=FLIGHT_SEED)
    t = Treant(cat, ring=sr.TROPICAL_MIN, jt=jt_from_catalog(cat),
               compaction_threshold=0.25)
    sess = t.open_session(ingest_spec(ring="tropical_min"), name="bench")
    plans_on = "plans" in t.cache_stats()
    disp0 = t.cache_stats()["plans"]["calibration_dispatches"] if plans_on else -1

    buf = t.stream("Flights")
    ticks = 0
    t0 = time.perf_counter()
    while True:
        rel = buf.base
        live = np.flatnonzero(rel._materialized_weights() != 0.0)
        mask = np.zeros(rel.num_rows, bool)
        mask[rng.choice(live, max(1, live.size // 12), replace=False)] = True
        buf.delete(mask)
        res = t.flush()
        ticks += 1
        assert all(u.queries_fallback == 0 for u in res.updates), (
            "tombstoned MIN delta fell back before compaction"
        )
        if res.compactions:
            break
        # absorbing ticks must not recalibrate: dispatch count stays flat
        if plans_on:
            assert (
                t.cache_stats()["plans"]["calibration_dispatches"] == disp0
            ), f"tick {ticks} recalibrated without compaction"
        assert ticks < 64, "compaction threshold never crossed"
    t_stream = time.perf_counter() - t0
    (cupd,) = res.compactions
    assert cupd.queries_fallback > 0  # MIN takes its ONE real recalibration
    emit("ingest/min_delete_ticks_to_compaction", t_stream,
         f"ticks={ticks} fallbacks=0 until compaction")

    t0 = time.perf_counter()
    sess.idle()  # drain the deprioritized recalibration in think-time
    t_recal = time.perf_counter() - t0
    if plans_on:
        assert t.cache_stats()["plans"]["calibration_dispatches"] > disp0
    emit("ingest/min_compaction_recalibrate", t_recal,
         f"one deprioritized recalibration after {ticks} absorbed ticks")
    # delete-only stream: EWMA → 1.0, so the learned threshold undercuts the
    # 0.25 base (eager reclaim) — assert the export reflects that posture
    comp = t.cache_stats()["ingest"]["compaction"]
    assert comp["Flights"]["threshold"] < 0.25, comp
    emit("ingest/min_compaction_learned_threshold",
         comp["Flights"]["threshold"] / 1e6,
         f"ewma={comp['Flights']['ewma']:.3f} base=0.25")
    cold = CJTEngine(t.jt, cat, sr.TROPICAL_MIN, store=MessageStore(),
                     use_plans=False)
    for viz in sess.vizzes:
        got = np.asarray(sess.read(viz).factor.field, np.float64)
        want, _ = cold.execute(sess.query_of(viz))
        np.testing.assert_array_equal(got, np.asarray(want.field, np.float64))
    assert t.catalog.get("Flights").tombstone_count == 0
    sess.close()


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    run_sustained(scale=scale)
    run_min_compaction(scale=scale)


if __name__ == "__main__":
    main()
