"""Fig 14/16 analog — Flight/IDEBench workload: per-visualization sequences of
interaction queries that progressively add selections/group-bys.

Compares Naive, Factorized (cold store), Tre+Offline (only the dashboard
CJTs), and Treant (online think-time calibration between interactions).
``--case-study`` prints the per-message runtimes for the 2nd interaction of
the 2nd visualization (the paper's Fig 16).
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import CJTEngine, MessageStore, Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in, mask_range

from .baselines import NaiveExecutor, cold_engine
from .common import emit, time_fn, timed_interact


def workload(cat):
    """5 visualizations; each: dashboard query + 2 progressive interactions."""
    d = cat.domains()
    q = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"))
    vizzes = {
        "v1_delay_by_carrier": q.with_group_by("carrier_group"),
        "v2_delay_by_state": q.with_group_by("airport_state"),
        "v3_delay_by_month": q.with_group_by("month"),
        "v4_count_by_dow": Query.make(cat, ring="sum").with_group_by("dow"),
        "v5_total": q,
    }
    seqs = {}
    for name, q0 in vizzes.items():
        q1 = q0.with_predicate(mask_in(d["carrier_group"], [0, 1], attr="carrier_group"))
        if name == "v2_delay_by_state":
            q2 = q1.with_predicate(mask_in(d["airport_size"], [2, 3], attr="airport_size"))
        elif name == "v3_delay_by_month":
            q2 = q1.with_predicate(mask_range(d["delay_bucket"], 3, 10, attr="delay_bucket"))
        else:
            q2 = q1.with_group_by(*(q1.group_by + ("dow",)))
        seqs[name] = [q0, q1, q2]
    return seqs


def run(scale: float = 1.0, case_study: bool = False, think_budget: int | None = None):
    cat = schema.flight(n_flights=int(300_000 * scale))
    jt = jt_from_catalog(cat)
    naive = NaiveExecutor(cat, "Flights")
    seqs = workload(cat)

    treant = Treant(cat, ring=sr.SUM, jt=jt)
    offline = Treant(cat, ring=sr.SUM, jt=jt)  # no online calibration
    t_off, _ = time_fn(
        lambda: [treant.register_dashboard(v, qs[0]) for v, qs in seqs.items()],
        repeats=1, warmup=0,
    )
    for v, qs in seqs.items():
        offline.register_dashboard(v, qs[0])
    emit("flight/CalibrateOffline", t_off, "5 visualizations")

    for viz, qs in seqs.items():
        for i, q in enumerate(qs):
            t_n, _ = time_fn(naive.execute, q, repeats=1, warmup=0)
            def factorized():
                eng = cold_engine(cat, sr.SUM, jt)
                f, _ = eng.execute(q)
                return f.field
            t_f, _ = time_fn(factorized, repeats=1, warmup=1)
            t_o, _ = timed_interact(offline, "u", viz, q)
            t_t, res = timed_interact(treant, "u", viz, q)
            emit(f"flight/{viz}/q{i}/naive", t_n)
            emit(f"flight/{viz}/q{i}/factorized", t_f)
            emit(f"flight/{viz}/q{i}/tre_offline", t_o)
            emit(f"flight/{viz}/q{i}/treant", t_t,
                 f"steiner={res.steiner_size} computed={res.stats.messages_computed} "
                 f"reused={res.stats.messages_reused}")
            # think-time calibration of the latest interaction query
            t_cal, n_cal = time_fn(
                lambda: treant.think_time("u", viz, budget_messages=think_budget),
                repeats=1, warmup=0,
            )
            emit(f"flight/{viz}/q{i}/calibrate_online", t_cal, f"messages={n_cal}")
    st = treant.cache_stats()
    emit("flight/store_bytes", st["bytes"] / 1e12, f"messages={st['messages']}")

    if case_study:
        _case_study(cat, jt, seqs)


def _case_study(cat, jt, seqs):
    """Fig 16: per-message timings for v2's 2nd interaction."""
    viz = "v2_delay_by_state"
    q0, q1, q2 = seqs[viz]
    for label, warm_queries in [
        ("factorized", []), ("tre_offline", [q0]), ("treant", [q0, q1]),
    ]:
        eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
        for qw in warm_queries:
            eng.calibrate(qw)
        eng.store.reset_stats()
        import time as _t
        placement = eng.place_predicates(q2)
        root = eng.choose_root(q2, placement)
        edges = jt.traversal_to_root(root)
        for (u, v) in edges:
            t0 = _t.perf_counter()
            eng.message(q2, u, v, placement)
            dt = _t.perf_counter() - t0
            if dt > 1e-4:
                emit(f"flight/case16/{label}/msg:{u.split(':')[1]}->{v.split(':')[1]}", dt)
        t0 = _t.perf_counter()
        eng.absorb(q2, root, placement)
        emit(f"flight/case16/{label}/absorb:{root.split(':')[1]}", _t.perf_counter() - t0)


def main():
    run(scale=1.0, case_study=True)


if __name__ == "__main__":
    main()
