"""Shared baselines for the dashboard benchmarks.

``NaiveExecutor`` mirrors what a DBMS does without factorization: materialize
the (filtered) denormalized join row-set, then hash-aggregate — cost grows
with the fact-table width × row count.  ``cold_engine`` is the paper's
``Factorized`` baseline: message passing but a cold message store per query.
"""

from __future__ import annotations

import numpy as np

from repro.core import CJTEngine, MessageStore, Query, jt_from_catalog
from repro.core import semiring as sr
from repro.relational.relation import Catalog


class NaiveExecutor:
    """Denormalize-then-aggregate (paper `Naive`)."""

    def __init__(self, catalog: Catalog, fact: str):
        self.catalog = catalog
        self.fact = fact

    def execute(self, q: Query, measure_col: str | None = None):
        cat = self.catalog
        fact = cat.get(self.fact, q.version_of(self.fact))
        n = fact.num_rows
        # 1) materialize the wide table: gather every dimension attribute
        cols: dict[str, np.ndarray] = {a: fact.codes[a] for a in fact.attrs}
        frontier = [self.fact]
        seen = {self.fact}
        while frontier:
            nxt = []
            for name in list(cat.names()):
                if name in seen or name in q.removed:
                    continue
                rel = cat.get(name, q.version_of(name))
                keys = [a for a in rel.attrs if a in cols]
                if not keys:
                    continue
                key = keys[0]
                # build key -> row index (dims are keyed by their first attr)
                idx = np.full(rel.domains[key], -1, np.int64)
                idx[rel.codes[key]] = np.arange(rel.num_rows)
                rows = idx[cols[key]]
                for a in rel.attrs:
                    if a not in cols:
                        cols[a] = rel.codes[a][rows]
                seen.add(name)
                nxt.append(name)
            if not nxt:
                break
        # 2) filters on the wide table
        mask = np.ones(n, bool)
        for p in q.predicates:
            mask &= p.mask[cols[p.attr]]
        # 3) aggregate
        if measure_col is None and q.measure is not None:
            measure_col = q.measure[1]
        vals = (
            cat.get(q.measure[0], q.version_of(q.measure[0])).measures[measure_col][
                : n
            ]
            if q.measure and q.measure[0] == self.fact
            else np.ones(n, np.float32)
        )
        vals = np.where(mask, vals, 0.0)
        if not q.group_by:
            return np.array(vals.sum(dtype=np.float64))
        dims = [self.catalog.domains()[a] for a in q.group_by]
        flat = np.ravel_multi_index(tuple(cols[a].astype(np.int64) for a in q.group_by), dims)
        out = np.zeros(int(np.prod(dims)))
        np.add.at(out, flat, vals)
        return out.reshape(dims)


def cold_engine(catalog: Catalog, ring=sr.SUM, jt=None) -> CJTEngine:
    return CJTEngine(jt or jt_from_catalog(catalog), catalog, ring, store=MessageStore())
