"""Fig 18 analog — factorized-ML augmentation on Favorita.

Trains ridge regression over the join via the covariance ring, then evaluates
30 synthetic augmentation relations (correlation φ ~ min(1, 1/Exp(10))):
``Fac`` retrains each candidate with a cold store; ``Treant`` calibrates the
base CJT once and each candidate costs one message (§4.3).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import FactorizedLinearRegression, FeatureSpec
from repro.relational import schema

from .common import emit


def run(n_sales: int = 60_000, n_aug_per_key: int = 10):
    cat = schema.favorita(n_sales=n_sales)
    augs = schema.favorita_augmentations(cat, n_per_key=n_aug_per_key)
    model = FactorizedLinearRegression(
        cat,
        features=[
            FeatureSpec("Sales", "unit_sales"),
            FeatureSpec("Stores", "store_type", categorical=True),
            FeatureSpec("Items", "perishable", categorical=True),
        ],
        target=FeatureSpec("Trans", "transactions"),
    )
    t0 = time.perf_counter()
    base = model.fit()
    t_base = time.perf_counter() - t0
    emit("ml_aug/base_fit", t_base, f"R2={base.r2:.4f}")

    # Fac baseline: cold factorized retrain per candidate
    t0 = time.perf_counter()
    fac_r2 = []
    for a in augs:
        res = model.fit_unfactorized_baseline(a)
        fac_r2.append(res.r2)
    t_fac = time.perf_counter() - t0
    emit("ml_aug/fac_cumulative", t_fac, f"{len(augs)} candidates")

    # Treant: calibrate once, then one message per candidate
    t0 = time.perf_counter()
    model.calibrate()
    t_cal = time.perf_counter() - t0
    emit("ml_aug/calibrate", t_cal)
    t0 = time.perf_counter()
    tre_r2 = []
    msgs = 0
    for a in augs:
        res = model.fit_augmented(a)
        tre_r2.append(res.r2)
        msgs += res.stats.messages_computed
    t_tre = time.perf_counter() - t0
    emit("ml_aug/treant_cumulative", t_tre,
         f"{len(augs)} candidates msgs={msgs} "
         f"speedup_vs_fac={(t_fac) / max(t_cal + t_tre, 1e-9):.1f}x")
    assert np.allclose(fac_r2, tre_r2, atol=1e-4), "Fac and Treant must agree"
    gains = np.array(tre_r2) - base.r2
    emit("ml_aug/best_gain", float(np.max(gains)) / 1e6,
         f"dR2 range [{gains.min():+.3f}, {gains.max():+.3f}]")
    return t_fac, t_cal + t_tre


def main():
    run()


if __name__ == "__main__":
    main()
