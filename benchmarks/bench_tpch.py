"""Fig 19/20 analog — TPC-H-style dashboard on the mini TPC-H schema.

Parameterized dashboard queries in the spirit of the paper's rewrites
(Appendix E): Q3' (revenue by orderdate/shippriority, filtered by
mktsegment + dates), Q10' (revenue by custkey bucket, filtered by
returnflag + orderdate).  Interactions vary one parameter value.

Reports Naive / Factorized / Treant latency per parameter (Fig 19),
speedup vs the annotated bag's row count (Fig 20a), and the message-store
overhead vs base data size (Fig 20b).  Calib-R/Calib-W map to calibration
compute vs message materialization bytes (we hold messages in memory; bytes
are reported instead of Redshift write time).
"""

from __future__ import annotations

import numpy as np

from repro.core import Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in

from .baselines import NaiveExecutor, cold_engine
from .common import emit, time_fn, timed_interact


PARAMS = [
    # (label, attr, values for the dashboard query, alternate values)
    ("segment", "mktsegment", [0], [1]),
    ("orderdate", "orderdate_b", list(range(0, 12)), list(range(12, 24))),
    ("shipdate", "shipdate_b", list(range(0, 12)), list(range(6, 18))),
    ("returnflag", "returnflag", [2], [0]),
    ("ptype", "ptype", [3], [7]),
]


def run(scale: float = 1.0):
    cat = schema.tpch(n_lineitem=int(300_000 * scale))
    jt = jt_from_catalog(cat)
    naive = NaiveExecutor(cat, "Lineitem")
    d = cat.domains()

    base = Query.make(cat, ring="sum", measure=("Lineitem", "revenue"))
    q3 = base.with_group_by("orderdate_b", "shippriority").with_predicate(
        mask_in(d["mktsegment"], [0], attr="mktsegment"))
    treant = Treant(cat, ring=sr.SUM, jt=jt)

    t_calib, _ = time_fn(lambda: treant.register_dashboard("q3", q3), repeats=1, warmup=0)
    emit("tpch/q3/Calib-R", t_calib)
    emit("tpch/q3/Calib-W_bytes", treant.cache_stats()["bytes"] / 1e12,
         f"{treant.cache_stats()['messages']} messages")

    rows_of = {
        "mktsegment": cat.get("Customer").num_rows,
        "orderdate_b": cat.get("Orders").num_rows,
        "shipdate_b": cat.get("Lineitem").num_rows,
        "returnflag": cat.get("Lineitem").num_rows,
        "ptype": cat.get("Lineitem").num_rows,
    }
    for label, attr, vals0, vals1 in PARAMS:
        q = q3.with_predicate(mask_in(d[attr], vals1, attr=attr))
        t_n, _ = time_fn(naive.execute, q, repeats=1, warmup=0)
        def factorized():
            eng = cold_engine(cat, sr.SUM, jt)
            f, _ = eng.execute(q)
            return f.field
        t_f, _ = time_fn(factorized, repeats=1, warmup=1)
        t_t, res = timed_interact(treant, "u", "q3", q)
        emit(f"tpch/q3/{label}/naive", t_n)
        emit(f"tpch/q3/{label}/factorized", t_f)
        emit(f"tpch/q3/{label}/treant", t_t,
             f"speedup={t_n / max(t_t, 1e-9):.0f}x bag_rows={rows_of[attr]}")
        treant.think_time("u", "q3", budget_messages=None)
    st = treant.cache_stats()
    base_bytes = sum(
        cat.get(n).num_rows * (len(cat.get(n).attrs) * 4 + 4) for n in cat.names()
    )
    emit("tpch/store_overhead", st["bytes"] / 1e12,
         f"store={st['bytes']/1e6:.1f}MB base={base_bytes/1e6:.1f}MB "
         f"ratio={st['bytes']/base_bytes:.2f}")


def main():
    run(scale=2.0)


if __name__ == "__main__":
    main()
