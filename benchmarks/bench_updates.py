"""Delta calibration — update-then-query latency vs recalibrate-from-scratch.

For chain and star schemas: calibrate a dashboard query, apply an append (and
a delete) to the fact relation, then compare

  delta   — ``CJTEngine.apply_delta``: n−1 delta messages, old ⊕ Δ, every
            off-path cached message reused, then one cache-hit query;
  rebuild — full ``calibrate`` of the new version on a cold store
            (2(n−1) messages, every base relation rescanned), then query.

Emits one CSV row per (schema, update-kind, path); ``derived`` records the
message counts so the strictly-fewer-messages claim is auditable.
"""

from __future__ import annotations

import numpy as np

from repro.core import CJTEngine, MessageStore, Query, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema

from .common import emit, seeded_rng, time_fn


def _random_append(rel, frac, rng):
    n = max(1, int(rel.num_rows * frac))
    codes = {a: rng.integers(0, rel.domains[a], n) for a in rel.attrs}
    measures = {m: rng.gamma(1.5, 10.0, n).astype(np.float32) for m in rel.measures}
    return rel.append_rows(codes, measures=measures)


def _timed_apply_delta(eng, q, delta):
    """Time apply_delta with XLA jit caches warm but the message store in its
    pre-update state (same discipline as common.timed_interact)."""
    snap = eng.store.snapshot()
    eng.apply_delta(q, delta)           # warm XLA jit cache
    eng.store.restore(snap)
    return time_fn(lambda: eng.apply_delta(q, delta), repeats=1, warmup=0)


def run_case(name: str, cat, fact: str, measure, group_by, frac: float = 0.01):
    jt = jt_from_catalog(cat)
    ring = sr.SUM
    rng = seeded_rng(f"updates/{name}")

    for kind in ("append", "delete"):
        eng = CJTEngine(jt, cat, ring)
        mk = lambda: Query.make(
            cat, ring="sum",
            measure=(fact, measure) if measure else None, group_by=group_by,
        )
        q = mk()
        eng.calibrate(q)
        rel = cat.get(fact)
        if kind == "append":
            new_rel, delta = _random_append(rel, frac, rng)
        else:
            new_rel, delta = rel.delete_rows(rng.random(rel.num_rows) < frac)
        cat.put(new_rel)

        # delta path: maintain cached messages, then query
        t_delta, (q_new, dstats) = _timed_apply_delta(eng, q, delta)
        t_q, (res, qstats) = time_fn(lambda: eng.execute(q_new), repeats=1, warmup=1)
        assert not dstats.fallback and qstats.messages_computed == 0

        # rebuild path: cold store, full calibration of the new version
        cold = CJTEngine(jt, cat, ring, store=MessageStore())
        cstats = cold.calibrate(mk())   # warm jit
        cold2 = CJTEngine(jt, cat, ring, store=MessageStore())
        t_full, cstats = time_fn(lambda: cold2.calibrate(mk()), repeats=1, warmup=0)

        assert dstats.delta_messages < cstats.messages_computed, (
            f"delta path must recompute strictly fewer messages: "
            f"{dstats.delta_messages} vs {cstats.messages_computed}"
        )
        emit(
            f"updates/{name}/{kind}/delta", t_delta + t_q,
            f"msgs={dstats.delta_messages} maintained={dstats.edges_maintained} "
            f"drows={dstats.delta_rows}",
        )
        emit(
            f"updates/{name}/{kind}/rebuild", t_full,
            f"msgs={cstats.messages_computed} rows={cstats.rows_scanned}",
        )

        # roll the catalog back so the delete case starts from the seed version
        cat.put(rel)


def run(scale: float = 0.33):
    run_case(
        "star_flight",
        schema.flight(n_flights=int(300_000 * scale)),
        "Flights", "dep_delay", ("carrier_group", "month"),
    )
    run_case(
        "chain6",
        schema.chain(r=6, fanout=8, domain=256),
        "R0", None, ("A4",),
    )


def main():
    run()


if __name__ == "__main__":
    main()
