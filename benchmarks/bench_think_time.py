"""Fig 15 analog — online-calibration sensitivity.

Latency of the 2nd interaction query as a function of the think-time
calibration budget (in messages) granted after the 1st: the stepped curve —
each completed sharable message knocks a chunk off the next query's Steiner
tree.
"""

from __future__ import annotations

from repro.core import Treant
from repro.core import semiring as sr
from repro.relational import schema

from .bench_flight import workload
from .common import emit, time_fn


def run(scale: float = 0.33):
    cat = schema.flight(n_flights=int(300_000 * scale))
    seqs = workload(cat)
    # pre-warm XLA jit caches so budget=0 isn't charged for compiles
    warm = Treant(cat, ring=sr.SUM)
    for viz in seqs:
        warm.register_dashboard(viz, seqs[viz][0])
        warm.interact("w", viz, seqs[viz][1])
        warm.think_time("w", viz)
        warm.interact("w", viz, seqs[viz][2])

    for viz in ("v1_delay_by_carrier", "v2_delay_by_state", "v3_delay_by_month"):
        q0, q1, q2 = seqs[viz]
        budgets = [0, 1, 2, 4, 6, 8]
        for budget in budgets:
            treant = Treant(cat, ring=sr.SUM)
            treant.register_dashboard(viz, q0)
            treant.interact("u", viz, q1)
            done = treant.think_time("u", viz, budget_messages=budget) if budget else 0
            t, res = time_fn(lambda: treant.interact("u", viz, q2), repeats=1, warmup=0)
            emit(f"think_time/{viz}/budget{budget}", t,
                 f"calibrated={done} reused={res.stats.messages_reused}")


def main():
    run()


if __name__ == "__main__":
    main()
