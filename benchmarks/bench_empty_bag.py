"""Fig 21 analog — empty-bag optimization on the TPC-DS-style star.

Q = MAX over (store, hour) of COUNT: without the empty bag the query
aggregates the fact bag's absorption; with the empty bag (store_key, hour...)
— here (store_key, time_key) as in Fig 5b — the materialized shortcut view
answers it directly.  Reports build time, query time, and sizes.
"""

from __future__ import annotations

import numpy as np

from repro.core import CJTEngine, MessageStore, Query, insert_empty_bag, jt_from_catalog
from repro.core import semiring as sr
from repro.core.calibration import factor_nbytes
from repro.relational import schema

from .common import emit, time_fn


def run(scale: float = 1.0):
    cat = schema.tpcds_star(n_sales=int(400_000 * scale))
    jt = jt_from_catalog(cat)
    q = Query.make(cat, ring="count", group_by=("store_key", "time_key"))

    # -- without empty bag: group-by over the fact bag ------------------------
    eng = CJTEngine(jt, cat, sr.COUNT, store=MessageStore())
    eng.calibrate(Query.make(cat, ring="count"))

    def q_no_bag():
        f, _ = eng.execute(q)
        return np.max(np.asarray(f.field))

    t_no, v_no = time_fn(q_no_bag, repeats=2, warmup=1)
    emit("empty_bag/query_without", t_no, f"max_count={v_no:.0f}")

    # -- with empty bag (store_key, time_key) under the fact --------------------
    jt2 = insert_empty_bag(
        jt, "TimeStores", ("store_key", "time_key"), host="bag:Store_Sales",
        reroute=["bag:Stores", "bag:Time"],
    )
    eng2 = CJTEngine(jt2, cat, sr.COUNT, store=MessageStore())
    t_build, _ = time_fn(lambda: eng2.calibrate(Query.make(cat, ring="count")),
                         repeats=1, warmup=0)
    emit("empty_bag/build", t_build)

    def q_bag():
        f, _ = eng2.execute(q)
        return np.max(np.asarray(f.field))

    t_yes, v_yes = time_fn(q_bag, repeats=2, warmup=1)
    assert abs(v_no - v_yes) < 1e-3
    emit("empty_bag/query_with", t_yes, f"speedup={t_no / max(t_yes, 1e-9):.1f}x")

    fact = cat.get("Store_Sales")
    fact_bytes = fact.num_rows * (len(fact.attrs) * 4 + 4)
    view_bytes = 4 * cat.domains()["store_key"] * cat.domains()["time_key"]
    emit("empty_bag/size_ratio", view_bytes / 1e12,
         f"fact={fact_bytes/1e6:.1f}MB view={view_bytes/1e6:.2f}MB "
         f"ratio={fact_bytes/max(view_bytes,1):.0f}x")


def main():
    run(scale=2.0)


if __name__ == "__main__":
    main()
