"""Exploratory-BI workload: predictive think-time + bin cubes vs σ-prefetch.

The PR-9 think-time path (``FixedKPrefetch``) pre-materializes the *nearest*
σ windows of the last brush — great for smooth drags, useless for the jumps
real exploration is made of: drill into a low bucket, glance at the top
buckets, backtrack to the unfiltered view, switch dimension.  This suite
drives exactly that loop over the Flight schema, twice:

- **leg A** — ``Treant(policy=FixedKPrefetch(2))``: the PR-9 σ-prefetch
  baseline.  The non-adjacent jump misses every parked candidate and pays a
  full warm fan-out execution.
- **leg B** — ``Treant(policy=PredictiveThinkTime(...))``: idle time builds a
  γ∪{brush-dim} **bin cube** per sibling viz, so ANY later σ on that
  dimension (jump, IN-list, backtrack-to-clear) is served by slicing the
  cube — 0 plan executions, 0 store probes (asserted below).

Timed passes are interleaved leg-A/leg-B so machine drift stays out of the
ratio.  Gated metrics: ``explore/brush_cube_hit_rate`` (structural — every
timed event must be cube-served), ``explore/warm_brush_cube`` (latency) and
``explore/cube_speedup`` (≥3x at full scale, the ISSUE-10 acceptance bar).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import (
    ClearFilter, DashboardSpec, FixedKPrefetch, PredictiveThinkTime, SetFilter,
    Treant, VizSpec, jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational import schema

from .common import emit

FLIGHT_SEED = 1
ROUNDS = 3

# (brush dimension, source viz); the workload cycles through all three —
# dimension switching is part of what the trajectory model has to absorb
BRUSH_DIMS = (
    ("carrier_group", "by_carrier"),
    ("delay_bucket", "by_delay"),
    ("month", "by_month"),
)

# the exploration happens against a held analysis context — two standing
# filters on OTHER dimensions (the defining crossfilter regime).  Multi-σ
# queries are where σ-family calibration stops helping: the jump brush
# composes three σs, so leg A re-runs real absorption work per sibling,
# while leg B's cubes were built *under* the context and still slice.
CONTEXT = (
    SetFilter("airport_size", values=(1, 2), source="by_size"),
    SetFilter("dow", lo=0, hi=4, source="by_dow"),
)


def explore_spec() -> DashboardSpec:
    m = ("Flights", "dep_delay")
    return DashboardSpec(vizzes=(
        VizSpec("by_state", measure=m, ring="sum", group_by=("airport_state",)),
        VizSpec("by_size", measure=m, ring="sum", group_by=("airport_size",)),
        VizSpec("by_carrier", measure=m, ring="sum", group_by=("carrier_group",)),
        VizSpec("by_delay", measure=m, ring="sum", group_by=("delay_bucket",)),
        VizSpec("by_month", measure=m, ring="sum", group_by=("month",)),
        VizSpec("by_dow", measure=m, ring="sum", group_by=("dow",)),
        VizSpec("state_by_size", measure=m, ring="sum",
                group_by=("airport_state", "airport_size")),
        VizSpec("carrier_by_month", measure=m, ring="sum",
                group_by=("carrier_group", "month")),
    ))


def _events(doms) -> list[tuple[SetFilter, list]]:
    """Per dimension: the drill anchor, then the timed exploration events —
    a non-adjacent jump (3-value IN-list: a different width than any
    σ-prefetch candidate, parked candidates are span-2 shifts) and the
    backtrack to unfiltered."""
    out = []
    for dim, src in BRUSH_DIMS:
        d = doms[dim]
        anchor = SetFilter(dim, values=(0, 1), source=src)
        jump = SetFilter(dim, values=(d - 3, d - 2, d - 1), source=src)
        out.append((anchor, [jump, ClearFilter(dim)]))
    return out


def _open(cat, jt, policy):
    t = Treant(cat, ring=sr.SUM, jt=jt, policy=policy)
    sess = t.open_session(explore_spec(), name="bench")
    return t, sess


def _warm(t, sess, events):
    """Untimed pass: sets the standing context, compiles every plan/
    cube-slice structure, plus one toggle/untoggle drill (the backtrack-
    heavy exploration pattern) so the visibility-scoped derive path is
    exercised in both legs."""
    for ctx in CONTEXT:
        sess.apply(ctx)
    sess.idle()
    for anchor, follows in events:
        sess.apply(anchor)
        sess.idle()
        for ev in follows:
            sess.apply(ev)
        sess.idle()
    from repro.core import ToggleRelation

    sess.apply(ToggleRelation("Carrier", viz="by_month"))
    sess.apply(events[0][0])                       # brush while toggled
    sess.apply(ClearFilter(events[0][0].attr))
    sess.apply(ToggleRelation("Carrier", viz="by_month"))  # backtrack
    sess.idle()


def _timed_pass(t, sess, events):
    """One drill/jump/backtrack loop; returns (latencies, cube-served flags,
    plan-exec delta over the timed events)."""
    lat, served = [], []
    for anchor, follows in events:
        sess.apply(anchor)
        sess.idle()                                # think-time: the leg's policy
        for ev in follows:
            t.store.block_until_ready()
            ex0 = _plan_execs(t)
            t0 = time.perf_counter()
            res = sess.apply(ev)
            jax.block_until_ready([r.factor.field for r in res.results.values()])
            lat.append(time.perf_counter() - t0)
            hits = sum(r.stats.bin_cube_hits for r in res.results.values())
            served.append(
                (hits == len(res.affected) > 0, _plan_execs(t) - ex0)
            )
    return lat, served


def _plan_execs(t) -> int:
    st = t.cache_stats()
    if "plans" not in st:
        return 0
    return st["plans"]["plans_built"] + st["plans"]["plan_hits"]


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    cat = schema.flight(n_flights=max(2_000, int(100_000 * scale)),
                        seed=FLIGHT_SEED)
    jt = jt_from_catalog(cat)
    doms = cat.domains()
    events = _events(doms)

    t_a, sess_a = _open(cat, jt, FixedKPrefetch(2))
    t_b, sess_b = _open(
        cat, jt, PredictiveThinkTime(cube_builds_per_idle=16, prefetch_k=2)
    )
    _warm(t_a, sess_a, events)
    _warm(t_b, sess_b, events)

    lat_a, lat_b, served_b = [], [], []
    for _ in range(ROUNDS):
        la, _ = _timed_pass(t_a, sess_a, events)
        lb, sb = _timed_pass(t_b, sess_b, events)
        lat_a += la
        lat_b += lb
        served_b += sb

    warm_a = float(np.median(lat_a))
    warm_b = float(np.median(lat_b))
    hit_rate = sum(1 for ok, _ in served_b if ok) / len(served_b)
    cube_execs = sum(d for ok, d in served_b if ok)

    emit("explore/warm_brush_prefetch", warm_a,
         f"σ-prefetch leg: non-adjacent jumps over {len(lat_a)} events")
    emit("explore/warm_brush_cube", warm_b,
         f"bin-cube leg: same events, cube-served={hit_rate:.2f}")
    speedup = warm_a / max(warm_b, 1e-9)
    emit("explore/cube_speedup", speedup / 1e6,
         f"bin cubes vs σ-prefetch on jumps = {speedup:.2f}x")
    emit("explore/brush_cube_hit_rate", hit_rate / 1e6,
         f"{sum(1 for ok, _ in served_b if ok)}/{len(served_b)} timed events "
         f"fully cube-served")

    st = sess_b.stats()
    emit("explore/bin_cube_hits", st["bin_cube_hits"] / 1e6,
         f"session cube hits = {st['bin_cube_hits']}")
    emit("explore/bin_cube_bytes", st["bin_cube_bytes"] / 1e12,
         f"cubes={st['bin_cubes']}")
    sched = t_b.cache_stats()["scheduler"]
    emit("explore/policy_decisions", sched["policy_decisions"] / 1e6,
         f"cube_builds={sched['cube_builds']}")

    # ISSUE-10 acceptance: every timed jump/backtrack is cube-served with
    # zero plan executions, and cube hits actually occurred
    assert st["bin_cube_hits"] > 0, "predictive leg never hit a bin cube"
    assert hit_rate == 1.0, (
        f"non-adjacent brushes escaped the bin cubes: hit rate {hit_rate:.2f}"
    )
    assert cube_execs == 0, (
        f"cube-served brushes still executed {cube_execs} plans"
    )
    if scale >= 1.0:
        assert speedup >= 3.0, (
            f"bin cubes only {speedup:.2f}x vs σ-prefetch on exploratory "
            f"jumps (acceptance bar is 3x)"
        )


if __name__ == "__main__":
    main()
