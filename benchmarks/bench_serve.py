"""Multi-tenant serving tier: cross-session batched fan-out vs serial apply.

Three scenarios over the Flight schema, all through :class:`TreantServer`:

1. **Shared-spec drag storm (1/16/64 sessions)** — N sessions over ONE
   dashboard spec each drag a brush through ``DRAG`` positions per round,
   clustered on a few hot σ windows (the shared-dashboard regime: many
   users brushing the same interesting ranges).  The server's event queue
   coalesces the superseded drag positions, digest-dedup shares one
   execution across sessions on the same window, and the remaining unique
   absorptions ride one vmapped ``execute_many`` dispatch spanning sibling
   sessions.  The serial baseline has none of those mechanisms — it must
   apply every submitted event one ``Session.apply`` at a time on a twin
   Treant.  Measures sustained events/sec (submitted events over wall
   time) and the p99 applied-event latency at each session count — the
   tentpole's acceptance bar is ≥4x events/sec at 64 sessions and
   ``REPRO_BENCH_SCALE≥0.1`` with ``cross_session_batch_width > 1`` and
   **bit-identical** per-session results.

2. **Distinct-spec storm (64 sessions)** — sessions cycle over four spec
   variants (different viz subsets): digest dedupe never fires across
   variants, so the win must come from coalescing + vmapped batching alone.

3. **Global byte budget** — the same storm under a store budget of 50% of
   the measured unbudgeted footprint: the store must stay under budget
   (above the pinned floor), evict only unpinned/quiescent entries, and
   every read must stay bit-identical to the unbudgeted run.

Count measures (``measure=None``) keep every aggregate integer-valued, so
"bit-identical" is a plain ``array_equal`` even across vmapped padding.
Ratio metrics are emitted as ratio/1e6 (the JSON value IS the ratio);
``events_per_sec`` follows the ``ingest/rows_per_sec`` convention
(value = events/sec); counter metrics emit count/1e6 (value = count).
"""

from __future__ import annotations

import contextlib
import gc
import os
import time

import numpy as np

from repro.core import (
    DashboardSpec, Drill, SetFilter, Treant, VizSpec, jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational import schema
from repro.serve import ServeStats, TreantServer

from .common import emit

FLIGHT_SEED = 1
ROUNDS = 4     # timed rounds; two warm rounds precede them
DRAG = 3       # brush positions per drag: 2 superseded + 1 final
WARM = -1      # warm marker: ONE σ window shared by all sessions
WARM2 = -2     # warm marker: hot-window layout (compiles the vmap widths)

# non-source dimensions each brush fans out to (6 vizzes per event)
FAN_DIMS = ("month", "carrier_group", "airport_size", "dow",
            "delay_bucket", "distance_bucket")


def serve_spec(dims=FAN_DIMS) -> DashboardSpec:
    vizzes = [VizSpec("by_state", measure=None, ring="sum",
                      group_by=("airport_state",))]
    vizzes += [VizSpec(f"by_{d}", measure=None, ring="sum", group_by=(d,))
               for d in dims]
    return DashboardSpec(vizzes=tuple(vizzes))


def spec_variants() -> list[DashboardSpec]:
    """Four distinct specs sharing only the brush source (no digest ever
    dedupes across variants with different viz sets)."""
    return [serve_spec(dims=FAN_DIMS[i:i + 3]) for i in range(4)]


HOT_WINDOWS = 8  # distinct σ windows per round, shared by 64/8 sessions each


def brush(i: int, rnd: int, step: int = DRAG - 1) -> SetFilter:
    """Session ``i``'s ``step``-th drag position of round ``rnd`` on
    ``airport_state`` (domain 52).

    Sessions cluster on ``HOT_WINDOWS`` hot windows per round; each session
    drags its brush toward its window through ``DRAG`` positions one unit
    apart, the last being the hot window itself.  Each round uses a
    distinct window *width* (2+rnd), so no window repeats across rounds —
    every timed round does real absorptions instead of pure cache hits.
    The warm rounds use width 6 (outside the timed range): ``WARM`` is ONE
    fixed window shared by all sessions; ``WARM2`` replays the hot-window
    layout so the vmapped batch widths compile before timing starts.
    """
    if rnd == WARM:
        return SetFilter(attr="airport_state", lo=20, hi=26, source="by_state")
    if rnd == WARM2:
        lo = (5 * (i % HOT_WINDOWS) + 3) % 49
        return SetFilter(attr="airport_state", lo=lo, hi=lo + 6,
                         source="by_state")
    lo = max((5 * (i % HOT_WINDOWS) + 11 * rnd) % 49 - (DRAG - 1 - step), 0)
    return SetFilter(attr="airport_state", lo=lo, hi=lo + 2 + rnd,
                     source="by_state")


@contextlib.contextmanager
def _no_gc():
    on = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        if on:
            gc.enable()
        gc.collect()


def _build(cat, jt):
    return Treant(cat, ring=sr.SUM, jt=jt)


def _drag_of(rnd: int) -> int:
    return 1 if rnd in (WARM, WARM2) else DRAG


def _serial_pass(sessions, rounds) -> tuple[list[float], float]:
    """Apply every submitted event one ``Session.apply`` at a time (no
    queue: every drag position executes); returns (per-event latencies,
    total wall seconds)."""
    lat = []
    with _no_gc():
        t0 = time.perf_counter()
        for rnd in rounds:
            for step in range(_drag_of(rnd)):
                for i, sess in enumerate(sessions):
                    t1 = time.perf_counter()
                    sess.apply(brush(i, rnd, step))
                    lat.append(time.perf_counter() - t1)
        total = time.perf_counter() - t0
    return lat, total


def _server_pass(server, handles, rounds) -> tuple[list[float], float]:
    """Submit each round's full drag stream, then drain batch-by-batch
    (superseded positions coalesce in the queue); the per-event latency is
    the batch wall time amortized over the events it applied."""
    lat = []
    with _no_gc():
        t0 = time.perf_counter()
        for rnd in rounds:
            for step in range(_drag_of(rnd)):
                for i, h in enumerate(handles):
                    h.submit(brush(i, rnd, step))
            while server.queue_depth:
                t1 = time.perf_counter()
                n = server.step()
                per = (time.perf_counter() - t1) / max(n, 1)
                lat.extend([per] * n)
        total = time.perf_counter() - t0
    return lat, total


def _assert_bit_identical(server_handles, serial_sessions):
    for h, sess in zip(server_handles, serial_sessions):
        for viz in sess.vizzes:
            got = np.asarray(h.read(viz).factor.field)
            want = np.asarray(sess.read(viz).factor.field)
            assert np.array_equal(got, want), (
                f"server session {h.id} viz {viz} diverged from serial apply"
            )


def run_fanout(scale: float = 1.0):
    cat = schema.flight(n_flights=max(2_000, int(50_000 * scale)),
                        seed=FLIGHT_SEED)
    jt = jt_from_catalog(cat)
    timed_rounds = list(range(ROUNDS))
    eps_by_n: dict[int, float] = {}
    serial_eps_by_n: dict[int, float] = {}

    for n_sessions in (1, 16, 64):
        spec = serve_spec()
        t_serial = _build(cat, jt)
        sessions = [t_serial.open_session(spec, name=f"s{i}")
                    for i in range(n_sessions)]
        t_srv = _build(cat, jt)
        server = TreantServer(t_srv, max_queue=4 * n_sessions)
        handles = [server.open_session(spec, name=f"s{i}")
                   for i in range(n_sessions)]

        # warm rounds (jit compiles, incl. the vmapped batch widths) on BOTH
        # sides, untimed; stats restart so counters reflect only timed rounds
        _serial_pass(sessions, [WARM, WARM2])
        _server_pass(server, handles, [WARM, WARM2])
        server.stats_ = ServeStats()

        lat_serial, wall_serial = _serial_pass(sessions, timed_rounds)
        lat_srv, wall_srv = _server_pass(server, handles, timed_rounds)
        n_events = n_sessions * ROUNDS * DRAG   # submitted (drag stream)
        n_applied = n_sessions * ROUNDS         # after coalescing
        serial_eps = n_events / max(wall_serial, 1e-9)
        eps = n_events / max(wall_srv, 1e-9)
        serial_eps_by_n[n_sessions] = serial_eps
        eps_by_n[n_sessions] = eps
        st = server.stats_

        emit(f"serve/events_per_sec_shared{n_sessions}", eps / 1e6,
             f"submitted={n_events} applied={st.events_processed} "
             f"batches={st.batches} serial={serial_eps:.0f}/s")
        emit(f"serve/p99_event_shared{n_sessions}",
             float(np.percentile(lat_srv, 99)),
             f"serial_p99={np.percentile(lat_serial, 99) * 1e6:.0f}us")
        if n_sessions > 1:
            # the whole point of the serving tier: sibling sessions ride one
            # dispatch — width must exceed 1 at ANY scale
            assert st.cross_session_batch_width > 1, (
                f"{n_sessions} sessions never shared a dispatch: {st}"
            )
        # superseded drag positions never execute
        assert st.coalesced_events == n_sessions * ROUNDS * (DRAG - 1), st
        assert st.events_processed == n_applied, st
        _assert_bit_identical(handles, sessions)
        if n_sessions == 64:
            emit("serve/cross_session_width",
                 st.cross_session_batch_width / 1e6,
                 f"max sessions in one dispatch (64 submitted)")

    speedup = eps_by_n[64] / max(serial_eps_by_n[64], 1e-9)
    emit("serve/speedup_shared64", speedup / 1e6,
         f"batched serving vs serial apply = {speedup:.2f}x")
    if scale >= 0.1:
        # the acceptance bar: one shared spec, 64 sessions, ≥4x sustained
        # events/sec over serial apply (bit-identity asserted above)
        assert speedup >= 4.0, (
            f"64-session batched serving only {speedup:.2f}x serial (< 4x)"
        )

    # -- distinct-spec leg: no digest dedupe across variants, the win is
    #    vmapped batching alone
    variants = spec_variants()
    t_serial = _build(cat, jt)
    sessions = [t_serial.open_session(variants[i % 4], name=f"d{i}")
                for i in range(64)]
    t_srv = _build(cat, jt)
    server = TreantServer(t_srv, max_queue=256)
    handles = [server.open_session(variants[i % 4], name=f"d{i}")
               for i in range(64)]
    _serial_pass(sessions, [WARM, WARM2])
    _server_pass(server, handles, [WARM, WARM2])
    server.stats_ = ServeStats()
    lat_serial, wall_serial = _serial_pass(sessions, timed_rounds)
    lat_srv, wall_srv = _server_pass(server, handles, timed_rounds)
    n_events = 64 * ROUNDS * DRAG
    eps = n_events / max(wall_srv, 1e-9)
    d_speedup = (n_events / max(wall_serial, 1e-9))
    d_speedup = eps / max(d_speedup, 1e-9)
    emit("serve/events_per_sec_distinct64", eps / 1e6,
         f"4 spec variants, p99={np.percentile(lat_srv, 99) * 1e6:.0f}us")
    emit("serve/speedup_distinct64", d_speedup / 1e6,
         f"distinct specs vs serial apply = {d_speedup:.2f}x")
    assert server.stats_.cross_session_batch_width > 1
    _assert_bit_identical(handles, sessions)


def _budget_storm(cat, jt, max_store_bytes):
    t = _build(cat, jt)
    server = TreantServer(t, max_store_bytes=max_store_bytes, max_queue=64)
    handles = [server.open_session(serve_spec(), name=f"b{i}")
               for i in range(16)]
    # each session drills one viz down one extra attr (16 distinct combos):
    # the drilled 2-attr contracts need γ-carry messages that base
    # calibration never pinned, so the storm carries evictable store state
    # on BOTH plan legs (with plans off, a plain brush is pure root
    # absorption over pinned messages — nothing a budget could evict)
    k = len(FAN_DIMS)
    for i, h in enumerate(handles):
        viz = FAN_DIMS[i % k]
        attr = FAN_DIMS[(i + 1 + i // k) % k]
        h.submit(Drill(viz=f"by_{viz}", attr=attr))
    while server.queue_depth:
        server.step()
    _server_pass(server, handles, [WARM, WARM2])
    _, wall = _server_pass(server, handles, list(range(ROUNDS)))
    return t, server, handles, wall


def run_budget(scale: float = 1.0):
    cat = schema.flight(n_flights=max(2_000, int(50_000 * scale)),
                        seed=FLIGHT_SEED)
    jt = jt_from_catalog(cat)
    t_free, _, free_handles, wall_free = _budget_storm(cat, jt, None)
    footprint = t_free.store.nbytes
    pinned = t_free.store.pinned_nbytes
    refs = {(i, viz): np.asarray(h.read(viz).factor.field)
            for i, h in enumerate(free_handles) for viz in h.session.vizzes}

    # the pinned base-calibration messages are the floor no budget may cross
    # (the store never evicts them), so "50% of the footprint" means 50% of
    # the *evictable* footprint on top of that floor — a budget below the
    # floor would be unsatisfiable by definition
    budget = pinned + (footprint - pinned) // 2
    t, server, handles, wall = _budget_storm(cat, jt, budget)
    store = t.store
    assert store.evictions > 0, "a 50% budget must evict"
    assert store.nbytes <= store.max_bytes, (
        f"store over budget at rest: {store.nbytes}B vs {store.max_bytes}B"
    )
    for sig in store._pinned:
        assert sig in store._data, f"pinned entry {sig} was evicted"
    # evicted entries recompute on demand, bit-identically
    for i, h in enumerate(handles):
        for viz in h.session.vizzes:
            got = np.asarray(h.read(viz).factor.field)
            assert np.array_equal(got, refs[(i, viz)]), (
                f"budgeted read diverged on session {i} viz {viz}"
            )
    emit("serve/budget_evictions", store.evictions / 1e6,
         f"budget={budget}B of {footprint}B footprint "
         f"({store.pinned_nbytes}B pinned floor + 50% evictable)")
    ratio = wall / max(wall_free, 1e-9)
    emit("serve/budget_wall_ratio", ratio / 1e6,
         f"budgeted vs unbudgeted storm wall = {ratio:.2f}x")


def main():
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    run_fanout(scale=scale)
    run_budget(scale=scale)


if __name__ == "__main__":
    main()
