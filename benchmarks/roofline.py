"""Roofline derivation from the dry-run artifacts (brief: §ROOFLINE ANALYSIS).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  Terms per (arch × shape), single-pod mesh (256 chips):

  compute    = HLO_FLOPs_per_device            / 197e12
  memory     = HLO_bytes_per_device            / 819e9
  collective = wire_bytes_per_device           / 50e9

HLO flops/bytes come from the *analysis* compiles (unrolled 1/2-unit
differencing — trip-count exact, see DESIGN.md §8); collective bytes from the
parsed per-device SPMD program (ring-model wire bytes; the raw operand-byte
sum per the brief's formula is also recorded in the artifacts).  MODEL_FLOPS
is 6·N(active)·tokens for training, 2·N·tokens for prefill/decode — the
MODEL/HLO ratio exposes remat and masked-attention waste.
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
OUT = Path(__file__).resolve().parents[1] / "artifacts" / "roofline.md"


def model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config
    from repro.configs.base import SHAPES

    cfg = get_config(arch)
    sh = SHAPES[shape]
    n = cfg.n_active_params()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len
    return 2.0 * n * sh.global_batch  # decode: one token per sequence


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        cells.append(d)
    return cells


def derive(cell: dict) -> dict | None:
    if cell.get("status") != "ok" or "analysis" not in cell:
        return None
    ex = cell["analysis"]["extrapolated"]
    chips = CHIPS[cell["mesh"]]
    flops = ex["flops"]            # per-device (SPMD program)
    bytes_ = ex["bytes"]
    wire = ex["wire_bytes"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = wire / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    mf = model_flops(cell["arch"], cell["shape"]) / chips
    bound = max(t_c, t_m, t_x)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom[0],
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "mem_gib": cell["memory"]["peak_per_device_bytes"] / 2**30,
    }


def lever(r: dict) -> str:
    """One sentence: what would move the dominant term down (brief req.)."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    if dom == "collective":
        if "train" in shape:
            return ("overlap FSDP weight gathers with compute (collective matmul) "
                    "and cut gather repeats by lowering grad-accum steps")
        if "moe" in arch or arch.startswith(("dbrx", "granite")):
            return "replace one-hot dispatch with sorted ragged all-to-all"
        return "ring/collective-permute attention over seq shards to overlap ICI with MXU"
    if dom == "memory":
        if "decode" in shape:
            return "KV-cache quantization (int8) and grouped-head cache reads"
        return "fuse norm/rope/residual chains; widen per-step arithmetic intensity (multi-query fusion)"
    if arch == "deepseek-coder-33b":
        return "context-parallel attention (attn_seq_shard=1, measured −87.6% §Perf/B)"
    return "exact causal-divide attention (attn_mode=divide, measured −47.6% §Perf/A)"


def render(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac | mem GiB | lever on dominant term |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['mem_gib']:.1f} | {lever(r)} |\n"
        )
    return hdr + body


def dryrun_table() -> str:
    """§Dry-run summary across BOTH meshes: every cell's compile + memory +
    collective schedule (artifacts/dryrun_summary.md)."""
    out = ("| arch | shape | mesh | status | peak GiB/chip | compile s | "
           "collectives (count) |\n|---|---|---|---|---|---|---|\n")
    for mesh in ("single", "multi"):
        for c in load_cells(mesh):
            if c.get("status") == "skipped":
                out += (f"| {c['arch']} | {c['shape']} | {mesh} | SKIP "
                        f"(full-attn @500k) | — | — | — |\n")
                continue
            if c.get("status") != "ok":
                out += f"| {c['arch']} | {c['shape']} | {mesh} | ERROR | — | — | — |\n"
                continue
            mem = c["memory"].get("peak_per_device_bytes", 0) / 2**30
            coll = c.get("collectives_schedule", {}).get("per_op", {})
            cs = " ".join(f"{k.replace('all-','a')}:{v['count']}" for k, v in sorted(coll.items()))
            out += (f"| {c['arch']} | {c['shape']} | {mesh} | ok | {mem:.1f} "
                    f"| {c.get('compile_s', 0):.0f} | {cs} |\n")
    return out


def main():
    dt = dryrun_table()
    (OUT.parent / "dryrun_summary.md").parent.mkdir(parents=True, exist_ok=True)
    (OUT.parent / "dryrun_summary.md").write_text(dt)
    rows = [d for c in load_cells("single") if (d := derive(c))]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    txt = render(rows)
    print(txt)
    skipped = [c for c in load_cells("single") if c.get("status") == "skipped"]
    for c in skipped:
        print(f"SKIP {c['arch']} × {c['shape']}: {c['reason'][:80]}")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(txt)
    # csv for EXPERIMENTS
    import csv
    with open(OUT.with_suffix(".csv"), "w", newline="") as f:
        if rows:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)


if __name__ == "__main__":
    main()
