"""Measured roofline for the CJT kernels → ``kernel_costs.json``.

Micro-benchmarks the three Pallas kernels the plan compiler can route to —
``segment_aggregate`` (sparse-bag ⊕ reduction), ``semiring_contract`` and
``tropical_contract`` (dense two-factor elimination) — against their jitted
lax/jnp reference implementations, on THIS machine and backend.  Three
numbers per kernel:

  launch_overhead_us   median wall time of a tile-sized call (fixed cost the
                       static gates were guessing at)
  bytes_per_sec        in+out bytes over wall time at the largest ladder size
  crossover_cost       largest one-hot-matmul work (N·G·V, resp. G·B·A) where
                       the kernel still beats the reference; geometric mean of
                       the last win and the first loss when they bracket

Derived knobs (the measured replacements for the old static gates):

  plan_kernel_cost          min over kernels of crossover_cost, floored at
                            the historical 1<<19 and capped at 1<<24 so a
                            noisy run can neither disable the kernel path nor
                            route pathological sizes to interpret mode
  calibration_union_budget  widening knee of segment_aggregate: the largest
                            segment count G where a fixed-N reduction still
                            runs within 2× of its G=64 time (widening a
                            calibration union is ~free up to there), clamped
                            to [64, 4096]

Outputs ``kernel_costs.json`` (machine-readable profile consumed by
``repro.kernels.costs``), ``roofline.md`` and ``roofline.csv`` to
``REPRO_BENCH_OUT`` (default: cwd).  Regenerate the committed default with

  PYTHONPATH=src REPRO_BENCH_OUT=benchmarks/baselines python -m benchmarks.roofline

``REPRO_PLAN_KERNEL_COST`` / ``REPRO_CALIBRATION_UNION_BUDGET`` env overrides
always win over the profile (see ``repro.core.plans``).
"""

from __future__ import annotations

import csv
import json
import os
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_aggregate import ops as seg_ops
from repro.kernels.segment_aggregate.ref import segment_aggregate_ref
from repro.kernels.semiring_contract import ops as sc_ops
from repro.kernels.semiring_contract.ref import semiring_contract_ref
from repro.kernels.tropical_contract import ops as tc_ops
from repro.kernels.tropical_contract.ref import tropical_contract_ref

from .common import emit, seeded_rng, time_fn

INTERPRET = jax.default_backend() != "tpu"

COST_FLOOR = 1 << 19
COST_CAP = 1 << 24
BUDGET_LO, BUDGET_HI = 64, 4096

# (n, g, v) ladder for segment_aggregate: cost = n·g·v
SEG_LADDER = [(256, 64, 4), (1024, 64, 4), (4096, 64, 4),
              (16384, 64, 4), (32768, 128, 4)]
# (g, b, a) ladder for the dense contractions: cost = g·b·a
DENSE_LADDER = [(32, 32, 32), (64, 64, 64), (128, 128, 128),
                (256, 128, 128), (256, 256, 256)]
# G ladder for the union-budget knee (fixed n, v=1)
KNEE_N = 4096
KNEE_LADDER = [64, 128, 256, 512, 1024, 2048, 4096]


_seg_ref = jax.jit(segment_aggregate_ref, static_argnums=(2, 3))
_sc_ref = jax.jit(semiring_contract_ref)
_tc_ref = jax.jit(partial(tropical_contract_ref), static_argnums=(2,))


def _seg_data(n: int, g: int, v: int):
    rng = seeded_rng(f"roofline/seg/{n}/{g}/{v}")
    codes = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    vals = jnp.asarray(rng.random((n, v)), jnp.float32)
    return codes, vals


def _dense_data(g: int, b: int, a: int):
    rng = seeded_rng(f"roofline/dense/{g}/{b}/{a}")
    m = jnp.asarray(rng.random((g, b)), jnp.float32)
    r = jnp.asarray(rng.random((b, a)), jnp.float32)
    return m, r


def _crossover(ladder: list[tuple[int, float, float]]) -> int:
    """Largest cost where the kernel wins; geomean with the first loss when
    the ladder brackets the flip.  All-win → top rung, all-lose → 0."""
    last_win = first_loss = None
    for cost, t_k, t_r in ladder:
        if t_k <= t_r:
            last_win = cost
        elif first_loss is None:
            first_loss = cost
    if last_win is None:
        return 0
    if first_loss is None or first_loss < last_win:
        return last_win
    return int((last_win * first_loss) ** 0.5)


def bench_segment_aggregate() -> dict:
    rows = []
    for n, g, v in SEG_LADDER:
        codes, vals = _seg_data(n, g, v)
        t_k, _ = time_fn(seg_ops.aggregate_op, codes, vals, g, op="sum",
                         interpret=INTERPRET)
        t_r, _ = time_fn(_seg_ref, codes, vals, g, "sum")
        rows.append((n * g * v, t_k, t_r))
    codes, vals = _seg_data(8, 8, 1)
    t0, _ = time_fn(seg_ops.aggregate_op, codes, vals, 8, op="sum",
                    interpret=INTERPRET, repeats=5)
    n, g, v = SEG_LADDER[-1]
    nbytes = 4 * (n + n * v + g * v)  # codes + values in, (g, v) out
    return {"launch_overhead_us": t0 * 1e6,
            "bytes_per_sec": nbytes / max(rows[-1][1], 1e-9),
            "crossover_cost": _crossover(rows),
            "ladder": [{"cost": c, "kernel_s": k, "ref_s": r}
                       for c, k, r in rows]}


def _bench_dense(op, ref) -> dict:
    rows = []
    for g, b, a in DENSE_LADDER:
        m, r = _dense_data(g, b, a)
        t_k, _ = time_fn(op, m, r)
        t_r, _ = time_fn(ref, m, r)
        rows.append((g * b * a, t_k, t_r))
    m, r = _dense_data(8, 8, 8)
    t0, _ = time_fn(op, m, r, repeats=5)
    g, b, a = DENSE_LADDER[-1]
    nbytes = 4 * (g * b + b * a + g * a)
    return {"launch_overhead_us": t0 * 1e6,
            "bytes_per_sec": nbytes / max(rows[-1][1], 1e-9),
            "crossover_cost": _crossover(rows),
            "ladder": [{"cost": c, "kernel_s": k, "ref_s": r}
                       for c, k, r in rows]}


def bench_union_knee() -> tuple[int, list[dict]]:
    """Largest G where widening a fixed-N segment reduction stays within 2×
    of its G=64 time — i.e. where launch/tile overhead still dominates and a
    union-carry calibration query widens for free."""
    rows, base = [], None
    for g in KNEE_LADDER:
        codes, vals = _seg_data(KNEE_N, g, 1)
        t, _ = time_fn(seg_ops.aggregate_op, codes, vals, g, op="sum",
                       interpret=INTERPRET)
        base = t if base is None else base
        rows.append({"num_segments": g, "kernel_s": t, "vs_g64": t / base})
    knee = BUDGET_LO
    for r in rows:
        if r["vs_g64"] <= 2.0:
            knee = max(knee, r["num_segments"])
    return min(max(knee, BUDGET_LO), BUDGET_HI), rows


def profile() -> dict:
    kernels = {
        "segment_aggregate": bench_segment_aggregate(),
        "semiring_contract": _bench_dense(
            partial(sc_ops.contract_op, interpret=INTERPRET), _sc_ref),
        "tropical_contract": _bench_dense(
            partial(tc_ops.contract_op, is_min=True, interpret=INTERPRET),
            lambda m, r: _tc_ref(m, r, True)),
    }
    budget, knee_rows = bench_union_knee()
    crossovers = [k["crossover_cost"] for k in kernels.values()]
    plan_cost = min(max(min(crossovers), COST_FLOOR), COST_CAP)
    return {
        "generated_by": "benchmarks.roofline",
        "backend": jax.default_backend(),
        "interpret": INTERPRET,
        "kernels": kernels,
        "union_knee": knee_rows,
        "derived": {
            "plan_kernel_cost": int(plan_cost),
            "calibration_union_budget": int(budget),
        },
    }


def render_md(prof: dict) -> str:
    out = ("# CJT kernel roofline (measured)\n\n"
           f"backend `{prof['backend']}`, interpret={prof['interpret']}\n\n"
           "| kernel | launch overhead µs | bytes/s | kernel-beats-ref up to cost |\n"
           "|---|---|---|---|\n")
    for name, k in prof["kernels"].items():
        out += (f"| {name} | {k['launch_overhead_us']:.1f} "
                f"| {k['bytes_per_sec']:.3e} | {k['crossover_cost']} |\n")
    d = prof["derived"]
    out += (f"\nderived: `plan_kernel_cost={d['plan_kernel_cost']}` "
            f"(floor {COST_FLOOR}, cap {COST_CAP}), "
            f"`calibration_union_budget={d['calibration_union_budget']}` "
            f"(clamped [{BUDGET_LO}, {BUDGET_HI}])\n")
    return out


def write_outputs(prof: dict, out_dir: Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "kernel_costs.json").write_text(
        json.dumps(prof, indent=2, sort_keys=True) + "\n")
    (out_dir / "roofline.md").write_text(render_md(prof))
    with open(out_dir / "roofline.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["kernel", "cost", "kernel_s", "ref_s"])
        for name, k in prof["kernels"].items():
            for row in k["ladder"]:
                w.writerow([name, row["cost"], row["kernel_s"], row["ref_s"]])


def main():
    prof = profile()
    for name, k in prof["kernels"].items():
        emit(f"roofline/{name}/launch_overhead", k["launch_overhead_us"] / 1e6)
        emit(f"roofline/{name}/crossover_cost_count", k["crossover_cost"] / 1e6,
             f"bytes/s={k['bytes_per_sec']:.3e}")
    d = prof["derived"]
    emit("roofline/derived/plan_kernel_cost_count", d["plan_kernel_cost"] / 1e6)
    emit("roofline/derived/calibration_union_budget_count",
         d["calibration_union_budget"] / 1e6)
    out_dir = Path(os.environ.get("REPRO_BENCH_OUT", "."))
    write_outputs(prof, out_dir)
    print(f"# wrote {out_dir / 'kernel_costs.json'} "
          f"(+ roofline.md, roofline.csv)", flush=True)


if __name__ == "__main__":
    main()
