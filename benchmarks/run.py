"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_<suite>.json`` summary (name → us_per_call) per executed suite, so
CI can upload perf artifacts and the trajectory accumulates.  Scales are
reduced for the 1-vCPU container; relative speedups (the paper's claims) are
scale-stable.

  python -m benchmarks.run              # all
  python -m benchmarks.run compiled     # one suite

``REPRO_BENCH_OUT`` overrides the JSON output directory (default: cwd).
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

from . import common


SUITES = [
    ("chain", "bench_chain", "Fig 23: JT vs No-JT on chain joins"),
    ("dashboard", "bench_dashboard", "Fig 13: Salesforce dashboard"),
    ("compiled", "bench_compiled", "Compiled message plans: jit+Pallas vs legacy"),
    ("flight", "bench_flight", "Fig 14/16: Flight/IDEBench workload"),
    ("think_time", "bench_think_time", "Fig 15: calibration sensitivity"),
    ("updates", "bench_updates", "Delta calibration: update-then-query vs rebuild"),
    ("ingest", "bench_ingest", "Streaming ingestion: coalesced ticks vs no-ingest baseline"),
    ("serve", "bench_serve", "Multi-tenant serving: cross-session batched fan-out + byte budget"),
    ("explore", "bench_explore", "Exploratory BI: predictive think-time + bin cubes vs σ-prefetch"),
    ("sharded", "bench_sharded", "Sharded CJT over a device mesh: rows/sec scaling 1-8 devices"),
    ("ml_aug", "bench_ml_augmentation", "Fig 18: factorized-ML augmentation"),
    ("tpch", "bench_tpch", "Fig 19/20: TPC-H dashboard"),
    ("empty_bag", "bench_empty_bag", "Fig 21: empty-bag optimization"),
    ("cube", "bench_cube", "Fig 24/25: data cubes over CJTs"),
]


def _write_json(key: str, rows) -> None:
    out_dir = os.environ.get("REPRO_BENCH_OUT", ".")
    path = os.path.join(out_dir, f"BENCH_{key}.json")
    with open(path, "w") as fh:
        json.dump({name: round(us, 3) for name, us, _ in rows}, fh,
                  indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}", flush=True)


def main() -> None:
    want = set(sys.argv[1:])
    failures = []
    print("name,us_per_call,derived")
    for key, module, desc in SUITES:
        if want and key not in want:
            continue
        print(f"# === {key}: {desc} ===", flush=True)
        t0 = time.time()
        start = len(common.ROWS)
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["main"])
            mod.main()
        except Exception:
            failures.append(key)
            traceback.print_exc()
        # failed suites get no JSON: a truncated summary in the perf
        # trajectory is worse than a missing one
        if len(common.ROWS) > start and key not in failures:
            _write_json(key, common.ROWS[start:])
        print(f"# === {key} done in {time.time() - t0:.1f}s ===", flush=True)
    # kernel roofline microbench: measures launch overhead / crossover for the
    # Pallas kernels and writes kernel_costs.json (nightly refresh; the
    # committed benchmarks/baselines/kernel_costs.json seeds the cost model)
    if not want or "roofline" in want:
        try:
            from . import roofline
            print("# === roofline (from dry-run artifacts) ===")
            roofline.main()
        except Exception:
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
