"""Shared benchmark helpers: timing, CSV emission, pinned RNG seeds.

Every benchmark must draw randomness through :func:`seeded_rng` (or pass an
explicit seed to the schema generators) so consecutive runs produce the same
data: a BENCH_*.json delta must be attributable to a code change, never to
sampling noise.
"""

from __future__ import annotations

import time
import zlib

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []

# One global seed for the whole benchmark suite; per-call streams are derived
# from it with a stable (process-independent) name hash.
DEFAULT_SEED = 0


def seeded_rng(name: str) -> np.random.Generator:
    """Deterministic per-use RNG stream (stable across processes/runs)."""
    return np.random.default_rng((DEFAULT_SEED, zlib.crc32(name.encode())))


def block(x):
    return jax.block_until_ready(x) if hasattr(x, "block_until_ready") or isinstance(
        x, (list, tuple, dict)
    ) else x


def time_fn(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> tuple[float, object]:
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if out is not None else None
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out) if out is not None else None
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def emit(name: str, seconds: float, derived: str = ""):
    """Print one `name,us_per_call,derived` CSV row (brief format)."""
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def timed_interact(treant, session: str, viz: str, q):
    """Time one Treant interaction with XLA jit caches warm but the message
    cache in its pre-interaction state (the paper warms caches before timing,
    §5.2).  Runs once on a store snapshot (warming compiles), restores, then
    times the real run.  Execution depends only on the store contents (the
    engine no longer plans against the previous query), so only the store
    needs restoring."""
    snap = treant.store.snapshot()
    treant.interact(session, viz, q)       # warm XLA jit cache
    treant.store.restore(snap)
    t0 = time.perf_counter()
    res = treant.interact(session, viz, q)
    return time.perf_counter() - t0, res
