"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
interpret mode (the brief's per-kernel requirement)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.segment_aggregate.ops import aggregate_op, level_aggregate
from repro.kernels.segment_aggregate.ref import segment_aggregate_ref
from repro.kernels.semiring_contract.ops import contract_op
from repro.kernels.semiring_contract.ref import semiring_contract_ref
from repro.kernels.tropical_contract.ops import contract_op as tropical_op
from repro.kernels.tropical_contract.ref import tropical_contract_ref


SHAPES = [(8, 8, 8), (64, 64, 64), (100, 70, 130), (256, 128, 200), (1, 300, 5)]


@pytest.mark.parametrize("g,b,a", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_semiring_contract_shapes(g, b, a, dtype):
    rng = np.random.default_rng(g * 1000 + b)
    m = rng.random((g, b)).astype(dtype)
    r = rng.random((b, a)).astype(dtype)
    got = contract_op(m, r)
    want = semiring_contract_ref(jnp.asarray(m), jnp.asarray(r))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), g=st.integers(1, 80), b=st.integers(1, 80),
       a=st.integers(1, 80))
def test_semiring_contract_fused_mask_property(seed, g, b, a):
    rng = np.random.default_rng(seed)
    m = rng.random((g, b)).astype(np.float32)
    r = rng.random((b, a)).astype(np.float32)
    mask = (rng.random(b) > 0.5).astype(np.float32)
    got = contract_op(m, r, mask)
    want = semiring_contract_ref(jnp.asarray(m), jnp.asarray(r), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("g,b,a", SHAPES[:4])
@pytest.mark.parametrize("is_min", [True, False])
def test_tropical_contract(g, b, a, is_min):
    rng = np.random.default_rng(a)
    m = rng.standard_normal((g, b)).astype(np.float32)
    r = rng.standard_normal((b, a)).astype(np.float32)
    got = tropical_op(m, r, is_min=is_min)
    want = tropical_contract_ref(jnp.asarray(m), jnp.asarray(r), is_min)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("n,g,v", [(64, 8, 1), (1000, 64, 3), (77, 13, 5), (4096, 300, 2)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_aggregate(n, g, v, op):
    rng = np.random.default_rng(n + g)
    codes = rng.integers(0, g, n).astype(np.int32)
    vals = rng.random((n, v)).astype(np.float32)
    got = aggregate_op(jnp.asarray(codes), jnp.asarray(vals), g, op=op)
    want = segment_aggregate_ref(jnp.asarray(codes), jnp.asarray(vals), g, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 600), g=st.integers(1, 100))
def test_segment_aggregate_property(seed, n, g):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, g, n).astype(np.int32)
    vals = rng.standard_normal((n, 2)).astype(np.float32)
    got = aggregate_op(jnp.asarray(codes), jnp.asarray(vals), g, op="sum")
    want = segment_aggregate_ref(jnp.asarray(codes), jnp.asarray(vals), g, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_segment_aggregate_1d_squeeze():
    codes = jnp.asarray([0, 1, 1, 2], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    got = aggregate_op(codes, vals, 3, op="sum")
    np.testing.assert_allclose(np.asarray(got), [1.0, 5.0, 4.0])


# -- multi-segment level launch: several messages, ONE kernel call ----------

def _level_items(specs, seed):
    rng = np.random.default_rng(seed)
    items = []
    for n, g, v in specs:
        codes = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
        vals = jnp.asarray(rng.standard_normal((n, v)).astype(np.float32))
        items.append((codes, vals, g))
    return items


@pytest.mark.parametrize("specs", [
    [(64, 8, 1)],                                  # degenerate: one message
    [(64, 8, 2), (100, 13, 2), (256, 64, 2)],      # equal widths
    [(30, 5, 1), (1000, 64, 4), (77, 13, 3)],      # ragged N/G/V
    [(7, 3, 1), (9, 300, 2)],                      # tiny rows, wide segments
])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_level_aggregate_matches_per_message(specs, op):
    """The fused block-diagonal launch must agree with running each message
    through the reference oracle independently."""
    items = _level_items(specs, seed=sum(n for n, _, _ in specs))
    outs = level_aggregate(items, op=op)
    assert len(outs) == len(items)
    for (codes, vals, g), got in zip(items, outs):
        want = segment_aggregate_ref(codes, vals, g, op)
        assert got.shape == (g, vals.shape[1])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), k=st.integers(1, 5))
def test_level_aggregate_property(seed, k):
    rng = np.random.default_rng(seed)
    widths = [(int(rng.integers(1, 201)), int(rng.integers(1, 41)),
               int(rng.integers(1, 5))) for _ in range(k)]
    items = _level_items(widths, seed=seed)
    outs = level_aggregate(items, op="sum")
    for (codes, vals, g), got in zip(items, outs):
        want = segment_aggregate_ref(codes, vals, g, "sum")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_level_aggregate_empty_segments_get_identity():
    """Segments no row maps to must hold the ⊕-identity, per op."""
    items = [(jnp.asarray([0, 0], jnp.int32),
              jnp.asarray([[1.0], [2.0]], jnp.float32), 4)]
    np.testing.assert_allclose(
        np.asarray(level_aggregate(items, op="sum")[0][:, 0]), [3.0, 0, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(level_aggregate(items, op="min")[0][1:, 0]),
        np.full(3, np.inf))
    np.testing.assert_array_equal(
        np.asarray(level_aggregate(items, op="max")[0][1:, 0]),
        np.full(3, -np.inf))
