"""Level-batched calibration: the metamorphic suite.

The tentpole's correctness spine: a level-synchronous batched calibration
pass (``CJTEngine.calibrate(batch=True)`` / ``calibrate_many``) must leave
the MessageStore in a state where every directed-edge message is
**bit-identical** to the sequential per-edge reference loop — across rings
(COUNT/SUM/MIN/MAX/MOMENTS), across tree shapes (chain/star/bushy) and with
compiled plans on or off (plans off degrades to the per-edge loop).
Measures are small integers, exactly representable in f32, so every
⊕-order — including the union-carry ⊕-marginalization narrowing — yields
the same bits (same convention as tests/test_batched_plans.py).

Plus: level-granular preemption (abandoning ``calibrate_levels_iter``
mid-pass keeps every completed level's messages servable), the scheduler's
cost-weighted priority (cheapest-remaining viz drains first), and the
dispatch/counter accounting the CI perf gate relies on.
"""

import jax
import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import (
    CJTEngine,
    DashboardSpec,
    MessageStore,
    Query,
    SetFilter,
    Treant,
    VizSpec,
    jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational.relation import Catalog, Relation, mask_in

RINGS = {
    "count": sr.COUNT,
    "sum": sr.SUM,
    "tropical_min": sr.TROPICAL_MIN,
    "tropical_max": sr.TROPICAL_MAX,
    "moments": sr.MOMENTS,
}


def _rel(name, attrs, doms, n, rng, measure=False):
    codes = {a: rng.integers(0, doms[a], n).astype(np.int32) for a in attrs}
    measures = (
        {"m": rng.integers(0, 16, n).astype(np.float32)} if measure else None
    )
    return Relation(name, tuple(attrs), codes, doms, measures=measures)


def chain_catalog(seed=0) -> Catalog:
    """F(a,b) ← S(b,c) ← T(c,d): a 3-bag chain (depth-2 levels)."""
    rng = np.random.default_rng(seed)
    doms = {"a": 6, "b": 7, "c": 5, "d": 8}
    return Catalog([
        _rel("F", ("a", "b"), doms, 500, rng, measure=True),
        _rel("S", ("b", "c"), doms, 60, rng),
        _rel("T", ("c", "d"), doms, 40, rng),
    ])


def star_catalog(seed=0) -> Catalog:
    """F(a,b)+m ← S(b,c), T(a,d), U(b,e): fact-centered star."""
    rng = np.random.default_rng(seed)
    doms = {"a": 13, "b": 7, "c": 10, "d": 5, "e": 9}
    return Catalog([
        _rel("F", ("a", "b"), doms, 600, rng, measure=True),
        _rel("S", ("b", "c"), doms, 77, rng),
        _rel("T", ("a", "d"), doms, 29, rng),
        _rel("U", ("b", "e"), doms, 41, rng),
    ])


def bushy_catalog(seed=0) -> Catalog:
    """Chain with side branches at both ends (mixed level widths)."""
    rng = np.random.default_rng(seed)
    doms = {"a": 6, "b": 7, "c": 5, "d": 8, "e": 4, "g": 9}
    return Catalog([
        _rel("F", ("a", "b"), doms, 400, rng, measure=True),
        _rel("S", ("b", "c"), doms, 70, rng),
        _rel("T", ("c", "d"), doms, 50, rng),
        _rel("A", ("a", "e"), doms, 30, rng),
        _rel("D", ("d", "g"), doms, 35, rng),
    ])


SHAPES = {"chain": chain_catalog, "star": star_catalog, "bushy": bushy_catalog}


def assert_stores_message_identical(e1, e2, q):
    placement = e1.place_predicates(q)
    for (u, v) in e1.jt.directed_edges():
        m1 = e1.message(q, u, v, placement)
        m2 = e2.message(q, u, v, placement)
        assert m1.attrs == m2.attrs
        l1 = jax.tree_util.tree_leaves(m1.field)
        l2 = jax.tree_util.tree_leaves(m2.field)
        assert len(l1) == len(l2)
        for a, b in zip(l1, l2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# metamorphic parity: level-batched ≡ per-edge, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", sorted(RINGS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_level_batched_equals_per_edge(ring_name, shape):
    cat = SHAPES[shape](seed=3)
    jt = jt_from_catalog(cat)
    measure = None if ring_name == "count" else ("F", "m")
    gamma = ("c",) if shape != "star" else ("c", "d")
    q = Query.make(cat, ring=ring_name, measure=measure, group_by=gamma)
    seq = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore())
    bat = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore())
    st_seq = seq.calibrate(q, batch=False)
    st_bat = bat.calibrate(q, batch=True)
    assert seq.is_calibrated(q) and bat.is_calibrated(q)
    assert_stores_message_identical(seq, bat, q)
    n_edges = len(jt.directed_edges())
    assert st_seq.messages_computed == n_edges
    # batched pass covers the same edges (level order differs, totals agree)
    assert st_bat.messages_computed + st_bat.messages_reused >= n_edges
    assert 0 < st_bat.calibration_dispatches <= st_seq.calibration_dispatches


@pytest.mark.parametrize("use_plans", [False, True])
def test_level_batched_plans_on_off(use_plans):
    """Plans off: the batch flag is inert and the per-edge reference loop
    runs — results must stay bit-identical either way."""
    cat = star_catalog(seed=5)
    jt = jt_from_catalog(cat)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    ref = CJTEngine(jt, cat, sr.SUM, store=MessageStore(), use_plans=False)
    eng = CJTEngine(
        jt, cat, sr.SUM, store=MessageStore(), use_plans=use_plans,
        batch_calibration=True,
    )
    ref.calibrate(q, batch=False)
    eng.calibrate(q)  # engine default: batch iff plans exist
    assert_stores_message_identical(ref, eng, q)
    if not use_plans:
        assert eng.plans is None  # level batching inert without plans


@pytest.mark.parametrize("ring_name", ["sum", "tropical_min", "moments"])
def test_calibrate_many_union_carry_parity(ring_name):
    """calibrate_many fuses sibling γs into union-carry passes; every member
    query must still be fully calibrated, bit-identical to per-edge."""
    cat = star_catalog(seed=7)
    jt = jt_from_catalog(cat)
    measure = ("F", "m")
    qs = [
        Query.make(cat, ring=ring_name, measure=measure, group_by=g)
        for g in [("c",), ("d",), ("e",), ("c", "d")]
    ]
    seq = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore())
    bat = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore())
    for q in qs:
        seq.calibrate(q, batch=False)
    _, effective = bat.calibrate_many(qs, batch=True)
    assert len(effective) < len(qs), "union-carry fused nothing"
    for q in qs:
        assert bat.is_calibrated(q), q.group_by
        f_seq, _ = seq.execute(q)
        f_bat, _ = bat.execute(q)
        for a, b in zip(
            jax.tree_util.tree_leaves(f_seq.field),
            jax.tree_util.tree_leaves(f_bat.field),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the whole point: strictly fewer message dispatches than per-edge
    assert (
        bat.plans.stats.calibration_dispatches
        < seq.plans.stats.calibration_dispatches
    )


def test_union_carry_respects_budget(monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION_UNION_BUDGET", "1")
    cat = star_catalog(seed=9)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    qs = [Query.make(cat, ring="sum", measure=("F", "m"), group_by=(g,))
          for g in ("c", "d")]
    eff = eng._union_carry(qs)
    assert [q.group_by for q in eff] == [("c",), ("d",)]  # nothing fused
    monkeypatch.setenv("REPRO_CALIBRATION_UNION_BUDGET", "256")
    eff = eng._union_carry(qs)
    assert [q.group_by for q in eff] == [("c", "d")]


# ---------------------------------------------------------------------------
# preemption: completed levels stay servable
# ---------------------------------------------------------------------------

def test_abandoned_iterator_keeps_completed_levels():
    cat = bushy_catalog(seed=11)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    it = eng.calibrate_levels_iter(q)
    completed = [next(it), next(it)]  # abandon mid-pass
    del it
    placement = eng.place_predicates(q)
    store_probes = eng.store.hits + eng.store.misses
    for level in completed:
        for (u, v) in level:
            base = eng.edge_sig(q, u, v, placement)
            assert eng.store.contains(base, eng.gamma_carry(q, u, v)), (
                f"completed-level message {(u, v)} not servable"
            )
    assert eng.store.hits + eng.store.misses >= store_probes
    # resuming from a fresh iterator finishes the pass (store dedupe)
    stats = eng.calibrate(q, batch=True)
    assert eng.is_calibrated(q)
    n_done = sum(len(lv) for lv in completed)
    assert stats.messages_reused >= n_done


def test_step_calibration_budget_exact_and_resumable():
    """Per-edge stepping (the scheduler's budget path) advances exactly
    max_edges and the level executor resumes from the parked offset."""
    cat = star_catalog(seed=13)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    plan = eng.calibration_plan(q)
    n_edges = len(jt.directed_edges())
    assert plan.edges_left() == n_edges
    assert eng.step_calibration(plan, max_edges=1) == 1
    assert plan.edges_left() == n_edges - 1
    # finish with the batched level executor, mid-level offset preserved
    stats = repro.core.ExecStats()
    while not plan.done:
        eng.run_calibration_level([plan], [stats])
    assert eng.is_calibrated(q)
    ref = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    ref.calibrate(q, batch=False)
    assert_stores_message_identical(ref, eng, q)


# ---------------------------------------------------------------------------
# scheduler: cost-weighted priority
# ---------------------------------------------------------------------------

def test_scheduler_drains_cheapest_remaining_first():
    """Two pending vizzes on different engines: the one with the smaller
    estimated remaining work must complete first (shortest-job-first),
    regardless of interaction recency."""
    cat = star_catalog(seed=17)
    jt = jt_from_catalog(cat)
    t = Treant(cat, ring=sr.SUM, jt=jt)
    cheap = Query.make(cat, ring="sum", measure=("F", "m"))
    costly = Query.make(
        cat, ring="count", group_by=("c", "d", "e"),
        predicates=(mask_in(13, [0, 1, 2], attr="a"),),
    )
    # pre-calibrate the cheap CJT: its remaining cost is ~0
    t.engine.calibrate(cheap)
    t.scheduler.schedule("s", "cheap", cheap, t.engine)
    t.scheduler.schedule(
        "s", "costly", costly, t.engine_for("count", None)
    )
    # recency alone would run "costly" first (scheduled last); cost-weighted
    # priority must complete "cheap" inside a budget that cannot finish both
    # (completed tasks are popped lazily on the next drain, so assert the
    # pass positions rather than queue membership)
    n_edges = len(jt.directed_edges())
    t.scheduler.run(budget_messages=n_edges, session="s")
    cheap_task = t.scheduler._tasks.get(("s", "cheap"))
    costly_task = t.scheduler._tasks[("s", "costly")]
    assert cheap_task is None or cheap_task.plan.done, "cheapest viz not drained"
    assert costly_task.plan is None or not costly_task.plan.done, (
        "budget finished everything — not discriminating"
    )


def test_idle_level_drain_batches_across_vizzes():
    """Session.idle without a message budget drains level-by-level across
    vizzes; sibling σ'd calibrations share signatures and batch."""
    cat = star_catalog(seed=19)
    jt = jt_from_catalog(cat)
    t = Treant(cat, ring=sr.SUM, jt=jt, batch_calibration=True)
    spec = DashboardSpec(vizzes=(
        VizSpec("by_c", measure=("F", "m"), ring="sum", group_by=("c",)),
        VizSpec("by_d", measure=("F", "m"), ring="sum", group_by=("d",)),
        VizSpec("by_e", measure=("F", "m"), ring="sum", group_by=("e",)),
    ))
    sess = t.open_session(spec, name="s", calibrate=False)
    # source viz keeps its dimension → the two siblings re-render + queue
    sess.apply(SetFilter("a", values=(1, 2), source="by_c"))
    assert t.scheduler.pending(sess.id) == 2
    done = sess.idle()
    assert done > 0
    assert t.scheduler.pending(sess.id) == 0
    for viz in ("by_d", "by_e"):
        assert t.engine.is_calibrated(sess.query_of(viz))


def test_scheduler_budget_still_exact_under_batching():
    """budget_messages forces per-edge granularity: never overshoots."""
    cat = star_catalog(seed=23)
    jt = jt_from_catalog(cat)
    t = Treant(cat, ring=sr.SUM, jt=jt, batch_calibration=True)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    t.scheduler.schedule("s", "v", q, t.engine)
    total = 0
    while True:
        got = t.scheduler.run(budget_messages=2, session="s")
        assert got <= 2
        if got == 0:
            break
        total += got
    assert total == len(jt.directed_edges())
    assert t.engine.is_calibrated(q)


# ---------------------------------------------------------------------------
# counters + env gate
# ---------------------------------------------------------------------------

def test_session_offline_counters_and_dispatch_reduction():
    spec = DashboardSpec(vizzes=(
        VizSpec("by_c", measure=("F", "m"), ring="sum", group_by=("c",)),
        VizSpec("by_d", measure=("F", "m"), ring="sum", group_by=("d",)),
        VizSpec("by_e", measure=("F", "m"), ring="sum", group_by=("e",)),
        VizSpec("by_cd", measure=("F", "m"), ring="sum", group_by=("c", "d")),
    ))
    cat = star_catalog(seed=29)
    jt = jt_from_catalog(cat)
    tb = Treant(cat, ring=sr.SUM, jt=jt, use_plans=True, batch_calibration=True)
    tu = Treant(cat, ring=sr.SUM, jt=jt, use_plans=True, batch_calibration=False)
    tb.open_session(spec, name="b")
    tu.open_session(spec, name="u")
    pb = tb.cache_stats()["plans"]
    pu = tu.cache_stats()["plans"]
    assert 0 < pb["calibration_dispatches"] < pu["calibration_dispatches"]
    assert pu["level_batched_execs"] == 0
    # both legs leave every viz fully calibrated and servable
    for t, name in ((tb, "b"), (tu, "u")):
        sess = t.session(name)
        for viz in ("by_c", "by_d", "by_e", "by_cd"):
            assert t.engine.is_calibrated(sess.query_of(viz))


def test_env_gate_batch_calibration(monkeypatch):
    cat = star_catalog(seed=31)
    monkeypatch.setenv("REPRO_BATCH_CALIBRATION", "0")
    t = Treant(cat, ring=sr.SUM)
    assert not t.engine.batch_calibration and not t.engine._batch_enabled()
    monkeypatch.setenv("REPRO_BATCH_CALIBRATION", "1")
    t = Treant(cat, ring=sr.SUM)
    assert t.engine.batch_calibration
    # explicit argument wins over the env
    t = Treant(cat, ring=sr.SUM, batch_calibration=False)
    assert not t.engine.batch_calibration


def test_update_then_close_releases_union_pins():
    """Delta maintenance must not mint phantom pins for messages pinned only
    through a wider union-carry variant: maintaining the narrow tracked
    queries used to add a direct pin per edge that no holder ever released,
    so close() after a Treant.update leaked pins forever."""
    cat = star_catalog(seed=41)
    t = Treant(cat, ring=sr.SUM, batch_calibration=True)
    spec = DashboardSpec(vizzes=(
        VizSpec("by_c", measure=("F", "m"), ring="sum", group_by=("c",)),
        VizSpec("by_d", measure=("F", "m"), ring="sum", group_by=("d",)),
    ))
    sess = t.open_session(spec)
    pinned_before = len(t.store._pinned)
    rng = np.random.default_rng(0)
    rel = cat.get("F")
    new_rel, delta = rel.append_rows(
        {a: rng.integers(0, rel.domains[a], 20) for a in rel.attrs},
        measures={"m": rng.integers(0, 16, 20).astype(np.float32)},
    )
    res = t.update(new_rel, delta)
    assert res.queries_fallback == 0
    # migration moves pins, it must not multiply them
    assert len(t.store._pinned) <= pinned_before
    sess.close()
    assert not t.store._pinned, "update+close leaked union-carry pins"


def test_close_unpins_union_carry_queries():
    """Session GC with batched calibration: the *effective* union queries
    hold the pins, and close() must release exactly those."""
    cat = star_catalog(seed=37)
    t = Treant(cat, ring=sr.SUM, batch_calibration=True)
    spec = DashboardSpec(vizzes=(
        VizSpec("by_c", measure=("F", "m"), ring="sum", group_by=("c",)),
        VizSpec("by_d", measure=("F", "m"), ring="sum", group_by=("d",)),
    ))
    sess = t.open_session(spec)
    assert t.store._pinned, "offline calibration pinned nothing"
    assert sess._pinned_queries, "no effective queries recorded"
    sess.close()
    assert not t.store._pinned, "close leaked union-carry pins"
