"""Runtime substrate: checkpointing (async/atomic/restore/reshard), data
pipeline determinism, straggler monitor, gradient compression, sharding rules."""

import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, restore_pytree, save_pytree
from repro.data.pipeline import StragglerMonitor, TokenPipeline, synth_batch
from repro.optim.compression import compress_int8, decompress_int8
from repro.runtime.compat import make_mesh


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.float32(3.5), "d": np.arange(4, dtype=np.int32)}}
    save_pytree(tree, tmp_path, 7)
    got, step = restore_pytree(tmp_path, template=tree)
    assert step == 7
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["b"]["d"], tree["b"]["d"])


def test_checkpoint_async_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"w": np.zeros(4, np.float32)}
    for s in (1, 2, 3, 4):
        ck.save_async({"w": np.full(4, s, np.float32)}, s)
    ck.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]
    got, s = restore_pytree(tmp_path, template=tree)
    assert s == 4 and got["w"][0] == 4.0
    ck.close()


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with different shardings (mesh change) — elastic scaling."""
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    save_pytree(tree, tmp_path, 1)
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = restore_pytree(tmp_path, template=tree, shardings=sh)
    assert got["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])


def test_pipeline_deterministic_restart():
    p1 = TokenPipeline(100, 2, 8, start_step=5)
    b1 = next(p1)
    p1.close()
    p2 = TokenPipeline(100, 2, 8, start_step=5)
    b2 = next(p2)
    p2.close()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    direct = synth_batch(100, 2, 8, 5)
    np.testing.assert_array_equal(b1["tokens"], direct["tokens"])


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=2.0, warmup=2)
    for i in range(8):
        assert not m.observe(i, 0.1)
    assert m.observe(8, 0.5)
    assert m.flagged == [(8, 0.5)]
    assert not m.observe(9, 0.11)  # ewma not polluted by the outlier


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(128), jnp.float32)}
    q, scales, err = compress_int8(g)
    deq = decompress_int8(q, scales)
    rel = np.linalg.norm(np.asarray(deq["w"]) - np.asarray(g["w"])) / np.linalg.norm(np.asarray(g["w"]))
    assert rel < 0.02
    # feeding the error back makes the SUM over steps exact-ish
    q2, s2, err2 = compress_int8(g, error=err)
    total = np.asarray(decompress_int8(q, scales)["w"]) + np.asarray(decompress_int8(q2, s2)["w"])
    want = 2 * np.asarray(g["w"])
    assert np.linalg.norm(total - want) / np.linalg.norm(want) < 0.02


def test_sharding_rules_divisibility_fallback():
    import os
    from repro.runtime.sharding import make_rules, pspec_for
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, multi_pod=False)
    # vocab 49155 can't shard 16-ways → but divisible by 1 here; simulate by hand
    from repro.runtime import sharding as sh_mod

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    fake_rules = sh_mod.ShardingRules(mesh=FakeMesh(), table=rules.table)
    p = pspec_for((49155, 1024), ("vocab", "embed"), fake_rules)
    assert p[0] is None          # 49155 % 16 != 0 → replicated
    assert p[1] == "data"
    p2 = pspec_for((100352, 1024), ("vocab", "embed"), fake_rules)
    assert p2[0] == "model"
    # same mesh axis never used twice
    p3 = pspec_for((64, 64), ("embed", "act_batch"), fake_rules)
    assert p3[0] == "data" and (len(p3) < 2 or p3[1] is None)


def test_train_driver_failure_recovery(tmp_path):
    from repro.launch.train import main as train_main
    losses = train_main([
        "--arch", "granite-moe-1b-a400m", "--steps", "10", "--batch", "2",
        "--seq", "32", "--ckpt-every", "4", "--inject-failure", "6",
        "--ckpt-dir", str(tmp_path), "--log-every", "5",
    ])
    assert len(losses) >= 10
    assert all(np.isfinite(l) for l in losses)
