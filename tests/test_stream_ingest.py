"""Streaming ingestion (ISSUE 6 tentpole): the metamorphic suite.

The correctness spine is *stream-then-flush ≡ rebuild*: any sequence of
append/delete micro-batches pushed through ``Treant.stream(...)`` and
committed by ``flush()`` must leave every tracked viz bit-identical to a cold
engine rebuilt over the committed relation versions — across group rings
(SUM/COUNT/MOMENTS absorb signed deltas) AND idempotent rings (MIN/MAX absorb
tombstoned deltas without fallback; deletes become visible at compaction).
Measures are small integers so every ⊕ order yields the same f32 bits (same
convention as tests/test_plans.py).

The coalescing contract: one version bump + one apply_delta sweep per
relation per tick, however many micro-batches arrived (``Treant.ingest``).

The watermark contract: all relations commit under ONE watermark bump, and a
reader snapshotting the catalog *during* maintenance sees the complete
pre-tick version vector — never a mix (asserted against ``commit_log``).
"""

import dataclasses

import jax
import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import (
    CJTEngine,
    DashboardSpec,
    MessageStore,
    Query,
    SetFilter,
    Treant,
    VizSpec,
    jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational import StreamBuffer
from repro.relational.relation import Catalog, Relation


def star_catalog(n_fact: int = 300, seed: int = 0) -> Catalog:
    """F(a,b)+m ← S(b,c), T(a,d), U(b,e); integer measures for bit-stability."""
    rng = np.random.default_rng(seed)
    doms = {"a": 13, "b": 7, "c": 10, "d": 5, "e": 9}

    def codes(attrs, n):
        return {x: rng.integers(0, doms[x], n).astype(np.int32) for x in attrs}

    f = Relation("F", ("a", "b"), codes(("a", "b"), n_fact), doms,
                 measures={"m": rng.integers(0, 16, n_fact).astype(np.float32)})
    s = Relation("S", ("b", "c"), codes(("b", "c"), 77), doms)
    t = Relation("T", ("a", "d"), codes(("a", "d"), 29), doms)
    u = Relation("U", ("b", "e"), codes(("b", "e"), 41), doms)
    return Catalog([f, s, t, u])


def assert_factors_identical(f1, f2):
    assert f1.attrs == f2.attrs
    l1 = jax.tree_util.tree_leaves(f1.field)
    l2 = jax.tree_util.tree_leaves(f2.field)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def fact_batch(rng, cat, n):
    rel = cat.get("F")
    return (
        {a: rng.integers(0, rel.domains[a], n).astype(np.int32) for a in rel.attrs},
        {"m": rng.integers(0, 16, n).astype(np.float32)},
    )


def spec_for(ring_name: str) -> DashboardSpec:
    measure = None if ring_name == "count" else ("F", "m")
    return DashboardSpec(vizzes=(
        VizSpec("by_c", measure=measure, ring=ring_name, group_by=("c",)),
        VizSpec("by_d", measure=measure, ring=ring_name, group_by=("d",)),
    ))


def cold_read(t: Treant, q: Query):
    """Execute ``q`` on a from-scratch engine over the committed catalog."""
    eng = CJTEngine(
        t.jt, t.catalog, t.engine_for(q.ring_name, q.measure).ring,
        store=MessageStore(), use_plans=False,
    )
    f, _ = eng.execute(q)
    return f


# ---------------------------------------------------------------------------
# metamorphic parity: stream-then-flush ≡ rebuild, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", ["sum", "count", "moments"])
def test_stream_flush_matches_rebuild_group_rings(ring_name):
    """Micro-batched appends + deletes over three ticks: after each flush the
    warm maintained read is bit-identical to a cold rebuild over the committed
    relation — and executes zero messages (pure delta maintenance)."""
    rng = np.random.default_rng(3)
    cat = star_catalog(seed=1)
    t = Treant(cat, ring=sr.get(ring_name), use_plans=True,
               compaction_threshold=0.0)
    sess = t.open_session(spec_for(ring_name), name="s")
    for tick in range(3):
        buf = t.stream("F")
        for _ in range(4):  # several micro-batches, ONE delta per tick
            codes, meas = fact_batch(rng, cat, 25)
            buf.append(codes, measures=meas)
        # delete a handful of pre-existing rows and a handful of rows that
        # were appended THIS tick (the latter cancel, never materialized)
        mask = np.zeros(buf.base.num_rows + buf.pending_appends, bool)
        mask[rng.choice(buf.base.num_rows, 6, replace=False)] = True
        mask[buf.base.num_rows + rng.choice(buf.pending_appends, 5, replace=False)] = True
        buf.delete(mask)
        res = t.flush()
        assert res.relations == ["F"]
        (upd,) = res.updates
        assert upd.queries_fallback == 0, f"tick {tick} fell back"
        assert upd.queries_maintained > 0
        for viz in ("by_c", "by_d"):
            r = sess.read(viz)
            assert r.stats.messages_computed == 0, "warm read recomputed"
            assert_factors_identical(r.factor, cold_read(t, sess.query_of(viz)))
    assert t.ingest.rows_cancelled == 3 * 5
    assert t.ingest.rows_deleted == 3 * 6
    sess.close()


def test_stream_mixed_delta_with_explicit_weights():
    """Weighted appends coalesce with deletes into one mixed delta whose
    negated-weight rows are the exact ⊕-inverse under SUM."""
    rng = np.random.default_rng(11)
    cat = star_catalog(seed=2)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.0)
    sess = t.open_session(spec_for("sum"), name="s")
    buf = t.stream("F")
    codes, meas = fact_batch(rng, cat, 30)
    buf.append(codes, measures=meas, weights=np.full(30, 2.0, np.float32))
    mask = np.zeros(buf.base.num_rows + 30, bool)
    mask[:8] = True
    buf.delete(mask)
    res = t.flush()
    assert res.updates[0].queries_fallback == 0
    for viz in ("by_c", "by_d"):
        assert_factors_identical(
            sess.read(viz).factor, cold_read(t, sess.query_of(viz))
        )
    sess.close()


# ---------------------------------------------------------------------------
# the coalescing contract: one bump + one sweep per relation per tick
# ---------------------------------------------------------------------------

def test_coalescing_invariant_counters_and_watermark():
    rng = np.random.default_rng(5)
    cat = star_catalog(seed=3)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.0)
    t.open_session(spec_for("sum"), name="s")
    wm0 = t.catalog.watermark
    ticks = 3
    for _ in range(ticks):
        for _ in range(5):  # 5 micro-batches per relation per tick
            codes, meas = fact_batch(rng, cat, 10)
            t.stream("F").append(codes, measures=meas)
            s_rel = t.stream("S").base
            t.stream("S").append({
                a: rng.integers(0, s_rel.domains[a], 4).astype(np.int32)
                for a in s_rel.attrs
            })
        res = t.flush()
        assert sorted(res.relations) == ["F", "S"]
    # T ticks over R=2 streamed relations: exactly T·R bumps and sweeps,
    # despite 5 micro-batches per relation per tick
    assert t.ingest.ticks == ticks
    assert t.ingest.version_bumps == t.ingest.delta_sweeps == ticks * 2
    # both relations commit under ONE watermark bump per tick
    assert t.catalog.watermark == wm0 + ticks
    assert t.ingest.rows_appended == ticks * (5 * 10 + 5 * 4)
    # an empty flush is free: no bump, no sweep, no watermark motion
    res = t.flush()
    assert res.updates == [] and res.compactions == []
    assert t.catalog.watermark == wm0 + ticks
    assert t.ingest.ticks == ticks


# ---------------------------------------------------------------------------
# inverse-free rings: tombstones absorb per tick, recalibrate at compaction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", ["tropical_min", "tropical_max"])
def test_min_max_delete_stream_recalibrates_only_at_compaction(ring_name):
    """Delete streams against MIN/MAX: every regular tick absorbs the
    tombstoned delta (zero fallbacks, zero calibration dispatches); the single
    real recalibration happens only when the tombstone ledger crosses the
    compaction threshold — and lands in think-time, not the flush path."""
    rng = np.random.default_rng(7)
    cat = star_catalog(n_fact=400, seed=4)
    t = Treant(cat, ring=sr.get(ring_name), use_plans=True,
               compaction_threshold=0.25)
    sess = t.open_session(spec_for(ring_name), name="s")
    q0 = sess.query_of("by_c")
    dispatches0 = t.cache_stats()["plans"]["calibration_dispatches"]
    compacted_at = None
    for tick in range(6):
        buf = t.stream("F")
        codes, meas = fact_batch(rng, cat, 12)
        buf.append(codes, measures=meas)
        live = np.flatnonzero(buf.base._materialized_weights() != 0.0)
        mask = np.zeros(buf.base.num_rows + buf.pending_appends, bool)
        mask[rng.choice(live, 30, replace=False)] = True
        buf.delete(mask)
        res = t.flush()
        (upd,) = res.updates
        assert upd.queries_fallback == 0, (
            f"tick {tick}: tombstoned delta fell back on {ring_name}"
        )
        # maintained result ≡ rebuild over the committed tombstoned relation
        for viz in ("by_c", "by_d"):
            r = sess.read(viz)
            assert r.stats.messages_computed == 0
            assert_factors_identical(r.factor, cold_read(t, sess.query_of(viz)))
        if res.compactions:
            compacted_at = tick
            break
        # no compaction yet → zero new calibration dispatches (the flush
        # path never recalibrates; reads are warm)
        assert (
            t.cache_stats()["plans"]["calibration_dispatches"] == dispatches0
        ), f"tick {tick} recalibrated without compaction"
    assert compacted_at is not None, "tombstone fraction never crossed threshold"
    (cupd,) = res.compactions
    # the empty compaction delta can't be absorbed by an idempotent ring:
    # the ONE real recalibration, re-queued at lowest scheduler priority
    assert cupd.queries_fallback > 0
    assert t.ingest.compactions == 1
    rel = t.catalog.get("F")
    assert rel.tombstone_count == 0, "compaction left tombstones behind"
    # drain the deprioritized recalibration in think-time, then re-read
    sess.idle()
    q1 = sess.query_of("by_c")
    assert q1.version_of("F") == rel.version
    assert t.cache_stats()["plans"]["calibration_dispatches"] > dispatches0
    for viz in ("by_c", "by_d"):
        assert_factors_identical(
            sess.read(viz).factor, cold_read(t, sess.query_of(viz))
        )
    assert q0.digest != q1.digest  # versions really advanced
    sess.close()


def test_group_ring_compaction_rekeys_without_fallback():
    """Under SUM the tombstones lift to exact ⊕-zero, so the empty compaction
    delta re-keys the n−1 messages: maintained, zero fallbacks, zero new
    message computations — and results stay bit-identical."""
    rng = np.random.default_rng(13)
    cat = star_catalog(seed=6)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.1)
    sess = t.open_session(spec_for("sum"), name="s")
    buf = t.stream("F")
    mask = np.zeros(buf.base.num_rows, bool)
    mask[rng.choice(buf.base.num_rows, 60, replace=False)] = True
    buf.delete(mask)
    res = t.flush()
    assert res.compactions, "tombstone fraction 0.2 must trigger compaction"
    (cupd,) = res.compactions
    assert cupd.queries_fallback == 0 and cupd.queries_maintained > 0
    assert t.catalog.get("F").tombstone_count == 0
    for viz in ("by_c", "by_d"):
        r = sess.read(viz)
        assert r.stats.messages_computed == 0
        assert_factors_identical(r.factor, cold_read(t, sess.query_of(viz)))
    sess.close()


# ---------------------------------------------------------------------------
# watermarks: concurrent reads never see a torn version vector
# ---------------------------------------------------------------------------

def test_mid_flush_reader_sees_complete_pre_tick_watermark(monkeypatch):
    """Snapshot the catalog's latest pointers from *inside* every apply_delta
    call of a two-relation tick: each snapshot must equal the complete
    pre-tick commit — staged versions must never leak into a reader's view —
    and a query derived mid-flush must execute against pre-tick data."""
    rng = np.random.default_rng(17)
    cat = star_catalog(seed=8)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.0)
    t.open_session(spec_for("sum"), name="s")
    pre = {n: cat.latest_version(n) for n in cat.names()}
    wm_pre = cat.watermark
    want = cold_read(t, Query.make(cat, ring="sum", measure=("F", "m"),
                                   group_by=("c",)))

    snapshots = []
    mid_factors = []
    orig = CJTEngine.apply_delta

    def spying_apply_delta(self, q, delta):
        snapshots.append({n: cat.latest_version(n) for n in cat.names()})
        mid_factors.append(cold_read(
            t, Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
        ))
        return orig(self, q, delta)

    monkeypatch.setattr(CJTEngine, "apply_delta", spying_apply_delta)
    codes, meas = fact_batch(rng, cat, 20)
    t.stream("F").append(codes, measures=meas)
    s_rel = t.stream("S").base
    t.stream("S").append({
        a: rng.integers(0, s_rel.domains[a], 6).astype(np.int32)
        for a in s_rel.attrs
    })
    res = t.flush()
    monkeypatch.setattr(CJTEngine, "apply_delta", orig)

    assert len(res.updates) == 2 and snapshots
    logged = {wm: snap for wm, snap in cat.commit_log}
    for snap in snapshots:
        assert snap == pre, "mid-flush reader saw a torn version vector"
        assert snap == logged[wm_pre]
    for f in mid_factors:
        assert_factors_identical(f, want)
    # post-commit: the new vector is logged under exactly one new watermark
    assert res.watermark == wm_pre + 1
    assert logged is not None and cat.watermark == wm_pre + 1
    post = {n: cat.latest_version(n) for n in cat.names()}
    assert dict(cat.commit_log)[res.watermark] == post
    assert post["F"] != pre["F"] and post["S"] != pre["S"]


# ---------------------------------------------------------------------------
# pinned union-carry queries survive coalesced ticks without pin leaks
# ---------------------------------------------------------------------------

def test_stream_ticks_migrate_union_pins_no_leak():
    """Under batched calibration the pinned union-carry queries hold the base
    pins; coalesced ticks must migrate (not multiply) them, and close() must
    release every one."""
    rng = np.random.default_rng(19)
    cat = star_catalog(seed=9)
    t = Treant(cat, ring=sr.SUM, use_plans=True, batch_calibration=True,
               compaction_threshold=0.0)
    sess = t.open_session(spec_for("sum"), name="s")
    assert t.store._pinned
    pinned0 = len(t.store._pinned)
    for _ in range(3):
        buf = t.stream("F")
        codes, meas = fact_batch(rng, cat, 15)
        buf.append(codes, measures=meas)
        mask = np.zeros(buf.base.num_rows + 15, bool)
        mask[rng.choice(buf.base.num_rows, 3, replace=False)] = True
        buf.delete(mask)
        res = t.flush()
        assert res.updates[0].queries_fallback == 0
        assert len(t.store._pinned) <= pinned0, "tick multiplied pins"
    for viz in ("by_c", "by_d"):
        assert_factors_identical(
            sess.read(viz).factor, cold_read(t, sess.query_of(viz))
        )
    sess.close()
    assert not t.store._pinned, "stream ticks + close leaked pins"


# ---------------------------------------------------------------------------
# StreamBuffer unit behavior
# ---------------------------------------------------------------------------

def test_stream_buffer_cancellation_and_empty_tick():
    cat = star_catalog(seed=10)
    buf = StreamBuffer(cat.get("F"))
    rng = np.random.default_rng(23)
    rel = cat.get("F")
    codes = {a: rng.integers(0, rel.domains[a], 8).astype(np.int32)
             for a in rel.attrs}
    buf.append(codes, measures={"m": np.arange(8, dtype=np.float32)})
    # delete every appended row within the tick: full cancellation
    mask = np.zeros(rel.num_rows + 8, bool)
    mask[rel.num_rows:] = True
    buf.delete(mask)
    base, delta = buf.coalesce()
    assert delta is None and base is rel
    assert buf.stats.rows_cancelled == 8 and buf.stats.ticks == 0
    # re-deleting a tombstone is a no-op
    buf.delete(np.arange(rel.num_rows) < 4)
    new_rel, d = buf.coalesce()
    assert d is not None and d.tombstoned and new_rel.tombstone_count == 4
    buf2 = StreamBuffer(new_rel)
    assert buf2.tombstone_fraction() == pytest.approx(4 / new_rel.num_rows)
    assert buf2.delete(np.arange(new_rel.num_rows) < 4) == 0
    base, delta = buf2.coalesce()
    assert delta is None
    # appends validate the schema
    with pytest.raises(ValueError):
        buf2.append({"a": np.zeros(2, np.int32)})
    with pytest.raises(ValueError):
        buf2.append({a: np.zeros(2, np.int32) for a in rel.attrs})
    # rebasing with pending batches is rejected (masks would misalign)
    buf2.append({a: np.zeros(2, np.int32) for a in rel.attrs},
                measures={"m": np.zeros(2, np.float32)})
    with pytest.raises(ValueError):
        buf2.rebase(rel)


def test_flush_result_and_ingest_stats_surfaces():
    rng = np.random.default_rng(29)
    cat = star_catalog(seed=12)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.0)
    codes, meas = fact_batch(rng, cat, 5)
    t.stream("F").append(codes, measures=meas)
    res = t.flush()
    assert res.relations == ["F"] and res.watermark == t.catalog.watermark
    st = t.cache_stats()
    assert st["watermark"] == t.catalog.watermark
    # the ingest dict is the counters plus the learned compaction posture
    expected = dataclasses.asdict(t.ingest)
    expected["compaction"] = t.compaction_policy.state(t.compaction_threshold)
    assert st["ingest"] == expected
    assert st["ingest"]["version_bumps"] == 1
    assert st["ingest"]["compaction"] == {"F": {"ewma": 0.0, "threshold": 0.0}}
