"""Declarative dashboard sessions: typed events, crossfilter fan-out, the
shared think-time scheduler, and the legacy-wrapper compatibility contract.

The scheduler regression test pins down the structural bug of the old API:
``Treant._calibrator`` was a single global slot, so an interaction on viz B
silently discarded viz A's partial think-time calibration (the iterator
restarted from edge 0 on every preemption and, under a small budget, never
reached the later edges).  The per-(session, viz) scheduler keeps A's
iterator position; only the viz actually interacted with is preempted.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    CJTEngine,
    ClearFilter,
    DashboardSpec,
    Drill,
    MessageStore,
    Query,
    Rollup,
    SetFilter,
    SwapMeasure,
    ToggleRelation,
    Treant,
    Undo,
    VizSpec,
    jt_from_catalog,
    steiner,
)
from repro.core import semiring as sr
from repro.relational import schema, sql
from repro.relational.relation import mask_in


@pytest.fixture(scope="module")
def flight():
    cat = schema.flight(n_flights=8_000)
    return cat, jt_from_catalog(cat)


def flight_spec() -> DashboardSpec:
    return DashboardSpec(vizzes=(
        VizSpec("by_state", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("airport_state",)),
        VizSpec("by_month", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("month",)),
        VizSpec("by_size", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("airport_size",)),
        VizSpec("by_carrier", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("carrier_group",)),
    ))


# ---------------------------------------------------------------------------
# Scheduler: the cross-viz preemption regression
# ---------------------------------------------------------------------------

def test_think_time_survives_other_viz_interaction(flight):
    """Progress on viz A's background calibration must survive interactions
    on viz B.  Fails against the legacy single-slot ``_calibrator`` (each B
    interaction reset A's iterator, so a budget-2 pass only ever revisited
    the first two edges); passes with the per-(session, viz) scheduler."""
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    d = cat.domains()
    qA = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"),
                    group_by=("airport_state",))
    # count-ring dashboard: B's messages share no Prop-2 signatures with A's,
    # so B interactions cannot accidentally calibrate A
    qB = Query.make(cat, ring="count", group_by=("month",))
    t.register_dashboard("A", qA)
    t.register_dashboard("B", qB)
    qA1 = qA.with_predicate(mask_in(d["carrier_group"], [0, 1], attr="carrier_group"))
    t.interact("s", "A", qA1)
    n_edges = len(t.jt.directed_edges())
    for i in range(n_edges):
        done = t.think_time("s", "A", budget_messages=2)
        assert done <= 2
        # a *different* B query every round: preempts B's pending task only
        t.interact("s", "B", qB.with_predicate(mask_in(d["dow"], [i % 7], attr="dow")))
    assert t.engine.is_calibrated(qA1)
    # B was preempted repeatedly, A never was
    assert t.scheduler.preemptions >= n_edges - 1
    assert t.scheduler.completed >= 1


def test_scheduler_budget_preserves_iterator_position(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"))
    t.register_dashboard("v", q0)
    d = cat.domains()
    q1 = q0.with_predicate(mask_in(d["dow"], [0], attr="dow"))
    t.interact("s", "v", q1)
    n_edges = len(t.jt.directed_edges())
    total = 0
    while True:
        got = t.think_time("s", "v", budget_messages=1)
        if got == 0:  # exhausted generator detected → task completed
            break
        total += got
    # budget-1 steps accumulate to exactly one full calibration pass
    assert total == n_edges
    assert t.engine.is_calibrated(q1)
    assert t.scheduler.pending() == 0


# ---------------------------------------------------------------------------
# Event layer ≡ hand-built query chains
# ---------------------------------------------------------------------------

ATTRS = ["carrier_group", "airport_size", "month", "dow"]
DRILLS = ["month", "dow", "carrier_group"]


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_event_sequence_matches_hand_built_chains(seed):
    """Any SetFilter/ClearFilter/Drill/Rollup sequence applied via
    ``Session.apply`` derives queries digest-identical to hand-built
    ``with_predicate`` / ``add_group_by`` chains."""
    cat = schema.flight(n_flights=1_000, seed=seed % 5)
    d = cat.domains()
    t = Treant(cat, ring=sr.SUM)
    spec = flight_spec()
    sess = t.open_session(spec, calibrate=False)
    rng = np.random.default_rng(seed)

    filters: dict[str, list[int]] = {}
    drills: dict[str, list[str]] = {v.name: [] for v in spec.vizzes}
    for _ in range(6):
        kind = rng.integers(4)
        if kind == 0:
            attr = ATTRS[rng.integers(len(ATTRS))]
            vals = sorted({int(v) for v in rng.integers(0, d[attr], 2)})
            sess.apply(SetFilter(attr, values=tuple(vals)))
            filters[attr] = vals
        elif kind == 1 and filters:
            attr = sorted(filters)[rng.integers(len(filters))]
            sess.apply(ClearFilter(attr))
            del filters[attr]
        elif kind == 2:
            viz = spec.names[rng.integers(len(spec.names))]
            a = DRILLS[rng.integers(len(DRILLS))]
            sess.apply(Drill(viz, a))
            if a not in drills[viz] and a not in spec.viz(viz).group_by:
                drills[viz].append(a)
        elif kind == 3:
            viz = spec.names[rng.integers(len(spec.names))]
            if drills[viz]:
                a = drills[viz].pop()
                sess.apply(Rollup(viz, a))

    for v in spec.vizzes:
        ref = Query.make(cat, ring=v.ring, measure=v.measure, group_by=v.group_by)
        for a in drills[v.name]:
            ref = ref.add_group_by(a)
        for attr, vals in filters.items():
            ref = ref.with_predicate(mask_in(d[attr], vals, attr=attr))
        assert sess.query_of(v.name).digest == ref.digest, (
            v.name, filters, drills[v.name]
        )


def test_undo_round_trip(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec(), calibrate=False)
    r1 = sess.apply(SetFilter("carrier_group", values=(0, 1), source="by_carrier"))
    before = {v: sess.query_of(v).digest for v in sess.vizzes}
    vals1 = np.asarray(r1.results["by_state"].factor.field, np.float64).copy()

    r2 = sess.apply(SetFilter("carrier_group", values=(2, 3), source="by_carrier"))
    assert sess.query_of("by_state").digest != before["by_state"]
    r3 = sess.apply(Undo())
    # undo re-renders exactly the vizzes the undone event had changed
    assert set(r3.affected) == set(r2.affected)
    assert {v: sess.query_of(v).digest for v in sess.vizzes} == before
    np.testing.assert_allclose(
        np.asarray(r3.results["by_state"].factor.field, np.float64), vals1, rtol=1e-5
    )
    # empty-stack Undo is a no-op
    sess.apply(Undo())
    assert sess.apply(Undo()).affected == ()


# ---------------------------------------------------------------------------
# Crossfilter fan-out semantics and correctness
# ---------------------------------------------------------------------------

def test_crossfilter_fan_out_excludes_source_and_matches_cold(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec())
    res = sess.apply(SetFilter("carrier_group", values=(0, 1), source="by_carrier"))
    # every linked viz except the brushing one re-renders
    assert set(res.affected) == {"by_state", "by_month", "by_size"}
    assert sess.query_of("by_carrier").predicates == ()
    for viz in res.affected:
        q = sess.query_of(viz)
        cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
        f_cold, _ = cold.execute(q)
        np.testing.assert_allclose(
            np.asarray(res.results[viz].factor.field, np.float64),
            np.asarray(f_cold.field, np.float64), rtol=1e-4, atol=1e-3,
        )


def test_sibling_vizzes_share_messages(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec())
    sess.apply(SetFilter("airport_size", values=(1, 2), source="by_size"))
    sess.idle()
    sess.apply(SetFilter("airport_size", values=(0, 3), source="by_size"))
    st_ = sess.stats()
    # messages materialized under one viz's execution/calibration served a
    # sibling (γ-independent Prop-2 signatures below the carry)
    assert st_["cross_viz_hits_total"] > 0
    assert st_["pending_calibrations"] > 0
    assert set(st_) >= {
        "vizzes", "events", "pending_calibrations", "preemptions",
        "scheduler_messages_total", "cross_viz_hits_total",
    }


def test_preemption_counts_only_interacted_viz(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec(), calibrate=False)
    sess.apply(SetFilter("carrier_group", values=(0,), source="by_carrier"))
    assert sess.stats()["preemptions"] == 0
    # second filter changes the same three vizzes → their pending (never run)
    # calibrations are replaced; by_carrier's is untouched
    sess.apply(SetFilter("carrier_group", values=(1,), source="by_carrier"))
    assert sess.stats()["preemptions"] == 3
    assert t.scheduler.pending(sess.id) == 3


def test_swap_measure_routes_to_sibling_ring_engine(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec(), calibrate=False)
    res = sess.apply(SwapMeasure("by_size", "Flights", "dep_delay", ring="tropical_min"))
    assert res.affected == ("by_size",)
    q = sess.query_of("by_size")
    assert q.ring_name == "tropical_min"
    cold = CJTEngine(jt, cat, sr.TROPICAL_MIN, store=MessageStore())
    f_cold, _ = cold.execute(q)
    np.testing.assert_allclose(
        np.asarray(res.results["by_size"].factor.field, np.float64),
        np.asarray(f_cold.field, np.float64), rtol=1e-5,
    )
    # the shared store holds both rings' messages without cross-serving
    assert "tropical_min" in t._engines and t._engines["tropical_min"].store is t.store


def test_count_with_measure_not_collapsed_onto_sum_engine(flight):
    """A count-ring query carrying a measure must run on a real COUNT engine
    (the SUM lift would sum the measure column); measure-free COUNT still
    collapses onto the SUM primary and shares its store/plans."""
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    q_cnt = Query.make(cat, ring="count", measure=("Flights", "dep_delay"),
                       group_by=("carrier_group",))
    t.register_dashboard("v", q_cnt)
    r = t.interact("s", "v", q_cnt)
    cold = CJTEngine(jt, cat, sr.COUNT, store=MessageStore())
    f_cold, _ = cold.execute(q_cnt)
    np.testing.assert_allclose(
        np.asarray(r.factor.field, np.float64),
        np.asarray(f_cold.field, np.float64), rtol=1e-5,
    )
    assert t.engine_for("count", ("Flights", "dep_delay")) is not t.engine
    assert t.engine_for("count", None) is t.engine


def test_toggle_relation_round_trip(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec(), calibrate=False)
    r1 = sess.apply(ToggleRelation("Dates", viz="by_state"))
    assert sess.query_of("by_state").removed == frozenset({"Dates"})
    assert r1.affected == ("by_state",)
    r2 = sess.apply(ToggleRelation("Dates", viz="by_state"))
    assert sess.query_of("by_state").removed == frozenset()
    assert r2.affected == ("by_state",)


# ---------------------------------------------------------------------------
# SQL entry point
# ---------------------------------------------------------------------------

def test_session_sql_matches_parse(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    sess = t.open_session(flight_spec(), calibrate=False)
    text = ("SELECT airport_state, SUM(dep_delay) FROM Flights "
            "WHERE month IN (1,2) AND airport_size BETWEEN 1 AND 2 "
            "GROUP BY airport_state")
    res = sess.sql("by_state", text)
    ref = sql.parse(text, cat)
    assert sess.query_of("by_state").digest == ref.digest
    assert sess.query_of("by_state").predicates == ref.predicates
    cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    f_cold, _ = cold.execute(ref)
    np.testing.assert_allclose(
        np.asarray(res.factor.field, np.float64),
        np.asarray(f_cold.field, np.float64), rtol=1e-4, atol=1e-3,
    )
    # sql predicates are digest-identical to typed SetFilter events
    ev_pred = sess.apply(SetFilter("month", values=(1, 2))).queries["by_month"]
    assert ref.predicates[1].digest in {p.digest for p in ev_pred.predicates}


# ---------------------------------------------------------------------------
# Engine-realized Steiner size (no duplicate planning)
# ---------------------------------------------------------------------------

def test_steiner_size_realized_from_exec_stats(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    d = cat.domains()
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"),
                    group_by=("airport_state",))
    t.register_dashboard("v", q0)
    eng = t.engine
    q1 = q0.with_predicate(mask_in(d["carrier_group"], [0], attr="carrier_group"))
    pln = steiner.plan(eng, q0, q1)
    res = t.interact("s", "v", q1)
    # realized ⊆ planned: the engine recomputes only inside the planned tree
    assert 1 <= res.steiner_size <= max(pln.size, 1) + 1
    assert res.steiner_size == steiner.realized_size(res.stats, None) or (
        res.stats.recomputed_edges == [] and res.steiner_size == 1
    )
    # read() now reports the realized size too (was hardcoded 0)
    r = t.read("s", "v")
    assert r.steiner_size == 1 and r.stats.messages_computed == 0


# ---------------------------------------------------------------------------
# Legacy wrappers over the new layer
# ---------------------------------------------------------------------------

def test_legacy_wrappers_still_work(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    d = cat.domains()
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"))
    t.register_dashboard("v", q0)
    q1 = q0.with_predicate(mask_in(d["month"], [3], attr="month"))
    r_a = t.interact("alice", "v", q1)
    r_b = t.interact("bob", "v", q1)       # same query, other session → cache
    assert r_b.stats.messages_computed == 0
    assert t.think_time("alice", "v", budget_messages=2) == 2
    st_ = t.cache_stats()
    assert st_["sessions"] == 2
    assert st_["scheduler"]["pending"] >= 1
    with pytest.raises(KeyError):
        t.interact("alice", "unregistered", q1)
