"""Model-layer correctness: flash attention vs naive oracle (fwd+grad),
causal-divide equivalence, SSD/WKV chunked vs sequential references,
prefill↔decode consistency, MoE routing invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, RWKVConfig, SSMConfig
from repro.models import lm
from repro.models import ssm as S
from repro.models.layers import causal_attention, flash_attention, moe_block


def naive_attention(q, k, v, causal=True):
    b, s, h, dh = q.shape
    g = h // k.shape[2]
    ke = jnp.repeat(k, g, axis=2)
    ve = jnp.repeat(v, g, axis=2)
    sc = jnp.einsum("bqhd,bthd->bhqt", q / np.sqrt(dh), ke)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    return jnp.einsum("bhqt,bthd->bqhd", p, ve)


@pytest.mark.parametrize("qc,kc", [(32, 32), (128, 64), (64, 128)])
def test_flash_matches_naive(qc, kc):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 128, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 16)), jnp.float32)
    o, _ = flash_attention(q, k, v, True, qc, kc, 0, 0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(naive_attention(q, k, v)),
                               rtol=2e-4, atol=2e-5)


def test_flash_grads_match_naive():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 8)), jnp.float32)

    def f_flash(q, k, v):
        o, _ = flash_attention(q, k, v, True, 16, 16, 0, 0)
        return jnp.sum(jnp.sin(o))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-4)


def test_divide_mode_exact_and_halves_flops():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 256, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 8)), jnp.float32)
    o1 = causal_attention(q, k, v, mode="full_masked", q_chunk=64, kv_chunk=64)
    o2 = causal_attention(q, k, v, mode="divide", q_chunk=32, kv_chunk=32, min_block=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-5)
    # FLOPs: divide does ~(S/2B+1)/(S/B) of the baseline matmuls
    from repro.runtime.compat import compiled_flops
    f_full = compiled_flops(jax.jit(lambda q, k, v: causal_attention(
        q, k, v, mode="full_masked", q_chunk=256, kv_chunk=256)
    ).lower(q, k, v).compile())
    f_div = compiled_flops(jax.jit(lambda q, k, v: causal_attention(
        q, k, v, mode="divide", q_chunk=64, kv_chunk=64, min_block=64)
    ).lower(q, k, v).compile())
    assert f_div < 0.72 * f_full, (f_div, f_full)


def test_ssd_chunked_matches_reference():
    rng = np.random.default_rng(3)
    b, s, h, p, n = 2, 64, 3, 4, 5
    xh = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    log_a = -jnp.asarray(rng.random((b, s, h)), jnp.float32)
    B_t = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C_t = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y1, s1 = S.ssd_chunked(xh, log_a, B_t, C_t, chunk=16)
    y2, s2 = S.ssd_reference(xh, log_a, B_t, C_t)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)
    y3, s3 = S.ssd_chunked(xh, log_a, B_t, C_t, chunk=16, vectorized=True)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s3), np.asarray(s2), rtol=1e-4, atol=1e-5)


def test_wkv_chunked_matches_reference():
    rng = np.random.default_rng(4)
    b, s, h, kk = 2, 64, 3, 8
    r = jnp.asarray(rng.standard_normal((b, s, h, kk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, kk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, kk)), jnp.float32)
    log_w = -jnp.asarray(0.1 + rng.random((b, s, h, kk)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, kk)), jnp.float32) * 0.1
    o1, s1 = S.wkv_chunked(r, k, v, log_w, u, chunk=16)
    o2, s2 = S.wkv_reference(r, k, v, log_w, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    o3, s3 = S.wkv_chunked(r, k, v, log_w, u, chunk=16, vectorized=True)
    np.testing.assert_allclose(np.asarray(o3), np.asarray(o2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s3), np.asarray(s2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# prefill ↔ decode consistency (the serving path computes the same function)
# ---------------------------------------------------------------------------

def _mk_cfg(pattern):
    base = dict(name="t", family="x", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_head=8, d_ff=64, vocab=64, loss_chunk=16,
                attn_q_chunk=16, attn_kv_chunk=16, attn_min_block=16)
    if pattern == "moe":
        return ModelConfig(**base, moe=MoEConfig(4, 2, 64, group=32, capacity_factor=2.0))
    if pattern == "zamba":
        base.update(n_layers=6, n_kv_heads=4)
        return ModelConfig(**base, pattern="zamba", shared_attn_every=3,
                           ssm=SSMConfig(state=8, head_dim=8, chunk=8), sub_quadratic=True)
    if pattern == "rwkv":
        return ModelConfig(**base, pattern="rwkv",
                           rwkv=RWKVConfig(head_dim=8, lora_rank=8, chunk=8),
                           sub_quadratic=True)
    if pattern == "vlm":
        base.update(n_layers=6)
        return ModelConfig(**base, pattern="vlm", cross_every=3, n_vision_tokens=4,
                           input_mode="tokens+vision")
    return ModelConfig(**base)


@pytest.mark.parametrize("pattern", ["uniform", "zamba", "rwkv", "vlm"])
def test_decode_matches_prefill(pattern):
    """Teacher-forced: prefill S tokens, then decode token S given the cache —
    logits must match a prefill of S+1 tokens."""
    cfg = _mk_cfg(pattern)
    rng = np.random.default_rng(5)
    b, s = 2, 16
    params = lm.init_params(cfg, 0)
    toks = rng.integers(0, cfg.vocab, (b, s + 1)).astype(np.int32)
    batch_s = {"tokens": toks[:, :s]}
    batch_s1 = {"tokens": toks}
    if pattern == "vlm":
        vis = rng.standard_normal((b, 4, cfg.d_model)).astype(np.float32)
        batch_s["vision"] = vis
        batch_s1["vision"] = vis
    lg_full, _ = lm.forward_prefill(params, cfg, batch_s1)
    _, caches = lm.forward_prefill(params, cfg, batch_s)
    # grow attention caches by one slot for the new token
    def grow(x, name):
        if name in ("k", "v", "shared_k", "shared_v"):
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, 1)
            return jnp.pad(x, pad)
        return x
    caches = {k: grow(v, k) for k, v in caches.items()}
    db = {"tokens": toks[:, s:s + 1]}
    if pattern == "vlm":
        db["vision"] = vis
    lg_dec, _ = lm.forward_decode(params, cfg, db, caches, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_and_balance():
    cfg = _mk_cfg("moe")
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 32, 32)), jnp.float32)
    params = lm.init_params(cfg, 0)["layers"]
    p = jax.tree_util.tree_map(lambda a: a[0], params["moe"])
    y, aux = moe_block(x, p, cfg)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # with huge capacity, every token is routed: y is a convex combo of experts
    assert not np.allclose(np.asarray(y), 0.0)


def test_train_step_decreases_loss_on_memorizable_batch():
    cfg = _mk_cfg("uniform")
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.runtime.step import make_train_step
    rng = np.random.default_rng(7)
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32),
    }
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=2, total_steps=60,
                          m_dtype="float32")
    params = lm.init_params(cfg, 0)
    opt = init_opt_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, donate=False)
    losses = []
    for _ in range(25):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_accum_equals_full_batch_grads():
    cfg = _mk_cfg("uniform")
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.runtime.step import make_train_step
    rng = np.random.default_rng(8)
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32),
    }
    opt_cfg = AdamWConfig(peak_lr=1e-3, m_dtype="float32")
    p0 = lm.init_params(cfg, 0)
    o0 = init_opt_state(p0, opt_cfg)
    s1 = make_train_step(cfg, opt_cfg, accum=1, donate=False)
    s2 = make_train_step(cfg, opt_cfg, accum=2, donate=False)
    p1, _, m1 = s1(p0, o0, batch)
    p2, _, m2 = s2(p0, o0, batch)
    # microbatched loss averages the same samples; grads accumulate in bf16 so
    # allow a loose-but-tight-enough tolerance
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=5e-4)
