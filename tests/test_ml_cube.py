"""Factorized ML (covariance ring) and CJT data cubes."""

import numpy as np
import pytest

from repro.core import (
    CJTEngine, FactorizedLinearRegression, FeatureSpec, MessageStore, Query,
    build_cube, jt_from_catalog,
)
from repro.core import semiring as sr
from repro.relational import schema


@pytest.fixture(scope="module")
def favorita():
    cat = schema.favorita(n_sales=8_000, n_stores=12, n_items=30, n_dates=20)
    return cat


def _numpy_fit(cat, w_ridge=1e-3):
    sales = cat.get("Sales"); stores = cat.get("Stores")
    items = cat.get("Items"); trans = cat.get("Trans")
    st_type = stores.codes["store_type"][sales.codes["store"]]
    perish = items.codes["perishable"][sales.codes["item"]]
    tmap = {}
    for s, d, t in zip(trans.codes["store"], trans.codes["date"],
                       trans.measures["transactions"]):
        tmap[(s, d)] = t
    y = np.array([tmap[(s, d)] for s, d in zip(sales.codes["store"], sales.codes["date"])])
    X = [np.ones(len(y)), sales.measures["unit_sales"]]
    X += [(st_type == c).astype(float) for c in range(5)]
    X += [(perish == c).astype(float) for c in range(2)]
    X = np.stack(X, 1)
    w = np.linalg.solve(X.T @ X + w_ridge * np.eye(X.shape[1]), X.T @ y)
    sse = ((X @ w - y) ** 2).sum()
    sst = ((y - y.mean()) ** 2).sum()
    return 1 - sse / sst


def _model(cat):
    return FactorizedLinearRegression(
        cat,
        features=[FeatureSpec("Sales", "unit_sales"),
                  FeatureSpec("Stores", "store_type", categorical=True),
                  FeatureSpec("Items", "perishable", categorical=True)],
        target=FeatureSpec("Trans", "transactions"),
    )


def test_factorized_fit_matches_numpy(favorita):
    model = _model(favorita)
    got = model.fit()
    want = _numpy_fit(favorita)
    assert abs(got.r2 - want) < 1e-3, (got.r2, want)


@pytest.mark.slow
def test_augmentation_single_message_and_agreement(favorita):
    model = _model(favorita)
    model.calibrate()
    augs = schema.favorita_augmentations(favorita, n_per_key=2)
    seen_keys = set()
    for a in augs:
        res = model.fit_augmented(a)
        base = model.fit_unfactorized_baseline(a)
        assert abs(res.r2 - base.r2) < 1e-4
        key = a.attrs[0]
        if key in seen_keys:
            # same host/separator → host→aug message fully reused (Fig 11)
            assert res.stats.messages_computed == 0
        else:
            assert res.stats.messages_computed <= 1
        seen_keys.add(key)


def test_aug_with_higher_phi_fits_better(favorita):
    model = _model(favorita)
    model.calibrate()
    augs = schema.favorita_augmentations(favorita, n_per_key=6, seed=9)
    date_augs = [a for a in augs if a.attrs[0] == "store"]
    r2 = {a.name: model.fit_augmented(a).r2 for a in date_augs}
    phi = {a.name: float(a.measures["phi"][0]) for a in date_augs}
    best_phi = max(phi, key=phi.get)
    assert phi[best_phi] < 0.2 or r2[best_phi] == max(r2.values())


def test_cube_correctness_and_reuse():
    cat = schema.flight(n_flights=10_000)
    jt = jt_from_catalog(cat)
    dims = ("carrier_group", "month", "dow")
    base = Query.make(cat, ring="count")
    eng = CJTEngine(jt, cat, sr.COUNT, store=MessageStore())
    rep = build_cube(eng, base, dims, h=2, pivot_k=1)
    apex = float(np.asarray(rep.cuboids[()].field))
    assert apex == cat.get("Flights").num_rows
    # roll-up consistency: every cuboid sums to the apex
    for combo, f in rep.cuboids.items():
        assert abs(float(np.asarray(f.field).sum()) - apex) < 1e-3 * apex
    # marginalizing the 2-attr cuboid gives the 1-attr cuboid
    f2 = rep.cuboids[("carrier_group", "month")]
    f1 = rep.cuboids[("carrier_group",)]
    np.testing.assert_allclose(
        np.asarray(f2.field).sum(1), np.asarray(f1.field), rtol=1e-5)
