"""Vmapped batch absorption + speculative σ prefetch: the metamorphic suite.

The batched fan-out's correctness spine is *metamorphic parity*: executing N
sibling absorptions through one vmapped compiled plan must be **bit-identical**
to executing them one by one — across every ring (SUM/COUNT/MIN/MAX/MOMENTS),
across batch widths that do and do not divide evenly into groups, with
heterogeneous γ domains (the ⊕-identity padding path) and with the plan cache
on or off (batching degrades to the sequential reference path).  Measures are
small integers, exactly representable in f32, so every summation order yields
the same bits (same convention as tests/test_plans.py).

The speculative-prefetch property: after ``Session.idle(speculate=k)``, a
``SetFilter`` to *any* prefetched σ value returns results digest-equal to a
cold engine while executing nothing — no store probes, no plan dispatches.

Plus the Session GC regression (ROADMAP): open-close cycles must not grow the
``MessageStore`` or leak pins.
"""

import hashlib

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import (
    CJTEngine,
    DashboardSpec,
    MessageStore,
    Query,
    SetFilter,
    Treant,
    VizSpec,
    jt_from_catalog,
    speculate_filters,
)
from repro.core import semiring as sr
from repro.relational.relation import Catalog, Relation, mask_in

N_FACT = 600  # > one 512-row kernel tile → exercises row padding


def star_catalog(n_fact: int = N_FACT, seed: int = 0) -> Catalog:
    """F(a,b)+m ← S(b,c), T(a,d), U(b,e).  Mixed γ domains (10/5/9) exercise
    the batch-padding path; integer measures keep f32 sums bitwise-stable."""
    rng = np.random.default_rng(seed)
    doms = {"a": 13, "b": 7, "c": 10, "d": 5, "e": 9}

    def codes(attrs, n):
        return {x: rng.integers(0, doms[x], n).astype(np.int32) for x in attrs}

    f = Relation("F", ("a", "b"), codes(("a", "b"), n_fact), doms,
                 measures={"m": rng.integers(0, 16, n_fact).astype(np.float32)})
    s = Relation("S", ("b", "c"), codes(("b", "c"), 77), doms)
    t = Relation("T", ("a", "d"), codes(("a", "d"), 29), doms)
    u = Relation("U", ("b", "e"), codes(("b", "e"), 41), doms)
    return Catalog([f, s, t, u])


RINGS = {
    "count": sr.COUNT,
    "sum": sr.SUM,
    "tropical_min": sr.TROPICAL_MIN,
    "tropical_max": sr.TROPICAL_MAX,
    "moments": sr.MOMENTS,
}


def assert_factors_identical(f1, f2):
    assert f1.attrs == f2.attrs
    l1 = jax.tree_util.tree_leaves(f1.field)
    l2 = jax.tree_util.tree_leaves(f2.field)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def digest_factor(f) -> str:
    h = hashlib.sha1()
    for leaf in jax.tree_util.tree_leaves(f.field):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# metamorphic parity: batched ≡ sequential, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", sorted(RINGS))
@pytest.mark.parametrize("width", [2, 3, 5])
def test_batched_parity_rings_and_widths(ring_name, width):
    """Same-γ siblings differing only in σ masks: every ring, widths that do
    (2) and don't (3, 5) tile evenly against the σ attr's domain."""
    cat = star_catalog(seed=width)
    jt = jt_from_catalog(cat)
    measure = None if ring_name == "count" else ("F", "m")
    base = Query.make(cat, ring=ring_name, measure=measure, group_by=("c",))
    qs = [base.with_predicate(mask_in(5, [i % 5], attr="d")) for i in range(width)]
    seq = CJTEngine(jt, cat, RINGS[ring_name], use_plans=True)
    bat = CJTEngine(jt, cat, RINGS[ring_name], use_plans=True)
    want = [seq.execute(q) for q in qs]
    got = bat.execute_many(qs)
    for (fw, _), (fg, sg) in zip(want, got):
        assert_factors_identical(fw, fg)
    assert bat.plans.stats.batched_absorptions >= 2
    assert bat.plans.stats.batch_width >= 2


@pytest.mark.parametrize("ring_name", sorted(RINGS))
def test_batched_parity_heterogeneous_gamma_padding(ring_name):
    """Siblings carrying *different* γ attrs (domains 10/5/9/7) batch through
    placeholder canonicalization + ⊕-identity padding — still bit-identical."""
    cat = star_catalog(seed=11)
    jt = jt_from_catalog(cat)
    measure = None if ring_name == "count" else ("F", "m")
    base = Query.make(cat, ring=ring_name, measure=measure)
    pred = mask_in(13, [0, 2, 5, 7], attr="a")
    qs = [base.with_group_by(g).with_predicate(pred) for g in ("c", "d", "e", "b")]
    seq = CJTEngine(jt, cat, RINGS[ring_name], use_plans=True)
    bat = CJTEngine(jt, cat, RINGS[ring_name], use_plans=True)
    # warm the base CJTs (the dashboard offline stage): every root converges
    # on the σ'd bag and the four absorptions share one batch signature
    for q in qs:
        seq.calibrate(q.without_predicate("a"))
        bat.calibrate(q.without_predicate("a"))
    want = [seq.execute(q) for q in qs]
    got = bat.execute_many(qs)
    for (fw, _), (fg, _) in zip(want, got):
        assert_factors_identical(fw, fg)
    assert bat.plans.stats.batched_absorptions >= 2


@pytest.mark.parametrize("use_plans", [False, True])
def test_batched_parity_plans_on_off(use_plans):
    """execute_many must agree bit-for-bit with the un-jitted reference
    engine whether the plan cache (and hence batching) is on or off."""
    cat = star_catalog(seed=17)
    jt = jt_from_catalog(cat)
    base = Query.make(cat, ring="sum", measure=("F", "m"))
    qs = [
        base.with_group_by("c").with_predicate(mask_in(5, [1, 3], attr="d")),
        base.with_group_by("d").with_predicate(mask_in(5, [1, 3], attr="d")),
        base.with_group_by("e").with_predicate(mask_in(5, [1, 3], attr="d")),
    ]
    ref = CJTEngine(jt, cat, sr.SUM, use_plans=False)
    eng = CJTEngine(jt, cat, sr.SUM, use_plans=use_plans)
    for q in qs:  # warm both so the batched engine's roots converge
        ref.calibrate(q.without_predicate("d"))
        eng.calibrate(q.without_predicate("d"))
    want = [ref.execute(q) for q in qs]
    got = eng.execute_many(qs)
    for (fw, _), (fg, _) in zip(want, got):
        assert_factors_identical(fw, fg)
    if use_plans:
        assert eng.plans.stats.batched_execs >= 1
    else:
        assert eng.plans is None  # batching inert, sequential fallback


def test_batched_execstats_counters():
    cat = star_catalog(seed=23)
    jt = jt_from_catalog(cat)
    base = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    qs = [base.with_predicate(mask_in(5, [i], attr="d")) for i in range(3)]
    eng = CJTEngine(jt, cat, sr.SUM, use_plans=True)
    results = eng.execute_many(qs)
    batched = [s for _, s in results if s.batched_absorptions]
    assert len(batched) >= 2
    assert all(s.batch_width >= 2 for s in batched)
    assert eng.plans.stats.batched_execs >= 1
    assert eng.plans.stats.batch_width == max(s.batch_width for s in batched)


def test_batched_plan_retrace_only_on_new_structure():
    """Re-brushing the same batch signature (new masks) must re-execute the
    cached vmapped plan — zero new traces, like the scalar plans."""
    cat = star_catalog(seed=29)
    jt = jt_from_catalog(cat)
    base = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    eng = CJTEngine(jt, cat, sr.SUM, use_plans=True)
    eng.execute_many([base.with_predicate(mask_in(5, [i], attr="d")) for i in (0, 1)])
    built = eng.plans.stats.plans_built
    out = eng.execute_many(
        [base.with_predicate(mask_in(5, [i], attr="d")) for i in (2, 4)]
    )
    assert eng.plans.stats.plans_built == built
    assert all(s.plan_hits > 0 or s.messages_reused > 0 for _, s in out)


# ---------------------------------------------------------------------------
# session-level: batched fan-out ≡ per-viz dispatch
# ---------------------------------------------------------------------------

def star_spec() -> DashboardSpec:
    return DashboardSpec(vizzes=(
        VizSpec("by_a", measure=("F", "m"), ring="sum", group_by=("a",)),
        VizSpec("by_c", measure=("F", "m"), ring="sum", group_by=("c",)),
        VizSpec("by_d", measure=("F", "m"), ring="sum", group_by=("d",)),
        VizSpec("by_e", measure=("F", "m"), ring="sum", group_by=("e",)),
    ))


def test_session_fanout_batched_vs_unbatched_bit_identical():
    cat = star_catalog(seed=31)
    jt = jt_from_catalog(cat)
    tb = Treant(cat, ring=sr.SUM, jt=jt, use_plans=True, batch_fanout=True)
    tu = Treant(cat, ring=sr.SUM, jt=jt, use_plans=True, batch_fanout=False)
    sb = tb.open_session(star_spec(), name="b")
    su = tu.open_session(star_spec(), name="u")
    events = [
        SetFilter("a", values=(0, 1), source="by_a"),
        SetFilter("a", values=(3,), source="by_a"),
        SetFilter("b", values=(2, 4)),
    ]
    for ev in events:
        rb, ru = sb.apply(ev), su.apply(ev)
        assert rb.affected == ru.affected
        for viz in rb.affected:
            assert_factors_identical(
                rb.results[viz].factor, ru.results[viz].factor
            )
    assert tb.cache_stats()["plans"]["batched_absorptions"] > 0
    assert tu.cache_stats()["plans"]["batched_absorptions"] == 0
    assert tb.cache_stats()["plans"]["batch_width"] >= 2


# ---------------------------------------------------------------------------
# speculative σ prefetch
# ---------------------------------------------------------------------------

def test_speculate_filters_shapes():
    ev = SetFilter("x", lo=4, hi=8)
    cands = speculate_filters(ev, 20, 3)
    assert [(c.lo, c.hi) for c in cands] == [(8, 12), (0, 4), (12, 16)]
    # clipped at the domain edge, deduped, deterministic
    cands = speculate_filters(SetFilter("x", lo=0, hi=8), 10, 4)
    assert [(c.lo, c.hi) for c in cands] == [(8, 10)]
    ev = SetFilter("x", values=(2, 3))
    cands = speculate_filters(ev, 10, 4)
    assert [c.values for c in cands] == [(4, 5), (0, 1), (6, 7), (8, 9)]
    assert all(c.attr == "x" and c.source == ev.source for c in cands)


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_prefetched_rebrush_is_pure_hit(seed):
    """After idle(speculate=k): a SetFilter to ANY prefetched σ value returns
    results digest-equal to a cold engine, with zero messages computed, zero
    plan executions and zero store misses — pure prefetch-cache hits."""
    rng = np.random.default_rng(seed)
    cat = star_catalog(n_fact=400, seed=seed % 7)
    jt = jt_from_catalog(cat)
    t = Treant(cat, ring=sr.SUM, jt=jt, use_plans=True, batch_fanout=True)
    sess = t.open_session(star_spec(), name="s")
    attr, dom = ("a", 13) if rng.integers(2) else ("b", 7)
    lo = int(rng.integers(0, dom - 1))
    ev = SetFilter(attr, lo=lo, hi=int(rng.integers(lo + 1, dom + 1)),
                   source="by_a")
    sess.apply(ev)
    sess.idle(speculate=2)
    cands = speculate_filters(ev, dom, 2)
    assert cands and sess.stats()["prefetched"] > 0
    cand = cands[int(rng.integers(len(cands)))]
    st0 = t.cache_stats()
    res = sess.apply(cand)
    st1 = t.cache_stats()
    assert res.affected  # the re-brush really changed the linked vizzes
    for viz in res.affected:
        s = res.results[viz].stats
        assert s.prefetch_hits == 1 and s.messages_computed == 0
        cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore(), use_plans=True)
        f_cold, _ = cold.execute(sess.query_of(viz))
        assert digest_factor(res.results[viz].factor) == digest_factor(f_cold)
    plan_execs = lambda st_: st_["plans"]["plans_built"] + st_["plans"]["plan_hits"]
    assert plan_execs(st1) == plan_execs(st0), "re-brush executed a plan"
    assert st1["misses"] == st0["misses"] and st1["hits"] == st0["hits"]


def test_speculation_counts_and_capacity():
    cat = star_catalog(seed=41)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(), name="s")
    sess.prefetch_capacity = 4
    sess.apply(SetFilter("a", values=(1, 2), source="by_a"))
    sess.idle(speculate=3)
    st_ = sess.stats()
    assert st_["speculative_queries_total"] > 0
    assert 0 < st_["prefetched"] <= 4
    assert t.scheduler.stats()["speculative_queries"] == st_["speculative_queries_total"]


# ---------------------------------------------------------------------------
# Session GC (ROADMAP): close unpins and drops producer-tagged entries
# ---------------------------------------------------------------------------

def test_session_close_gc_two_cycles_store_stable():
    """Two open-close cycles (each brushing a *different* σ value) must not
    grow the MessageStore: close unpins the base CJTs and evicts the
    session-produced interaction messages, so only the shared offline
    calibration survives."""
    cat = star_catalog(seed=43)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sizes, pinned = [], []
    for i in range(2):
        sess = t.open_session(star_spec())
        sess.apply(SetFilter("a", values=(i,), source="by_a"))
        sess.idle()
        sess.apply(SetFilter("b", values=(i, i + 1)))
        sess.idle(speculate=1)
        sess.close()
        sizes.append(len(t.store))
        pinned.append(len(t.store._pinned))
        assert t.scheduler.pending(sess.id) == 0
    assert sizes[1] <= sizes[0], f"store grew across sessions: {sizes}"
    assert pinned == [0, 0], "close leaked pins"
    assert t.cache_stats()["sessions"] == 0


def test_fallback_update_releases_pins_before_version_bump():
    """A delta the ring cannot absorb (MIN delete) migrates no pins, but the
    base queries are version-bumped: the old-version pins must be released
    during the update — a later close() only knows the bumped sigs and would
    otherwise leak them forever (unevictable store entries)."""
    cat = star_catalog(seed=59)
    t = Treant(cat, ring=sr.TROPICAL_MIN, use_plans=True)
    spec = DashboardSpec(vizzes=(
        VizSpec("by_c", measure=("F", "m"), ring="tropical_min", group_by=("c",)),
    ))
    sess = t.open_session(spec)
    assert t.store._pinned
    mask = np.zeros(cat.get("F").num_rows, bool)
    mask[:5] = True
    new_rel, delta = cat.get("F").delete_rows(mask)
    res = t.update(new_rel, delta)
    assert res.queries_fallback > 0
    sess.close()
    assert not t.store._pinned, "fallback update leaked old-version pins"


def test_idle_budget_gates_speculation():
    cat = star_catalog(seed=61)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(), name="s")
    sess.apply(SetFilter("a", values=(1,), source="by_a"))
    # exhausted message budget: calibration consumed it all, no speculation
    sess.idle(budget_messages=1, speculate=2)
    assert sess.stats()["prefetched"] == 0
    # slack budget: speculation runs
    sess.idle(speculate=2)
    assert sess.stats()["prefetched"] > 0


def test_clear_and_undo_invalidate_speculation_anchor():
    from repro.core import ClearFilter, Undo

    cat = star_catalog(seed=67)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(), name="s", calibrate=False)
    sess.apply(SetFilter("a", values=(1,), source="by_a"))
    sess.apply(ClearFilter("a"))
    sess.idle(speculate=2)  # no anchor: must not re-insert the cleared σ
    assert sess.stats()["prefetched"] == 0
    sess.apply(SetFilter("b", values=(2,)))
    sess.apply(Undo())      # brush undone → anchor dropped with it
    sess.idle(speculate=2)
    assert sess.stats()["prefetched"] == 0


def test_close_keeps_other_sessions_pins():
    cat = star_catalog(seed=47)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    s1 = t.open_session(star_spec(), name="s1")
    s2 = t.open_session(star_spec(), name="s2")
    s1.apply(SetFilter("a", values=(0,), source="by_a"))
    s1.close()
    # s2 pinned the same base signatures: they must survive s1's GC
    assert t.store._pinned, "shared pins dropped by sibling close"
    for v in ("by_a", "by_c", "by_d", "by_e"):
        assert t.engine.is_calibrated(s2.query_of(v))
    s2.close()
    assert not t.store._pinned


# ---------------------------------------------------------------------------
# env gates (CI matrix)
# ---------------------------------------------------------------------------

def test_env_gates_use_plans_and_batch_fanout(monkeypatch):
    cat = star_catalog(seed=53)
    monkeypatch.setenv("REPRO_USE_PLANS", "0")
    monkeypatch.setenv("REPRO_BATCH_FANOUT", "0")
    t = Treant(cat, ring=sr.SUM)
    assert t.engine.plans is None and not t.batch_fanout
    assert "plans" not in t.cache_stats()
    monkeypatch.setenv("REPRO_USE_PLANS", "1")
    monkeypatch.setenv("REPRO_BATCH_FANOUT", "1")
    t = Treant(cat, ring=sr.SUM)
    assert t.engine.plans is not None and t.batch_fanout
    # explicit arguments always win over the env
    t = Treant(cat, ring=sr.SUM, use_plans=False, batch_fanout=False)
    assert t.engine.plans is None and not t.batch_fanout
