"""Semiring law property tests (hypothesis): the algebra CJT correctness
rests on — commutativity/associativity of ⊕/⊗, distributivity, identities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import semiring as sr

RINGS = [sr.COUNT, sr.SUM, sr.TROPICAL_MIN, sr.TROPICAL_MAX, sr.BOOL, sr.MOMENTS]


def _elem(ring, rng, shape=(3,)):
    if ring.name == "bool":
        return jnp.asarray(rng.random(shape) > 0.5)
    if ring.name == "moments":
        return tuple(jnp.asarray(rng.integers(0, 5, shape), jnp.float32) for _ in range(3))
    return jnp.asarray(rng.integers(0, 7, shape), jnp.float32)


def _eq(ring, a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float64), np.asarray(y, np.float64),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ring", RINGS, ids=lambda r: r.name)
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_semiring_laws(ring, seed):
    rng = np.random.default_rng(seed)
    a, b, c = (_elem(ring, rng) for _ in range(3))
    _eq(ring, ring.mul(a, b), ring.mul(b, a))                      # ⊗ comm
    _eq(ring, ring.add(a, b), ring.add(b, a))                      # ⊕ comm
    _eq(ring, ring.mul(ring.mul(a, b), c), ring.mul(a, ring.mul(b, c)))
    _eq(ring, ring.add(ring.add(a, b), c), ring.add(a, ring.add(b, c)))
    # distributivity: a ⊗ (b ⊕ c) == (a⊗b) ⊕ (a⊗c)
    _eq(ring, ring.mul(a, ring.add(b, c)), ring.add(ring.mul(a, b), ring.mul(a, c)))
    # identities
    ones = ring.ones((3,))
    zeros = ring.zeros((3,))
    _eq(ring, ring.mul(a, ones), a)
    _eq(ring, ring.add(a, zeros), a)
    # annihilation: a ⊗ 0 == 0   (holds for all our rings)
    _eq(ring, ring.mul(a, zeros), zeros)


@pytest.mark.parametrize("ring", RINGS, ids=lambda r: r.name)
def test_reduce_matches_fold(ring):
    rng = np.random.default_rng(1)
    a = _elem(ring, rng, shape=(4, 5))
    red = ring.add_reduce(a, (0,))
    leaves = jax.tree_util.tree_leaves(a)
    acc = jax.tree_util.tree_map(lambda l: l[0], a)
    for i in range(1, 4):
        acc = ring.add(acc, jax.tree_util.tree_map(lambda l: l[i], a))
    _eq(ring, red, acc)


def test_segment_reduce_matches_dense():
    rng = np.random.default_rng(2)
    for ring in (sr.SUM, sr.TROPICAL_MIN, sr.TROPICAL_MAX, sr.BOOL, sr.MOMENTS):
        vals = _elem(ring, rng, shape=(20,))
        ids = jnp.asarray(rng.integers(0, 4, 20))
        out = ring.segment_reduce(vals, ids, 4)
        for g in range(4):
            mask = np.asarray(ids) == g
            if not mask.any():
                continue
            sub = jax.tree_util.tree_map(lambda l: l[jnp.asarray(mask)], vals)
            acc = jax.tree_util.tree_map(lambda l: l[0], sub)
            n = int(mask.sum())
            for i in range(1, n):
                acc = ring.add(acc, jax.tree_util.tree_map(lambda l: l[i], sub))
            got = jax.tree_util.tree_map(lambda l: l[g], out)
            _eq(ring, got, acc)


def test_covariance_ring_outer_products():
    ring = sr.make_covariance_ring(3)
    a = ring.ones(())
    c, s, q = a
    assert c.shape == () and s.shape == (3,) and q.shape == (3, 3)
    x = (jnp.ones(()), jnp.asarray([1.0, 2.0, 0.0]), None)
    x = (x[0], x[1], x[1][:, None] * x[1][None, :])
    y = (jnp.ones(()), jnp.asarray([0.0, 0.0, 3.0]), None)
    y = (y[0], y[1], y[1][:, None] * y[1][None, :])
    c, s, q = ring.mul(x, y)
    # joined tuple has features [1, 2, 3]: Q must be the full outer product
    np.testing.assert_allclose(np.asarray(s), [1, 2, 3])
    np.testing.assert_allclose(np.asarray(q), np.outer([1, 2, 3], [1, 2, 3]))
