"""Sharded CJT execution (ISSUE 9): metamorphic sharded ≡ single-device.

The tentpole's correctness spine: row-sharding the fact relation over a
device mesh and ⊕-all-reducing the γ-indexed partials must be **invisible**
— on integer data the sharded engine's answers and stored messages are
bit-identical to a single-device engine across rings (SUM/COUNT/MIN/MAX;
MOMENTS under allclose), join shapes (chain/star/bushy), plans on/off and
mesh widths 1/2/8.  Sharding is an execution strategy, never a semantic:
rings without a collective (BOOL) silently run unsharded, relations whose
row bucket does not divide the mesh fall back per-dispatch, and deltas
(``apply_delta`` / ``stream().flush()``) shard the same way the base scan
does.

Mesh-dependent tests skip unless the process has enough virtual devices —
run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
sharded leg does; see also ``REPRO_SHARD_DEVICES``).
"""

import jax
import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import CJTEngine, MessageStore, Query, Treant, jt_from_catalog
from repro.core import distributed as dist
from repro.core import semiring as sr
from repro.relational.relation import Catalog, mask_in

from test_level_calibration import (
    RINGS,
    SHAPES,
    assert_stores_message_identical,
    bushy_catalog,
    chain_catalog,
)


def mesh_or_skip(nshards: int):
    if nshards > 1 and jax.device_count() < nshards:
        pytest.skip(
            f"needs {nshards} devices (XLA_FLAGS="
            f"--xla_force_host_platform_device_count={nshards})"
        )
    return dist.make_engine_mesh(nshards)


def _query(cat, ring_name, shape="chain"):
    measure = None if ring_name in ("count", "bool") else ("F", "m")
    gamma = ("c",) if shape != "star" else ("c", "d")
    dom_a = cat.get("F").domains["a"]
    return Query.make(
        cat, ring=ring_name, measure=measure, group_by=gamma,
        predicates=(mask_in(dom_a, [1, 2, 3], attr="a"),),
    )


def _engines(shape, ring_name, nshards, seed=3, use_plans=True):
    """(sharded, reference) engine pair over identically-seeded catalogs.

    Separate Catalog instances keep the reference engine free of the
    sharded catalog's row placement — same seed, same bits."""
    mesh = mesh_or_skip(nshards)
    ring = RINGS[ring_name] if ring_name in RINGS else sr.get(ring_name)
    cats = [SHAPES[shape](seed=seed) for _ in range(2)]
    if mesh is not None:
        cats[0].set_row_placement(dist.row_placement(mesh))
    shd = CJTEngine(jt_from_catalog(cats[0]), cats[0], ring,
                    store=MessageStore(), use_plans=use_plans, mesh=mesh)
    ref = CJTEngine(jt_from_catalog(cats[1]), cats[1], ring,
                    store=MessageStore(), use_plans=use_plans)
    return shd, ref, cats


def _assert_factors_match(got, want, exact=True):
    l1 = jax.tree_util.tree_leaves(got.field)
    l2 = jax.tree_util.tree_leaves(want.field)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        a, b = np.asarray(a), np.asarray(b)
        if exact:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-6)


# ---------------------------------------------------------------------------
# metamorphic parity: sharded ≡ single-device, bit-identical on integer data
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", sorted(RINGS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_sharded_matches_single_device(ring_name, shape):
    shd, ref, cats = _engines(shape, ring_name, nshards=8)
    q1, q2 = _query(cats[0], ring_name, shape), _query(cats[1], ring_name, shape)
    exact = ring_name != "moments"
    # cold execute, batched calibration, warm re-execute: every path agrees
    _assert_factors_match(shd.execute(q1)[0], ref.execute(q2)[0], exact)
    shd.calibrate(q1, batch=True)
    ref.calibrate(q2, batch=True)
    _assert_factors_match(shd.execute(q1)[0], ref.execute(q2)[0], exact)
    if exact:
        assert_stores_message_identical(shd, ref, q1)
    if ring_name in ("sum", "count", "tropical_min", "tropical_max"):
        assert shd.plans.stats.shard_execs > 0
        assert shd.plans.stats.allreduce_bytes > 0
        assert ref.plans.stats.shard_execs == 0


@pytest.mark.parametrize("nshards", [1, 2, 8])
@pytest.mark.parametrize("use_plans", [True, False])
def test_sharded_mesh_widths_and_plans_on_off(nshards, use_plans):
    """Every mesh width gives the single-device bits; with plans off the
    mesh is inert (sharding lives in the plan cache) but must stay correct."""
    shd, ref, cats = _engines("chain", "sum", nshards, use_plans=use_plans)
    q1, q2 = _query(cats[0], "sum"), _query(cats[1], "sum")
    _assert_factors_match(shd.execute(q1)[0], ref.execute(q2)[0])
    shd.calibrate(q1, batch=True)
    ref.calibrate(q2, batch=True)
    _assert_factors_match(shd.execute(q1)[0], ref.execute(q2)[0])
    assert_stores_message_identical(shd, ref, q1)


def test_sharded_update_then_read():
    """apply_delta on a sharded fact: maintained messages equal the
    single-device maintenance AND a cold rebuild over the updated catalog."""
    shd, ref, cats = _engines("chain", "sum", nshards=8, seed=7)
    q1, q2 = _query(cats[0], "sum"), _query(cats[1], "sum")
    shd.calibrate(q1, batch=True)
    ref.calibrate(q2, batch=True)
    rng = np.random.default_rng(5)
    n = 96
    codes = {a: rng.integers(0, cats[0].get("F").domains[a], n) for a in ("a", "b")}
    meas = {"m": rng.integers(0, 16, n).astype(np.float32)}
    for eng, cat in ((shd, cats[0]), (ref, cats[1])):
        rel, delta = cat.get("F").append_rows(
            {a: v.copy() for a, v in codes.items()}, measures={"m": meas["m"].copy()}
        )
        cat.put(rel)
        if eng is shd:
            q1, st = eng.apply_delta(q1, delta)
        else:
            q2, st = eng.apply_delta(q2, delta)
        assert not st.fallback
    got, es = shd.execute(q1)
    assert es.messages_computed == 0  # maintenance kept the CJT warm
    _assert_factors_match(got, ref.execute(q2)[0])
    cold = CJTEngine(jt_from_catalog(cats[1]), cats[1], sr.SUM,
                     store=MessageStore(), use_plans=False)
    _assert_factors_match(got, cold.execute(q2)[0])


def test_sharded_stream_flush_parity():
    """stream().flush() on a sharded Treant coalesces + maintains the same
    bits as an unsharded Treant fed the identical micro-batches."""
    mesh = mesh_or_skip(8)
    pair = []
    for m in (mesh, 0):  # mesh=0 opts out even when REPRO_SHARD_DEVICES is set
        cat = chain_catalog(seed=9)
        t = Treant(cat, ring=sr.SUM, mesh=m)
        q = _query(cat, "sum")
        t.engine.calibrate(q, batch=True)
        rng = np.random.default_rng(21)
        buf = t.stream("F")
        for _ in range(3):
            n = 40
            buf.append(
                {a: rng.integers(0, cat.get("F").domains[a], n) for a in ("a", "b")},
                measures={"m": rng.integers(0, 16, n).astype(np.float32)},
            )
        mask = np.zeros(cat.get("F").num_rows + buf.pending_appends, bool)
        mask[rng.choice(cat.get("F").num_rows, 25, replace=False)] = True
        buf.delete(mask)
        res = t.flush()
        assert res.relations == ["F"]
        q = q.with_version("F", cat.latest_version("F"))
        pair.append(t.engine.execute(q)[0])
    _assert_factors_match(pair[0], pair[1])


def test_sharded_mid_level_abandonment():
    """Mirror of test_abandoned_iterator_keeps_completed_levels on a mesh:
    abandoning the level iterator mid-pass keeps every completed level's
    messages servable, and the finished pass matches single-device bits."""
    mesh = mesh_or_skip(8)
    cat = bushy_catalog(seed=11)
    cat.set_row_placement(dist.row_placement(mesh))
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore(), mesh=mesh)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    it = eng.calibrate_levels_iter(q)
    completed = [next(it), next(it)]  # abandon mid-pass
    del it
    placement = eng.place_predicates(q)
    for level in completed:
        for (u, v) in level:
            base = eng.edge_sig(q, u, v, placement)
            assert eng.store.contains(base, eng.gamma_carry(q, u, v)), (
                f"completed-level message {(u, v)} not servable"
            )
    stats = eng.calibrate(q, batch=True)
    assert eng.is_calibrated(q)
    assert stats.messages_reused >= sum(len(lv) for lv in completed)
    ref_cat = bushy_catalog(seed=11)
    ref = CJTEngine(jt_from_catalog(ref_cat), ref_cat, sr.SUM,
                    store=MessageStore())
    ref.calibrate(
        Query.make(ref_cat, ring="sum", measure=("F", "m"), group_by=("c",)),
        batch=True,
    )
    assert_stores_message_identical(eng, ref, q)


def test_bool_ring_falls_back_unsharded():
    """BOOL has no ⊕-inverse and no min/max collective: the plan cache must
    refuse to shard (correct answers, zero sharded dispatches)."""
    shd, ref, cats = _engines("chain", "bool", nshards=8)
    q1, q2 = _query(cats[0], "bool"), _query(cats[1], "bool")
    _assert_factors_match(shd.execute(q1)[0], ref.execute(q2)[0])
    assert shd.plans.stats.shard_execs == 0
    assert shd.plans.stats.allreduce_bytes == 0


def test_shard_counters_surface_in_cache_stats():
    mesh = mesh_or_skip(8)
    cat = chain_catalog(seed=3)
    t = Treant(cat, ring=sr.SUM, use_plans=True, mesh=mesh)
    t.engine.execute(_query(cat, "sum"))
    st = t.cache_stats()["plans"]
    assert st["shard_execs"] > 0
    assert st["allreduce_bytes"] > 0
    assert st["shard_imbalance"] >= 1.0


# ---------------------------------------------------------------------------
# device-free units: collective map, imbalance math, mesh acquisition
# ---------------------------------------------------------------------------

def test_ring_collective_map():
    assert dist.ring_collective(sr.SUM) is jax.lax.psum
    assert dist.ring_collective(sr.COUNT) is jax.lax.psum
    assert dist.ring_collective(sr.MOMENTS) is jax.lax.psum
    assert dist.ring_collective(sr.TROPICAL_MIN) is jax.lax.pmin
    assert dist.ring_collective(sr.TROPICAL_MAX) is jax.lax.pmax
    assert dist.ring_collective(sr.BOOL) is None


def test_shard_imbalance_math():
    # perfectly balanced: 512 rows over 8 shards of a 512 bucket
    assert dist.shard_imbalance(512, 512, 8) == pytest.approx(1.0)
    # 500 rows padded to 512: the fullest shard holds 64/62.5 of its share
    assert dist.shard_imbalance(500, 512, 8) == pytest.approx(512 / 500)
    # tiny relation, one shard does all the work
    assert dist.shard_imbalance(3, 64, 8) == pytest.approx(8.0)
    assert dist.shard_imbalance(100, 128, 1) == 1.0
    assert dist.shard_imbalance(0, 64, 8) == 0.0


def test_make_engine_mesh_disabled(monkeypatch):
    assert dist.make_engine_mesh(0) is None
    assert dist.make_engine_mesh(1) is None
    monkeypatch.delenv("REPRO_SHARD_DEVICES", raising=False)
    assert dist.shard_devices() == 0
    assert dist.make_engine_mesh() is None
    monkeypatch.setenv("REPRO_SHARD_DEVICES", "not-a-number")
    assert dist.shard_devices() == 0
    monkeypatch.setenv("REPRO_SHARD_DEVICES", "8")
    assert dist.shard_devices() == 8
    # more shards than devices: sharding silently disables (never an error)
    monkeypatch.setenv("REPRO_SHARD_DEVICES", str(jax.device_count() * 1000))
    assert dist.make_engine_mesh() is None
