"""Predictive think-time policy + bin cubes (ISSUE 10 tentpole).

Correctness spine: a brush served by slicing a parked γ∪{dim} bin cube
(``Factor.select`` per σ mask, then ⊕-marginalize the dim away) must be
**bit-identical** to cold execution — across rings (SUM/COUNT/MIN/MAX/
MOMENTS) and tree shapes (chain/star/bushy).  Measures are small integers,
exactly representable in f32, so every ⊕-order yields the same bits (same
convention as tests/test_level_calibration.py).

Plus the API-redesign satellites: the unified ``ThinkTimePolicy`` surface
(``speculate=k`` ≡ ``FixedKPrefetch(k)`` parity, DeprecationWarning exactly
once), the one-place typed think-time config with env overrides, cube
invalidation selectivity on update/flush, the trajectory model, and the
server pool admitting cubes.
"""

import warnings

import jax
import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import (
    BrushTrajectory,
    ClearFilter,
    DashboardSpec,
    DrainCalibration,
    FixedKPrefetch,
    PredictiveThinkTime,
    SetFilter,
    Treant,
    VizSpec,
    reset_deprecation_warnings,
    reset_think_time_config,
    think_time_config,
)
from repro.core import semiring as sr
from repro.relational.relation import Catalog, Relation

RINGS = ("count", "sum", "tropical_min", "tropical_max", "moments")


def _rel(name, attrs, doms, n, rng, measure=False):
    codes = {a: rng.integers(0, doms[a], n).astype(np.int32) for a in attrs}
    measures = (
        {"m": rng.integers(0, 16, n).astype(np.float32)} if measure else {}
    )
    return Relation(name, tuple(attrs), codes, doms, measures=measures)


def chain_catalog(seed=0):
    rng = np.random.default_rng(seed)
    doms = {"a": 6, "b": 7, "c": 5, "d": 8}
    return Catalog([
        _rel("F", ("a", "b"), doms, 500, rng, measure=True),
        _rel("S", ("b", "c"), doms, 60, rng),
        _rel("T", ("c", "d"), doms, 40, rng),
    ]), "d"


def star_catalog(seed=0):
    rng = np.random.default_rng(seed)
    doms = {"a": 13, "b": 7, "c": 10, "d": 5, "e": 9}
    return Catalog([
        _rel("F", ("a", "b"), doms, 600, rng, measure=True),
        _rel("S", ("b", "c"), doms, 77, rng),
        _rel("T", ("a", "d"), doms, 29, rng),
        _rel("U", ("b", "e"), doms, 41, rng),
    ]), "c"


def bushy_catalog(seed=0):
    rng = np.random.default_rng(seed)
    doms = {"a": 6, "b": 7, "c": 5, "d": 8, "e": 4, "g": 9}
    return Catalog([
        _rel("F", ("a", "b"), doms, 400, rng, measure=True),
        _rel("S", ("b", "c"), doms, 70, rng),
        _rel("T", ("c", "d"), doms, 50, rng),
        _rel("A", ("a", "e"), doms, 30, rng),
        _rel("D", ("d", "g"), doms, 35, rng),
    ]), "g"


SHAPES = {"chain": chain_catalog, "star": star_catalog, "bushy": bushy_catalog}


def two_viz_spec(ring, dim):
    """"main" grouped by a, plus the brush-source viz on ``dim`` (source
    exclusion keeps its own dimension unfiltered, crossfilter-style)."""
    measure = None if ring == "count" else ("F", "m")
    return DashboardSpec(vizzes=(
        VizSpec("main", measure=measure, ring=ring, group_by=("a",)),
        VizSpec("brush_src", measure=measure, ring=ring, group_by=(dim,)),
    ))


def assert_factor_equal(f1, f2):
    assert f1.attrs == f2.attrs
    l1 = jax.tree_util.tree_leaves(f1.field)
    l2 = jax.tree_util.tree_leaves(f2.field)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _plan_execs(t):
    st = t.cache_stats()
    p = st.get("plans")
    return (p["plans_built"] + p["plan_hits"]) if p else 0


@pytest.fixture(autouse=True)
def _fresh_config(monkeypatch):
    reset_think_time_config()
    yield
    reset_think_time_config()


# ---------------------------------------------------------------------------
# tentpole: cube slice ≡ cold execution, bit-identical (rings × shapes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring", RINGS)
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_cube_slice_matches_cold_execution(ring, shape):
    cat, dim = SHAPES[shape](seed=11)
    spec = two_viz_spec(ring, dim)
    primary = sr.SUM if ring in ("count", "sum") else sr.get(ring)
    t = Treant(cat, ring=primary, use_plans=True)
    sess = t.open_session(spec, name="s")
    dom = cat.domains()[dim]
    sess.apply(SetFilter(dim, lo=0, hi=max(dom // 2, 1), source="brush_src"))
    assert sess._build_bin_cube("main", dim)
    # several σ shapes on the cube dimension: range, IN-list, full clear
    events = [
        SetFilter(dim, lo=1, hi=dom, source="brush_src"),
        SetFilter(dim, values=(0, dom - 1), source="brush_src"),
        ClearFilter(dim),
    ]
    cold_t = Treant(SHAPES[shape](seed=11)[0], ring=primary, use_plans=True)
    cold = cold_t.open_session(spec, name="cold")
    for ev in events:
        warm_res = sess.apply(ev)
        cold_res = cold.apply(ev)
        assert warm_res.affected == cold_res.affected == ("main",)
        st = warm_res.results["main"].stats
        assert st.bin_cube_hits == 1, f"{ev} missed the cube"
        assert_factor_equal(
            warm_res.results["main"].factor, cold_res.results["main"].factor
        )
    sess.close()
    cold.close()


# ---------------------------------------------------------------------------
# tentpole acceptance: 0 plan executions, 0 store probes on a warm brush
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_plans", [False, True])
def test_warm_brush_zero_executions_zero_store_probes(use_plans):
    cat, dim = star_catalog(seed=23)
    t = Treant(cat, ring=sr.SUM, use_plans=use_plans)
    sess = t.open_session(two_viz_spec("sum", dim), name="s")
    sess.apply(SetFilter(dim, lo=2, hi=5, source="brush_src"))
    sess.idle(policy=PredictiveThinkTime(prefetch_k=0))
    assert sess._bin_cubes, "predictive idle built no cube"
    store = t.store
    probes0 = (store.hits, store.misses, store.widen_hits)
    execs0 = _plan_execs(t)
    res = sess.apply(SetFilter(dim, lo=7, hi=9, source="brush_src"))
    assert res.affected == ("main",)
    assert res.results["main"].stats.bin_cube_hits == 1
    assert sess.bin_cube_hits == 1
    assert (store.hits, store.misses, store.widen_hits) == probes0, (
        "cube-served brush probed the message store"
    )
    assert _plan_execs(t) == execs0, "cube-served brush executed a plan"
    sess.close()


# ---------------------------------------------------------------------------
# invalidation selectivity on update / flush
# ---------------------------------------------------------------------------

def _star_cube_session(seed, **viz_kwargs):
    cat, dim = star_catalog(seed=seed)
    t = Treant(cat, ring=sr.SUM, use_plans=True, compaction_threshold=0.0)
    spec = DashboardSpec(vizzes=(
        VizSpec("sees_u", measure=("F", "m"), ring="sum", group_by=("a",)),
        VizSpec("blind_u", measure=("F", "m"), ring="sum", group_by=("d",),
                removed=("U",)),
        VizSpec("brush_src", measure=("F", "m"), ring="sum", group_by=(dim,)),
    ))
    sess = t.open_session(spec, name="s")
    sess.apply(SetFilter(dim, lo=2, hi=6, source="brush_src"))
    assert sess._build_bin_cube("sees_u", dim)
    assert sess._build_bin_cube("blind_u", dim)
    return cat, t, sess, dim


def test_update_invalidates_only_cubes_that_see_the_relation():
    cat, t, sess, dim = _star_cube_session(seed=31)
    rng = np.random.default_rng(0)
    u = cat.get("U")
    new_u, delta = u.append_rows(
        {a: rng.integers(0, u.domains[a], 10).astype(np.int32) for a in u.attrs}
    )
    t.update(new_u, delta)
    vizzes = {viz for viz, _ in sess._bin_cubes}
    assert vizzes == {"blind_u"}, (
        f"update kept/dropped the wrong cubes: {vizzes}"
    )
    # the survivor still serves, bit-identically to cold post-update state
    res = sess.apply(SetFilter(dim, lo=0, hi=3, source="brush_src"))
    assert res.results["blind_u"].stats.bin_cube_hits == 1
    assert res.results["sees_u"].stats.bin_cube_hits == 0
    cold = t.open_session(DashboardSpec(vizzes=(
        VizSpec("blind_u", measure=("F", "m"), ring="sum", group_by=("d",),
                removed=("U",)),
        VizSpec("brush_src", measure=("F", "m"), ring="sum", group_by=(dim,)),
    )), name="cold")
    cres = cold.apply(SetFilter(dim, lo=0, hi=3, source="brush_src"))
    assert_factor_equal(
        res.results["blind_u"].factor, cres.results["blind_u"].factor
    )
    sess.close()
    cold.close()


def test_flush_invalidates_only_cubes_that_see_the_relation():
    cat, t, sess, dim = _star_cube_session(seed=37)
    rng = np.random.default_rng(1)
    u = cat.get("U")
    t.stream("U").append(
        {a: rng.integers(0, u.domains[a], 6).astype(np.int32) for a in u.attrs}
    )
    t.flush()
    assert {viz for viz, _ in sess._bin_cubes} == {"blind_u"}
    sess.close()


# ---------------------------------------------------------------------------
# API redesign: deprecation shims + FixedKPrefetch parity
# ---------------------------------------------------------------------------

def test_speculate_kwarg_equals_fixed_k_policy():
    """idle(speculate=k) and idle(policy=FixedKPrefetch(k)) must park the
    exact same (viz, digest) entries."""
    def parked(policy=None, speculate=0):
        cat, dim = star_catalog(seed=41)
        t = Treant(cat, ring=sr.SUM, use_plans=True)
        sess = t.open_session(two_viz_spec("sum", dim), name="s")
        sess.apply(SetFilter(dim, lo=3, hi=5, source="brush_src"))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            sess.idle(speculate=speculate, policy=policy)
        keys = sorted(sess._prefetched)
        dists = [sess._prefetched[k].dist for k in keys]
        sess.close()
        return keys, dists

    assert parked(speculate=3) == parked(policy=FixedKPrefetch(3))


def test_deprecated_kwargs_warn_exactly_once():
    reset_deprecation_warnings()
    cat, dim = star_catalog(seed=43)
    t = Treant(cat, ring=sr.SUM, use_plans=False)
    sess = t.open_session(two_viz_spec("sum", dim), name="s", calibrate=False)
    sess.apply(SetFilter(dim, lo=1, hi=3, source="brush_src"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        sess.idle(speculate=1)
        sess.idle(speculate=2)   # second use: silent
        sess.idle(speculate=1)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, f"expected exactly one DeprecationWarning, got {len(dep)}"
    assert "FixedKPrefetch" in str(dep[0].message)
    # the server kwarg is a distinct key: warns once too, independently
    from repro.serve import TreantServer

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        TreantServer(t, speculate=2)
        TreantServer(t, speculate=3)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    sess.close()


def test_default_idle_is_pure_drain():
    """idle() with no policy stays calibration-only: no speculation, no
    cubes (behavior of every pre-policy caller)."""
    cat, dim = star_catalog(seed=47)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    assert isinstance(t.think_time_policy, DrainCalibration)
    sess = t.open_session(two_viz_spec("sum", dim), name="s")
    sess.apply(SetFilter(dim, lo=2, hi=4, source="brush_src"))
    sess.idle()
    assert not sess._prefetched and not sess._bin_cubes
    assert t.scheduler.policy_decisions == 0
    sess.close()


def test_treant_level_policy_default_applies_to_sessions():
    cat, dim = star_catalog(seed=53)
    t = Treant(cat, ring=sr.SUM, use_plans=True,
               policy=PredictiveThinkTime(prefetch_k=0))
    sess = t.open_session(two_viz_spec("sum", dim), name="s")
    sess.apply(SetFilter(dim, lo=2, hi=4, source="brush_src"))
    sess.idle()
    assert sess._bin_cubes, "Treant(policy=) default was not applied by idle()"
    assert t.scheduler.policy_decisions > 0
    sess.close()


# ---------------------------------------------------------------------------
# API redesign: one typed config, env overrides win
# ---------------------------------------------------------------------------

def test_think_time_config_env_overrides(monkeypatch):
    monkeypatch.setenv("REPRO_PREFETCH_CAPACITY", "7")
    monkeypatch.setenv("REPRO_PREFETCH_K", "5")
    monkeypatch.setenv("REPRO_BIN_CUBE", "0")
    monkeypatch.setenv("REPRO_BIN_CUBE_MAX_DIMS", "2")
    monkeypatch.setenv("REPRO_BIN_CUBE_CAPACITY", "9")
    monkeypatch.setenv("REPRO_BIN_CUBE_CELLS", "123")
    reset_think_time_config()
    cfg = think_time_config()
    assert (cfg.prefetch_capacity, cfg.prefetch_k) == (7, 5)
    assert cfg.bin_cubes is False
    assert (cfg.cube_builds_per_idle, cfg.cube_capacity) == (2, 9)
    assert cfg.cube_cell_budget == 123
    # the resolved config seeds new sessions
    cat, dim = star_catalog(seed=59)
    t = Treant(cat, ring=sr.SUM, use_plans=False)
    sess = t.open_session(two_viz_spec("sum", dim), name="s", calibrate=False)
    assert sess.prefetch_capacity == 7
    # REPRO_BIN_CUBE=0 disables builds even under the predictive policy
    sess.apply(SetFilter(dim, lo=2, hi=4, source="brush_src"))
    sess.idle(policy=PredictiveThinkTime(prefetch_k=0))
    assert not sess._bin_cubes
    sess.close()


def test_cube_cell_budget_derives_from_union_budget(monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION_UNION_BUDGET", "100")
    monkeypatch.delenv("REPRO_BIN_CUBE_CELLS", raising=False)
    reset_think_time_config()
    cfg = think_time_config()
    assert cfg.union_budget == 100
    assert cfg.cube_cell_budget == 32 * 100
    # an explicit REPRO_BIN_CUBE_CELLS still wins over the derivation
    monkeypatch.setenv("REPRO_BIN_CUBE_CELLS", "50")
    reset_think_time_config()
    assert think_time_config().cube_cell_budget == 50


def test_cube_cell_budget_caps_builds(monkeypatch):
    monkeypatch.setenv("REPRO_BIN_CUBE_CELLS", "4")  # 13·10 cells ≫ 4
    reset_think_time_config()
    cat, dim = star_catalog(seed=61)
    t = Treant(cat, ring=sr.SUM, use_plans=False)
    sess = t.open_session(two_viz_spec("sum", dim), name="s", calibrate=False)
    sess.apply(SetFilter(dim, lo=2, hi=4, source="brush_src"))
    assert not sess._build_bin_cube("main", dim)
    assert not sess._bin_cubes
    sess.close()


# ---------------------------------------------------------------------------
# trajectory model
# ---------------------------------------------------------------------------

def test_trajectory_direction_biases_candidates():
    tr = BrushTrajectory()
    for i, t0 in enumerate(range(3)):
        tr.observe(SetFilter("x", lo=2 + 2 * i, hi=4 + 2 * i), now=float(t0))
    assert tr.direction["x"] > 0
    cands = tr.next_filters(domain=20, k=2)
    # steady upward drift: both predicted windows continue up-domain
    assert all(c.lo > 6 for c in cands), [(c.lo, c.hi) for c in cands]
    # downward drift flips the bias
    tr2 = BrushTrajectory()
    for i, t0 in enumerate(range(3)):
        tr2.observe(SetFilter("x", lo=14 - 2 * i, hi=16 - 2 * i), now=float(t0))
    assert tr2.direction["x"] < 0
    cands2 = tr2.next_filters(domain=20, k=2)
    assert all(c.lo < 10 for c in cands2), [(c.lo, c.hi) for c in cands2]


def test_trajectory_switch_probability_and_ranking():
    tr = BrushTrajectory()
    # strict alternation x, y, x, y → high switch probability → the
    # *previous* dimension outranks the latest
    for i, attr in enumerate(["x", "y", "x", "y"]):
        tr.observe(SetFilter(attr, lo=0, hi=2, source=f"src_{attr}"), now=float(i))
    assert tr.switch_prob > 0.5
    assert tr.ranked_dims()[0] == "x"
    assert tr.source_of("y") == "src_y"
    # dwelling on one dimension → low switch probability → it stays first
    tr2 = BrushTrajectory()
    for i in range(4):
        tr2.observe(SetFilter("x", lo=i, hi=i + 2), now=float(i))
    assert tr2.switch_prob < 0.5
    assert tr2.ranked_dims()[0] == "x"


def test_predictive_policy_skips_brush_source_viz():
    """The dim's source viz never carries that σ (source exclusion), so no
    cube for (source viz, dim) is ever built."""
    cat, dim = star_catalog(seed=67)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(two_viz_spec("sum", dim), name="s")
    sess.apply(SetFilter(dim, lo=2, hi=5, source="brush_src"))
    sess.idle(policy=PredictiveThinkTime(prefetch_k=0))
    assert all(e.viz != "brush_src" for e in sess._bin_cubes.values())
    sess.close()


# ---------------------------------------------------------------------------
# serving tier: pooled cubes serve ANY session
# ---------------------------------------------------------------------------

def test_server_pool_cube_serves_sibling_session():
    from repro.serve import TreantServer

    cat, dim = star_catalog(seed=71)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    server = TreantServer(t, policy=PredictiveThinkTime(prefetch_k=0))
    spec = two_viz_spec("sum", dim)
    h1 = server.open_session(spec, name="u1")
    h2 = server.open_session(spec, name="u2")
    h1.submit(SetFilter(dim, lo=2, hi=5, source="brush_src"))
    server.step()
    server.idle()  # builds u1's cube and publishes it into the pool
    assert any(e.dim == dim for e in server._pool.values()), (
        "idle did not pool the bin cube"
    )
    # a DIFFERENT session brushes a σ nobody prefetched: pooled-cube slice
    h2.submit(SetFilter(dim, lo=7, hi=9, source="brush_src"))
    server.step()
    res = h2.last_result
    assert res.affected == ("main",)
    assert res.results["main"].stats.bin_cube_hits == 1
    assert server.stats_.pool_cube_hits == 1
    # bit-identical to a cold session applying the same brush
    cold_t = Treant(star_catalog(seed=71)[0], ring=sr.SUM, use_plans=True)
    cold = cold_t.open_session(spec, name="cold")
    cres = cold.apply(SetFilter(dim, lo=7, hi=9, source="brush_src"))
    assert_factor_equal(
        res.results["main"].factor, cres.results["main"].factor
    )
    cold.close()
    server.close_session("u1")
    server.close_session("u2")


def test_session_stats_and_cache_stats_surface_cube_counters():
    cat, dim = star_catalog(seed=73)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(two_viz_spec("sum", dim), name="s")
    sess.apply(SetFilter(dim, lo=2, hi=5, source="brush_src"))
    sess.idle(policy=PredictiveThinkTime(prefetch_k=0))
    sess.apply(SetFilter(dim, lo=6, hi=8, source="brush_src"))
    st = sess.stats()
    assert st["bin_cubes"] >= 1 and st["bin_cube_hits"] == 1
    assert st["bin_cube_bytes"] > 0
    assert st["trajectory"]["events"] == 2
    cs = t.cache_stats()
    assert cs["bin_cube_hits"] == 1 and cs["bin_cube_bytes"] > 0
    assert cs["scheduler"]["cube_builds"] >= 1
    assert cs["scheduler"]["policy_decisions"] > 0
    assert cs["plans"]["cube_builds"] >= 1
    assert cs["plans"]["cube_slices"] == 1
    sess.close()
    assert not sess._bin_cubes