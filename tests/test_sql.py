"""SQL front-end: parsed queries execute identically to hand-built ones."""

import numpy as np
import pytest

from repro.core import CJTEngine, Query, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in
from repro.relational.sql import SqlError, parse


@pytest.fixture(scope="module")
def cat():
    return schema.salesforce(n_opp=2000, n_user=30, n_camp=10, n_acc=20, n_role=4)


def test_parse_sum_group_by(cat):
    q = parse(
        "SELECT camp_type, SUM(amount) FROM Opp, User, Role, Camp, Acc "
        "WHERE role_name IN (1,2) GROUP BY camp_type",
        cat,
    )
    ref = Query.make(
        cat, ring="sum", measure=("Opp", "amount"), group_by=("camp_type",),
        predicates=[mask_in(4, [1, 2], attr="role_name")],
    )
    assert q.digest == ref.digest


def test_parsed_query_executes(cat):
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    q = parse("SELECT SUM(amount) FROM Opp WHERE state = 3", cat)
    f, _ = eng.execute(q)
    opp, user, acc = cat.get("Opp"), cat.get("User"), cat.get("Acc")
    state_of = acc.codes["state"][np.argsort(acc.codes["acc_id"])]
    mask = state_of[opp.codes["acc_id"]] == 3
    want = opp.measures["amount"][mask].sum()
    np.testing.assert_allclose(float(np.asarray(f.field)), want, rtol=1e-4)


def test_parse_count_between(cat):
    q = parse("SELECT COUNT(*) FROM Opp WHERE start_q BETWEEN 2 AND 5", cat)
    assert q.ring_name == "count"
    assert q.predicates[0].mask.sum() == 4


def test_reject_non_spja(cat):
    with pytest.raises(SqlError):
        parse("SELECT camp_type FROM Opp", cat)          # no aggregate
    with pytest.raises(SqlError):
        parse("DELETE FROM Opp", cat)
