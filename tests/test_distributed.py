"""Distributed CJT calibration (shard_map) — runs in a subprocess with 8
virtual devices so the rest of the suite keeps the single real device."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, numpy as np, jax.numpy as jnp
    from repro.core.distributed import (
        calibrate_chain_reference, chain_absorptions_reference,
        make_chain_calibrate, place_chain_factors,
    )
    from repro.runtime.compat import make_mesh
    mesh = make_mesh((8,), ("data",))
    r, d = 6, 64
    rng = np.random.default_rng(0)
    factors_np = [rng.random((d, d)).astype(np.float32) for _ in range(r)]
    fwd_ref, bwd_ref = calibrate_chain_reference([jnp.asarray(f) for f in factors_np])
    fn = make_chain_calibrate(mesh, "data", r, d)
    factors = place_chain_factors(mesh, "data", factors_np)
    fwd, bwd, total = fn(factors)
    for i in range(r - 1):
        np.testing.assert_allclose(np.asarray(fwd[i]), np.asarray(fwd_ref[i]), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(bwd[i]), np.asarray(bwd_ref[i]), rtol=1e-4)
    v = jnp.ones(d)
    for f in factors_np:
        v = v @ jnp.asarray(f)
    np.testing.assert_allclose(float(total), float(v.sum()), rtol=1e-3)
    # calibration invariant: absorptions agree across bags
    absb = chain_absorptions_reference([jnp.asarray(f) for f in factors_np], fwd_ref, bwd_ref)
    totals = [float(jnp.sum(a)) for a in absb]
    assert max(totals) - min(totals) < 1e-3 * max(totals)
    # collective schedule: r-1 reduce-scatters and r-1 all-gathers (+1 in absorption)
    import re
    txt = jax.jit(fn).lower(factors).compile().as_text()
    rs = len(re.findall(r"reduce-scatter", txt))
    ag = len(re.findall(r"all-gather", txt))
    # multi-measure fused calibration agrees with per-measure passes
    from repro.core.distributed import make_chain_calibrate_multi
    from jax.sharding import NamedSharding, PartitionSpec as P
    V = 3
    leaf_np = rng.random((d, V)).astype(np.float32)
    fnm = make_chain_calibrate_multi(mesh, "data", r, d, V)
    sh = NamedSharding(mesh, P("data", None))
    leaf = jax.device_put(jnp.asarray(leaf_np), sh)
    fwd_m, bwd_m, totals = fnm(factors, leaf)
    for j in range(V):
        v = jnp.asarray(leaf_np[:, j])
        for f in factors_np:
            v = v @ jnp.asarray(f)
        np.testing.assert_allclose(float(totals[j]), float(v.sum()), rtol=1e-3)
        np.testing.assert_allclose(
            np.asarray(fwd_m[-1][:, j]), np.asarray(fwd_m[-1][:, j]), rtol=1e-4)
    print(json.dumps({"ok": True, "rs": rs, "ag": ag}))
""")


def test_sharded_chain_calibration_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["rs"] >= 5 and rec["ag"] >= 5
