"""Factor algebra: contraction == brute-force join-aggregate on random
relations (hypothesis property test over schemas/rings)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import semiring as sr
from repro.core.factor import Factor, brute_force_join_aggregate, contract, ones_factor


def _random_factor(ring, attrs, doms, rng):
    shape = tuple(doms[a] for a in attrs)
    if ring.name == "bool":
        field = jnp.asarray(rng.random(shape) > 0.6)
    elif ring.name == "moments":
        field = tuple(jnp.asarray(rng.integers(0, 4, shape), jnp.float32) for _ in range(3))
    else:
        field = jnp.asarray(rng.integers(0, 5, shape), jnp.float32)
    return Factor(tuple(attrs), field, ring)


SCHEMAS = [
    [("A", "B"), ("B", "C")],
    [("A", "B"), ("A", "C"), ("A", "D")],          # star (Fig 2)
    [("A", "B"), ("B", "C"), ("C", "D")],          # chain (Ex. 3)
    [("A",), ("A", "B"), ("B",)],
]


@pytest.mark.parametrize("ring", [sr.COUNT, sr.SUM, sr.TROPICAL_MIN, sr.BOOL],
                         ids=lambda r: r.name)
@pytest.mark.parametrize("schema", SCHEMAS)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), keep_mask=st.integers(0, 15))
def test_contract_matches_brute_force(ring, schema, seed, keep_mask):
    rng = np.random.default_rng(seed)
    attrs = sorted({a for s in schema for a in s})
    doms = {a: int(rng.integers(2, 5)) for a in attrs}
    factors = [_random_factor(ring, s, doms, rng) for s in schema]
    keep = tuple(a for i, a in enumerate(attrs) if keep_mask >> i & 1)
    got = contract(factors, keep, ring).project_to(keep)
    want = brute_force_join_aggregate(factors, keep, ring).project_to(keep)
    import jax
    for lx, ly in zip(jax.tree_util.tree_leaves(got.field),
                      jax.tree_util.tree_leaves(want.field)):
        np.testing.assert_allclose(np.asarray(lx, np.float64), np.asarray(ly, np.float64),
                                   rtol=1e-5, atol=1e-6)


def test_select_is_idempotent_and_ring_agnostic():
    rng = np.random.default_rng(3)
    for ring in (sr.SUM, sr.TROPICAL_MAX, sr.BOOL):
        f = _random_factor(ring, ("A", "B"), {"A": 4, "B": 3}, rng)
        mask = jnp.asarray([True, False, True, False])
        once = f.select("A", mask)
        twice = once.select("A", mask)
        import jax
        for lx, ly in zip(jax.tree_util.tree_leaves(once.field),
                          jax.tree_util.tree_leaves(twice.field)):
            np.testing.assert_allclose(np.asarray(lx, np.float64), np.asarray(ly, np.float64))


def test_identity_factor_is_join_neutral():
    rng = np.random.default_rng(4)
    f = _random_factor(sr.SUM, ("A", "B"), {"A": 3, "B": 2}, rng)
    ident = ones_factor(sr.SUM, ("B",), {"B": 2})
    got = f.product(ident)
    np.testing.assert_allclose(np.asarray(got.project_to(("A", "B")).field),
                               np.asarray(f.field))


def test_project_reorders_with_trailing_dims():
    ring = sr.MOMENTS
    rng = np.random.default_rng(5)
    f = _random_factor(ring, ("A", "B"), {"A": 2, "B": 3}, rng)
    g = f.project_to(("B", "A"))
    np.testing.assert_allclose(np.asarray(g.field[1]), np.asarray(f.field[1]).T)
