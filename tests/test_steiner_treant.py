"""Steiner planning + Treant middleware: recomputed edges ⊆ Steiner tree,
think-time calibration monotonicity, cross-session cache sharing."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import CJTEngine, MessageStore, Query, Treant, jt_from_catalog, steiner
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in


@pytest.fixture(scope="module")
def flight():
    cat = schema.flight(n_flights=20_000)
    return cat, jt_from_catalog(cat)


def test_minimal_steiner_tree_is_path(flight):
    cat, jt = flight
    nodes, edges = steiner.minimal_steiner_tree(jt, {"bag:Carrier", "bag:Airport"})
    # carrier—flights—airport path
    assert "bag:Flights" in nodes
    assert len(nodes) == 3 and len(edges) == 2


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500))
def test_recomputed_edges_within_steiner_tree(seed):
    """Property (§3.4.3): after calibration, an interaction query only
    recomputes messages whose directed edge lies inside the Steiner tree."""
    cat = schema.flight(n_flights=5_000, seed=seed % 7)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    rng = np.random.default_rng(seed)
    d = cat.domains()
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"),
                    group_by=("carrier_group",))
    eng.calibrate(q0)
    attrs = ["airport_state", "month", "dow", "carrier_group", "airport_size"]
    attr = attrs[rng.integers(len(attrs))]
    q1 = q0.with_predicate(mask_in(d[attr], [int(rng.integers(d[attr]))], attr=attr))
    pln = steiner.plan(eng, q0, q1)
    f, stats = eng.execute(q1)
    allowed = steiner.directed_edges_into(pln) | {(b, b) for b in pln.nodes}
    for (u, v) in stats.recomputed_edges:
        assert (u, v) in allowed or u in pln.nodes, (u, v, pln)


def test_steiner_size_tracks_query_distance(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    d = cat.domains()
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"))
    t.register_dashboard("v", q0)
    q1 = q0.with_predicate(mask_in(d["carrier_group"], [0], attr="carrier_group"))
    r1 = t.interact("s", "v", q1)
    # identical query again: zero-size plan, pure cache hits
    r2 = t.interact("s", "v", q1)
    assert r2.stats.messages_computed == 0
    assert r2.steiner_size <= 1


def test_think_time_calibration_reduces_next_latency(flight):
    cat, jt = flight
    d = cat.domains()
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"),
                    group_by=("airport_state",))
    q1 = q0.with_predicate(mask_in(d["carrier_group"], [0, 1], attr="carrier_group"))
    q2 = q1.with_predicate(mask_in(d["airport_size"], [1], attr="airport_size"))

    def run(budget):
        t = Treant(cat, ring=sr.SUM, jt=jt)
        t.register_dashboard("v", q0)
        t.interact("s", "v", q1)
        if budget:
            t.think_time("s", "v", budget_messages=budget)
        res = t.interact("s", "v", q2)
        return res.stats.messages_computed, np.asarray(res.factor.field)

    cold_computed, cold = run(0)
    warm_computed, warm = run(8)
    assert warm_computed <= cold_computed
    np.testing.assert_allclose(warm, cold, rtol=1e-5)


def test_cross_session_sharing(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"))
    t.register_dashboard("v", q0)
    d = cat.domains()
    q1 = q0.with_predicate(mask_in(d["month"], [3], attr="month"))
    r_a = t.interact("alice", "v", q1)
    r_b = t.interact("bob", "v", q1)  # same query, different session → cache
    assert r_b.stats.messages_computed == 0


def test_preemption_keeps_partial_messages(flight):
    cat, jt = flight
    t = Treant(cat, ring=sr.SUM, jt=jt)
    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"))
    t.register_dashboard("v", q0)
    d = cat.domains()
    q1 = q0.with_predicate(mask_in(d["dow"], [0], attr="dow"))
    t.interact("s", "v", q1)
    n_before = len(t.store)
    done = t.think_time("s", "v", budget_messages=2)   # preempted early
    assert done == 2
    assert len(t.store) >= n_before  # materialized messages persisted
