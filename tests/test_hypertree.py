"""JT construction: GYO acyclicity, RIP validation, empty bags, augmentation."""

import pytest

from repro.core.hypertree import (
    CyclicSchemaError, attach_relation, build_join_tree, insert_empty_bag,
    is_acyclic, jt_from_catalog,
)
from repro.relational import schema


def test_acyclic_detection():
    assert is_acyclic({"R": ["A", "B"], "S": ["B", "C"], "T": ["C", "D"]})
    assert is_acyclic({"R": ["A", "B"], "S": ["A", "C"], "T": ["A", "D"]})
    # triangle
    assert not is_acyclic({"R": ["A", "B"], "S": ["B", "C"], "T": ["A", "C"]})


def test_cyclic_raises():
    with pytest.raises(CyclicSchemaError):
        build_join_tree(
            {"R": ("A", "B"), "S": ("B", "C"), "T": ("A", "C")},
            {"A": 2, "B": 2, "C": 2},
        )


@pytest.mark.parametrize("maker", [schema.salesforce, schema.flight, schema.favorita,
                                   schema.tpch, schema.tpcds_star])
def test_catalog_trees_validate(maker):
    cat = maker() if maker is not schema.salesforce else maker(n_opp=1000)
    jt = jt_from_catalog(cat)
    jt.validate()
    # every relation's bag covers its attrs (edge coverage)
    for name in cat.names():
        bag = jt.mapping[name]
        assert set(cat.get(name).attrs) <= set(jt.bags[bag])


def test_separators_and_subtrees():
    cat = schema.chain(4, fanout=2, domain=8)
    jt = jt_from_catalog(cat)
    assert jt.separator("bag:R0", "bag:R1") == ("A1",)
    sub = jt.subtree_bags("bag:R0", "bag:R1")
    assert sub == ("bag:R0",)
    assert set(jt.subtree_bags("bag:R1", "bag:R0")) == {"bag:R1", "bag:R2", "bag:R3"}


def test_empty_bag_insert_preserves_rip():
    cat = schema.tpcds_star(n_sales=1000)
    jt = jt_from_catalog(cat)
    jt2 = insert_empty_bag(jt, "TimeStores", ("store_key", "time_key"),
                           host="bag:Store_Sales", reroute=["bag:Stores", "bag:Time"])
    jt2.validate()
    assert "bag:TimeStores" in jt2.empty_bags
    assert "bag:TimeStores" in jt2.adj["bag:Store_Sales"]
    assert "bag:Stores" in jt2.adj["bag:TimeStores"]


def test_empty_bag_rejects_uncovered_separator():
    cat = schema.tpcds_star(n_sales=1000)
    jt = jt_from_catalog(cat)
    with pytest.raises(AssertionError):
        insert_empty_bag(jt, "Bad", ("store_key",), host="bag:Store_Sales",
                         reroute=["bag:Time"])  # separator time_key not covered


def test_attach_relation_single_key():
    cat = schema.favorita(n_sales=1000)
    jt = jt_from_catalog(cat)
    jt2, bag = attach_relation(jt, "Aug", ("store", "extra"), {"store": 54, "extra": 3})
    jt2.validate()
    assert jt2.mapping["Aug"] == bag


def test_traversal_covers_all_edges():
    cat = schema.salesforce(n_opp=500)
    jt = jt_from_catalog(cat)
    for root in jt.bags:
        tra = jt.traversal_to_root(root)
        assert len(tra) == len(jt.bags) - 1
        # each child appears before its parent's edge
        seen = set()
        for u, v in tra:
            for w in jt.subtree_bags(u, v):
                seen.add(w)
        assert seen == set(jt.bags) - {root}
