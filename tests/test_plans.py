"""Compiled message plans (core.plans): parity, metamorphic and cache tests.

- Parity: the compiled/Pallas path must match the legacy un-jitted reference
  path across non-tile-divisible N/G, min/max segment ops, trailing statistic
  dims (MOMENTS) and predicate masks.
- Metamorphic: with integer-valued measures (exactly representable in f32,
  so every summation order yields the same bits) ``execute`` must be
  **bit-identical** with the plan cache on vs off.
- Caching: structural reuse across versions/masks, bounded signature memo,
  Σ-widening probe stats.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import CJTEngine, MessageStore, Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.core.factor import Factor
from repro.relational.relation import LRU, Catalog, Relation, mask_in

N_FACT = 600  # > one 512-row kernel tile → exercises row padding


def star_catalog(n_fact: int = N_FACT, seed: int = 0) -> Catalog:
    """Tiny star: F(a,b)+m ← S(b,c), T(a,d).  Domains straddle the 8-lane
    tile minimum (5 < 8 ≤ 13) so the kernel's group padding is exercised;
    measures are small integers so f32 sums are exact (bitwise-stable)."""
    rng = np.random.default_rng(seed)
    doms = {"a": 13, "b": 7, "c": 10, "d": 5}

    def codes(attrs, n):
        return {x: rng.integers(0, doms[x], n).astype(np.int32) for x in attrs}

    f = Relation("F", ("a", "b"), codes(("a", "b"), n_fact), doms,
                 measures={"m": rng.integers(0, 16, n_fact).astype(np.float32)})
    s = Relation("S", ("b", "c"), codes(("b", "c"), 77), doms,
                 measures={"w": rng.integers(0, 8, 77).astype(np.float32)})
    t = Relation("T", ("a", "d"), codes(("a", "d"), 29), doms)
    return Catalog([f, s, t])


def engines(cat, ring, **kw):
    jt = jt_from_catalog(cat)
    ref = CJTEngine(jt, cat, ring, use_plans=False, **kw)
    pln = CJTEngine(jt, cat, ring, use_plans=True, **kw)
    return ref, pln


def assert_factors_equal(f1: Factor, f2: Factor, exact: bool):
    assert f1.attrs == f2.attrs
    l1 = jax.tree_util.tree_leaves(f1.field)
    l2 = jax.tree_util.tree_leaves(f2.field)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        a, b = np.asarray(a), np.asarray(b)
        if exact:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# parity: compiled path ≡ reference path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("group_by", [(), ("c",), ("c", "d")])
def test_sparse_parity_sum_nondivisible(group_by):
    cat = star_catalog()
    ref, pln = engines(cat, sr.SUM)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=group_by)
    f1, _ = ref.execute(q)
    f2, s2 = pln.execute(q)
    assert_factors_equal(f1, f2, exact=True)
    assert s2.plan_traces > 0 and s2.kernel_execs > 0


@pytest.mark.parametrize("ring,name", [(sr.TROPICAL_MIN, "tropical_min"),
                                       (sr.TROPICAL_MAX, "tropical_max")])
def test_sparse_parity_minmax_kernel_ops(ring, name):
    cat = star_catalog(seed=3)
    ref, pln = engines(cat, ring)
    q = Query.make(cat, ring=name, measure=("F", "m"), group_by=("c",))
    f1, _ = ref.execute(q)
    f2, s2 = pln.execute(q)
    # min/max are order-insensitive: exact equality regardless of tiling
    assert_factors_equal(f1, f2, exact=True)
    assert s2.kernel_execs > 0


def test_sparse_parity_moments_trailing_dims():
    """MOMENTS (compound (c,s,q) element) rides the segment kernel as three
    stacked f32 columns — one segment pass for count/sum/sumsq — and must
    flow through the compiled plan with its tuple field intact."""
    cat = star_catalog(seed=5)
    ref, pln = engines(cat, sr.MOMENTS)
    q = Query.make(cat, ring="moments", measure=("F", "m"), group_by=("c",))
    f1, _ = ref.execute(q)
    f2, s2 = pln.execute(q)
    assert len(jax.tree_util.tree_leaves(f2.field)) == 3
    assert_factors_equal(f1, f2, exact=True)
    assert s2.plan_traces > 0 and s2.kernel_execs > 0  # stacked-leaf kernel


def test_sparse_parity_predicate_masks():
    cat = star_catalog(seed=7)
    ref, pln = engines(cat, sr.SUM)
    base = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("b",))
    q = base.with_predicate(mask_in(10, [1, 3, 9], attr="c"))
    q = q.with_predicate(mask_in(5, [0, 2], attr="d"))
    f1, _ = ref.execute(q)
    f2, _ = pln.execute(q)
    assert_factors_equal(f1, f2, exact=True)


def test_dense_two_factor_semiring_contract_route():
    """With everything densified, bag contraction takes the dense plan; the
    2-factor arithmetic case must route through the semiring_contract kernel
    and agree with the legacy einsum path bit-for-bit on integer data."""
    cat = star_catalog(seed=11)
    ref, pln = engines(cat, sr.SUM, dense_rows_threshold=10**9)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    f1, _ = ref.execute(q)
    f2, s2 = pln.execute(q)
    assert_factors_equal(f1, f2, exact=True)
    assert s2.kernel_execs > 0


@pytest.mark.parametrize("ring,name", [(sr.TROPICAL_MIN, "tropical_min"),
                                       (sr.TROPICAL_MAX, "tropical_max")])
def test_dense_two_factor_tropical_contract_route(ring, name):
    """The dense 2-factor tropical case (⊗ = +, ⊕ = min/max is exactly the
    tropical matmul) routes through the tropical_contract kernel under the
    same measured cost gate, bit-identical to the legacy reduce path."""
    cat = star_catalog(seed=11)
    ref, pln = engines(cat, ring, dense_rows_threshold=10**9)
    q = Query.make(cat, ring=name, measure=("F", "m"), group_by=("c",))
    f1, _ = ref.execute(q)
    f2, s2 = pln.execute(q)
    assert_factors_equal(f1, f2, exact=True)
    assert s2.kernel_execs > 0, "tropical dense route must hit the kernel"


# ---------------------------------------------------------------------------
# metamorphic: plan cache on ≡ off, bit-identical
# ---------------------------------------------------------------------------

def test_metamorphic_execute_bit_identical_plans_on_vs_off():
    cat = star_catalog(seed=13)
    queries = []
    for ring_name, measure in [("count", None), ("sum", ("F", "m")),
                               ("moments", ("F", "m"))]:
        q0 = Query.make(cat, ring=ring_name, measure=measure, group_by=("c",))
        queries += [
            q0,
            q0.with_group_by("c", "d"),
            q0.with_predicate(mask_in(7, [0, 2, 5], attr="b")),
            q0.with_removed("T"),
        ]
    ring_of = {"count": sr.COUNT, "sum": sr.SUM, "moments": sr.MOMENTS}
    for q in queries:
        ref, pln = engines(cat, ring_of[q.ring_name])
        f1, _ = ref.execute(q)
        f2, _ = pln.execute(q)
        assert_factors_equal(f1, f2, exact=True)


# ---------------------------------------------------------------------------
# structural plan reuse
# ---------------------------------------------------------------------------

def test_version_bump_reuses_compiled_plan():
    """A measure perturbation bumps every Prop-2 signature but keeps the
    structure: the second execution must add zero new plan traces."""
    cat = star_catalog(seed=17)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    eng.execute(q)
    built = eng.plans.stats.plans_built
    cat.put(cat.get("F").perturb_measure("m", 0.5, seed=1, version="v1"))
    q1 = q.with_version("F", "v1")
    _, s1 = eng.execute(q1)
    assert eng.plans.stats.plans_built == built
    assert s1.plan_traces == 0 and s1.plan_hits > 0


def test_new_predicate_mask_reuses_compiled_plan():
    cat = star_catalog(seed=19)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    q0 = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    eng.execute(q0.with_predicate(mask_in(5, [0, 1], attr="d")))
    built = eng.plans.stats.plans_built
    _, s = eng.execute(q0.with_predicate(mask_in(5, [2, 4], attr="d")))
    assert eng.plans.stats.plans_built == built  # same structure, new σ mask
    assert s.plan_traces == 0


def test_delta_maintenance_runs_through_plans():
    # explicit use_plans=True: the REPRO_USE_PLANS=0 CI leg must not turn
    # this into a plans-off engine (the assertions below count kernel execs)
    cat = star_catalog(seed=23)
    tre = Treant(cat, ring=sr.SUM, use_plans=True)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    tre.register_dashboard("viz", q)
    rng = np.random.default_rng(29)
    f = cat.get("F")
    new_rel, delta = f.append_rows(
        {a: rng.integers(0, f.domains[a], 8).astype(np.int32) for a in f.attrs},
        {"m": rng.integers(0, 16, 8).astype(np.float32)},
    )
    res = tre.update(new_rel, delta)
    assert res.queries_maintained == 1 and res.queries_fallback == 0
    got = tre.read("u", "viz").factor
    # oracle: rebuild from scratch on the merged relation, legacy path
    cat2 = Catalog([new_rel, cat.get("S"), cat.get("T")])
    ref = CJTEngine(jt_from_catalog(cat2), cat2, sr.SUM, use_plans=False)
    want, _ = ref.execute(Query.make(cat2, ring="sum", measure=("F", "m"),
                                     group_by=("c",)))
    assert_factors_equal(want, got, exact=True)
    assert tre.cache_stats()["plans"]["kernel_execs"] > 0


# ---------------------------------------------------------------------------
# bounded caches + Σ-widening probe index
# ---------------------------------------------------------------------------

def test_sig_memo_is_bounded():
    cat = star_catalog(seed=31)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.COUNT)
    eng._sig_memo = LRU(capacity=16)
    q0 = Query.make(cat, ring="count")
    for lo in range(8):  # 8 distinct interaction queries
        eng.execute(q0.with_predicate(mask_in(10, [lo], attr="c")))
    assert len(eng._sig_memo) <= 16


def test_widen_probe_short_circuit_and_stats():
    store = MessageStore()
    wide = Factor(("a", "b"), jnp.arange(12, dtype=jnp.float32).reshape(4, 3), sr.SUM)
    store.put("base", ("a", "b"), wide)
    # γ outside the widen union: no scan at all
    assert store.get("base", ("z",)) is None
    assert store.widen_scans == 0 and store.widen_scan_steps == 0
    # γ subset: scanned, narrowed, counted
    got = store.get("base", ("a",))
    assert got is not None and got.attrs == ("a",)
    np.testing.assert_allclose(np.asarray(got.field),
                               np.asarray(wide.field).sum(axis=1))
    assert store.widen_hits == 1
    assert store.widen_scans == 1 and store.widen_scan_steps >= 1
    # narrowing stored the result: the repeat probe is an exact hit, no scan
    scans = store.widen_scans
    assert store.get("base", ("a",)) is not None
    assert store.widen_scans == scans


def test_widen_probe_prefers_smallest_superset():
    store = MessageStore()
    big = Factor(("a", "b", "c"),
                 jnp.ones((4, 3, 2), jnp.float32), sr.SUM)
    small = Factor(("a", "b"), jnp.full((4, 3), 2.0, jnp.float32), sr.SUM)
    store.put("base", ("a", "b", "c"), big)
    store.put("base", ("a", "b"), small)
    got = store.get("base", ("a",))
    # smallest superset (a,b) narrows first: sum over b of the 2.0 factor
    np.testing.assert_allclose(np.asarray(got.field), np.full((4,), 6.0))


def test_widen_index_dropped_on_eviction():
    """Evicting a message must also drop its Σ-widening index entries —
    otherwise a long update stream grows the probe index without bound."""
    f = Factor(("a",), jnp.ones((64,), jnp.float32), sr.SUM)
    store = MessageStore(max_bytes=2 * 64 * 4)  # room for 2 factors
    for i in range(8):
        store.put(f"base{i}", ("a",), f)
    assert len(store) == 2
    assert len(store._widen) == 2
    assert len(store._sig_index) == 2
    assert sum(len(v) for v in store._widen_bysize.values()) == 2
    # evicted entries no longer advertise as contained
    assert not store.contains("base0", ("a",))
    assert store.contains("base7", ("a",))


def test_store_snapshot_restore_keeps_widen_index():
    store = MessageStore()
    store.put("base", ("a", "b"),
              Factor(("a", "b"), jnp.ones((2, 2), jnp.float32), sr.SUM))
    snap = store.snapshot()
    store.put("other", ("c",), Factor(("c",), jnp.ones((2,), jnp.float32), sr.SUM))
    store.restore(snap)
    assert store.get("base", ("a",)) is not None  # widen index rebuilt
    assert store.get("other", ("c",)) is None


def test_catalog_dev_codes_cached_and_lru_bounded():
    cat = star_catalog(seed=37)
    rel = cat.get("F")
    idx1, total1 = cat.dev_flat_codes(rel, ("a", "b"))
    idx2, total2 = cat.dev_flat_codes(rel, ("a", "b"))
    assert idx1 is idx2 and total1 == total2 == 13 * 7
    want = np.ravel_multi_index(
        (rel.codes["a"].astype(np.int64), rel.codes["b"].astype(np.int64)), (13, 7)
    )
    # codes are padded to the plan row bucket: real rows exact, pad rows 0
    assert idx1.shape == (rel.row_bucket,) and rel.row_bucket >= rel.num_rows
    np.testing.assert_array_equal(np.asarray(idx1)[: rel.num_rows], want)
    np.testing.assert_array_equal(np.asarray(idx1)[rel.num_rows:], 0)
    cat._dev_codes = LRU(capacity=2)
    for attrs in [("a",), ("b",), ("a", "b")]:
        cat.dev_flat_codes(rel, attrs)
    assert len(cat._dev_codes) <= 2
