"""Level-fused kernel launches: the metamorphic suite for ISSUE 7.

With ``fuse_level_kernel`` on (and plans + level batching on), every
calibration level executes as ONE host dispatch whose kernel-eligible batch
groups share a single multi-segment Pallas launch.  The fused pass must leave
the MessageStore **bit-identical** to the sequential per-edge reference loop —
across rings (COUNT/SUM/MIN/MAX/MOMENTS), tree shapes (chain/star/bushy) and
plans on/off (plans off → fusion inert, per-edge loop).  Measures are small
integers exactly representable in f32, so every ⊕-order yields the same bits
(same convention as tests/test_level_calibration.py, whose catalogs this
reuses).

Plus: the dispatch-counter bound the bench gate relies on
(``calibration_dispatches ≤ levels``), the fused-launch counters, the
``REPRO_FUSE_LEVEL_KERNEL`` env gate, MOMENTS stacked-leaf kernel ≡ lax
parity, the measured-cost-profile resolution chain
(``repro.kernels.costs``), and the ``cache_stats`` MAX_FIELDS aggregation
regression (satellite 6).
"""

import json

import jax
import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import CJTEngine, MessageStore, Query, Treant, jt_from_catalog
from repro.core import plans as plans_mod
from repro.core import semiring as sr
from repro.core.plans import PlanStats
from repro.kernels import costs as kernel_costs

from test_level_calibration import (  # same rootdir, shared catalogs
    RINGS,
    SHAPES,
    assert_stores_message_identical,
    star_catalog,
)


def _engines(cat, ring, use_plans=True):
    """(per-edge reference, level-fused) engine pair on separate stores."""
    jt = jt_from_catalog(cat)
    ref = CJTEngine(jt, cat, ring, store=MessageStore(), use_plans=False)
    fus = CJTEngine(
        jt, cat, ring, store=MessageStore(), use_plans=use_plans,
        batch_calibration=True, fuse_level_kernel=True,
    )
    return jt, ref, fus


# ---------------------------------------------------------------------------
# metamorphic parity: level-fused ≡ per-edge, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", sorted(RINGS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_level_fused_equals_per_edge(ring_name, shape):
    cat = SHAPES[shape](seed=3)
    measure = None if ring_name == "count" else ("F", "m")
    gamma = ("c",) if shape != "star" else ("c", "d")
    q = Query.make(cat, ring=ring_name, measure=measure, group_by=gamma)
    jt, ref, fus = _engines(cat, RINGS[ring_name])
    ref.calibrate(q, batch=False)
    fus.calibrate(q, batch=True)
    assert ref.is_calibrated(q) and fus.is_calibrated(q)
    assert_stores_message_identical(ref, fus, q)
    # one host dispatch per level, never more
    levels = max(len(jt.calibration_levels(b)) for b in jt.bags)
    assert 0 < fus.plans.stats.calibration_dispatches <= levels


@pytest.mark.parametrize("use_plans", [False, True])
def test_level_fused_plans_on_off(use_plans):
    """Plans off: the fuse flag is inert (no plan cache to fuse through) and
    the per-edge loop runs — results stay bit-identical either way."""
    cat = star_catalog(seed=5)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    _, ref, fus = _engines(cat, sr.SUM, use_plans=use_plans)
    ref.calibrate(q, batch=False)
    fus.calibrate(q)
    assert_stores_message_identical(ref, fus, q)
    if not use_plans:
        assert fus.plans is None


def test_fused_launch_counters():
    """A fused offline pass records ≥ 1 multi-segment launch covering > 1
    message, and the launch count never exceeds the dispatch count."""
    cat = star_catalog(seed=7)
    _, _, fus = _engines(cat, sr.SUM)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    fus.calibrate(q, batch=True)
    st = fus.plans.stats
    assert st.fused_level_launches >= 1, st
    assert st.fused_level_messages > st.fused_level_launches, (
        "a fused launch should cover several same-level messages"
    )
    assert st.fused_level_launches <= st.calibration_dispatches


def test_fused_vs_unfused_batched_identical():
    """Fused levels vs the (PR 5) batched-but-unfused path: same bits, and
    fusion never dispatches more often."""
    cat = star_catalog(seed=11)
    jt = jt_from_catalog(cat)
    q = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c", "d"))
    unf = CJTEngine(jt, cat, sr.SUM, store=MessageStore(),
                    batch_calibration=True, fuse_level_kernel=False)
    fus = CJTEngine(jt, cat, sr.SUM, store=MessageStore(),
                    batch_calibration=True, fuse_level_kernel=True)
    unf.calibrate(q, batch=True)
    fus.calibrate(q, batch=True)
    assert_stores_message_identical(unf, fus, q)
    assert fus.plans.stats.fused_level_launches > 0
    assert unf.plans.stats.fused_level_launches == 0
    assert (fus.plans.stats.calibration_dispatches
            <= unf.plans.stats.calibration_dispatches)


# ---------------------------------------------------------------------------
# MOMENTS through the kernel: stacked-leaf ≡ lax fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", ["moments", "sum"])
def test_kernel_vs_lax_fallback_parity(ring_name, monkeypatch):
    """Forcing the cost gate shut (REPRO_PLAN_KERNEL_COST=0) must not change
    a single bit — the stacked-leaf kernel and the lax segment path are
    ⊕-order-identical on exactly-representable data."""
    cat = star_catalog(seed=13)
    jt = jt_from_catalog(cat)
    q = Query.make(cat, ring=ring_name, measure=("F", "m"), group_by=("c",))
    monkeypatch.setenv("REPRO_PLAN_KERNEL_COST", str(1 << 30))
    ker = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore(),
                    batch_calibration=True, fuse_level_kernel=True)
    ker.calibrate(q, batch=True)
    assert ker.plans.stats.kernel_execs > 0, "kernel path not exercised"
    monkeypatch.setenv("REPRO_PLAN_KERNEL_COST", "0")
    lax = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore(),
                    batch_calibration=True, fuse_level_kernel=True)
    lax.calibrate(q, batch=True)
    assert lax.plans.stats.kernel_execs == 0
    assert lax.plans.stats.fallback_execs > 0
    assert_stores_message_identical(ker, lax, q)


def test_moments_rides_segment_kernel():
    """MOMENTS (compound (c, s, q) element) is kernel-eligible: its three
    equal-shape leaves stack as f32 columns through one segment launch."""
    cat = star_catalog(seed=17)
    _, _, fus = _engines(cat, sr.MOMENTS)
    q = Query.make(cat, ring="moments", measure=("F", "m"), group_by=("c",))
    fus.calibrate(q, batch=True)
    assert fus.plans.stats.kernel_execs > 0


# ---------------------------------------------------------------------------
# env gate + cost-profile resolution chain
# ---------------------------------------------------------------------------

def test_env_gate_fuse_level_kernel(monkeypatch):
    cat = star_catalog(seed=19)
    monkeypatch.setenv("REPRO_FUSE_LEVEL_KERNEL", "0")
    t = Treant(cat, ring=sr.SUM)
    assert not t.fuse_level_kernel and not t.engine.fuse_level_kernel
    monkeypatch.setenv("REPRO_FUSE_LEVEL_KERNEL", "1")
    t = Treant(cat, ring=sr.SUM)
    assert t.fuse_level_kernel and t.engine.fuse_level_kernel
    # explicit argument wins over the env
    t = Treant(cat, ring=sr.SUM, fuse_level_kernel=False)
    assert not t.engine.fuse_level_kernel
    # sibling engines inherit the flag
    assert t.engine_for("count", ("F", "m")).fuse_level_kernel is False


def test_kernel_cost_profile_resolution(monkeypatch, tmp_path):
    prof = tmp_path / "kernel_costs.json"
    prof.write_text(json.dumps(
        {"derived": {"plan_kernel_cost": 123456,
                     "calibration_union_budget": 777}}))
    monkeypatch.setenv(kernel_costs.PROFILE_ENV, str(prof))
    monkeypatch.delenv("REPRO_PLAN_KERNEL_COST", raising=False)
    monkeypatch.delenv("REPRO_CALIBRATION_UNION_BUDGET", raising=False)
    kernel_costs.reset_cache()
    try:
        assert kernel_costs.derived_plan_kernel_cost() == 123456
        assert kernel_costs.derived_union_budget() == 777
        # the plan gates default to the measured values ...
        assert plans_mod._kernel_cost_max() == 123456
        assert plans_mod.calibration_union_budget() == 777
        # ... but explicit env overrides always win
        monkeypatch.setenv("REPRO_PLAN_KERNEL_COST", "42")
        monkeypatch.setenv("REPRO_CALIBRATION_UNION_BUDGET", "64")
        assert plans_mod._kernel_cost_max() == 42
        assert plans_mod.calibration_union_budget() == 64
    finally:
        kernel_costs.reset_cache()


def test_kernel_cost_profile_disabled_and_malformed(monkeypatch, tmp_path):
    # "" disables the profile → historical static defaults
    monkeypatch.setenv(kernel_costs.PROFILE_ENV, "")
    monkeypatch.delenv("REPRO_PLAN_KERNEL_COST", raising=False)
    monkeypatch.delenv("REPRO_CALIBRATION_UNION_BUDGET", raising=False)
    kernel_costs.reset_cache()
    try:
        assert kernel_costs.load_profile() is None
        assert plans_mod._kernel_cost_max() == 1 << 19
        assert plans_mod.calibration_union_budget() == 512
        # malformed JSON / non-positive values degrade to None, not a crash
        bad = tmp_path / "bad.json"
        bad.write_text("{not json")
        monkeypatch.setenv(kernel_costs.PROFILE_ENV, str(bad))
        kernel_costs.reset_cache()
        assert kernel_costs.load_profile() is None
        neg = tmp_path / "neg.json"
        neg.write_text(json.dumps({"derived": {"plan_kernel_cost": -5}}))
        monkeypatch.setenv(kernel_costs.PROFILE_ENV, str(neg))
        kernel_costs.reset_cache()
        assert kernel_costs.derived_plan_kernel_cost() is None
    finally:
        kernel_costs.reset_cache()


# ---------------------------------------------------------------------------
# cache_stats aggregation (satellite 6 regression)
# ---------------------------------------------------------------------------

def test_cache_stats_max_fields_aggregation():
    """Multi-ring dashboards aggregate plan counters across sibling engines:
    width counters (PlanStats.MAX_FIELDS) take the max, everything else
    sums.  The old hardcoded tuple silently summed newly added width fields;
    the aggregation must now be driven by the declaration."""
    assert set(PlanStats.MAX_FIELDS) <= set(PlanStats().as_dict())
    cat = star_catalog(seed=23)
    t = Treant(cat, ring=sr.SUM, use_plans=True, batch_calibration=True,
               fuse_level_kernel=True)
    for ring_name, measure in [("sum", ("F", "m")), ("moments", ("F", "m")),
                               ("tropical_min", ("F", "m"))]:
        q = Query.make(cat, ring=ring_name, measure=measure, group_by=("c",))
        t.engine_for(ring_name, measure).calibrate(q, batch=True)
    engines = list(t._engines.values())
    assert len(engines) >= 3
    agg = t.cache_stats()["plans"]
    for field in PlanStats.MAX_FIELDS:
        assert agg[field] == max(e.plans.stats.as_dict()[field]
                                 for e in engines), field
    for field in ("calibration_dispatches", "fused_level_launches",
                  "fused_level_messages", "plans_built"):
        assert agg[field] == sum(e.plans.stats.as_dict()[field]
                                 for e in engines), field
    assert agg["fused_level_launches"] > 0


def test_fused_counters_survive_jit_cache_hits():
    """A second calibration of an identical-structure query hits the traced
    level plan (plan_hits) yet still counts its fused launches."""
    cat = star_catalog(seed=29)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore(),
                    batch_calibration=True, fuse_level_kernel=True)
    q1 = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("c",))
    q2 = Query.make(cat, ring="sum", measure=("F", "m"), group_by=("d",))
    eng.calibrate(q1, batch=True)
    first = eng.plans.stats.fused_level_launches
    assert first > 0
    eng.calibrate(q2, batch=True)
    assert eng.plans.stats.fused_level_launches >= first
