"""CJT engine correctness: execution vs einsum oracle, calibration invariant,
message reuse (Prop 2), Σ-compensation widening, versioned updates, removal."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CJTEngine, MessageStore, Query, jt_from_catalog
from repro.core import semiring as sr
from repro.core.factor import contract
from repro.relational import schema
from repro.relational.relation import mask_in


@pytest.fixture(scope="module")
def sf():
    cat = schema.salesforce(n_opp=3000, n_user=40, n_camp=15, n_acc=25, n_role=5)
    return cat, jt_from_catalog(cat)


def oracle(cat, keep, preds=(), measure=("Opp", "amount"), removed=()):
    factors = []
    for n in cat.names():
        if n in removed:
            continue
        fac = cat.get(n).to_factor(sr.SUM, measure[1] if n == measure[0] else None)
        for p in preds:
            if p.attr in fac.attrs:
                fac = fac.select(p.attr, jnp.asarray(p.mask))
        factors.append(fac)
    return contract(factors, keep)


def _close(f, o):
    np.testing.assert_allclose(
        np.asarray(f.project_to(o.attrs).field, np.float64),
        np.asarray(o.field, np.float64), rtol=1e-4, atol=1e-3)


def test_execute_group_by_and_filters(sf):
    cat, jt = sf
    eng = CJTEngine(jt, cat, sr.SUM)
    d = cat.domains()
    pred = mask_in(d["state"], [1, 2, 3], attr="state")
    q = Query.make(cat, ring="sum", measure=("Opp", "amount"),
                   group_by=("camp_type", "title"), predicates=[pred])
    f, _ = eng.execute(q)
    _close(f, oracle(cat, ("camp_type", "title"), (pred,)))


def test_every_root_gives_same_answer(sf):
    cat, jt = sf
    eng = CJTEngine(jt, cat, sr.SUM)
    q = Query.make(cat, ring="sum", measure=("Opp", "amount"), group_by=("role_name",))
    results = []
    for root in jt.bags:
        f = eng.absorb(q, root).project_to(("role_name",))
        results.append(np.asarray(f.field))
    for r in results[1:]:
        np.testing.assert_allclose(r, results[0], rtol=1e-4)


def test_calibration_invariant(sf):
    """§3.4.1: adjacent bags' absorptions agree on separators."""
    cat, jt = sf
    eng = CJTEngine(jt, cat, sr.SUM)
    q = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    eng.calibrate(q)
    assert eng.is_calibrated(q)
    assert eng.check_calibration(q)


def test_interaction_reuses_messages(sf):
    cat, jt = sf
    eng = CJTEngine(jt, cat, sr.SUM)
    q0 = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    eng.calibrate(q0)
    d = cat.domains()
    q1 = q0.with_predicate(mask_in(d["role_name"], [0], attr="role_name"))
    f, stats = eng.execute(q1)
    # the σ lands on the Role leaf; rooting there reuses everything
    assert stats.messages_computed == 0
    _close(f, oracle(cat, (), (q1.predicates[0],)))


def test_sigma_compensation_via_widening(sf):
    """Dropping a γ reuses the wider cached message by ⊕-marginalization."""
    cat, jt = sf
    store = MessageStore()
    eng = CJTEngine(jt, cat, sr.SUM, store=store)
    q_wide = Query.make(cat, ring="sum", measure=("Opp", "amount"), group_by=("title",))
    eng.calibrate(q_wide)
    store.reset_stats()
    q_narrow = q_wide.with_group_by()  # drop γ(title)
    f, stats = eng.execute(q_narrow)
    assert store.widen_hits > 0 or stats.messages_computed == 0
    _close(f, oracle(cat, ()))


def test_versioned_update_localizes_recompute(sf):
    cat, jt = sf
    eng = CJTEngine(jt, cat, sr.SUM)
    q0 = Query.make(cat, ring="sum", measure=("Opp", "amount"), group_by=("camp_type",))
    eng.calibrate(q0)
    camp2 = cat.get("Camp").perturb_measure("budget", 0.5, seed=3, version="v1")
    cat.put(camp2)
    q1 = q0.with_version("Camp", "v1")
    f, stats = eng.execute(q1)
    # budget isn't the measure — results identical; messages from Camp's
    # subtree still must be recomputed (signature changed)
    _close(f, oracle(cat, ("camp_type",)))
    assert stats.messages_computed <= len(jt.bags)


def test_removal(sf):
    cat, jt = sf
    eng = CJTEngine(jt, cat, sr.SUM)
    q = Query.make(cat, ring="sum", measure=("Opp", "amount"),
                   group_by=("camp_type",), removed=["Acc"])
    f, _ = eng.execute(q)
    _close(f, oracle(cat, ("camp_type",), removed={"Acc"}))


def test_lru_eviction_keeps_pinned(sf):
    cat, jt = sf
    store = MessageStore(max_bytes=1)  # evict everything unpinned
    eng = CJTEngine(jt, cat, sr.SUM, store=store)
    q = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    eng.calibrate(q, pin=True)
    assert len(store) == 2 * (len(jt.bags) - 1)  # pinned survive
    eng2 = CJTEngine(jt, cat, sr.SUM, store=MessageStore(max_bytes=1))
    eng2.calibrate(Query.make(cat, ring="sum", measure=("Opp", "amount")))
    assert len(eng2.store) == 0  # unpinned evicted


@pytest.mark.parametrize("ring_name,measure", [
    ("count", None),
    ("sum", ("Opp", "amount")),
    ("tropical_max", ("Opp", "amount")),
    ("moments", ("Opp", "amount")),
])
def test_rings_through_engine(sf, ring_name, measure):
    cat, jt = sf
    ring = sr.get(ring_name)
    eng = CJTEngine(jt, cat, ring)
    q = Query.make(cat, ring=ring_name, measure=measure, group_by=("camp_type",))
    f, _ = eng.execute(q)
    factors = [cat.get(n).to_factor(ring, measure[1] if measure and n == measure[0] else None)
               for n in cat.names()]
    want = contract(factors, ("camp_type",), ring)
    import jax
    for lx, ly in zip(jax.tree_util.tree_leaves(f.project_to(("camp_type",)).field),
                      jax.tree_util.tree_leaves(want.field)):
        np.testing.assert_allclose(np.asarray(lx, np.float64), np.asarray(ly, np.float64),
                                   rtol=1e-4, atol=1e-3)
