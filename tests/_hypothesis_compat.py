"""Thin fallback for ``hypothesis`` so the property-test modules always collect.

When the real package is installed (see requirements-dev.txt) it is re-exported
unchanged.  Otherwise a deterministic mini-implementation covers exactly the
subset this suite uses — ``@settings(max_examples=..., deadline=None)`` over
``@given(name=st.integers(lo, hi), ...)`` — by drawing ``max_examples``
seeded examples per test and running the body once for each.  No shrinking,
no database: failures print the drawn example so they can be replayed by hand.
"""

from __future__ import annotations

try:  # pragma: no cover — exercised when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw  # draw(rng) -> value

    class strategies:  # noqa: N801 — mimics the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            pool = list(elements)
            return _Strategy(lambda rng: pool[int(rng.integers(0, len(pool)))])

    class settings:  # noqa: N801
        def __init__(self, max_examples: int = 10, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._compat_max_examples = self.max_examples
            return fn

    def given(**strategy_kwargs):
        names = sorted(strategy_kwargs)  # fixed draw order for determinism

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", 10)
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = {k: strategy_kwargs[k]._draw(rng) for k in names}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception:
                        print(f"falsifying example ({i + 1}/{n}): {drawn}")
                        raise

            # hide the drawn parameters from pytest's fixture resolution,
            # exactly as real hypothesis does
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[p for k, p in sig.parameters.items() if k not in strategy_kwargs]
            )
            del wrapper.__wrapped__  # keep pytest off the original signature
            return wrapper

        return deco
