"""Per-architecture smoke tests (brief requirement): a REDUCED config of the
same family runs one forward/train step on CPU — output shapes + no NaNs.
The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import smoke_config
from repro.models import lm


def _batch(cfg, b=2, s=32, rng=None):
    rng = rng or np.random.default_rng(0)
    out = {"labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
    if cfg.input_mode == "embeddings":
        out["embeds"] = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
    else:
        out["tokens"] = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    if cfg.input_mode == "tokens+vision":
        out["vision"] = rng.standard_normal(
            (b, cfg.n_vision_tokens, cfg.d_model)
        ).astype(np.float32)
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    full = get_config(arch)
    cfg = smoke_config(full)
    assert cfg.family == full.family and cfg.pattern == full.pattern
    params = lm.init_params(cfg, 0)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.forward_train(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    logits, caches = jax.jit(lambda p, b: lm.forward_prefill(p, cfg, b))(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    # one optimizer step moves parameters
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.runtime.step import make_train_step
    opt_cfg = AdamWConfig(m_dtype="float32")
    opt = init_opt_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, donate=False)
    p2, _, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert moved, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """Pin the exact published hyperparameters from the brief."""
    cfg = get_config(arch)
    expect = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expect, (arch, got, expect)
    if arch == "dbrx-132b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 4)
    if arch == "granite-moe-1b-a400m":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (32, 8)
    if arch == "zamba2-1.2b":
        assert cfg.ssm.state == 64 and cfg.sub_quadratic
    if arch == "rwkv6-7b":
        assert cfg.sub_quadratic and cfg.pattern == "rwkv"
    if arch == "llama-3.2-vision-90b":
        assert cfg.pattern == "vlm"


def test_param_counts_plausible():
    """n_params() sits near the advertised sizes (sanity for MODEL_FLOPS)."""
    expect_b = {
        "dbrx-132b": (110, 150), "nemotron-4-340b": (300, 380),
        "deepseek-coder-33b": (28, 38), "stablelm-12b": (10, 14),
        "llama-3.2-vision-90b": (75, 100), "rwkv6-7b": (6, 9),
        "nemotron-4-15b": (13, 18),
    }
    for arch, (lo, hi) in expect_b.items():
        n = get_config(arch).n_params() / 1e9
        assert lo <= n <= hi, (arch, n)
    n_active = get_config("dbrx-132b").n_active_params() / 1e9
    assert 30 <= n_active <= 45, n_active
