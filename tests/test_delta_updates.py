"""Delta calibration: incremental CJT maintenance under data updates.

Metamorphic contract: for any sequence of appends/deletes,
``apply_delta(Δ)`` followed by a query must equal a from-scratch calibration
over the updated catalog — across SUM/COUNT/AVG(MOMENTS) rings and both
update kinds — while recomputing zero messages at query time.  Plus cache
correctness: version-bumped Prop-2 signatures mean no stale message can ever
serve a post-update query, and pre-update queries keep answering from their
own snapshot.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core import CJTEngine, MessageStore, Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in


RINGS = {"sum": sr.SUM, "count": sr.COUNT, "moments": sr.MOMENTS}


def _query(cat, ring_name, group_by=("carrier_group", "month")):
    measure = ("Flights", "dep_delay") if ring_name != "count" else None
    return Query.make(cat, ring=ring_name, measure=measure, group_by=group_by)


def _random_update(rel, rng):
    if rng.integers(2) == 0:
        n = int(rng.integers(1, 200))
        codes = {a: rng.integers(0, rel.domains[a], n) for a in rel.attrs}
        measures = {m: rng.gamma(1.5, 10.0, n).astype(np.float32) for m in rel.measures}
        return rel.append_rows(codes, measures=measures)
    return rel.delete_rows(rng.random(rel.num_rows) < 0.08)


def _assert_factors_close(got, want, rtol=2e-3, atol=5e-2):
    import jax

    for a, b in zip(jax.tree_util.tree_leaves(got.field),
                    jax.tree_util.tree_leaves(want.field)):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=rtol, atol=atol,
        )


@pytest.mark.slow
@pytest.mark.parametrize("ring_name", sorted(RINGS))
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_update_sequence_matches_rebuild(ring_name, seed):
    """update(Δ)* then query ≡ rebuild-from-scratch on the updated catalog."""
    rng = np.random.default_rng(seed)
    cat = schema.flight(n_flights=2_000, seed=seed % 5)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, RINGS[ring_name])
    q = _query(cat, ring_name)
    eng.calibrate(q)
    rel = cat.get("Flights")
    for _ in range(int(rng.integers(1, 4))):
        rel, delta = _random_update(rel, rng)
        cat.put(rel)
        q, stats = eng.apply_delta(q, delta)
        assert not stats.fallback
        assert stats.edges_maintained == len(jt.bags) - 1
    got, es = eng.execute(q)
    # every message is a cache hit: maintenance re-calibrated the CJT
    assert es.messages_computed == 0, es.recomputed_edges
    cold = CJTEngine(jt, cat, RINGS[ring_name], store=MessageStore())
    want, _ = cold.execute(_query(cat, ring_name))
    _assert_factors_close(got, want)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_update_with_predicates_matches_rebuild(seed):
    """Maintenance respects σ annotations placed anywhere in the tree."""
    rng = np.random.default_rng(seed)
    cat = schema.flight(n_flights=2_000, seed=seed % 3)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    d = cat.domains()
    q = _query(cat, "sum").with_predicate(
        mask_in(d["airport_state"], [int(v) for v in rng.choice(d["airport_state"], 10, replace=False)],
                attr="airport_state")
    ).with_predicate(
        mask_in(d["delay_bucket"], [0, 1, 2, 3], attr="delay_bucket")
    )
    eng.calibrate(q)
    rel = cat.get("Flights")
    for _ in range(2):
        rel, delta = _random_update(rel, rng)
        cat.put(rel)
        q, stats = eng.apply_delta(q, delta)
        assert not stats.fallback
    got, es = eng.execute(q)
    assert es.messages_computed == 0
    cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    want, _ = cold.execute(q)
    _assert_factors_close(got, want)


def test_append_then_delete_roundtrip():
    """Deleting exactly the appended rows restores the original answers (SUM)."""
    cat = schema.flight(n_flights=2_000)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    q0 = _query(cat, "sum")
    eng.calibrate(q0)
    base, _ = eng.execute(q0)
    rel = cat.get("Flights")
    rng = np.random.default_rng(3)
    n0 = rel.num_rows
    codes = {a: rng.integers(0, rel.domains[a], 64) for a in rel.attrs}
    rel1, d1 = rel.append_rows(codes, measures={"dep_delay": rng.gamma(1.5, 10.0, 64)})
    cat.put(rel1)
    q1, _ = eng.apply_delta(q0, d1)
    mask = np.zeros(rel1.num_rows, bool)
    mask[n0:] = True
    rel2, d2 = rel1.delete_rows(mask)
    cat.put(rel2)
    q2, _ = eng.apply_delta(q1, d2)
    back, es = eng.execute(q2)
    assert es.messages_computed == 0
    _assert_factors_close(back, base, rtol=1e-4, atol=1e-2)


def test_no_stale_signature_survives_update():
    """Prop-2 signature bumping: old and new snapshots never cross-contaminate."""
    cat = schema.flight(n_flights=2_000)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    q_old = _query(cat, "sum")
    eng.calibrate(q_old)
    placement = eng.place_predicates(q_old)
    old_answer, _ = eng.execute(q_old)

    rel = cat.get("Flights")
    rng = np.random.default_rng(9)
    codes = {a: rng.integers(0, rel.domains[a], 300) for a in rel.attrs}
    new_rel, delta = rel.append_rows(
        codes, measures={"dep_delay": np.full(300, 100.0, np.float32)}
    )
    cat.put(new_rel)
    q_new, stats = eng.apply_delta(q_old, delta)
    assert not stats.fallback

    u0 = jt.mapping["Flights"]
    placement_new = eng.place_predicates(q_new)
    for u, v in jt.directed_edges():
        sig_old = eng.edge_sig(q_old, u, v, placement)
        sig_new = eng.edge_sig(q_new, u, v, placement_new)
        if u0 in jt.subtree_bags(u, v):
            # changed messages live under bumped signatures
            assert sig_old != sig_new, (u, v)
        else:
            # untouched subtrees keep their signature — that's the reuse
            assert sig_old == sig_new, (u, v)
        assert eng.store.contains(eng.edge_sig(q_new, u, v, placement_new),
                                  eng.gamma_carry(q_new, u, v))

    # the new query sees the update, the old query still answers its snapshot
    new_answer, es = eng.execute(q_new)
    assert es.messages_computed == 0
    assert not np.allclose(np.asarray(new_answer.field), np.asarray(old_answer.field))
    old_again, _ = eng.execute(q_old)
    np.testing.assert_allclose(
        np.asarray(old_again.field), np.asarray(old_answer.field), rtol=1e-6
    )


def test_tropical_append_maintains_delete_falls_back():
    """MIN ring: appends combine via ⊕=min; deletes have no inverse → fallback."""
    cat = schema.flight(n_flights=1_500)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.TROPICAL_MIN)
    q = Query.make(cat, ring="tropical_min", measure=("Flights", "dep_delay"),
                   group_by=("carrier_group",))
    eng.calibrate(q)
    rel = cat.get("Flights")
    rng = np.random.default_rng(5)
    codes = {a: rng.integers(0, rel.domains[a], 40) for a in rel.attrs}
    rel1, d_app = rel.append_rows(codes, measures={"dep_delay": rng.gamma(1.5, 10.0, 40)})
    cat.put(rel1)
    q1, st_app = eng.apply_delta(q, d_app)
    assert not st_app.fallback
    got, es = eng.execute(q1)
    assert es.messages_computed == 0
    cold = CJTEngine(jt, cat, sr.TROPICAL_MIN, store=MessageStore())
    want, _ = cold.execute(q1)
    _assert_factors_close(got, want, rtol=1e-5, atol=1e-5)

    rel2, d_del = rel1.delete_rows(rng.random(rel1.num_rows) < 0.1)
    cat.put(rel2)
    q2, st_del = eng.apply_delta(q1, d_del)
    assert st_del.fallback and st_del.edges_maintained == 0
    # nothing stale: recompute-on-demand still yields the right answer
    got2, _ = eng.execute(q2)
    cold2 = CJTEngine(jt, cat, sr.TROPICAL_MIN, store=MessageStore())
    want2, _ = cold2.execute(q2)
    _assert_factors_close(got2, want2, rtol=1e-5, atol=1e-5)


def test_pinned_dashboard_messages_stay_pinned():
    """Maintained counterparts of pinned (dashboard) messages are pinned too."""
    cat = schema.flight(n_flights=1_500)
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    q = _query(cat, "sum")
    eng.calibrate(q, pin=True)
    rel = cat.get("Flights")
    rng = np.random.default_rng(2)
    codes = {a: rng.integers(0, rel.domains[a], 50) for a in rel.attrs}
    new_rel, delta = rel.append_rows(codes, measures={"dep_delay": rng.gamma(1.5, 10.0, 50)})
    cat.put(new_rel)
    q_new, stats = eng.apply_delta(q, delta)
    assert stats.edges_maintained == len(jt.bags) - 1
    placement = eng.place_predicates(q_new)
    placement_old = eng.place_predicates(q)
    u0 = jt.mapping["Flights"]
    for u, v in jt.directed_edges():
        if u0 in jt.subtree_bags(u, v):
            base = eng.edge_sig(q_new, u, v, placement)
            assert eng.store.is_pinned(base, eng.gamma_carry(q_new, u, v)), (u, v)
            # the pin migrated: the stale generation is evictable again
            old_base = eng.edge_sig(q, u, v, placement_old)
            assert not eng.store.is_pinned(old_base, eng.gamma_carry(q, u, v)), (u, v)


@pytest.mark.parametrize("weird", ["v0Δweird", "aΔbΔc", "Δ"])
def test_delta_version_derivation_with_delta_in_caller_version(weird):
    """Caller-supplied versions containing 'Δ' must round-trip: the old
    ``version.split('Δ', 1)[1]`` derivation found the caller's delimiter
    first and grafted garbage into the new version."""
    cat = schema.flight(n_flights=500)
    rel = cat.get("Flights").with_version(weird)
    rng = np.random.default_rng(2)
    codes = {a: rng.integers(0, rel.domains[a], 10) for a in rel.attrs}
    new_rel, delta = rel.append_rows(
        codes, measures={"dep_delay": np.ones(10, np.float32)}
    )
    assert delta is not None
    assert delta.old_version == weird
    # both versions extend the caller's version with ONE new suffix
    assert new_rel.version.startswith(weird + "+")
    assert delta.rows.version.startswith(weird + "Δ")
    assert new_rel.version[len(weird) + 1:] == delta.rows.version[len(weird) + 1:]
    assert delta.new_version == new_rel.version
    # and a delete chained on top still parses cleanly
    nxt, d2 = new_rel.delete_rows(np.arange(new_rel.num_rows) < 3)
    assert nxt.version.startswith(new_rel.version + "+")
    assert d2.rows.version.startswith(new_rel.version + "Δ")
    # maintenance through the weird chain stays exact
    jt = jt_from_catalog(cat)
    eng = CJTEngine(jt, cat, sr.SUM)
    cat.put(rel)
    q = _query(cat, "sum").with_version("Flights", weird)
    eng.calibrate(q)
    cat.put(new_rel)
    q, stats = eng.apply_delta(q, delta)
    assert not stats.fallback
    got, es = eng.execute(q)
    assert es.messages_computed == 0
    cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    want, _ = cold.execute(q)
    _assert_factors_close(got, want)


def test_zero_row_updates_short_circuit():
    """Empty appends/deletes are no-ops: same relation object back, no delta,
    no version bump — and Treant.update(rel, None) maintains nothing."""
    cat = schema.flight(n_flights=500)
    rel = cat.get("Flights")
    same, delta = rel.append_rows(
        {a: np.zeros(0, np.int32) for a in rel.attrs},
        measures={"dep_delay": np.zeros(0, np.float32)},
    )
    assert same is rel and delta is None
    same, delta = rel.delete_rows(np.zeros(rel.num_rows, bool))
    assert same is rel and delta is None
    # compacting a relation with no tombstones is equally free
    same, delta = rel.compact()
    assert same is rel and delta is None

    t = Treant(cat, ring=sr.SUM)
    t.register_dashboard("v1", _query(cat, "sum", group_by=("carrier_group",)))
    wm = t.catalog.watermark
    ver = t.catalog.latest_version("Flights")
    res = t.update(rel, None)
    assert res.queries_maintained == 0 and res.queries_fallback == 0
    assert res.stats == []
    assert t.catalog.watermark == wm, "empty update bumped the watermark"
    assert t.catalog.latest_version("Flights") == ver
    assert t.ingest.version_bumps == 0 and t.ingest.delta_sweeps == 0


def test_treant_update_end_to_end():
    """Treant.update maintains dashboards + sessions and serves fresh data
    at cache-hit speed; a cold Treant over the updated catalog agrees."""
    cat = schema.flight(n_flights=2_000)
    t = Treant(cat, ring=sr.SUM)
    q0 = _query(cat, "sum", group_by=("carrier_group",))
    t.register_dashboard("v1", q0)
    d = cat.domains()
    q1 = q0.with_predicate(mask_in(d["month"], [0, 1, 2], attr="month"))
    t.interact("s", "v1", q1)
    t.think_time("s", "v1")

    rel = cat.get("Flights")
    rng = np.random.default_rng(4)
    codes = {a: rng.integers(0, rel.domains[a], 120) for a in rel.attrs}
    new_rel, delta = rel.append_rows(
        codes, measures={"dep_delay": np.full(120, 77.0, np.float32)}
    )
    res = t.update(new_rel, delta)
    assert res.queries_fallback == 0 and res.queries_maintained >= 1

    r = t.read("s", "v1")
    assert r.stats.messages_computed == 0, r.stats.recomputed_edges
    cold = Treant(cat, ring=sr.SUM)
    cold.register_dashboard("v1", _query(cat, "sum", group_by=("carrier_group",)))
    cold.interact("s", "v1",
                  _query(cat, "sum", group_by=("carrier_group",)).with_predicate(
                      mask_in(d["month"], [0, 1, 2], attr="month")))
    want = cold.read("s", "v1")
    _assert_factors_close(r.factor, want.factor, rtol=1e-4, atol=1e-2)
