"""Prefetch/speculation bugfix sweep (ISSUE 6 satellites 1–3).

1. Capacity eviction must drop the *farthest-from-anchor* prefetch entries —
   the old policy popped dict-insertion order, which (speculate_filters being
   nearest-first) evicted precisely the candidates most likely to be hit.
2. ``Treant.update``/``flush`` must invalidate only prefetched results whose
   query can *see* the updated relation; entries on disjoint dimensions
   (relation in R̄) keep stable digests and stay servable.
3. ``speculate_filters`` must return exactly ``min(k, feasible)`` distinct
   in-domain candidates for ANY anchor — the old step-count termination
   guards were vacuous/premature at domain edges.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import (
    DashboardSpec,
    SetFilter,
    Treant,
    VizSpec,
    speculate_filters,
)
from repro.core import semiring as sr
from repro.relational.relation import Catalog, Relation


def star_catalog(n_fact: int = 300, seed: int = 0) -> Catalog:
    rng = np.random.default_rng(seed)
    doms = {"a": 13, "b": 7, "c": 10, "d": 5, "e": 9}

    def codes(attrs, n):
        return {x: rng.integers(0, doms[x], n).astype(np.int32) for x in attrs}

    f = Relation("F", ("a", "b"), codes(("a", "b"), n_fact), doms,
                 measures={"m": rng.integers(0, 16, n_fact).astype(np.float32)})
    s = Relation("S", ("b", "c"), codes(("b", "c"), 77), doms)
    t = Relation("T", ("a", "d"), codes(("a", "d"), 29), doms)
    u = Relation("U", ("b", "e"), codes(("b", "e"), 41), doms)
    return Catalog([f, s, t, u])


def star_spec(**viz_kwargs) -> DashboardSpec:
    return DashboardSpec(vizzes=(
        VizSpec("by_a", measure=("F", "m"), ring="sum", group_by=("a",)),
        VizSpec("by_c", measure=("F", "m"), ring="sum", group_by=("c",)),
        VizSpec("by_d", measure=("F", "m"), ring="sum", group_by=("d",),
                **viz_kwargs),
        VizSpec("by_e", measure=("F", "m"), ring="sum", group_by=("e",)),
    ))


# ---------------------------------------------------------------------------
# satellite 1: eviction keeps the nearest-to-anchor entries
# ---------------------------------------------------------------------------

def test_eviction_keeps_nearest_candidates():
    """speculate(3) over 3 linked vizzes parks 9 entries; capacity 3 must
    keep exactly the rank-0 (nearest window) entries, so a ±1-window re-brush
    is still a pure prefetch hit."""
    cat = star_catalog(seed=71)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(), name="s")
    sess.prefetch_capacity = 3
    ev = SetFilter("a", lo=4, hi=6, source="by_a")
    sess.apply(ev)
    sess.idle(speculate=3)
    assert len(sess._prefetched) == 3
    assert all(e.dist == 0 for e in sess._prefetched.values()), (
        "eviction dropped nearest-to-anchor entries"
    )
    nearest = speculate_filters(ev, 13, 3)[0]  # the adjacent window
    res = sess.apply(nearest)
    assert len(res.affected) == 3
    for viz in res.affected:
        s_ = res.results[viz].stats
        assert s_.prefetch_hits == 1 and s_.messages_computed == 0, (
            f"{viz}: ±1-window re-brush missed the prefetch cache"
        )
    sess.close()


def test_eviction_order_regression_vs_insertion_order():
    """Direct unit check of the policy: overshoot parks ranks [0,0,0,1,1,1,…]
    in insertion order; survivors must be the low ranks, not the early
    insertions' complement."""
    cat = star_catalog(seed=73)
    t = Treant(cat, ring=sr.SUM, use_plans=False)
    sess = t.open_session(star_spec(), name="s", calibrate=False)
    sess.prefetch_capacity = 4
    sess.apply(SetFilter("a", values=(5, 6), source="by_a"))
    sess.idle(speculate=4)
    dists = sorted(e.dist for e in sess._prefetched.values())
    assert len(dists) == 4
    # 3 vizzes × rank 0 survive plus the earliest rank-1 insertion
    assert dists == [0, 0, 0, 1]
    sess.close()


# ---------------------------------------------------------------------------
# satellite 2: updates invalidate only prefetches that can see the relation
# ---------------------------------------------------------------------------

def test_update_keeps_prefetch_on_disjoint_dimension():
    """A viz with U ∈ R̄ can never observe an update to U: its prefetched
    fan-out must survive the version bump (digest hashes effective versions
    only) while every U-seeing viz's entries are dropped."""
    cat = star_catalog(seed=79)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(removed=("U",)), name="s")
    ev = SetFilter("a", lo=2, hi=4, source="by_a")
    sess.apply(ev)
    sess.idle(speculate=1)
    entries = dict(sess._prefetched)
    blind = {k for k, e in entries.items() if "U" in e.query.removed}
    seeing = set(entries) - blind
    assert blind and seeing  # by_d is blind to U; by_c / by_e see it
    rng = np.random.default_rng(0)
    u = cat.get("U")
    new_u, delta = u.append_rows(
        {a: rng.integers(0, u.domains[a], 10).astype(np.int32) for a in u.attrs}
    )
    res = t.update(new_u, delta)
    assert res.queries_fallback == 0
    assert set(sess._prefetched) == blind, (
        "update invalidated the U-blind prefetch (or kept a U-seeing one)"
    )
    # the surviving entry is really served: re-brush hits without executing
    nearest = speculate_filters(ev, 13, 1)[0]
    res2 = sess.apply(nearest)
    s_d = res2.results["by_d"].stats
    assert s_d.prefetch_hits == 1 and s_d.messages_computed == 0
    # the U-seeing vizzes re-executed against the new version instead
    assert res2.results["by_c"].stats.prefetch_hits == 0
    sess.close()


def test_flush_invalidates_only_streamed_relation_prefetches():
    """Same selectivity through the streaming path: a flush tick touching U
    keeps the U-blind viz's entries."""
    cat = star_catalog(seed=83)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.0)
    sess = t.open_session(star_spec(removed=("U",)), name="s")
    sess.apply(SetFilter("a", lo=2, hi=4, source="by_a"))
    sess.idle(speculate=1)
    blind = {k for k, e in sess._prefetched.items() if "U" in e.query.removed}
    assert blind
    rng = np.random.default_rng(1)
    u = cat.get("U")
    t.stream("U").append(
        {a: rng.integers(0, u.domains[a], 6).astype(np.int32) for a in u.attrs}
    )
    t.flush()
    assert set(sess._prefetched) == blind
    sess.close()


# ---------------------------------------------------------------------------
# satellite 3: speculate_filters returns min(k, feasible) distinct candidates
# ---------------------------------------------------------------------------

def _range_feasible(lo: int, hi: int, domain: int) -> int:
    width = max(hi - lo, 1)
    pos = (domain - lo - 1) // width   # i ≥ 1 with lo + i·width < domain
    neg = (hi - 1) // width            # i ≥ 1 with hi − i·width > 0
    return pos + neg


@settings(max_examples=60, deadline=None)
@given(d=st.integers(0, 10_000), l=st.integers(0, 10_000),
       h=st.integers(0, 10_000), k=st.integers(0, 60))
def test_range_speculation_count_property(d, l, h, k):
    domain = 1 + d % 50
    lo = l % domain
    hi = lo + 1 + h % (domain - lo)
    ev = SetFilter("x", lo=lo, hi=hi)
    cands = speculate_filters(ev, domain, k)
    assert len(cands) == min(k, _range_feasible(lo, hi, domain)), (
        f"lo={lo} hi={hi} domain={domain} k={k}: "
        f"{[(c.lo, c.hi) for c in cands]}"
    )
    seen = set()
    for c in cands:
        assert 0 <= c.lo < c.hi <= domain
        assert (c.lo, c.hi) != (lo, hi)
        seen.add((c.lo, c.hi))
    assert len(seen) == len(cands), "duplicate candidates"


@settings(max_examples=60, deadline=None)
@given(d=st.integers(0, 10_000), v=st.integers(0, 10_000),
       w=st.integers(0, 10_000), k=st.integers(0, 60))
def test_in_list_speculation_count_property(d, v, w, k):
    domain = 2 + d % 40
    v0 = v % domain
    v1 = min(domain - 1, v0 + w % 3)
    vals = tuple(sorted({v0, v1}))
    span = vals[-1] - vals[0] + 1
    pos = (domain - 1 - vals[-1]) // span  # i ≥ 1 with vals[-1] + i·span < domain
    neg = vals[0] // span                  # i ≥ 1 with vals[0] − i·span ≥ 0
    cands = speculate_filters(SetFilter("x", values=vals), domain, k)
    assert len(cands) == min(k, pos + neg), (
        f"vals={vals} domain={domain} k={k}: {[c.values for c in cands]}"
    )
    seen = set()
    for c in cands:
        assert all(0 <= x < domain for x in c.values)
        assert c.values != vals
        seen.add(c.values)
    assert len(seen) == len(cands)


def test_speculation_domain_edge_regressions():
    """The concrete edge cases the old step-count guards got wrong."""
    # anchor at the high edge: positive direction dies instantly, but every
    # feasible negative window must still be produced (the old range guard
    # broke out of the loop before emitting them)
    cands = speculate_filters(SetFilter("x", lo=6, hi=8), 10, 10)
    assert [(c.lo, c.hi) for c in cands] == [(8, 10), (4, 6), (2, 4), (0, 2)]
    # clipped positive edge window is feasible and emitted once
    cands = speculate_filters(SetFilter("x", lo=3, hi=7), 9, 10)
    assert [(c.lo, c.hi) for c in cands] == [(7, 9), (0, 3)]
    # IN-list at the high edge: the old ``abs(step·span) > domain`` guard was
    # vacuous for the positive direction (it kept stepping past the domain)
    cands = speculate_filters(SetFilter("x", values=(8, 9)), 10, 10)
    assert [c.values for c in cands] == [(6, 7), (4, 5), (2, 3), (0, 1)]
    # both directions immediately infeasible → empty, and terminates
    assert speculate_filters(SetFilter("x", lo=0, hi=10), 10, 5) == []
    assert speculate_filters(SetFilter("x", values=(0, 9)), 10, 5) == []
    # k=0 never emits
    assert speculate_filters(SetFilter("x", lo=2, hi=4), 10, 0) == []


def test_speculation_candidates_are_nearest_first():
    cands = speculate_filters(SetFilter("x", lo=4, hi=6), 12, 6)
    dist = [abs(c.lo - 4) for c in cands]
    assert dist == sorted(dist)


# ---------------------------------------------------------------------------
# ISSUE 10 satellite: speculation (and fan-out) after ToggleRelation removed
# the relation carrying the anchored brush dimension
# ---------------------------------------------------------------------------

def test_speculation_skips_viz_that_no_longer_sees_brush_dim():
    """Attr "c" lives only in relation S.  After ``ToggleRelation("S",
    viz="by_e")`` the anchored σ(c) is unplaceable for by_e — background
    speculation used to crash with ``KeyError("σ(c) not available in bag")``
    and could park poisoned entries.  It must skip by_e (and the fan-out
    must serve by_e *unfiltered*, per crossfilter semantics)."""
    from repro.core import ToggleRelation

    cat = star_catalog(seed=89)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(), name="s")
    ev = SetFilter("c", lo=3, hi=6, source="by_c")
    sess.apply(ev)
    res = sess.apply(ToggleRelation("S", viz="by_e"))
    # by_e re-renders without the now-invisible σ(c)
    assert res.affected == ("by_e",)
    assert not t.sees_attr(sess.derive("by_e"), "c")
    # speculation must neither crash nor park entries for by_e
    sess.idle(speculate=2)
    assert sess._prefetched, "speculation produced nothing at all"
    assert all(viz != "by_e" for viz, _ in sess._prefetched), (
        "speculation parked an entry for a viz that cannot see the brush dim"
    )
    # the surviving candidates still serve: nearest re-brush is a pure hit
    nearest = speculate_filters(ev, 10, 1)[0]
    res2 = sess.apply(nearest)
    for viz in ("by_a", "by_d"):
        assert res2.results[viz].stats.prefetch_hits == 1
    sess.close()


def test_toggle_unfiltered_viz_matches_cold_execution():
    """The σ-dropped derivation is bit-identical to a fresh session that
    toggled the relation without ever brushing the dimension."""
    import jax.numpy as jnp
    from repro.core import ToggleRelation

    cat = star_catalog(seed=97)
    t = Treant(cat, ring=sr.SUM, use_plans=True)
    sess = t.open_session(star_spec(), name="s")
    sess.apply(SetFilter("c", lo=2, hi=5, source="by_c"))
    warm = sess.apply(ToggleRelation("S", viz="by_e")).results["by_e"]
    t2 = Treant(star_catalog(seed=97), ring=sr.SUM, use_plans=True)
    s2 = t2.open_session(star_spec(), name="s2")
    cold = s2.apply(ToggleRelation("S", viz="by_e")).results["by_e"]
    assert warm.factor.attrs == cold.factor.attrs
    assert jnp.array_equal(warm.factor.field, cold.factor.field)
    sess.close()
    s2.close()
