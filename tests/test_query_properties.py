"""Hypothesis property tests on the Query IR and engine invariants under
random interaction sequences — the paper's correctness contract: any sequence
of cached interactions returns exactly what a cold engine returns."""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import CJTEngine, MessageStore, Query, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in


@pytest.fixture(scope="module")
def world():
    cat = schema.salesforce(n_opp=2_000, n_user=25, n_camp=10, n_acc=15, n_role=4)
    return cat, jt_from_catalog(cat)


ATTRS = ["role_name", "title", "camp_type", "state", "start_q", "stage"]
GROUPS = ["camp_type", "title", "state", "role_name"]


def _random_query(cat, rng, base=None):
    q = base or Query.make(cat, ring="sum", measure=("Opp", "amount"))
    d = cat.domains()
    for _ in range(rng.integers(0, 3)):
        a = ATTRS[rng.integers(len(ATTRS))]
        vals = rng.choice(d[a], size=max(1, d[a] // 3), replace=False)
        q = q.with_predicate(mask_in(d[a], [int(v) for v in vals], attr=a))
    gb = [GROUPS[i] for i in range(len(GROUPS)) if rng.integers(2)]
    return q.with_group_by(*gb[:2])


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_interaction_sequence_matches_cold_engine(world, seed):
    """Warm-cache execution over a random interaction path ≡ cold execution."""
    cat, jt = world
    rng = np.random.default_rng(seed)
    warm = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    q = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    warm.calibrate(q)
    for _ in range(3):
        q = _random_query(cat, rng, q)
        f_warm, _ = warm.execute(q)
        cold = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
        f_cold, _ = cold.execute(q)
        np.testing.assert_allclose(
            np.asarray(f_warm.project_to(q.group_by).field, np.float64),
            np.asarray(f_cold.project_to(q.group_by).field, np.float64),
            rtol=1e-4, atol=1e-3,
        )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_query_digest_is_content_addressed(world, seed):
    cat, _ = world
    rng = np.random.default_rng(seed)
    q1 = _random_query(cat, rng)
    q2 = _random_query(cat, np.random.default_rng(seed))  # same stream
    assert q1.digest == q2.digest
    d = cat.domains()
    q3 = q1.with_predicate(mask_in(d["stage"], [0], attr="stage"))
    assert q3.digest != q1.digest
    # predicate replacement on the same attr is idempotent in digest
    q4 = q3.with_predicate(mask_in(d["stage"], [0], attr="stage"))
    assert q4.digest == q3.digest


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_group_by_permutation_invariance(world, seed):
    """γ order affects output axis order only, never values."""
    cat, jt = world
    rng = np.random.default_rng(seed)
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    base = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    f1, _ = eng.execute(base.with_group_by("camp_type", "title"))
    f2, _ = eng.execute(base.with_group_by("title", "camp_type"))
    np.testing.assert_allclose(
        np.asarray(f1.field, np.float64),
        np.asarray(f2.project_to(("camp_type", "title")).field, np.float64),
        rtol=1e-4,
    )


def test_marginalization_consistency_over_predicates(world):
    """Σ_A of a γ=A query equals the γ=∅ query under any shared σ."""
    cat, jt = world
    d = cat.domains()
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    pred = mask_in(d["state"], [1, 2, 5], attr="state")
    q0 = Query.make(cat, ring="sum", measure=("Opp", "amount"), predicates=[pred])
    qA = q0.with_group_by("camp_type")
    f0, _ = eng.execute(q0)
    fA, _ = eng.execute(qA)
    np.testing.assert_allclose(
        float(np.asarray(fA.field, np.float64).sum()),
        float(np.asarray(f0.field, np.float64)), rtol=1e-5)


def test_disjoint_selection_partition(world):
    """σ(A∈S) + σ(A∈S̄) partitions the unfiltered total (semiring linearity)."""
    cat, jt = world
    d = cat.domains()
    eng = CJTEngine(jt, cat, sr.SUM, store=MessageStore())
    base = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    half = list(range(d["title"] // 2))
    rest = list(range(d["title"] // 2, d["title"]))
    f_all, _ = eng.execute(base)
    f_a, _ = eng.execute(base.with_predicate(mask_in(d["title"], half, attr="title")))
    f_b, _ = eng.execute(base.with_predicate(mask_in(d["title"], rest, attr="title")))
    np.testing.assert_allclose(
        float(np.asarray(f_a.field)) + float(np.asarray(f_b.field)),
        float(np.asarray(f_all.field)), rtol=1e-5)
