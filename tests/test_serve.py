"""TreantServer (ISSUE 8): multi-tenant serving-tier invariants.

The correctness spine: N sessions served through one ``TreantServer`` —
with micro-batching, coalescing, cross-session batched fan-out, a shared
prefetch pool and a global store byte budget — must produce per-session
results **bit-identical** to the same event sequences applied serially on
private single-session Treants.  Everything the server shares (messages,
vmapped dispatches, deduped executions, evicted-and-recomputed entries) is
an optimization, never a semantic.

Plus the concurrency satellites: watermark reads stay un-torn across
server-driven background flushes, ``commit_log`` trims only unpinned
snapshots, eviction never drops pinned/in-flight entries, one session's
close never drops store entries a sibling still references, and
per-relation compaction thresholds follow the learned delete mix.
"""

import numpy as np
import pytest

import repro.core  # noqa: F401 — import order (core before relational)
from repro.core import DashboardSpec, Treant, VizSpec
from repro.core import semiring as sr
from repro.core.dashboard import ClearFilter, SetFilter, Undo
from repro.relational.relation import Catalog, Relation
from repro.serve import QueueFull, TreantServer

from test_stream_ingest import (
    assert_factors_identical,
    fact_batch,
    spec_for,
    star_catalog,
)


def brush(lo: int, hi: int) -> SetFilter:
    return SetFilter(attr="a", lo=lo, hi=hi, source="by_c")


def drain(server: TreantServer) -> None:
    while server.queue_depth:
        server.step()


# ---------------------------------------------------------------------------
# cross-session batched fan-out ≡ serial per-session apply (bit-identical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring_name", ["sum", "tropical_min", "moments"])
def test_cross_session_fanout_matches_serial_apply(ring_name):
    """8 sessions over one shared spec, brushing a mix of shared and distinct
    σ values, drained through cross-session micro-batches: every session's
    every viz must equal a serial apply of its own event on a private
    Treant — and at least one dispatch must have served >1 session."""
    spec = spec_for(ring_name)
    t = Treant(star_catalog(), ring=sr.get(ring_name), use_plans=True)
    server = TreantServer(t)
    events = [brush(i % 4, i % 4 + 3) for i in range(8)]  # 4 shared σ, 2 each
    handles = [server.open_session(spec, name=f"s{i}") for i in range(8)]
    for h, ev in zip(handles, events):
        h.submit(ev)
    drain(server)
    assert t.cache_stats()["serve"]["cross_session_batch_width"] > 1
    for h, ev in zip(handles, events):
        ref_t = Treant(star_catalog(), ring=sr.get(ring_name), use_plans=True)
        ref = ref_t.open_session(spec, name="ref")
        ref.apply(ev)
        for viz in ("by_c", "by_d"):
            assert_factors_identical(
                h.read(viz).factor, ref.read(viz).factor
            )


def test_followup_brushes_and_multi_event_sequences_match_serial():
    """Several batches deep (brush → re-brush → clear → undo), per-session
    state stays exactly what a serial apply loop would produce."""
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t)
    seqs = {
        "s0": [brush(0, 3), brush(4, 7), ClearFilter(attr="a")],
        "s1": [brush(2, 5), Undo(), brush(6, 9)],
        "s2": [brush(0, 3), brush(0, 3)],  # idempotent re-brush
    }
    handles = {sid: server.open_session(spec, name=sid) for sid in seqs}
    # interleave: one event per session per round, drained between rounds
    for rnd in range(3):
        for sid, seq in seqs.items():
            if rnd < len(seq):
                handles[sid].submit(seq[rnd])
        drain(server)
    for sid, seq in seqs.items():
        ref_t = Treant(star_catalog(), use_plans=True)
        ref = ref_t.open_session(spec, name="ref")
        for ev in seq:
            ref.apply(ev)
        for viz in ("by_c", "by_d"):
            assert_factors_identical(
                handles[sid].read(viz).factor, ref.read(viz).factor
            )


# ---------------------------------------------------------------------------
# event queue: coalescing, fairness, backpressure
# ---------------------------------------------------------------------------

def test_superseded_events_coalesce_and_are_never_executed():
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t)
    h = server.open_session(spec, name="s")
    for lo in range(5):  # five brush positions queued back-to-back
        h.submit(brush(lo, lo + 3))
    assert server.queue_depth == 1, "stale brush positions must coalesce away"
    assert server.stats_.coalesced_events == 4
    drain(server)
    # only the LAST position was executed
    ref_t = Treant(star_catalog(), use_plans=True)
    ref = ref_t.open_session(spec, name="ref")
    ref.apply(brush(4, 7))
    assert_factors_identical(h.read("by_d").factor, ref.read("by_d").factor)
    assert server.stats_.events_processed == 1


def test_queued_undo_blocks_coalescing():
    """Each applied event pushes an undo snapshot, so once an Undo is queued
    the earlier brush must NOT be coalesced away (it changes what the Undo
    reverts to)."""
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t)
    h = server.open_session(spec, name="s")
    h.submit(brush(0, 3))
    h.submit(Undo())
    h.submit(brush(4, 7))
    assert server.queue_depth == 3
    drain(server)
    ref_t = Treant(star_catalog(), use_plans=True)
    ref = ref_t.open_session(spec, name="ref")
    for ev in (brush(0, 3), Undo(), brush(4, 7)):
        ref.apply(ev)
    assert_factors_identical(h.read("by_d").factor, ref.read("by_d").factor)


def test_micro_batch_fairness_one_event_per_session():
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t)
    ha = server.open_session(spec, name="a")
    hb = server.open_session(spec, name="b")
    # a bursty session queues order-sensitive events (no coalescing)
    from repro.core.dashboard import Drill, Rollup
    ha.submit(Drill(viz="by_c", attr="d"))
    ha.submit(Rollup(viz="by_c", attr="d"))
    ha.submit(Drill(viz="by_c", attr="e"))
    hb.submit(brush(0, 3))
    n = server.step()
    # first batch: one event from each session, not three from the burster
    assert n == 2
    assert server.stats_.batches == 1
    drain(server)
    assert server.stats_.events_processed == 4


def test_backpressure_reject_raises_and_drain_makes_room():
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t, max_queue=2, backpressure="reject")
    h = server.open_session(spec, name="s")
    from repro.core.dashboard import Drill
    h.submit(Drill(viz="by_c", attr="d"))
    h.submit(Drill(viz="by_c", attr="e"))
    with pytest.raises(QueueFull):
        h.submit(Drill(viz="by_d", attr="e"))
    assert server.stats_.rejected_events == 1
    drain(server)

    t2 = Treant(star_catalog(), use_plans=True)
    server2 = TreantServer(t2, max_queue=2, backpressure="drain")
    h2 = server2.open_session(spec, name="s")
    h2.submit(Drill(viz="by_c", attr="d"))
    h2.submit(Drill(viz="by_c", attr="e"))
    h2.submit(Drill(viz="by_d", attr="e"))  # forces a synchronous drain
    assert server2.stats_.backpressure_drains == 1
    assert server2.queue_depth <= 2
    drain(server2)
    assert server2.stats_.events_processed == 3


# ---------------------------------------------------------------------------
# global byte budget: priority eviction, pinned/in-flight exemption,
# bit-identical recomputation
# ---------------------------------------------------------------------------

def _run_brush_storm(max_store_bytes=None, sessions=6, ring_name="sum"):
    spec = spec_for(ring_name)
    t = Treant(star_catalog(), ring=sr.get(ring_name), use_plans=True)
    server = TreantServer(t, max_store_bytes=max_store_bytes)
    handles = [server.open_session(spec, name=f"s{i}") for i in range(sessions)]
    for rnd in range(4):
        for i, h in enumerate(handles):
            h.submit(brush((rnd + i) % 9, (rnd + i) % 9 + 3))
        drain(server)
    return t, server, handles


def test_byte_budget_stays_under_budget_and_reads_bit_identical():
    # unbudgeted footprint first
    t_free, _, free_handles = _run_brush_storm(None)
    unbudgeted = t_free.store.nbytes
    refs = {
        (h.id, viz): h.read(viz).factor
        for h in free_handles for viz in ("by_c", "by_d")
    }
    t, server, handles = _run_brush_storm(max_store_bytes=unbudgeted // 2)
    store = t.store
    assert store.evictions > 0, "a 50% budget must actually evict"
    # pinned entries are the floor no budget may cross; above it, the store
    # must respect the budget once every dispatch has closed
    assert store.nbytes - store.pinned_nbytes <= store.max_bytes
    for sig in store._pinned:
        assert sig in store._data, f"pinned entry {sig} was evicted"
    assert store._inflight_depth == 0 and not store._inflight
    # every read recomputes evicted entries on demand, bit-identically
    for h in handles:
        for viz in ("by_c", "by_d"):
            assert_factors_identical(
                h.read(viz).factor, refs[(h.id, viz)]
            )


def test_inflight_entries_survive_eviction_inside_a_dispatch():
    """Force a budget so tight every put overflows: the messages a dispatch
    itself just materialized (in-flight) must not be evicted out from under
    it — the dispatch completes and returns the correct result."""
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    ref_t = Treant(star_catalog(), use_plans=True)
    ref = ref_t.open_session(spec, name="ref")
    server = TreantServer(t, max_store_bytes=1)  # absurdly tight
    h = server.open_session(spec, name="s")
    h.submit(brush(2, 5))
    drain(server)
    ref.apply(brush(2, 5))
    for viz in ("by_c", "by_d"):
        assert_factors_identical(h.read(viz).factor, ref.read(viz).factor)


# ---------------------------------------------------------------------------
# Session.close under sharing: consumer refcounts
# ---------------------------------------------------------------------------

def test_close_does_not_drop_entries_a_live_session_references():
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t)
    ha = server.open_session(spec, name="a")
    hb = server.open_session(spec, name="b")
    # a brushes first (produces the σ messages), b brushes the same σ later
    # (per-viz dispatch so b genuinely HITS a's tagged entries)
    ha.submit(brush(1, 4))
    drain(server)
    hb.submit(brush(1, 4))
    drain(server)
    owned_by_a = {
        s for s, owner in t.store._producer.items() if owner.startswith("a:")
    }
    shared = {s for s in owned_by_a if "b" in t.store._users.get(s, set())}
    assert shared, "b must have been recorded as a consumer of a's entries"
    ha.close()
    for sig in shared:
        assert sig in t.store._data, (
            "closing the producer dropped an entry a live session references"
        )
        assert t.store._producer[sig].startswith("b:"), (
            "ownership must pass to the surviving reader"
        )
    # warm re-read for b: no recomputation of the shared messages
    r = hb.read("by_d")
    assert r.stats.messages_computed == 0
    # now b closes too: with no surviving reader the entries finally drop
    hb.close()
    for sig in shared:
        assert sig not in t.store._data


def test_interleaved_open_close_cycles_stay_consistent():
    """Open/close churn with shared brushes: reads on live sessions stay
    bit-identical to serial, pins never leak, producers never dangle."""
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t)
    ref_t = Treant(star_catalog(), use_plans=True)
    ref = ref_t.open_session(spec, name="ref")
    ref.apply(brush(3, 6))
    live = {}
    for cycle in range(4):
        sid = f"g{cycle}"
        live[sid] = server.open_session(spec, name=sid)
        live[sid].submit(brush(3, 6))
        drain(server)
        if cycle % 2 == 1:  # close the *previous* session, keep this one
            prev = f"g{cycle - 1}"
            live.pop(prev).close()
        for h in live.values():
            assert_factors_identical(
                h.read("by_d").factor, ref.read("by_d").factor
            )
    for h in list(live.values()):
        h.close()
    assert len(server.sessions) == 0
    # no dangling producer tags for closed sessions' entries
    closed = {f"g{c}:" for c in range(4)}
    for sig, owner in t.store._producer.items():
        assert sig in t.store._data
        assert not any(owner.startswith(p) for p in closed) or sig in t.store._pinned


# ---------------------------------------------------------------------------
# commit_log retention + snapshot-read pinning
# ---------------------------------------------------------------------------

def test_commit_log_trims_unpinned_but_keeps_pinned_snapshots():
    cat = star_catalog()
    cat.commit_retention = 8
    t = Treant(cat, use_plans=False, compaction_threshold=0.0)
    rng = np.random.default_rng(3)
    # pin the snapshot an imaginary long-running reader holds
    pinned_wm = cat.pin_watermark()
    pinned_snapshot = dict(cat._latest)
    for _ in range(20):
        buf = t.stream("F")
        codes, meas = fact_batch(rng, cat, 5)
        buf.append(codes, measures=meas)
        t.flush()
    # retention exceeded, but the pinned snapshot (and everything after it)
    # must survive
    logged = {wm: snap for wm, snap in cat.commit_log}
    assert pinned_wm in logged and logged[pinned_wm] == pinned_snapshot
    assert len(cat.commit_log) > cat.commit_retention
    cat.release_watermark(pinned_wm)
    assert len(cat.commit_log) <= cat.commit_retention
    assert pinned_wm not in dict(cat.commit_log)


def test_snapshot_read_context_and_refcounted_pins():
    cat = star_catalog()
    cat.commit_retention = 2
    t = Treant(cat, use_plans=False, compaction_threshold=0.0)
    rng = np.random.default_rng(4)
    with cat.snapshot_read() as (wm, versions):
        w2 = cat.pin_watermark(wm)  # second holder of the same mark
        assert w2 == wm
        for _ in range(6):
            buf = t.stream("F")
            codes, meas = fact_batch(rng, cat, 5)
            buf.append(codes, measures=meas)
            t.flush()
        assert wm in dict(cat.commit_log)
        assert dict(cat.commit_log)[wm] == versions
    # context exited but the second pin still holds
    assert wm in dict(cat.commit_log)
    cat.release_watermark(wm)
    assert wm not in dict(cat.commit_log)
    assert len(cat.commit_log) <= cat.commit_retention


def test_server_sessions_pin_their_read_watermark_across_ticks():
    spec = spec_for("sum")
    cat = star_catalog()
    cat.commit_retention = 2
    t = Treant(cat, use_plans=True, compaction_threshold=0.0)
    server = TreantServer(t)
    h = server.open_session(spec, name="s")
    opened_at = h._pinned_wm
    rng = np.random.default_rng(5)
    for _ in range(5):
        buf = t.stream("F")
        codes, meas = fact_batch(rng, cat, 5)
        buf.append(codes, measures=meas)
        t.flush()  # caller-thread flush: the session does not participate
    assert opened_at in dict(cat.commit_log), (
        "trimming dropped the snapshot a server session still holds"
    )
    # the session interacts → its pin advances, the old snapshot trims
    h.submit(brush(0, 3))
    drain(server)
    assert h._pinned_wm == cat.watermark
    assert opened_at not in dict(cat.commit_log)
    h.close()


# ---------------------------------------------------------------------------
# per-relation compaction thresholds (learned delete mix)
# ---------------------------------------------------------------------------

def test_compaction_policy_learns_per_relation_delete_mix():
    from repro.relational.stream import CompactionPolicy

    pol = CompactionPolicy()
    base = 0.25
    assert pol.threshold("F", base) == base  # no observations yet
    for _ in range(8):
        pol.observe("heavy", n_app=1, n_del=9)   # delete-heavy
        pol.observe("light", n_app=9, n_del=1)   # append-mostly
    assert pol.threshold("heavy", base) < base < pol.threshold("light", base)
    assert pol.threshold("heavy", base) >= base * 0.5
    assert pol.threshold("light", base) <= min(0.9, base * 1.5)
    assert pol.threshold("anything", 0.0) == 0.0  # disabled stays disabled


def test_delete_heavy_relation_compacts_earlier_than_append_mostly():
    """Same tombstone fraction, different learned mixes: the delete-heavy
    relation crosses its (tightened) threshold first."""
    cat = star_catalog(n_fact=400)
    t = Treant(cat, ring=sr.SUM, use_plans=False, compaction_threshold=0.25)
    rng = np.random.default_rng(9)
    compacted: dict[str, int] = {}
    for tick in range(12):
        buf = t.stream("F")
        # delete-heavy mix on F: few appends, many deletes
        codes, meas = fact_batch(rng, cat, 4)
        buf.append(codes, measures=meas)
        live = np.flatnonzero(buf.base._materialized_weights() != 0.0)
        mask = np.zeros(buf.base.num_rows + buf.pending_appends, bool)
        mask[rng.choice(live, 20, replace=False)] = True
        buf.delete(mask)
        res = t.flush()
        for c in res.compactions:
            compacted.setdefault(c.relation, tick)
    assert "F" in compacted, "delete-heavy relation never compacted"
    thr = t.compaction_policy.threshold("F", t.compaction_threshold)
    assert thr < t.compaction_threshold, (
        "learned threshold should be tighter than the base for delete-heavy"
    )


# ---------------------------------------------------------------------------
# server-driven think-time: background flush, scheduler drain, shared pool
# ---------------------------------------------------------------------------

def test_idle_runs_background_flush_and_unturn_watermark_reads():
    """Streaming ingest moves off the caller thread: events + idle() ticks
    interleave, and every session's post-tick read equals a cold rebuild
    over the committed versions (no torn/stale state)."""
    spec = spec_for("sum")
    cat = star_catalog()
    t = Treant(cat, use_plans=True, compaction_threshold=0.0)
    server = TreantServer(t)
    handles = [server.open_session(spec, name=f"s{i}") for i in range(3)]
    rng = np.random.default_rng(11)
    for rnd in range(3):
        buf = t.stream("F")
        codes, meas = fact_batch(rng, cat, 10)
        buf.append(codes, measures=meas)
        for i, h in enumerate(handles):
            h.submit(brush((rnd + i) % 6, (rnd + i) % 6 + 3))
        drain(server)
        assert buf.has_pending  # nothing flushed on the event path
        server.idle()           # ← background tick happens HERE
        assert not buf.has_pending
        for h in handles:
            q = h.query_of("by_d")
            assert q.version_of("F") == cat.latest_version("F")
            eng = t.engine_for(q.ring_name, q.measure)
            cold = Treant(
                Catalog([cat.get(n) for n in cat.names()]), use_plans=False
            )
            ref, _ = cold.engine.execute(
                q.with_version("F", cat.latest_version("F"))
            )
            assert_factors_identical(h.read("by_d").factor, ref)
    assert server.stats_.background_flushes == 3


def test_idle_drains_think_time_and_shared_pool_serves_sibling_sessions():
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t, speculate=4)
    ha = server.open_session(spec, name="a")
    hb = server.open_session(spec, name="b")
    ha.submit(brush(3, 6))
    drain(server)
    server.idle()  # speculate around a's brush → shared pool
    assert len(server._pool) > 0
    # b brushes a NEIGHBOR window a never executed — (6,9) is a's first
    # speculation candidate (ranges shift by whole widths) — and is served
    # from the pool that a's think-time filled
    before = server.stats_.shared_prefetch_hits
    hb.submit(brush(6, 9))
    drain(server)
    assert server.stats_.shared_prefetch_hits > before
    # and the pool-served result is still bit-identical to serial
    ref_t = Treant(star_catalog(), use_plans=True)
    ref = ref_t.open_session(spec, name="ref")
    ref.apply(brush(6, 9))
    assert_factors_identical(hb.read("by_d").factor, ref.read("by_d").factor)


def test_pool_eviction_keeps_just_hit_entry_fifo_would_drop():
    """Regression: the shared pool evicted in plain insertion order, so the
    OLDEST entry went first even when it was the one just served to a
    sibling session.  A hit must refresh recency: after overflowing the
    pool, the just-hit digest survives while cold never-read entries go."""
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t, speculate=4, pool_capacity=4)
    ha = server.open_session(spec, name="a")
    hb = server.open_session(spec, name="b")
    ha.submit(brush(3, 6))
    drain(server)
    server.idle()  # a's speculations fill the pool (oldest first)
    assert len(server._pool) > 0
    oldest = next(iter(server._pool))  # insertion-oldest = FIFO's victim
    # (6,9) is a's first speculation candidate for brush(3,6) — the oldest
    # pool entry — and b hits it
    hb.submit(brush(6, 9))
    drain(server)
    assert server.stats_.shared_prefetch_hits > 0
    hit_digest = [d for d, p in server._pool.items() if p.hot]
    assert hit_digest == [oldest]  # b hit exactly the FIFO victim
    # now overflow the pool: more speculation around new brushes
    ha.submit(brush(0, 3))
    hb.submit(brush(12, 15))
    drain(server)
    server.idle()
    assert server.stats_.pool_evictions > 0
    assert oldest in server._pool  # FIFO would have popped it first


def test_pool_eviction_orders_by_cost_and_never_drops_hot_entries():
    """Unit check of the eviction policy itself: cheapest non-hot entry of
    the cold window goes first; hot (hit-this-batch) entries are exempt even
    when they are both the oldest and the cheapest; an all-hot pool admits
    over capacity rather than dropping a shielded entry."""
    import types

    from repro.serve.server import _Pooled

    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t, pool_capacity=3)
    costs = {"d1": 0.0, "d2": 1.0, "d3": 4.0, "d4": 2.0, "d5": 3.0}
    for d, c in costs.items():
        server._pool[d] = _Pooled(None, None, cost=c, hot=(d == "d1"))
    server._absorb_prefetch(types.SimpleNamespace(_prefetched={}))
    # d1 is oldest AND cheapest, but hot → kept; d2 (cost 1) and d4 (cost 2)
    # are the two cheapest cold entries → evicted
    assert set(server._pool) == {"d1", "d3", "d5"}
    assert server.stats_.pool_evictions == 2
    for p in server._pool.values():
        p.hot = True
    server._pool["d6"] = _Pooled(None, None, cost=0.0, hot=True)
    server._absorb_prefetch(types.SimpleNamespace(_prefetched={}))
    assert len(server._pool) == 4  # over capacity: every entry is shielded
    assert server.stats_.pool_evictions == 2


def test_serve_counters_surface_in_cache_stats():
    spec = spec_for("sum")
    t = Treant(star_catalog(), use_plans=True)
    server = TreantServer(t, max_store_bytes=1 << 20)
    h = server.open_session(spec, name="s")
    h.submit(brush(0, 3))
    drain(server)
    st = t.cache_stats()["serve"]
    for key in (
        "queue_depth", "coalesced_events", "cross_session_batch_width",
        "store_evictions", "bytes_held", "bytes_pinned", "byte_budget",
        "sessions", "events_processed", "batches",
    ):
        assert key in st, key
    assert st["sessions"] == 1 and st["events_processed"] == 1
    assert st["byte_budget"] == 1 << 20
