"""A multi-viz crossfilter dashboard session over the Flight schema, driven
by typed interaction events with think-time calibration between them
(paper §4.2.1; Mosaic-style linked selection).

Four linked vizzes share one engine/message store: brushing the carrier bar
chart fans a SetFilter out to the other three, whose re-renders reuse each
other's materialized messages.  ``Session.idle`` spends simulated user
think-time on the shared scheduler, so the next brush is a few dimension-bag
absorptions instead of full message passing.

    PYTHONPATH=src python examples/dashboard_session.py
"""

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.baselines import NaiveExecutor  # noqa: E402
from repro.core import (  # noqa: E402
    DashboardSpec, Drill, SetFilter, Treant, Undo, VizSpec, jt_from_catalog,
)
from repro.core import semiring as sr  # noqa: E402
from repro.relational import schema  # noqa: E402


def main():
    cat = schema.flight(n_flights=100_000)
    jt = jt_from_catalog(cat)
    treant = Treant(cat, ring=sr.SUM, jt=jt)
    naive = NaiveExecutor(cat, "Flights")

    spec = DashboardSpec(vizzes=(
        VizSpec("delay_map", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("airport_state",)),
        VizSpec("monthly", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("month",)),
        VizSpec("by_size", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("airport_size",)),
        VizSpec("carrier_bar", measure=("Flights", "dep_delay"), ring="sum",
                group_by=("carrier_group",)),
    ))
    t0 = time.perf_counter()
    sess = treant.open_session(spec, name="anna")
    print(f"[offline] calibrated 4 linked vizzes in {time.perf_counter()-t0:.2f}s")

    events = [
        ("brush carriers 0-1", SetFilter("carrier_group", values=(0, 1),
                                         source="carrier_bar")),
        ("re-brush carriers 2-3", SetFilter("carrier_group", values=(2, 3),
                                            source="carrier_bar")),
        ("brush big airports", SetFilter("airport_size", values=(2, 3),
                                         source="by_size")),
        ("drill monthly by dow", Drill("monthly", "dow")),
        ("undo the drill", Undo()),
    ]
    for label, ev in events:
        res = sess.apply(ev)
        t_naive = 0.0
        ok = True
        for viz in res.affected:
            q = sess.query_of(viz)
            t1 = time.perf_counter()
            r_naive = naive.execute(q)
            t_naive += time.perf_counter() - t1
            ok &= np.allclose(np.asarray(res.results[viz].factor.field).ravel().sum(),
                              np.asarray(r_naive).sum(), rtol=1e-3)
        print(f"[online] {label:22s} {len(res.affected)} vizzes re-rendered "
              f"naive={t_naive*1e3:7.1f}ms treant={res.latency_s*1e3:6.1f}ms "
              f"({t_naive/max(res.latency_s,1e-9):5.0f}x) match={ok}")
        # user thinks; the scheduler calibrates the affected vizzes' CJTs
        n = sess.idle(budget_seconds=2.0)
        print(f"         think-time: {n} messages calibrated "
              f"(pending={sess.stats()['pending_calibrations']})")
    print("[session]", sess.stats())
    print("[cache]", treant.cache_stats())


if __name__ == "__main__":
    main()
