"""A simulated multi-user dashboard session over the Flight schema, with
think-time calibration between interactions (paper §4.2.1, Example 14) and a
live Naive-vs-Treant latency comparison.

    PYTHONPATH=src python examples/dashboard_session.py
"""

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.baselines import NaiveExecutor  # noqa: E402
from repro.core import Query, Treant, jt_from_catalog  # noqa: E402
from repro.core import semiring as sr  # noqa: E402
from repro.relational import schema  # noqa: E402
from repro.relational.relation import mask_in  # noqa: E402


def main():
    cat = schema.flight(n_flights=100_000)
    jt = jt_from_catalog(cat)
    treant = Treant(cat, ring=sr.SUM, jt=jt)
    naive = NaiveExecutor(cat, "Flights")
    d = cat.domains()

    q0 = Query.make(cat, ring="sum", measure=("Flights", "dep_delay"),
                    group_by=("airport_state",))
    t0 = time.perf_counter()
    treant.register_dashboard("delay_map", q0)
    print(f"[offline] calibrated dashboard in {time.perf_counter()-t0:.2f}s")

    session = [
        ("filter carriers 0-1", q0.with_predicate(
            mask_in(d["carrier_group"], [0, 1], attr="carrier_group"))),
        ("... and big airports", q0.with_predicate(
            mask_in(d["carrier_group"], [0, 1], attr="carrier_group"))
            .with_predicate(mask_in(d["airport_size"], [2, 3], attr="airport_size"))),
        ("... break out by month", q0.with_predicate(
            mask_in(d["carrier_group"], [0, 1], attr="carrier_group"))
            .with_predicate(mask_in(d["airport_size"], [2, 3], attr="airport_size"))
            .add_group_by("month")),
    ]
    for label, q in session:
        t0 = time.perf_counter()
        r_naive = naive.execute(q)
        t_naive = time.perf_counter() - t0
        res = treant.interact("anna", "delay_map", q)
        ok = np.allclose(np.asarray(res.factor.field).ravel().sum(),
                         np.asarray(r_naive).sum(), rtol=1e-3)
        print(f"[online] {label:24s} naive={t_naive*1e3:7.1f}ms "
              f"treant={res.latency_s*1e3:6.1f}ms "
              f"({t_naive/max(res.latency_s,1e-9):5.0f}x) match={ok}")
        # user thinks; Treant calibrates the current query in the background
        n = treant.think_time("anna", "delay_map", budget_seconds=2.0)
        print(f"         think-time: {n} messages calibrated")
    print("[cache]", treant.cache_stats())


if __name__ == "__main__":
    main()
