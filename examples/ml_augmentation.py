"""Factorized-ML augmentation (paper §4.3 / Fig 18): train a linear model
over the Favorita join, then evaluate 30 augmentation candidates at one
message each via the calibrated CJT.

    PYTHONPATH=src python examples/ml_augmentation.py
"""

import time

import numpy as np

from repro.core import FactorizedLinearRegression, FeatureSpec
from repro.relational import schema


def main():
    cat = schema.favorita(n_sales=60_000)
    model = FactorizedLinearRegression(
        cat,
        features=[
            FeatureSpec("Sales", "unit_sales"),
            FeatureSpec("Stores", "store_type", categorical=True),
            FeatureSpec("Items", "perishable", categorical=True),
        ],
        target=FeatureSpec("Trans", "transactions"),
    )
    base = model.fit()
    print(f"base model: R2={base.r2:.4f}")

    t0 = time.perf_counter()
    model.calibrate()
    print(f"calibration: {time.perf_counter()-t0:.2f}s "
          f"(≈2× one factorized training, per the paper)")

    augs = schema.favorita_augmentations(cat, n_per_key=10)
    t0 = time.perf_counter()
    results = []
    for a in augs:
        r = model.fit_augmented(a)
        phi = float(a.measures["phi"][0])
        results.append((r.r2 - base.r2, phi, a.name, r.stats.messages_computed))
    dt = time.perf_counter() - t0
    print(f"evaluated {len(augs)} augmentations in {dt:.2f}s "
          f"({dt/len(augs)*1e3:.0f}ms each)")
    results.sort(reverse=True)
    print("top 5 augmentations (ΔR², φ, name, messages computed):")
    for dr2, phi, name, msgs in results[:5]:
        print(f"  {dr2:+.4f}  φ={phi:.2f}  {name}  msgs={msgs}")


if __name__ == "__main__":
    main()
