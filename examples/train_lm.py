"""End-to-end training driver example.

Default: a fast CPU-sized run (~0.4M params, 50 steps) of the stablelm-12b
*family* (reduced config) with async checkpointing, failure injection at step
30, and recovery — the full fault-tolerance path.

The ~100M-parameter run from the brief (same driver, bigger preset):

    PYTHONPATH=src python examples/train_lm.py --hundred-m

    (≈ train --preset 100m --steps 300 --batch 4 --seq 512; takes a while
    on 1 CPU core; on a v5e slice this is seconds.)
"""

import sys

from repro.launch.train import main as train_main


def main():
    if "--hundred-m" in sys.argv:
        args = [
            "--arch", "stablelm-12b", "--preset", "100m",
            "--steps", "300", "--batch", "4", "--seq", "512",
            "--ckpt-every", "50", "--log-every", "10",
        ]
    else:
        args = [
            "--arch", "stablelm-12b", "--preset", "10m",
            "--steps", "50", "--batch", "4", "--seq", "128",
            "--ckpt-every", "20", "--inject-failure", "30",
            "--log-every", "5", "--telemetry-dashboard",
        ]
    losses = train_main(args)
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"example complete: loss {losses[0]:.3f} → {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
