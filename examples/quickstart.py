"""Quickstart: build a CJT over a star schema, run interaction queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Query, Treant, jt_from_catalog
from repro.core import semiring as sr
from repro.relational import schema
from repro.relational.relation import mask_in
from repro.relational.sql import parse


def main():
    # 1. the data engineer's offline stage: join graph + dashboard queries
    cat = schema.salesforce(n_opp=50_000)
    jt = jt_from_catalog(cat)
    print("join tree bags:", sorted(jt.bags))

    treant = Treant(cat, ring=sr.SUM, jt=jt)
    total = Query.make(cat, ring="sum", measure=("Opp", "amount"))
    pie = total.with_group_by("camp_type")
    treant.register_dashboard("pipeline_total", total)
    treant.register_dashboard("pipeline_by_campaign", pie)
    print("offline calibration done:", treant.cache_stats())

    # 2. the domain user's online stage: widgets → interaction queries
    d = cat.domains()
    q1 = pie.with_predicate(mask_in(d["role_name"], [1], attr="role_name",
                                    label="Role = Sales Associate"))
    res = treant.interact("anna", "pipeline_by_campaign", q1)
    print(f"filter by role: {res.latency_s*1e3:.1f}ms, "
          f"messages computed={res.stats.messages_computed} "
          f"reused={res.stats.messages_reused}")
    print("  pipeline by campaign type:", np.asarray(res.factor.field).round(0)[:5])

    # think-time: calibrate the latest query in the background
    n = treant.think_time("anna", "pipeline_by_campaign")
    print(f"think-time calibration materialized {n} messages")

    # 3. next interaction builds on the previous one — and on its CJT
    q2 = q1.add_group_by("title")
    res2 = treant.interact("anna", "pipeline_by_campaign", q2)
    print(f"add group-by title: {res2.latency_s*1e3:.1f}ms, "
          f"computed={res2.stats.messages_computed} reused={res2.stats.messages_reused}")

    # 4. the SQL face of the middleware
    q3 = parse("SELECT camp_type, SUM(amount) FROM Opp WHERE state IN (1,2,3) "
               "GROUP BY camp_type", cat)
    res3 = treant.interact("anna", "pipeline_by_campaign", q3)
    print(f"SQL interaction: {res3.latency_s*1e3:.1f}ms  "
          f"result[:4]={np.asarray(res3.factor.field)[:4].round(0)}")


if __name__ == "__main__":
    main()
