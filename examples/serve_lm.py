"""Batched serving example: prefill + autoregressive decode with donated
caches, on a reduced zamba2 (hybrid SSM) config — the O(1)-state decode path
that long_500k exercises at scale.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


def main():
    serve_main([
        "--arch", "zamba2-1.2b", "--batch", "4",
        "--prompt-len", "64", "--gen", "32",
    ])


if __name__ == "__main__":
    main()
